//! Paper Table II: digit recognition across subarray sizes — images/step,
//! energy/image, area, execution time, NM.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, black_box, exhibit_header};
use xpoint_imc::report::table2::{table2_rows, table2_table, template_layer};
use xpoint_imc::runtime::artifact::artifacts_available;
use xpoint_imc::runtime::ArtifactStore;

fn main() {
    exhibit_header("Paper Table II — digit recognition evaluation (config 3, 10K images)");
    let layer = if artifacts_available() {
        ArtifactStore::open_default()
            .and_then(|s| s.single_layer())
            .unwrap_or_else(|_| template_layer())
    } else {
        println!("(artifacts missing — template weights; run `make artifacts` for trained ones)");
        template_layer()
    };
    let rows = table2_rows(&layer);
    print!("{}", table2_table(&rows).render());
    println!(
        "speedup largest vs smallest: {:.1}× (paper: ~17×)",
        rows[0].exec_time / rows[4].exec_time
    );

    println!();
    bench("table2 full evaluation (5 designs)", || {
        black_box(table2_rows(&layer));
    });
}

//! # xpoint-imc — 3D XPoint as an in-memory computing accelerator
//!
//! A device/circuit/architecture simulator stack reproducing
//! *"Exploring the Feasibility of Using 3D XPoint as an In-Memory Computing
//! Accelerator"* (Zabihi et al., 2021).
//!
//! ## Front door: the engine
//!
//! Inference is served through one declarative configuration → engine API,
//! regardless of model fidelity:
//!
//! ```no_run
//! use xpoint_imc::engine::{BackendKind, EngineSpec, NetworkSource};
//!
//! let spec = EngineSpec::new(BackendKind::Ideal).with_network(NetworkSource::Template);
//! let mut engine = spec.build_engine()?;              // Box<dyn Engine>
//! let result = engine.infer_batch(&[vec![false; 121]])?;
//! println!("class {} in {} J", result.classes[0], engine.telemetry().energy);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The same [`engine::EngineSpec`] is constructible from CLI flags
//! (`xpoint serve --fabric --grid 4`) and from JSON (`--engine spec.json`),
//! and [`engine::EngineSpec::build`] is the only construction path for
//! every backend — the coordinator, the exhibits, the benches and the
//! examples all go through it.
//!
//! ## Choosing a backend
//!
//! | [`engine::BackendKind`] | model | when to use |
//! |---|---|---|
//! | `Ideal` | one subarray, exact Eq. 3 TMVM, no wire parasitics | functional work, fastest simulation, paper Table II accounting |
//! | `Parasitic` | one subarray + the Appendix-A Thevenin ladder | electrical fidelity: attenuation, noise-margin-limited behavior |
//! | `Fabric` | event-driven grid of subarrays, tiled + pipelined | multi-layer networks, scaling studies, utilization/interlink traffic |
//! | `Xla` | AOT-compiled JAX/Pallas graph on PJRT (needs `make artifacts`) | golden-model verification, host-speed inference |
//! | `Sharded` | N shards of any kind above, each on its own thread behind an async least-loaded scheduler | serving throughput: scale one engine to many arrays (`--shards N`); elastic with `--autoscale min,max` |
//! | `Remote` | one shard's worth of fabric served by an `xpoint shard-host` process behind a TCP or Unix socket | multi-host serving: `--remote host:port\|unix:/path`; mixes with local shards into one fleet (`--shards N --remote …`) |
//!
//! All six present the same [`engine::Engine`] trait: batched inference,
//! [`engine::Capabilities`] introspection, typed [`engine::Telemetry`]
//! (energy/time/steps/utilization) and a non-blocking `submit`/`poll`
//! pair — genuinely asynchronous for `Sharded` (tickets complete out of
//! order on shard threads), synchronous-at-submit for the rest. Simulated
//! kinds are bit-exact with each other's functional semantics (pinned by
//! the engine equivalence and sharding integration tests), and a sharded
//! engine is bit-exact with a single engine of its inner spec while its
//! energy/time telemetry sums across shards.
//!
//! ## Live weight reprogramming
//!
//! Every simulated kind can also **swap its network in place** —
//! [`engine::Engine::swap_network`] (blocking) or the non-blocking
//! `begin_swap`/`poll_swap` pair — returning a typed
//! [`engine::SwapReport`] (SET/RESET pulse counts, programming time and
//! energy from the [`device::ReprogramPlan`] diff; the fabric adds
//! spine/interlink weight-distribution traffic). A `Sharded` engine rolls
//! the swap: each shard walks `Serving → Draining → Reprogramming →
//! Rejoining` ([`engine::ShardState`]) one at a time while the least-loaded
//! dispatcher routes around it, so with ≥2 shards throughput never hits
//! zero and every completion is wholly-old or wholly-new — never a torn
//! mix (pinned by the `integration_reprogram` soak harness). The serving
//! shell drives it with `xpoint serve --swap-to <network>` and the
//! `xpoint reprogram` exhibit shows the drain/reprogram timeline. The XLA
//! golden model cannot swap (its weights are baked into the AOT graph) and
//! fails with the typed [`engine::EngineError::SwapUnsupported`].
//!
//! ## Shard autoscaling
//!
//! A `Sharded` engine built from an [`engine::AutoscaleSpec`]
//! (`--autoscale min,max`, builder, or the JSON `autoscale` section) is
//! **elastic**: the coordinator's scheduler evaluates an
//! [`coordinator::AutoscalePolicy`] (queue-depth watermarks, cooldown)
//! against the engine's live load every pass, and the fleet walks
//!
//! ```text
//!           retire                           spawn (parked slot)
//! Serving ─────────▶ Draining ─▶ Parked ─────────▶ Programming ─▶ Rejoining ─▶ Serving
//!                    (tickets     (cells + wear     (delta back to the
//!                     redeemable)  history kept)     resident network)
//!                                    └─ every slot worn/vetoed? a fresh slot instead:
//!                                       Spawning ─▶ Rejoining ─▶ Serving
//!                                       (full weight image into blank cells)
//! ```
//!
//! Capacity decisions price endurance: every programming pulse (deploy,
//! swap, spawn) accrues per-slot wear ([`engine::Telemetry`]'s
//! `wear_pulses`), and a slot whose pulse-endurance budget would be
//! exceeded is **vetoed** — never selected for spawn. Scale events, wear
//! and programming costs land in [`coordinator::MetricsSnapshot`]; the
//! `xpoint autoscale` exhibit replays a bursty trace (with `--json`
//! output for CI diffing), and `serve --autoscale min,max` runs it live.
//!
//! ## Layer map (bottom-up)
//!
//! * [`util`] / [`testing`] — self-contained substrates (PRNG, stats, table
//!   rendering, CSV/JSON I/O, a mini property-testing framework). The
//!   build is fully offline, so these replace `rand`, `serde`, `criterion`
//!   and `proptest`.
//! * [`device`] — PCM + OTS compact models (paper Fig. 2, Table IV): state,
//!   partial crystallization, SET/RESET pulse dynamics, and the
//!   [`device::ReprogramPlan`] per-cell rewrite cost model (the diff a
//!   live weight swap programs).
//! * [`circuit`] — a generic resistive-network substrate: netlist builder,
//!   modified-nodal-analysis solver (dense LU with a banded fast path), and
//!   numeric Thevenin extraction. Used to *validate* the paper's analytic
//!   parasitic model against full circuit simulation.
//! * [`interconnect`] — ASAP7 metal/via tables (Tables V–VI) and the three
//!   wire configurations of Table I.
//! * [`analysis`] — the paper's core contribution: the recursive
//!   `R_th`/`α_th` Thevenin model (Appendix A), the ideal voltage windows
//!   (Eqs. 4–5), the noise margin (Eq. 7), acceptable-region geometry,
//!   maximum-subarray-size search, and the seeded Monte Carlo
//!   variability engine ([`analysis::variability_sweep`]): lognormal
//!   conductance/driver corners over the array-size ladder, reporting
//!   noise-margin and digit-accuracy distributions per size (served as
//!   the byte-deterministic `xpoint montecarlo` exhibit).
//! * [`array`] — the 3D XPoint subarray state machine and the TMVM
//!   (thresholded matrix–vector multiply) engine, in both ideal (Eq. 3) and
//!   parasitic-aware modes, with energy/latency/area accounting and the two
//!   multi-bit schemes of Table III.
//! * [`scaling`] — inter-subarray links (BL-to-BL and BL-to-WLT, Fig. 6) and
//!   matrix tiling across subarrays.
//! * [`fabric`] — the multi-subarray fabric simulator: a discrete-event
//!   model of a grid of interconnected subarrays executing multi-layer
//!   networks tiled across the grid, with image-level pipelining,
//!   per-subarray occupancy, interlink traffic/latency and energy; tile
//!   placement is strategy-selectable ([`fabric::PlacementStrategy`]:
//!   round-robin or the locality-aware serpentine), tile steps run at a
//!   selectable electrical fidelity ([`fabric::Fidelity`]: ideal packed
//!   popcounts, or the parasitic per-cell Thevenin walk with per-tile
//!   noise-margin minima — pinned bit-exact against the scalar oracle by
//!   `tests/prop_parasitic.rs`), and
//!   [`fabric::FabricExecutor::reprogram`] rewrites the placed weights in
//!   place (program traffic over the same spine and write drivers).
//! * [`nn`] — the binary neural-network mapping (Figs. 4 and 8), the
//!   synthetic 11×11 digit workload, a conv2d-as-TMVM lowering, and
//!   [`nn::packed`] — the bit-packed hot-path currency: row-major `u64`
//!   lanes ([`nn::BitMatrix`]/[`nn::BitVec`], tail bits always masked),
//!   `XOR/AND + count_ones` forward kernels
//!   ([`nn::PackedLayer`]/[`nn::PackedMlp`]) and the `Arc`-shared
//!   [`nn::PackedBatch`] the batching/dispatch layers move instead of
//!   cloning `Vec<Vec<bool>>`. The scalar kernels stay as the reference
//!   oracle, pinned bit-exact by `tests/prop_packed.rs`; the subarray's
//!   ideal-mode TMVM and the fabric's tile step take the packed popcount
//!   fast path, while parasitic mode keeps the per-cell electrical walk.
//! * [`runtime`] — PJRT client wrapper (via the `xla` crate) that loads the
//!   AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and serves as
//!   the functional golden model on the rust side.
//! * [`engine`] — **the public serving API**: [`engine::EngineSpec`]
//!   (declarative config: code / CLI / JSON, including the `swap_to`
//!   reprogramming section), the [`engine::Engine`] trait (inference +
//!   capabilities + telemetry + submit/poll + the
//!   swap_network/begin_swap/poll_swap reprogramming surface), the typed
//!   [`engine::EngineError`], the concrete backends
//!   ([`engine::SimBackend`], [`engine::FabricBackend`],
//!   [`engine::XlaBackend`]) and the asynchronous
//!   [`engine::ShardedEngine`] (N shards, least-loaded dispatch,
//!   out-of-order completion, rolling weight swaps through the
//!   [`engine::ShardState`] lifecycle, elastic spawn/retire with
//!   pulse-endurance wear budgets when built from an
//!   [`engine::AutoscaleSpec`], and a parasitic-fidelity **canary**
//!   slot (`--shards N --canary F`) that mirrors a deterministic sample
//!   of live traffic and reports ideal-vs-parasitic divergence through
//!   [`engine::CanaryReport`]) behind the
//!   [`engine::EngineSpec::build`] registry.
//! * [`net`] — multi-host serving: a length-prefixed, versioned wire
//!   protocol ([`net::Msg`]) for everything that drives a shard
//!   (inference, live swaps, telemetry, shutdown), the `xpoint
//!   shard-host` socket server ([`net::Listener`], [`net::serve_factory`])
//!   and [`net::RemoteBackend`] — an [`engine::Engine`] whose substrate
//!   lives behind a socket, with connect/io timeouts, typed
//!   [`engine::EngineError::Remote`] failures and a `healthy()` signal
//!   the sharded scheduler uses to route around a dead host.
//! * [`coordinator`] — the L3 serving shell: request batching plus one
//!   scheduler thread per engine, driving it purely through the
//!   non-blocking `submit`/`poll` pair (spawned from
//!   [`engine::BackendFactory`]) without ever spinning a host core
//!   (idle waits park on the engine's completion channel), with
//!   per-shard telemetry in the metrics, rolling live weight updates
//!   ([`coordinator::Coordinator::swap_network`]), the
//!   [`coordinator::AutoscalePolicy`] evaluated live in the scheduler
//!   loop — spawns, retires, vetoes and wear all land in the metrics
//!   snapshot — and [`coordinator::TrafficTrace`]: seeded offered-load
//!   traces (uniform / bursty / diurnal / multi-tenant, plus JSON
//!   record/replay) that `serve --trace` and the autoscale exhibit
//!   replay deterministically.
//! * [`report`] — each paper exhibit (Fig. 10/11/13, Tables I–III, fabric
//!   scaling, sharded serving, live reprogramming, shard autoscaling) as
//!   a library function returning structured rows, shared by benches,
//!   examples and the CLI.
//!
//! See `examples/quickstart.rs` for a runnable end-to-end tour. For the
//! operator's view of the same machinery there are two manuals:
//! `docs/WORKLOADS.md` (every `--network` workload with runnable
//! commands, the im2col conv lowering and the multibit cost model) and
//! `docs/OPERATIONS.md` (shards, remote fleets, rolling swaps,
//! autoscaling watermarks, canary triage and the `TrafficTrace` JSON
//! schema).

pub mod util;
pub mod testing;
pub mod device;
pub mod circuit;
pub mod interconnect;
pub mod analysis;
pub mod array;
pub mod scaling;
pub mod fabric;
pub mod nn;
pub mod runtime;
pub mod engine;
pub mod net;
pub mod coordinator;
pub mod report;
pub mod cli;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

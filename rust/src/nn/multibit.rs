//! N-ary (multi-bit) inference as a functional model plus its exact
//! lowering onto the binary TMVM substrate.
//!
//! A [`MultibitLayer`] holds integer weights `w ∈ 0..=2^b−1` and fires
//! neuron `i` when `Σ_j w_ij·x_j ≥ θ`. The serving substrate only knows
//! binary cells, so the layer lowers the low-power way (paper Fig. 7(b)):
//! each logical input is replicated into `2^b − 1` adjacent columns and a
//! weight of `w` stores `w` crystalline cells in that column group — the
//! binary popcount of the lowered row then *equals* the integer dot
//! product, making the lowering bit-exact against the scalar oracle
//! ([`MultibitLayer::forward`]), which `tests` pin property-style.
//!
//! The energy/area price of running N-ary dot products on the array is a
//! separate concern, modeled by
//! [`multibit_tmvm_cost`](crate::array::multibit::multibit_tmvm_cost) and
//! folded into serving telemetry by the engine layer.

use super::layer::BinaryLayer;

/// A single N-ary layer: integer weights, thresholded integer dot product.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MultibitLayer {
    /// `weights[i][j] ∈ 0..=max_weight(bits)`.
    pub weights: Vec<Vec<u32>>,
    /// Firing threshold on the integer dot product.
    pub theta: usize,
    /// Weight resolution in bits (`b ≥ 1`).
    pub bits: usize,
}

impl MultibitLayer {
    /// Largest representable weight at `bits` resolution: `2^b − 1`.
    pub fn max_weight(bits: usize) -> u32 {
        assert!((1..=16).contains(&bits), "weight resolution out of range");
        (1u32 << bits) - 1
    }

    pub fn new(weights: Vec<Vec<u32>>, theta: usize, bits: usize) -> Self {
        let max = Self::max_weight(bits);
        assert!(!weights.is_empty() && !weights[0].is_empty());
        assert!(weights.iter().all(|row| row.len() == weights[0].len()));
        assert!(
            weights.iter().flatten().all(|&w| w <= max),
            "weight exceeds {bits}-bit range"
        );
        Self {
            weights,
            theta,
            bits,
        }
    }

    /// Full-scale quantization of a binary layer: every stored bit becomes
    /// the largest `bits`-bit weight and the threshold scales to match, so
    /// the thresholded outputs (and the count-space argmax) are identical
    /// to the source layer's by construction.
    pub fn from_binary(layer: &BinaryLayer, bits: usize) -> Self {
        let m = Self::max_weight(bits);
        Self {
            weights: layer
                .weights
                .iter()
                .map(|row| row.iter().map(|&b| if b { m } else { 0 }).collect())
                .collect(),
            theta: layer.theta * m as usize,
            bits,
        }
    }

    pub fn n_in(&self) -> usize {
        self.weights[0].len()
    }

    pub fn n_out(&self) -> usize {
        self.weights.len()
    }

    /// Cells each logical weight occupies in the low-power lowering
    /// (`2^b − 1` unary copies).
    pub fn copies(&self) -> usize {
        Self::max_weight(self.bits) as usize
    }

    /// Scalar oracle: `out[i] = Σ_j w_ij·x_j ≥ θ`.
    pub fn forward(&self, x: &[bool]) -> Vec<bool> {
        assert_eq!(x.len(), self.n_in());
        self.weights
            .iter()
            .map(|row| {
                let acc: usize = row
                    .iter()
                    .zip(x)
                    .map(|(&w, &b)| if b { w as usize } else { 0 })
                    .sum();
                acc >= self.theta
            })
            .collect()
    }

    /// Integer count-space argmax (first-max-wins, matching
    /// [`BinaryLayer::argmax`] tie-breaking).
    pub fn argmax(&self, x: &[bool]) -> usize {
        assert_eq!(x.len(), self.n_in());
        let counts: Vec<usize> = self
            .weights
            .iter()
            .map(|row| {
                row.iter()
                    .zip(x)
                    .map(|(&w, &b)| if b { w as usize } else { 0 })
                    .sum()
            })
            .collect();
        super::layer::argmax_counts(&counts)
    }

    /// The input a lowered layer consumes: each logical pixel replicated
    /// into its `2^b − 1` unary copies, in column-group order.
    pub fn expand_input(&self, x: &[bool]) -> Vec<bool> {
        assert_eq!(x.len(), self.n_in());
        expand_unary(x, self.copies())
    }

    /// Lower onto the binary substrate (Fig. 7(b) replication): over the
    /// expanded input of `n_in · (2^b − 1)` columns, row `i` stores `w_ij`
    /// crystalline cells in input `j`'s column group. The popcount of the
    /// lowered row against [`expand_input`](Self::expand_input) equals the
    /// integer dot product exactly, so thresholds (and θ) carry unchanged.
    pub fn lower_unary(&self) -> BinaryLayer {
        let copies = self.copies();
        let rows = self
            .weights
            .iter()
            .map(|row| {
                let mut bits = Vec::with_capacity(row.len() * copies);
                for &w in row {
                    for c in 0..copies {
                        bits.push((c as u32) < w);
                    }
                }
                bits
            })
            .collect();
        BinaryLayer::new(rows, self.theta)
    }
}

/// Replicate each element of `x` into `copies` adjacent positions — the
/// input-side half of the unary lowering (the serving shell applies this
/// to every submitted image when a multibit network is resident).
pub fn expand_unary(x: &[bool], copies: usize) -> Vec<bool> {
    assert!(copies >= 1);
    let mut out = Vec::with_capacity(x.len() * copies);
    for &b in x {
        for _ in 0..copies {
            out.push(b);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, bits: usize) -> MultibitLayer {
        let max = MultibitLayer::max_weight(bits) as usize;
        let weights: Vec<Vec<u32>> = (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.range(0, max + 1) as u32).collect())
            .collect();
        // a threshold somewhere inside the reachable dot-product range
        let theta = rng.range(1, n_in * max / 2 + 2);
        MultibitLayer::new(weights, theta, bits)
    }

    /// The tentpole contract: the unary lowering is bit-exact against the
    /// scalar N-ary oracle for arbitrary weights, inputs and resolutions.
    #[test]
    fn unary_lowering_matches_the_scalar_oracle() {
        let mut rng = Pcg32::seeded(0x0b17);
        for _ in 0..60 {
            let bits = rng.range(1, 7);
            let n_in = rng.range(1, 24);
            let n_out = rng.range(1, 8);
            let layer = random_layer(&mut rng, n_out, n_in, bits);
            let lowered = layer.lower_unary();
            assert_eq!(lowered.n_in(), n_in * layer.copies());
            assert_eq!(lowered.n_out(), n_out);
            for _ in 0..8 {
                let x: Vec<bool> = (0..n_in).map(|_| rng.bernoulli(0.5)).collect();
                let expanded = layer.expand_input(&x);
                assert_eq!(
                    lowered.forward(&expanded),
                    layer.forward(&x),
                    "bits={bits} n_in={n_in} n_out={n_out}"
                );
                assert_eq!(lowered.argmax(&expanded), layer.argmax(&x));
            }
        }
    }

    /// Full-scale quantization preserves every decision of the source
    /// binary layer: `M·count ≥ M·θ ⇔ count ≥ θ`, and count-space argmax
    /// is scale-invariant.
    #[test]
    fn full_scale_quantization_is_decision_equivalent() {
        let mut rng = Pcg32::seeded(0x0b18);
        for bits in 1..=4 {
            let weights: Vec<Vec<bool>> = (0..6)
                .map(|_| (0..17).map(|_| rng.bernoulli(0.5)).collect())
                .collect();
            let binary = BinaryLayer::new(weights, 4);
            let multibit = MultibitLayer::from_binary(&binary, bits);
            for _ in 0..12 {
                let x: Vec<bool> = (0..17).map(|_| rng.bernoulli(0.4)).collect();
                assert_eq!(multibit.forward(&x), binary.forward(&x), "bits={bits}");
                assert_eq!(multibit.argmax(&x), binary.argmax(&x), "bits={bits}");
                // and the lowered form agrees end to end over expanded input
                let lowered = multibit.lower_unary();
                assert_eq!(
                    lowered.forward(&multibit.expand_input(&x)),
                    binary.forward(&x)
                );
            }
        }
    }

    #[test]
    fn expand_unary_replicates_in_group_order() {
        assert_eq!(
            expand_unary(&[true, false], 3),
            vec![true, true, true, false, false, false]
        );
        assert_eq!(expand_unary(&[true], 1), vec![true]);
    }

    #[test]
    fn one_bit_lowering_is_the_identity() {
        let mut rng = Pcg32::seeded(0x0b19);
        let layer = random_layer(&mut rng, 4, 9, 1);
        let lowered = layer.lower_unary();
        assert_eq!(lowered.n_in(), 9);
        let x: Vec<bool> = (0..9).map(|_| rng.bernoulli(0.5)).collect();
        assert_eq!(layer.expand_input(&x), x);
        assert_eq!(lowered.forward(&x), layer.forward(&x));
    }
}

//! Netlist builder: nodes, conductances, independent sources.

/// Node identifier. Node 0 is ground.
pub type NodeId = usize;

/// The ground node.
pub const GROUND: NodeId = 0;

/// A two-terminal conductance element.
#[derive(Clone, Copy, Debug)]
pub struct Conductance {
    pub a: NodeId,
    pub b: NodeId,
    pub g: f64,
}

/// An independent current source pushing `i` amps from `from` into `to`.
#[derive(Clone, Copy, Debug)]
pub struct CurrentSource {
    pub from: NodeId,
    pub to: NodeId,
    pub i: f64,
}

/// An independent voltage source fixing `v(pos) - v(neg) = v`.
#[derive(Clone, Copy, Debug)]
pub struct VoltageSource {
    pub pos: NodeId,
    pub neg: NodeId,
    pub v: f64,
}

/// A resistive network with independent sources, solved by MNA.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    n_nodes: usize,
    pub(crate) conductances: Vec<Conductance>,
    pub(crate) isources: Vec<CurrentSource>,
    pub(crate) vsources: Vec<VoltageSource>,
    labels: Vec<(String, NodeId)>,
}

impl Netlist {
    /// New netlist containing only the ground node.
    pub fn new() -> Self {
        Self {
            n_nodes: 1,
            ..Default::default()
        }
    }

    /// Allocate a fresh node.
    pub fn node(&mut self) -> NodeId {
        let id = self.n_nodes;
        self.n_nodes += 1;
        id
    }

    /// Allocate a fresh labelled node (debugging aid).
    pub fn labelled_node(&mut self, label: &str) -> NodeId {
        let id = self.node();
        self.labels.push((label.to_string(), id));
        id
    }

    /// Look up a node by label.
    pub fn find(&self, label: &str) -> Option<NodeId> {
        self.labels
            .iter()
            .find(|(l, _)| l == label)
            .map(|&(_, id)| id)
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn n_vsources(&self) -> usize {
        self.vsources.len()
    }

    /// All conductance elements (inspection / KCL checks in tests).
    pub fn conductance_elements(&self) -> &[Conductance] {
        &self.conductances
    }

    /// Add a conductance `g` (siemens) between nodes `a` and `b`.
    /// Zero conductances are dropped (open circuit).
    pub fn conductance(&mut self, a: NodeId, b: NodeId, g: f64) {
        assert!(a < self.n_nodes && b < self.n_nodes, "unknown node");
        assert!(g.is_finite() && g >= 0.0, "conductance must be >= 0, got {g}");
        if g > 0.0 && a != b {
            self.conductances.push(Conductance { a, b, g });
        }
    }

    /// Add a resistor by resistance value (ohms).
    pub fn resistor(&mut self, a: NodeId, b: NodeId, r: f64) {
        assert!(r > 0.0, "resistance must be positive, got {r}");
        self.conductance(a, b, 1.0 / r);
    }

    /// Add an independent current source (`i` amps flowing `from` → `to`).
    pub fn current_source(&mut self, from: NodeId, to: NodeId, i: f64) {
        assert!(from < self.n_nodes && to < self.n_nodes);
        self.isources.push(CurrentSource { from, to, i });
    }

    /// Add an independent voltage source `v(pos) − v(neg) = v`. Returns the
    /// source index (its branch current appears in the solution).
    pub fn voltage_source(&mut self, pos: NodeId, neg: NodeId, v: f64) -> usize {
        assert!(pos < self.n_nodes && neg < self.n_nodes);
        self.vsources.push(VoltageSource { pos, neg, v });
        self.vsources.len() - 1
    }

    /// A copy of this netlist with all independent sources zeroed (voltage
    /// sources → shorts via 0 V, current sources → removed). Used for
    /// Thevenin resistance extraction.
    pub fn dead_network(&self) -> Netlist {
        let mut out = self.clone();
        out.isources.clear();
        for vs in &mut out.vsources {
            vs.v = 0.0;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_allocation_monotone() {
        let mut n = Netlist::new();
        let a = n.node();
        let b = n.node();
        assert_eq!((a, b), (1, 2));
        assert_eq!(n.n_nodes(), 3);
    }

    #[test]
    fn zero_conductance_dropped() {
        let mut n = Netlist::new();
        let a = n.node();
        n.conductance(GROUND, a, 0.0);
        assert!(n.conductances.is_empty());
    }

    #[test]
    #[should_panic(expected = "must be >= 0")]
    fn negative_conductance_rejected() {
        let mut n = Netlist::new();
        let a = n.node();
        n.conductance(GROUND, a, -1.0);
    }

    #[test]
    fn labels_resolve() {
        let mut n = Netlist::new();
        let a = n.labelled_node("driver");
        assert_eq!(n.find("driver"), Some(a));
        assert_eq!(n.find("nope"), None);
    }

    #[test]
    fn dead_network_zeroes_sources() {
        let mut n = Netlist::new();
        let a = n.node();
        n.voltage_source(a, GROUND, 5.0);
        n.current_source(GROUND, a, 1e-3);
        let dead = n.dead_network();
        assert!(dead.isources.is_empty());
        assert_eq!(dead.vsources[0].v, 0.0);
    }
}

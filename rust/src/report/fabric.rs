//! Fabric scaling exhibit (new, beyond the paper's single-subarray
//! tables): pipelined multi-layer inference throughput, subarray
//! utilization, interlink traffic and energy as a function of fabric size.
//!
//! The workload is a fixed three-layer binary network (121→64→32→10,
//! digit-sized input) tiled over 32×32-cell subarrays; only the fabric
//! grid varies, so the table isolates the effect of spreading the same
//! tile set over more subarrays — the §IV scalability story turned into a
//! throughput claim.

use crate::engine::{BackendKind, EngineSpec};
use crate::nn::BinaryLayer;
use crate::util::si::{format_duration, format_pct, format_si};
use crate::util::{Pcg32, Table};

/// Subarray tile dimensions used by the exhibit.
pub const FABRIC_TILE: (usize, usize) = (32, 32);

/// Default fabric grids swept by the exhibit.
pub const FABRIC_GRIDS: [(usize, usize); 5] = [(1, 1), (1, 2), (2, 2), (3, 3), (4, 4)];

/// One evaluated fabric size.
#[derive(Clone, Debug)]
pub struct FabricScalingRow {
    pub grid_rows: usize,
    pub grid_cols: usize,
    pub nodes: usize,
    pub tiles: usize,
    pub batch: usize,
    /// Simulated end-to-end batch time \[s\].
    pub makespan: f64,
    /// Makespan in computational-step quanta.
    pub cycles: u64,
    /// Simulated throughput \[images/s\].
    pub throughput: f64,
    /// Mean / peak subarray busy fraction.
    pub mean_util: f64,
    pub max_util: f64,
    /// Interlink hop-transfers and line-hops (per-hop traffic sums).
    pub transfers: u64,
    pub lines: u64,
    /// Total energy per image \[J\].
    pub energy_per_image: f64,
}

/// The fixed three-layer exhibit workload (deterministic weights).
pub fn fabric_workload() -> Vec<BinaryLayer> {
    let mut rng = Pcg32::seeded(0xfab);
    let mut layer = |n_out: usize, n_in: usize, theta: usize| {
        BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.35)).collect())
                .collect(),
            theta,
        )
    };
    vec![layer(64, 121, 12), layer(32, 64, 8), layer(10, 32, 4)]
}

/// Run the exhibit: the same workload and batch on each fabric grid, each
/// engine constructed through the declarative [`EngineSpec`] registry and
/// read back through the unified telemetry surface.
pub fn fabric_scaling_rows(
    grids: &[(usize, usize)],
    batch: usize,
) -> crate::Result<Vec<FabricScalingRow>> {
    let layers = fabric_workload();
    let mut rng = Pcg32::seeded(0x1112);
    let images: Vec<Vec<bool>> = (0..batch)
        .map(|_| (0..layers[0].n_in()).map(|_| rng.bernoulli(0.4)).collect())
        .collect();

    let mut rows = Vec::with_capacity(grids.len());
    for &(gr, gc) in grids {
        let spec = EngineSpec::new(BackendKind::Fabric)
            .with_layers(layers.clone())
            .with_grid(gr, gc)
            .with_tile(FABRIC_TILE.0, FABRIC_TILE.1)
            .with_fabric_max_batch(batch.max(1))
            .with_batching(batch.max(1), 200);
        let mut engine = spec.build_engine()?;
        let res = engine.infer_batch(&images)?;
        let tel = engine.telemetry();
        rows.push(FabricScalingRow {
            grid_rows: gr,
            grid_cols: gc,
            nodes: gr * gc,
            tiles: engine.capabilities().tiles,
            batch,
            makespan: res.sim_time,
            cycles: tel.cycles,
            throughput: if res.sim_time > 0.0 {
                batch as f64 / res.sim_time
            } else {
                0.0
            },
            mean_util: tel.mean_utilization(),
            max_util: tel.max_utilization(),
            transfers: tel.link_transfers,
            lines: tel.link_lines,
            energy_per_image: if batch > 0 {
                res.energy / batch as f64
            } else {
                0.0
            },
        });
    }
    Ok(rows)
}

/// Render the exhibit table.
pub fn fabric_scaling_table(rows: &[FabricScalingRow]) -> Table {
    let title = format!(
        "Fabric scaling — pipelined 3-layer inference, {}×{} subarrays, batch {}",
        FABRIC_TILE.0,
        FABRIC_TILE.1,
        rows.first().map_or(0, |r| r.batch)
    );
    let mut t = Table::new(&title).header(&[
        "Fabric",
        "Subarrays",
        "Tiles",
        "Makespan",
        "Cycles",
        "Throughput",
        "Util (mean/max)",
        "Link xfers",
        "Line-hops",
        "E/image",
    ]);
    for r in rows {
        t.row(&[
            format!("{}×{}", r.grid_rows, r.grid_cols),
            r.nodes.to_string(),
            r.tiles.to_string(),
            format_duration(r.makespan),
            r.cycles.to_string(),
            format!("{} img/s", format_si(r.throughput, "")),
            format!("{} / {}", format_pct(r.mean_util), format_pct(r.max_util)),
            r.transfers.to_string(),
            r.lines.to_string(),
            format_si(r.energy_per_image, "J"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_scales_with_fabric_size() {
        let rows = fabric_scaling_rows(&FABRIC_GRIDS, 32).unwrap();
        assert_eq!(rows.len(), 5);
        // same workload everywhere: tile count is constant
        assert!(rows.windows(2).all(|w| w[0].tiles == w[1].tiles));
        // more subarrays → strictly faster batch, until tiles spread out
        let t1 = rows.first().unwrap().throughput;
        let t16 = rows.last().unwrap().throughput;
        assert!(
            t16 > 2.0 * t1,
            "16 subarrays {t16:.0} img/s vs 1 subarray {t1:.0} img/s"
        );
        // makespans are monotonically non-increasing across the sweep
        assert!(rows.windows(2).all(|w| w[1].makespan <= w[0].makespan * 1.001));
        // single-node fabric moves nothing across grid interlinks
        assert_eq!(rows[0].transfers, 0);
        assert!(rows.last().unwrap().transfers > 0);
        // utilization is a valid fraction, higher when nodes are shared
        assert!(rows.iter().all(|r| r.mean_util > 0.0 && r.max_util <= 1.0));
        assert!(rows[0].mean_util > rows.last().unwrap().mean_util);
        // energy per image stays in the physical (sub-nJ) regime
        assert!(rows.iter().all(|r| r.energy_per_image > 1e-13 && r.energy_per_image < 2e-9));
    }

    #[test]
    fn table_renders_all_rows() {
        let rows = fabric_scaling_rows(&[(1, 1), (2, 2)], 8).unwrap();
        let t = fabric_scaling_table(&rows);
        assert_eq!(t.n_rows(), 2);
        let s = t.render();
        assert!(s.contains("1×1") && s.contains("2×2"), "{s}");
    }
}

//! Property tests pinning the packed hot path to the scalar reference
//! oracle: every packed kernel (`BitVec`/`BitMatrix` plumbing, the
//! `PackedLayer`/`PackedMlp` forward passes, the fabric tile step and the
//! subarray's ideal-mode TMVM fast path) must be bit-exact with the
//! per-cell scalar walk it replaced, for arbitrary shapes — including
//! widths that are not multiples of 64 and all-zero / all-one tail lanes.

use xpoint_imc::analysis::ArrayDesign;
use xpoint_imc::array::{Level, Subarray, TmvmMode};
use xpoint_imc::device::DeviceParams;
use xpoint_imc::fabric::{tile_step, tile_step_packed, vdd_for_theta};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::nn::packed::{tail_mask, words_for};
use xpoint_imc::nn::{BinaryLayer, BitMatrix, BitVec, PackedBatch, PackedLayer, PackedMlp};
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

/// Widths biased toward the u64 lane boundary: exact multiples of 64 and
/// their ±1 neighbours show up often, so the tail-lane masking is
/// exercised at every alignment.
fn arbitrary_width(rng: &mut Pcg32) -> usize {
    if rng.bernoulli(0.35) {
        *rng.choose(&[1, 2, 63, 64, 65, 127, 128, 129])
    } else {
        rng.range(1, 200)
    }
}

/// Bit rows with densities including the 0.0 / 1.0 extremes, so tail
/// lanes come out all-zero and all-one, not just mixed.
fn arbitrary_bits(rng: &mut Pcg32, n: usize) -> Vec<bool> {
    let p = *rng.choose(&[0.0, 0.15, 0.5, 0.85, 1.0]);
    (0..n).map(|_| rng.bernoulli(p)).collect()
}

fn tail_is_masked(words: &[u64], n_bits: usize) -> bool {
    match words.last() {
        Some(&w) => w & !tail_mask(n_bits) == 0,
        None => n_bits == 0,
    }
}

#[test]
fn bitvec_roundtrips_and_keeps_the_tail_invariant() {
    forall(
        Config::default().cases(400),
        "BitVec roundtrips through bools with a masked tail",
        |rng: &mut Pcg32| {
            let n = arbitrary_width(rng);
            let bits = arbitrary_bits(rng, n);
            let mut v = BitVec::from_bools(&bits);
            if v.len() != n || v.words().len() != words_for(n) {
                return Err(format!("shape: len {} words {}", v.len(), v.words().len()));
            }
            if !tail_is_masked(v.words(), n) {
                return Err(format!("tail lane has bits past width {n}"));
            }
            let ones = bits.iter().filter(|&&b| b).count() as u32;
            if v.count_ones() != ones {
                return Err(format!("count_ones {} != {ones}", v.count_ones()));
            }
            if v.to_bools() != bits {
                return Err("to_bools mismatch".into());
            }
            let i = rng.range(0, n);
            if v.get(i) != bits[i] {
                return Err(format!("get({i}) mismatch"));
            }
            // flipping one bit keeps the tail invariant and roundtrips
            v.set(i, !bits[i]);
            let mut flipped = bits.clone();
            flipped[i] = !bits[i];
            if v.to_bools() != flipped || !tail_is_masked(v.words(), n) {
                return Err(format!("set({i}) broke the representation"));
            }
            Ok(())
        },
    );
}

#[test]
fn bitmatrix_rows_are_bit_exact_views() {
    forall(
        Config::default().cases(250),
        "BitMatrix rows roundtrip and popcount like the bool rows",
        |rng: &mut Pcg32| {
            let n_rows = rng.range(1, 8);
            let n_cols = arbitrary_width(rng);
            let rows: Vec<Vec<bool>> = (0..n_rows).map(|_| arbitrary_bits(rng, n_cols)).collect();
            let m = BitMatrix::from_rows(&rows);
            if m.n_rows() != n_rows || m.n_cols() != n_cols {
                return Err("shape mismatch".into());
            }
            if m.to_rows() != rows {
                return Err("to_rows mismatch".into());
            }
            let x = arbitrary_bits(rng, n_cols);
            let xv = BitVec::from_bools(&x);
            for (r, row) in rows.iter().enumerate() {
                if !tail_is_masked(m.row(r), n_cols) {
                    return Err(format!("row {r} tail lane unmasked"));
                }
                if m.row_bools(r) != *row {
                    return Err(format!("row_bools({r}) mismatch"));
                }
                let ones = row.iter().filter(|&&b| b).count() as u32;
                if m.row_count_ones(r) != ones {
                    return Err(format!("row_count_ones({r}) != {ones}"));
                }
                let and = row.iter().zip(&x).filter(|(&w, &xi)| w && xi).count() as u32;
                if m.row_and_count(r, &xv) != and {
                    return Err(format!("row_and_count({r}) != {and}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn packed_layer_matches_the_scalar_oracle() {
    forall(
        Config::default().cases(300),
        "PackedLayer counts/forward/argmax == BinaryLayer",
        |rng: &mut Pcg32| {
            let n_out = rng.range(1, 12);
            let n_in = arbitrary_width(rng);
            let theta = rng.range(1, n_in + 1);
            let weights: Vec<Vec<bool>> = (0..n_out).map(|_| arbitrary_bits(rng, n_in)).collect();
            let layer = BinaryLayer::new(weights, theta);
            let packed = PackedLayer::from(&layer);
            let x = arbitrary_bits(rng, n_in);
            let xv = BitVec::from_bools(&x);
            let want = layer.counts(&x);
            if packed.counts(&xv) != want {
                return Err(format!("counts mismatch ({n_out}x{n_in}, theta {theta})"));
            }
            if packed.counts_words(xv.words()) != want {
                return Err("counts_words disagrees with counts".into());
            }
            if packed.forward(&xv).to_bools() != layer.forward(&x) {
                return Err(format!("forward mismatch ({n_out}x{n_in}, theta {theta})"));
            }
            if packed.argmax(&xv) != layer.argmax(&x)
                || packed.argmax_words(xv.words()) != layer.argmax(&x)
            {
                return Err("argmax mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn packed_mlp_chains_bit_exactly() {
    forall(
        Config::default().cases(150),
        "PackedMlp forward/final_counts == chained BinaryLayers",
        |rng: &mut Pcg32| {
            let n_in = arbitrary_width(rng);
            let hidden = rng.range(1, 40);
            let n_out = rng.range(1, 12);
            let l1 = BinaryLayer::new(
                (0..hidden).map(|_| arbitrary_bits(rng, n_in)).collect(),
                rng.range(1, n_in + 1),
            );
            let l2 = BinaryLayer::new(
                (0..n_out).map(|_| arbitrary_bits(rng, hidden)).collect(),
                rng.range(1, hidden + 1),
            );
            let x = arbitrary_bits(rng, n_in);
            let y1 = l1.forward(&x);
            let mlp = PackedMlp::from_layers(&[l1, l2.clone()]);
            if mlp.n_in() != n_in || mlp.n_out() != n_out {
                return Err("shape mismatch".into());
            }
            let xv = BitVec::from_bools(&x);
            if mlp.forward(&xv).to_bools() != l2.forward(&y1) {
                return Err(format!("forward mismatch ({n_in}->{hidden}->{n_out})"));
            }
            if mlp.final_counts(&xv) != l2.counts(&y1) {
                return Err("final_counts mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn packed_batch_views_share_one_buffer() {
    forall(
        Config::default().cases(200),
        "PackedBatch packs, slices and unpacks without copying bits",
        |rng: &mut Pcg32| {
            let n = rng.range(1, 10);
            let w = arbitrary_width(rng);
            let images: Vec<Vec<bool>> = (0..n).map(|_| arbitrary_bits(rng, w)).collect();
            let batch = match PackedBatch::from_images(&images) {
                Some(b) => b,
                None => return Err("uniform batch refused to pack".into()),
            };
            if batch.len() != n || batch.width() != w {
                return Err("shape mismatch".into());
            }
            if batch.to_images() != images {
                return Err("to_images mismatch".into());
            }
            let i = rng.range(0, n);
            if batch.image_bools(i) != images[i] {
                return Err(format!("image_bools({i}) mismatch"));
            }
            // a sub-view aliases the parent's lanes (Arc share, no copy)
            let lo = rng.range(0, n);
            let hi = rng.range(lo, n) + 1;
            let view = batch.slice(lo..hi);
            if view.to_images() != images[lo..hi] {
                return Err(format!("slice({lo}..{hi}) mismatch"));
            }
            if view.row_words(0).as_ptr() != batch.row_words(lo).as_ptr() {
                return Err("slice copied the buffer".into());
            }
            // ragged batches stay scalar (one row of a different width is
            // still uniform when it's the only row, so need n >= 2)
            if w >= 2 && n >= 2 {
                let mut ragged = images;
                ragged[n - 1].pop();
                if PackedBatch::from_images(&ragged).is_some() {
                    return Err("ragged batch must not pack".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tile_step_packed_is_bit_identical() {
    forall(
        Config::default().cases(250),
        "tile_step_packed == tile_step to the last f64 bit",
        |rng: &mut Pcg32| {
            let n_rows = rng.range(1, 12);
            let n_cols = arbitrary_width(rng);
            let weights: Vec<Vec<bool>> =
                (0..n_rows).map(|_| arbitrary_bits(rng, n_cols)).collect();
            let x = arbitrary_bits(rng, n_cols);
            let p = DeviceParams::default();
            let theta = rng.range(1, n_cols + 1);
            let v_dd = vdd_for_theta(theta, &p) * rng.range_f64(0.8, 1.2);
            let scalar = tile_step(&weights, &x, v_dd, &p);
            let packed = tile_step_packed(
                &BitMatrix::from_rows(&weights),
                &BitVec::from_bools(&x),
                v_dd,
                &p,
            );
            if packed.counts != scalar.counts || packed.active != scalar.active {
                return Err(format!("counts mismatch ({n_rows}x{n_cols})"));
            }
            if packed.current_sum.to_bits() != scalar.current_sum.to_bits() {
                return Err(format!(
                    "current_sum drifted: {:e} vs {:e}",
                    packed.current_sum, scalar.current_sum
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn ideal_tmvm_fast_path_matches_the_per_cell_walk() {
    forall(
        Config::default().cases(60),
        "Subarray::tmvm_rows (Ideal) == tmvm_rows_scalar oracle",
        |rng: &mut Pcg32| {
            let n_row = rng.range(2, 14);
            let n_col = rng.range(4, 80);
            let mut fast =
                Subarray::new(ArrayDesign::new(n_row, n_col, LineConfig::config3(), 3.0, 1.0));
            let mut oracle =
                Subarray::new(ArrayDesign::new(n_row, n_col, LineConfig::config3(), 3.0, 1.0));
            let grid: Vec<Vec<bool>> = (0..n_row).map(|_| arbitrary_bits(rng, n_col)).collect();
            fast.program_level(Level::Top, &grid);
            oracle.program_level(Level::Top, &grid);
            let x = arbitrary_bits(rng, n_col);
            let active_rows = rng.range(0, n_row + 1);
            let out_col = rng.range(0, n_col);
            let theta = rng.range(1, n_col + 1);
            // off-boundary voltage: outputs/outcomes must agree exactly,
            // currents to f64 rounding (the count-space sum reassociates)
            let v = fast.vdd_for_threshold(theta) * rng.range_f64(0.9, 1.25);
            let a = fast.tmvm_rows(&x, out_col, v, TmvmMode::Ideal, active_rows);
            let b = oracle.tmvm_rows_scalar(&x, out_col, v, TmvmMode::Ideal, active_rows);
            if a.outputs != b.outputs || a.outcomes != b.outcomes {
                return Err(format!(
                    "decision mismatch ({n_row}x{n_col}, active {active_rows}, theta {theta})"
                ));
            }
            for (row, (ia, ib)) in a.currents.iter().zip(&b.currents).enumerate() {
                if (ia - ib).abs() > 1e-12 * ib.abs() + 1e-18 {
                    return Err(format!("row {row} current {ia:e} vs {ib:e}"));
                }
            }
            if (a.energy - b.energy).abs() > 1e-9 * b.energy.abs() + 1e-24 {
                return Err(format!("energy {:e} vs {:e}", a.energy, b.energy));
            }
            for row in 0..n_row {
                let (fa, or) = (
                    fast.peek(Level::Bottom, row, out_col),
                    oracle.peek(Level::Bottom, row, out_col),
                );
                if fa != or {
                    return Err(format!("bottom-level bit differs at row {row}"));
                }
            }
            Ok(())
        },
    );
}

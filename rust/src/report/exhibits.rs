//! Figs. 10, 11, 13 and Tables I, III.

use crate::analysis::{
    ladder_thevenin, noise_margin, region_boundary_alpha, ArrayDesign,
};
use crate::array::{multibit_tmvm_cost, MultibitCost, MultibitScheme};
use crate::interconnect::{CellGeometry, LineConfig};
use crate::util::si::{format_pct, format_si};
use crate::util::Table;

// ------------------------------------------------------------------ Table I

/// Table I: the three metal-line configurations with the derived minimum
/// cell footprint.
pub fn table1_rows() -> Table {
    let fmt_layers = |ls: &[usize]| {
        ls.iter()
            .map(|k| format!("M{k}"))
            .collect::<Vec<_>>()
            .join(", ")
    };
    let mut t = Table::new("Table I — metal-line configurations (ASAP7)")
        .header(&["Config", "WLT", "WLB", "BL", "Wmin × Lmin"]);
    for cfg in LineConfig::all() {
        let (w, l) = cfg.min_cell();
        t.row(&[
            cfg.id.to_string(),
            fmt_layers(&cfg.wlt),
            fmt_layers(&cfg.wlb),
            fmt_layers(&cfg.bl),
            format!("{:.0}nm × {:.0}nm", w * 1e9, l * 1e9),
        ]);
    }
    t
}

// ----------------------------------------------------------------- Fig. 10

/// One point of the Fig. 10(b)/(c) series.
#[derive(Clone, Copy, Debug)]
pub struct Fig10Row {
    pub n_row: usize,
    pub r_th: f64,
    pub alpha: f64,
}

/// Fig. 10(b)+(c): `R_th` and `α_th` at the last row vs `N_row`
/// (configuration 1, `N_col = 128`, `L = 4·L_min`, `W = W_min`).
///
/// The output-loading assumption matters (Appendix A keeps `G_{O_i}`
/// symbolic): with outputs still in the **preset** (amorphous) state the
/// row branches barely load the line and `R_th` accumulates wire
/// resistance, growing with `N_row` like the paper's Fig. 10(b); at the
/// crystalline endpoint the conducting branches clamp `R_th` while `α_th`
/// collapses instead. `fig10_series` reports the preset case; the bench
/// prints both as an ablation.
pub fn fig10_series(n_rows: &[usize], r_driver: f64) -> Vec<Fig10Row> {
    fig10_series_loaded(n_rows, r_driver, crate::analysis::OutputLoading::Preset)
}

/// See [`fig10_series`].
pub fn fig10_series_loaded(
    n_rows: &[usize],
    r_driver: f64,
    loading: crate::analysis::OutputLoading,
) -> Vec<Fig10Row> {
    n_rows
        .iter()
        .map(|&n| {
            let d = ArrayDesign::new(n, 128, LineConfig::config1(), 4.0, 1.0)
                .with_driver(r_driver)
                .with_loading(loading);
            let th = ladder_thevenin(&d, n);
            Fig10Row {
                n_row: n,
                r_th: th.r_th,
                alpha: th.alpha,
            }
        })
        .collect()
}

// ----------------------------------------------------------------- Fig. 11

/// Fig. 11 data: first/last-row voltage windows and the NM = 0 separating
/// line in the `(α_th, R_th)` plane.
#[derive(Clone, Debug)]
pub struct Fig11Data {
    pub design: String,
    pub v_min_first: f64,
    pub v_max_first: f64,
    pub v_min_last: f64,
    pub v_max_last: f64,
    pub window: Option<(f64, f64)>,
    pub nm: f64,
    /// `(r_th, α_boundary)` samples of the separating line.
    pub boundary: Vec<(f64, f64)>,
}

/// Fig. 11(a)+(b) for a given design.
pub fn fig11_regions(design: &ArrayDesign, r_th_samples: &[f64]) -> Fig11Data {
    let nm = noise_margin(design);
    let window = if nm.v_lo() <= nm.v_hi() {
        Some((nm.v_lo(), nm.v_hi()))
    } else {
        None
    };
    Fig11Data {
        design: format!(
            "config {} {}×{}",
            design.config.id, design.n_row, design.n_col
        ),
        v_min_first: nm.v_min_first,
        v_max_first: nm.v_max_first,
        v_min_last: nm.v_min_last,
        v_max_last: nm.v_max_last,
        window,
        nm: nm.noise_margin(),
        boundary: r_th_samples
            .iter()
            .map(|&r| (r, region_boundary_alpha(design, r)))
            .collect(),
    }
}

// ----------------------------------------------------------------- Fig. 13

/// One NM-sweep series (one line of a Fig. 13 panel).
#[derive(Clone, Debug)]
pub struct Fig13Series {
    pub config: u8,
    /// (x value, NM) points; x is panel-specific.
    pub points: Vec<(f64, f64)>,
}

/// The four Fig. 13 panels. Fixed parameters follow the paper's captions:
/// (a) NM vs `N_row`   — `N_col=128, L=4L_min, W=W_min`
/// (b) NM vs `L_cell`  — `N_col=N_row=128, W=W_min` (x = L/L_min)
/// (c) NM vs `W_cell`  — `N_col=128, N_row=64, L=4L_min` (x = W/W_min)
/// (d) NM vs `N_col`   — `N_row=256, L=4L_min, W=W_min` (span fixed at the
///     11×11 workload's 121 columns; see DESIGN.md §6 for why this is the
///     reading under which the paper's "flat in N_column" holds)
pub fn fig13_sweeps(panel: char) -> Vec<Fig13Series> {
    LineConfig::all()
        .into_iter()
        .map(|cfg| {
            let id = cfg.id;
            let points = match panel {
                'a' => [64usize, 128, 256, 512, 1024, 2048]
                    .iter()
                    .map(|&n| {
                        let d = ArrayDesign::new(n, 128, cfg.clone(), 4.0, 1.0);
                        (n as f64, noise_margin(&d).noise_margin())
                    })
                    .collect(),
                'b' => [1.0, 2.0, 3.0, 4.0, 6.0, 8.0]
                    .iter()
                    .map(|&ls| {
                        let d = ArrayDesign::new(128, 128, cfg.clone(), ls, 1.0);
                        (ls, noise_margin(&d).noise_margin())
                    })
                    .collect(),
                'c' => [1.0, 1.5, 2.0, 3.0, 4.0]
                    .iter()
                    .map(|&ws| {
                        let d = ArrayDesign::new(64, 128, cfg.clone(), 4.0, ws);
                        (ws, noise_margin(&d).noise_margin())
                    })
                    .collect(),
                'd' => [128usize, 256, 512, 1024, 2048]
                    .iter()
                    .map(|&nc| {
                        let d = ArrayDesign::new(256, nc, cfg.clone(), 4.0, 1.0)
                            .with_span(121.min(nc));
                        (nc as f64, noise_margin(&d).noise_margin())
                    })
                    .collect(),
                _ => panic!("panel must be a..d"),
            };
            Fig13Series { config: id, points }
        })
        .collect()
}

// ---------------------------------------------------------------- Table III

/// Table III: multi-bit TMVM energy/area for both schemes, 1–6 bits.
pub fn table3_rows(v_dd: f64) -> (Vec<MultibitCost>, Vec<MultibitCost>, Table) {
    let design = ArrayDesign::new(128, 128, LineConfig::config3(), 3.0, 1.0);
    let ae: Vec<MultibitCost> = (1..=6)
        .map(|b| multibit_tmvm_cost(&design, MultibitScheme::AreaEfficient, b, 121, v_dd))
        .collect();
    let lp: Vec<MultibitCost> = (1..=6)
        .map(|b| multibit_tmvm_cost(&design, MultibitScheme::LowPower, b, 121, v_dd))
        .collect();
    let mut t = Table::new("Table III — multi-bit TMVM energy and area")
        .header(&["Scheme", "Metric", "1", "2", "3", "4", "5", "6"]);
    let fmt = |c: &MultibitCost, energy: bool| -> String {
        if !c.feasible {
            return "infeasible(>5V)".into();
        }
        if energy {
            format_si(c.energy, "J")
        } else {
            format!("{:.2}µm²", c.area * 1e12)
        }
    };
    for (name, costs, energy) in [
        ("Area-efficient", &ae, true),
        ("Low-power", &lp, true),
        ("Area-efficient", &ae, false),
        ("Low-power", &lp, false),
    ] {
        let metric = if energy { "Energy" } else { "Area" };
        let cells: Vec<String> = costs.iter().map(|c| fmt(c, energy)).collect();
        t.row(&[
            name.to_string(),
            metric.to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            cells[3].clone(),
            cells[4].clone(),
            cells[5].clone(),
        ]);
    }
    (ae, lp, t)
}

/// Helper: render Fig. 13 series as a table for terminal output.
pub fn fig13_table(panel: char, xlabel: &str) -> Table {
    let series = fig13_sweeps(panel);
    let mut t = Table::new(&format!("Fig. 13({panel}) — NM vs {xlabel}"))
        .header(&[xlabel, "config 1", "config 2", "config 3"]);
    let n = series[0].points.len();
    for i in 0..n {
        let x = series[0].points[i].0;
        let xs = if x.fract() == 0.0 && x >= 8.0 {
            format!("{x:.0}")
        } else {
            format!("{x}")
        };
        t.row(&[
            xs,
            format_pct(series[0].points[i].1),
            format_pct(series[1].points[i].1),
            format_pct(series[2].points[i].1),
        ]);
    }
    t
}

/// Geometry helper reused by reports.
pub fn cell_of(design: &ArrayDesign) -> CellGeometry {
    design.cell
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reproduces_paper_min_cells() {
        let t = table1_rows();
        let s = t.render();
        assert!(s.contains("36nm × 36nm"));
        assert!(s.contains("48nm × 80nm"));
        assert!(s.contains("36nm × 80nm"));
    }

    #[test]
    fn fig10_trends() {
        let rows = fig10_series(&[16, 64, 256, 1024], 100.0);
        assert!(rows.windows(2).all(|w| w[1].r_th >= w[0].r_th));
        assert!(rows.windows(2).all(|w| w[1].alpha <= w[0].alpha));
    }

    #[test]
    fn fig11_has_window_for_small_arrays() {
        let d = ArrayDesign::new(64, 128, LineConfig::config3(), 4.0, 1.0);
        let data = fig11_regions(&d, &[0.0, 5e3, 10e3]);
        assert!(data.window.is_some());
        assert!(data.nm > 0.0);
        assert_eq!(data.boundary.len(), 3);
        // boundary alpha increases with r_th
        assert!(data.boundary[2].1 > data.boundary[0].1);
    }

    #[test]
    fn fig13_panels_have_three_configs() {
        for panel in ['a', 'b', 'c', 'd'] {
            let s = fig13_sweeps(panel);
            assert_eq!(s.len(), 3);
            assert!(s.iter().all(|ser| !ser.points.is_empty()));
        }
    }

    #[test]
    fn fig13a_config3_dominates_config1() {
        let s = fig13_sweeps('a');
        for i in 0..s[0].points.len() {
            assert!(
                s[2].points[i].1 >= s[0].points[i].1,
                "config3 ≥ config1 at N_row={}",
                s[0].points[i].0
            );
        }
    }

    #[test]
    fn fig13d_is_flat() {
        for ser in fig13_sweeps('d') {
            let nms: Vec<f64> = ser.points.iter().map(|p| p.1).collect();
            let spread = nms.iter().cloned().fold(f64::MIN, f64::max)
                - nms.iter().cloned().fold(f64::MAX, f64::min);
            assert!(spread < 0.02, "config {} spread {spread}", ser.config);
        }
    }

    #[test]
    fn table3_shapes() {
        let (ae, lp, t) = table3_rows(0.9);
        assert!(t.render().contains("infeasible"));
        assert!(ae[3].max_voltage > 5.0, "4-bit AE needs >5V");
        assert!(lp[5].feasible);
        // LP area exponential vs AE linear
        assert!(lp[5].area > 8.0 * ae[5].area);
    }
}

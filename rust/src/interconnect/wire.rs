//! Wire-segment conductance calculation from cell geometry.
//!
//! Orientation conventions (matching the paper's §V/§VI observations):
//!
//! * Word lines (WLT above the top PCM level, WLB below the bottom one) run
//!   across **rows**: one WL per input column, each crossing all `N_row`
//!   rows. A WL segment within one cell footprint has **length `W_cell`**
//!   and its width is limited by the row pitch: **width ≤ `L_cell` − S_min**.
//!   This is why NM improves with `L_cell` (wider WLs) and degrades with
//!   `W_cell` (longer WL segments) — Fig. 13(b)/(c).
//! * Bit lines run across **columns** in the middle of the stack: a BL
//!   segment has **length `L_cell`** and **width ≤ `W_cell` − S_min`**.
//!   BL resistance is in series with the (much larger) PCM resistance, which
//!   is why NM is flat in `N_column` — Fig. 13(d).

use super::asap7::MetalLayer;

/// Conductance of one wire segment on `layer` \[S\].
///
/// `length` is the cell pitch along the wire; `pitch_across` is the cell
/// pitch perpendicular to the wire, which bounds the drawn wire width to
/// `pitch_across − S_min` (never below the layer's `W_min` — a layout that
/// cannot fit even a minimum-width wire is rejected by
/// [`crate::interconnect::LineConfig::min_cell`] constraints upstream).
pub fn segment_conductance(layer: &MetalLayer, length: f64, pitch_across: f64) -> f64 {
    let width = wire_width(layer, pitch_across);
    1.0 / layer.wire_resistance(length, width)
}

/// Drawn wire width on `layer` given the perpendicular cell pitch.
pub fn wire_width(layer: &MetalLayer, pitch_across: f64) -> f64 {
    (pitch_across - layer.s_min).max(layer.w_min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::asap7::metal;

    #[test]
    fn min_pitch_gives_min_width() {
        let m1 = metal(1);
        assert_eq!(wire_width(m1, m1.pitch_min()), m1.w_min);
    }

    #[test]
    fn wider_pitch_gives_wider_wire() {
        let m3 = metal(3);
        let w = wire_width(m3, 4.0 * m3.pitch_min());
        assert!((w - (144e-9 - 18e-9)).abs() < 1e-18);
    }

    #[test]
    fn conductance_scales_inverse_with_length() {
        let m2 = metal(2);
        let g1 = segment_conductance(m2, 36e-9, 36e-9);
        let g2 = segment_conductance(m2, 72e-9, 36e-9);
        assert!((g1 / g2 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn conductance_grows_with_pitch_across() {
        let m3 = metal(3);
        let narrow = segment_conductance(m3, 36e-9, m3.pitch_min());
        let wide = segment_conductance(m3, 36e-9, 4.0 * m3.pitch_min());
        assert!(wide > 3.0 * narrow);
    }
}

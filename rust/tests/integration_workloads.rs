//! Integration: multibit and conv workloads as first-class
//! `NetworkSource`s through the serving stack. Pins the tentpole
//! contracts: the conv Toeplitz lowering served by an engine is
//! bit-exact with the direct convolution oracle, the multibit unary
//! lowering is decision-equivalent with its source layer end to end,
//! the Table III resolution premium lands in telemetry and survives the
//! sharded aggregate, and both workloads run through the coordinator
//! exactly as `xpoint serve --network …` drives them.

use xpoint_imc::cli::Args;
use xpoint_imc::coordinator::{Coordinator, MetricsSnapshot};
use xpoint_imc::engine::{BackendKind, Engine, EngineSpec, NetworkSource};
use xpoint_imc::nn::dataset::{DigitGen, IMAGE_SIDE, TEST_SEED};
use xpoint_imc::nn::{conv_bank, expand_unary, MultibitLayer};
use xpoint_imc::report::table2::template_layer;

fn spec_from(args: &[&str]) -> EngineSpec {
    let args = Args::parse(args.iter().map(|s| s.to_string()));
    EngineSpec::from_args(&args).expect("spec parses")
}

#[test]
fn conv_engine_is_bit_exact_with_the_direct_convolution() {
    let spec = spec_from(&["serve", "--network", "conv:4x3x3"]);
    let (filters, kh, kw, theta) = match spec.network {
        NetworkSource::Conv {
            filters,
            kh,
            kw,
            theta,
        } => (filters, kh, kw, theta),
        other => panic!("expected conv source, got {other:?}"),
    };
    let conv = conv_bank(filters, kh, kw, theta);
    let (oh, ow) = conv.out_shape(IMAGE_SIDE, IMAGE_SIDE).unwrap();

    let mut engine = spec.build_engine().unwrap();
    let caps = engine.capabilities();
    assert_eq!(caps.n_in, IMAGE_SIDE * IMAGE_SIDE);
    assert_eq!(caps.n_out, filters * oh * ow);

    let mut gen = DigitGen::new(TEST_SEED);
    let images: Vec<Vec<bool>> = (0..12).map(|_| gen.next_sample().pixels).collect();
    let res = engine.infer_batch(&images).unwrap();
    for (img, served) in images.iter().zip(&res.bits) {
        let direct = conv.forward_direct(img, IMAGE_SIDE, IMAGE_SIDE).unwrap();
        for (f, plane) in direct.iter().enumerate() {
            assert_eq!(
                &served[f * oh * ow..(f + 1) * oh * ow],
                &plane[..],
                "feature map {f} diverges from the direct convolution"
            );
        }
    }
    // feature maps are not class predictions
    assert!(!spec.network.is_classifier());
    // binary conv carries no multibit premium
    assert_eq!(engine.telemetry().multibit_energy, 0.0);
}

#[test]
fn multibit_engine_is_decision_equivalent_with_the_binary_template() {
    for spec_str in ["multibit:2", "multibit:2:area", "multibit:1:lowpower"] {
        let spec = spec_from(&["serve", "--network", spec_str]);
        let bits = match spec.network {
            NetworkSource::Multibit { bits, .. } => bits,
            other => panic!("expected multibit source, got {other:?}"),
        };
        let template = template_layer();
        let lowered = MultibitLayer::from_binary(&template, bits);
        let mut engine = spec.build_engine().unwrap();
        let expansion = spec.network.input_expansion();
        assert_eq!(engine.capabilities().n_in, template.n_in() * expansion);

        let mut gen = DigitGen::new(TEST_SEED);
        let samples: Vec<_> = (0..10).map(|_| gen.next_sample()).collect();
        let expanded: Vec<Vec<bool>> = samples
            .iter()
            .map(|s| expand_unary(&s.pixels, expansion))
            .collect();
        let res = engine.infer_batch(&expanded).unwrap();
        for (i, s) in samples.iter().enumerate() {
            assert_eq!(
                res.bits[i],
                template.forward(&s.pixels),
                "{spec_str}: thresholded bits diverge from the binary source"
            );
            assert_eq!(res.classes[i], lowered.argmax(&s.pixels), "{spec_str}");
            assert_eq!(res.classes[i], template.argmax(&s.pixels), "{spec_str}");
        }
    }
}

#[test]
fn multibit_premium_lands_in_telemetry_and_totals() {
    let spec = spec_from(&["serve", "--network", "multibit:3"]);
    let premium = spec.multibit_premium();
    assert!(premium > 0.0, "a multibit workload must carry a premium");

    let mut engine = spec.build_engine().unwrap();
    let expansion = spec.network.input_expansion();
    let mut gen = DigitGen::new(TEST_SEED);
    let images: Vec<Vec<bool>> = (0..8)
        .map(|_| expand_unary(&gen.next_sample().pixels, expansion))
        .collect();
    let res = engine.infer_batch(&images).unwrap();
    let t = engine.telemetry();
    let expected = premium * images.len() as f64;
    assert!(
        (t.multibit_energy - expected).abs() <= 1e-12 * expected.max(1.0),
        "telemetry premium {} != {} (8 images × {premium})",
        t.multibit_energy,
        expected
    );
    assert!(
        t.energy >= t.multibit_energy,
        "the premium is included in total energy, not extra"
    );
    assert!(res.energy >= premium * images.len() as f64);

    // the binary baseline carries none
    let binary = spec_from(&["serve", "--network", "template"]);
    let mut engine = binary.build_engine().unwrap();
    let mut gen = DigitGen::new(TEST_SEED);
    let images: Vec<Vec<bool>> = (0..8).map(|_| gen.next_sample().pixels).collect();
    engine.infer_batch(&images).unwrap();
    assert_eq!(engine.telemetry().multibit_energy, 0.0);
}

/// Drive a workload through the coordinator exactly as `xpoint serve`
/// does (expansion client-side, labels only for classifiers) and return
/// the metrics snapshot plus every prediction's output bits.
fn serve_workload(
    spec: &EngineSpec,
    n_images: usize,
) -> (MetricsSnapshot, Vec<Vec<bool>>, Vec<Vec<bool>>) {
    let expansion = spec.network.input_expansion();
    let classifier = spec.network.is_classifier();
    let backends = spec.build_factories().unwrap();
    let mut coord = Coordinator::spawn(backends, spec.coordinator_config());
    let mut gen = DigitGen::new(TEST_SEED);
    let mut raw = Vec::with_capacity(n_images);
    let mut receivers = Vec::with_capacity(n_images);
    for _ in 0..n_images {
        let s = gen.next_sample();
        let pixels = if expansion > 1 {
            expand_unary(&s.pixels, expansion)
        } else {
            s.pixels.clone()
        };
        raw.push(s.pixels);
        receivers.push(coord.submit(pixels, classifier.then_some(s.label)).unwrap());
    }
    let bits: Vec<Vec<bool>> = receivers
        .into_iter()
        .map(|rx| rx.recv().expect("prediction arrives").bits)
        .collect();
    (coord.shutdown(), raw, bits)
}

#[test]
fn sharded_conv_serving_stays_bit_exact_end_to_end() {
    let spec = spec_from(&["serve", "--network", "conv:2x3x3", "--shards", "2"]);
    assert_eq!(spec.kind, BackendKind::Sharded);
    let conv = conv_bank(2, 3, 3, 5);
    let (oh, ow) = conv.out_shape(IMAGE_SIDE, IMAGE_SIDE).unwrap();
    let (snap, raw, bits) = serve_workload(&spec, 48);
    assert_eq!(snap.images, 48);
    assert_eq!(snap.multibit_energy, 0.0);
    assert!(snap.accuracy.is_none(), "feature maps carry no labels");
    for (img, served) in raw.iter().zip(&bits) {
        let direct = conv.forward_direct(img, IMAGE_SIDE, IMAGE_SIDE).unwrap();
        for (f, plane) in direct.iter().enumerate() {
            assert_eq!(&served[f * oh * ow..(f + 1) * oh * ow], &plane[..]);
        }
    }
}

#[test]
fn sharded_multibit_serving_accrues_the_premium_across_shards() {
    let spec = spec_from(&["serve", "--network", "multibit:2", "--shards", "2"]);
    let template = template_layer();
    let (snap, raw, bits) = serve_workload(&spec, 40);
    assert_eq!(snap.images, 40);
    for (img, served) in raw.iter().zip(&bits) {
        assert_eq!(served, &template.forward(img));
    }
    let expected = spec.multibit_premium() * 40.0;
    assert!(
        (snap.multibit_energy - expected).abs() <= 1e-12 * expected.max(1.0),
        "sharded aggregate premium {} != {expected}",
        snap.multibit_energy
    );
    assert!(snap.energy >= snap.multibit_energy);
    // both shards saw traffic, and the per-shard breakout sums to the total
    assert_eq!(snap.shards.len(), 2);
    let shard_sum: f64 = snap.shards.iter().map(|t| t.multibit_energy).sum();
    assert!((shard_sum - snap.multibit_energy).abs() < 1e-15 * expected.max(1.0));
}

#[test]
fn infeasible_multibit_schemes_fail_the_spec_not_the_worker() {
    // area-efficient at >= 4 bits needs V_DD·2^(b−1) > the 5 V ceiling at
    // the Table II operating point — validate() must reject it eagerly,
    // before any worker thread exists
    let argv = ["serve", "--network", "multibit:4:area"];
    let args = Args::parse(argv.iter().map(|s| s.to_string()));
    let err = EngineSpec::from_args(&args).unwrap_err();
    assert!(
        err.to_string().contains("multibit"),
        "expected a multibit feasibility error, got: {err}"
    );
}

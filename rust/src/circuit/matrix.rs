//! Dense row-major matrix with LU decomposition (partial pivoting) — the
//! linear-algebra kernel under the MNA solver. Also provides a banded
//! factorization fast path used for ladder-structured crosspoint netlists,
//! where the MNA matrix has small bandwidth under natural node ordering.

use anyhow::bail;

/// Dense row-major `n × n` matrix.
#[derive(Clone, Debug)]
pub struct Matrix {
    n: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(n: usize) -> Self {
        Self {
            n,
            data: vec![0.0; n * n],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.n + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.n + c] = v;
    }

    #[inline]
    pub fn add(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.n + c] += v;
    }

    /// Solve `A x = b` by LU with partial pivoting. Consumes a copy of the
    /// matrix; `b.len()` must equal `n`.
    pub fn solve(&self, b: &[f64]) -> crate::Result<Vec<f64>> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        let mut a = self.data.clone();
        let mut x = b.to_vec();
        let mut perm: Vec<usize> = (0..n).collect();

        for k in 0..n {
            // pivot
            let mut p = k;
            let mut pmax = a[perm[k] * n + k].abs();
            for r in (k + 1)..n {
                let v = a[perm[r] * n + k].abs();
                if v > pmax {
                    pmax = v;
                    p = r;
                }
            }
            if pmax == 0.0 || !pmax.is_finite() {
                bail!("singular or non-finite matrix at column {k} (pivot {pmax})");
            }
            perm.swap(k, p);
            let prow = perm[k] * n;
            let pivot = a[prow + k];
            for r in (k + 1)..n {
                let row = perm[r] * n;
                let factor = a[row + k] / pivot;
                if factor == 0.0 {
                    continue;
                }
                a[row + k] = factor; // store L
                for c in (k + 1)..n {
                    a[row + c] -= factor * a[prow + c];
                }
            }
        }
        // forward substitution (apply L, permuted)
        let mut y = vec![0.0; n];
        for r in 0..n {
            let row = perm[r] * n;
            let mut s = x[perm[r]];
            for c in 0..r {
                s -= a[row + c] * y[c];
            }
            y[r] = s;
        }
        // back substitution (U)
        for r in (0..n).rev() {
            let row = perm[r] * n;
            let mut s = y[r];
            for c in (r + 1)..n {
                s -= a[row + c] * x[c];
            }
            x[r] = s / a[row + r];
        }
        Ok(x)
    }

    /// Multiply `A · x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let mut y = vec![0.0; self.n];
        for r in 0..self.n {
            let row = r * self.n;
            let mut s = 0.0;
            for c in 0..self.n {
                s += self.data[row + c] * x[c];
            }
            y[r] = s;
        }
        y
    }

    /// Half-bandwidth of the matrix (max |r-c| with a non-zero entry).
    pub fn bandwidth(&self) -> usize {
        let mut bw = 0;
        for r in 0..self.n {
            for c in 0..self.n {
                if self.data[r * self.n + c] != 0.0 {
                    bw = bw.max(r.abs_diff(c));
                }
            }
        }
        bw
    }
}

/// Banded LU solver without pivoting (valid for the diagonally-dominant MNA
/// conductance matrices produced by resistive networks with every node tied
/// to ground through some path). Stores only the band.
///
/// For an `n`-unknown system with half-bandwidth `k`, factorization is
/// `O(n·k²)` instead of `O(n³)` — this is what makes full-circuit validation
/// of 1024-row arrays tractable.
#[derive(Clone, Debug)]
pub struct BandedMatrix {
    n: usize,
    k: usize,              // half bandwidth
    data: Vec<f64>,        // (2k+1) diagonals, row-major: data[r*(2k+1) + (c - r + k)]
}

impl BandedMatrix {
    pub fn zeros(n: usize, half_bandwidth: usize) -> Self {
        let k = half_bandwidth;
        Self {
            n,
            k,
            data: vec![0.0; n * (2 * k + 1)],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn half_bandwidth(&self) -> usize {
        self.k
    }

    #[inline]
    fn idx(&self, r: usize, c: usize) -> Option<usize> {
        let k = self.k as isize;
        let off = c as isize - r as isize + k;
        if off < 0 || off > 2 * k {
            None
        } else {
            Some(r * (2 * self.k + 1) + off as usize)
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.idx(r, c).map(|i| self.data[i]).unwrap_or(0.0)
    }

    #[inline]
    pub fn add(&mut self, r: usize, c: usize, v: f64) {
        match self.idx(r, c) {
            Some(i) => self.data[i] += v,
            None => panic!("entry ({r},{c}) outside band k={}", self.k),
        }
    }

    /// In-place LU (no pivoting) + solve.
    pub fn solve(&self, b: &[f64]) -> crate::Result<Vec<f64>> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        let k = self.k;
        let mut a = self.data.clone();
        let w = 2 * k + 1;
        let mut x = b.to_vec();
        let at = |a: &Vec<f64>, r: usize, c: usize| -> f64 {
            let off = c as isize - r as isize + k as isize;
            a[r * w + off as usize]
        };
        let set = |a: &mut Vec<f64>, r: usize, c: usize, v: f64| {
            let off = c as isize - r as isize + k as isize;
            a[r * w + off as usize] = v;
        };
        for p in 0..n {
            let pivot = at(&a, p, p);
            if pivot.abs() < 1e-300 || !pivot.is_finite() {
                bail!("banded LU: zero/non-finite pivot at {p}");
            }
            let rmax = (p + k).min(n - 1);
            for r in (p + 1)..=rmax {
                let factor = at(&a, r, p) / pivot;
                if factor == 0.0 {
                    continue;
                }
                set(&mut a, r, p, factor);
                let cmax = (p + k).min(n - 1);
                for c in (p + 1)..=cmax {
                    let v = at(&a, r, c) - factor * at(&a, p, c);
                    set(&mut a, r, c, v);
                }
                x[r] -= factor * x[p];
            }
        }
        for r in (0..n).rev() {
            let cmax = (r + k).min(n - 1);
            let mut s = x[r];
            for c in (r + 1)..=cmax {
                s -= at(&a, r, c) * x[c];
            }
            x[r] = s / at(&a, r, r);
        }
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn solves_known_system() {
        // [2 1; 1 3] x = [3; 5] -> x = [4/5, 7/5]
        let mut a = Matrix::zeros(2);
        a.set(0, 0, 2.0);
        a.set(0, 1, 1.0);
        a.set(1, 0, 1.0);
        a.set(1, 1, 3.0);
        let x = a.solve(&[3.0, 5.0]).unwrap();
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn detects_singular() {
        let mut a = Matrix::zeros(2);
        a.set(0, 0, 1.0);
        a.set(0, 1, 2.0);
        a.set(1, 0, 2.0);
        a.set(1, 1, 4.0);
        assert!(a.solve(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn random_solve_residual_small() {
        let mut rng = Pcg32::seeded(9);
        for _ in 0..20 {
            let n = rng.range(2, 30);
            let mut a = Matrix::zeros(n);
            for r in 0..n {
                for c in 0..n {
                    a.set(r, c, rng.range_f64(-1.0, 1.0));
                }
                // diagonally dominate to stay well-conditioned
                a.add(r, r, 4.0 * n as f64 * if rng.bernoulli(0.5) { 1.0 } else { -1.0 });
            }
            let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let x = a.solve(&b).unwrap();
            let r = a.matvec(&x);
            for i in 0..n {
                assert!((r[i] - b[i]).abs() < 1e-8, "residual too large");
            }
        }
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        // [0 1; 1 0] x = [2; 3] -> x = [3, 2]
        let mut a = Matrix::zeros(2);
        a.set(0, 1, 1.0);
        a.set(1, 0, 1.0);
        let x = a.solve(&[2.0, 3.0]).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12 && (x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn banded_matches_dense() {
        let mut rng = Pcg32::seeded(21);
        for _ in 0..10 {
            let n = rng.range(3, 40);
            let k = rng.range(1, 4.min(n));
            let mut dense = Matrix::zeros(n);
            let mut band = BandedMatrix::zeros(n, k);
            for r in 0..n {
                for c in r.saturating_sub(k)..(r + k + 1).min(n) {
                    let v = rng.range_f64(-1.0, 1.0);
                    dense.set(r, c, v);
                    band.add(r, c, v);
                }
                let boost = 10.0 * (k as f64 + 1.0);
                dense.add(r, r, boost);
                band.add(r, r, boost);
            }
            let b: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let xd = dense.solve(&b).unwrap();
            let xb = band.solve(&b).unwrap();
            for i in 0..n {
                assert!((xd[i] - xb[i]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn bandwidth_reports_band() {
        let mut a = Matrix::zeros(4);
        a.set(0, 0, 1.0);
        a.set(3, 1, 2.0);
        assert_eq!(a.bandwidth(), 2);
    }
}

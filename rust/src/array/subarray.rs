//! Subarray state machine: `2 × N_row × N_column` PCM cells in two stacked
//! levels (paper Fig. 1), with write/read/preset memory operations.

use super::energy::EnergyLedger;
use crate::analysis::ArrayDesign;
use crate::device::PcmCell;
use crate::nn::packed::BitMatrix;

/// The two PCM levels of a (two-deck) 3D XPoint subarray.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Top level — holds operands/weights during computation.
    Top,
    /// Bottom level — holds thresholded outputs.
    Bottom,
}

/// A 3D XPoint subarray.
///
/// Cell indexing is `(row, col)` with `row < n_row`, `col < n_col`; the top
/// and bottom levels each hold a full `n_row × n_col` grid.
#[derive(Clone, Debug)]
pub struct Subarray {
    design: ArrayDesign,
    top: Vec<PcmCell>,
    bottom: Vec<PcmCell>,
    /// Packed shadow of the top level's logical bits. Every top-level
    /// mutation goes through `write_bit(bool)`, which lands cells exactly
    /// at the crystalline/amorphous endpoints, so this mirror is always
    /// faithful — it is what the ideal-mode TMVM popcount path reads
    /// instead of walking per-cell conductances.
    top_bits: BitMatrix,
    /// Energy/latency ledger for all operations on this subarray.
    pub ledger: EnergyLedger,
    /// Per-row `(α_th, R_th)` cache for parasitic-mode TMVM — the design
    /// geometry is immutable, so the ladder Thevenin sweep is computed once
    /// and reused by every step (§Perf in EXPERIMENTS.md).
    pub(crate) thevenin_cache: Option<Vec<crate::analysis::LadderThevenin>>,
}

impl Subarray {
    /// Fresh subarray; all cells amorphous (logic 0).
    pub fn new(design: ArrayDesign) -> Self {
        let n = design.n_row * design.n_col;
        Self {
            top_bits: BitMatrix::zeros(design.n_row, design.n_col),
            design,
            top: vec![PcmCell::new(); n],
            bottom: vec![PcmCell::new(); n],
            ledger: EnergyLedger::new(),
            thevenin_cache: None,
        }
    }

    pub fn design(&self) -> &ArrayDesign {
        &self.design
    }

    pub fn n_row(&self) -> usize {
        self.design.n_row
    }

    pub fn n_col(&self) -> usize {
        self.design.n_col
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.n_row() && col < self.n_col());
        row * self.design.n_col + col
    }

    fn level(&self, level: Level) -> &[PcmCell] {
        match level {
            Level::Top => &self.top,
            Level::Bottom => &self.bottom,
        }
    }

    fn level_mut(&mut self, level: Level) -> &mut Vec<PcmCell> {
        match level {
            Level::Top => &mut self.top,
            Level::Bottom => &mut self.bottom,
        }
    }

    /// Read one cell (non-destructive; books a read pulse).
    pub fn read(&mut self, level: Level, row: usize, col: usize) -> bool {
        let p = self.design.device;
        let i = self.idx(row, col);
        self.ledger.book_read(1, 0.2, p.i_read, p.t_read);
        self.level(level)[i].bit()
    }

    /// Peek a cell without booking energy (debug/verification path).
    pub fn peek(&self, level: Level, row: usize, col: usize) -> bool {
        self.level(level)[self.idx(row, col)].bit()
    }

    /// Write one cell with a SET or RESET pulse.
    pub fn write(&mut self, level: Level, row: usize, col: usize, bit: bool) {
        let p = self.design.device;
        let i = self.idx(row, col);
        let (amp, dur) = if bit {
            (p.i_set, p.t_set)
        } else {
            (p.i_reset, p.t_reset)
        };
        // programming voltage ~ the threshold-switched cell drop
        self.ledger.book_write(p.v_switch, amp, dur);
        self.level_mut(level)[i].write_bit(bit);
        if level == Level::Top {
            self.top_bits.set(row, col, bit);
        }
    }

    /// Program a whole level from a row-major bit matrix
    /// (`bits[row][col]`). Rows are written in parallel per word line: one
    /// write slot per row.
    pub fn program_level(&mut self, level: Level, bits: &[Vec<bool>]) {
        assert_eq!(bits.len(), self.n_row(), "row count mismatch");
        let p = self.design.device;
        for (r, row_bits) in bits.iter().enumerate() {
            assert_eq!(row_bits.len(), self.n_col(), "col count mismatch");
            for (c, &b) in row_bits.iter().enumerate() {
                let i = self.idx(r, c);
                self.level_mut(level)[i].write_bit(b);
                if level == Level::Top {
                    self.top_bits.set(r, c, b);
                }
            }
            // one parallel write pulse per row (worst-case RESET timing)
            self.ledger
                .book_preset(self.design.n_col as u64, p.v_switch, p.i_reset, p.t_reset, false);
        }
    }

    /// Preset an output column at the bottom level to logic 0 (paper
    /// §III-A first bullet). `pipelined = true` overlaps the preset with
    /// the previous computational step.
    pub fn preset_output_column(&mut self, col: usize, pipelined: bool) {
        let p = self.design.device;
        for r in 0..self.n_row() {
            let i = self.idx(r, col);
            self.level_mut(Level::Bottom)[i].write_bit(false);
        }
        self.ledger
            .book_preset(self.n_row() as u64, p.v_switch, p.i_reset, p.t_reset, pipelined);
    }

    /// Read a whole bottom column (one parallel read slot).
    pub fn read_bottom_column(&mut self, col: usize) -> Vec<bool> {
        let p = self.design.device;
        self.ledger
            .book_read(self.n_row() as u64, 0.2, p.i_read, p.t_read);
        (0..self.n_row())
            .map(|r| self.bottom[self.idx(r, col)].bit())
            .collect()
    }

    /// Top-level conductance of cell `(row, col)` \[S\].
    pub fn top_conductance(&self, row: usize, col: usize) -> f64 {
        self.top[self.idx(row, col)].conductance(&self.design.device)
    }

    /// Direct (write-free) bottom-cell update used by the TMVM engine.
    pub(crate) fn force_bottom(&mut self, row: usize, col: usize, bit: bool) {
        let i = self.idx(row, col);
        self.bottom[i].write_bit(bit);
    }

    /// Direct (write-free) top-cell update used by inter-subarray links
    /// (the programming energy rides the source computation pulse).
    pub(crate) fn force_top(&mut self, row: usize, col: usize, bit: bool) {
        let i = self.idx(row, col);
        self.top[i].write_bit(bit);
        self.top_bits.set(row, col, bit);
    }

    /// Packed lanes of one top-level row — the ideal-mode TMVM hot path
    /// (tail bits past `n_col` are always zero).
    #[inline]
    pub fn top_row_words(&self, row: usize) -> &[u64] {
        self.top_bits.row(row)
    }

    /// Borrow the top level bits of one row as booleans (no energy).
    pub fn top_row_bits(&self, row: usize) -> Vec<bool> {
        (0..self.n_col())
            .map(|c| self.top[self.idx(row, c)].bit())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::LineConfig;

    fn small() -> Subarray {
        Subarray::new(ArrayDesign::new(4, 6, LineConfig::config1(), 1.0, 1.0))
    }

    #[test]
    fn fresh_array_is_all_zero() {
        let sa = small();
        for r in 0..4 {
            for c in 0..6 {
                assert!(!sa.peek(Level::Top, r, c));
                assert!(!sa.peek(Level::Bottom, r, c));
            }
        }
    }

    #[test]
    fn write_read_roundtrip() {
        let mut sa = small();
        sa.write(Level::Top, 2, 3, true);
        assert!(sa.read(Level::Top, 2, 3));
        assert!(!sa.read(Level::Top, 2, 2));
        sa.write(Level::Top, 2, 3, false);
        assert!(!sa.read(Level::Top, 2, 3));
        assert!(sa.ledger.writes >= 2 && sa.ledger.reads >= 3);
    }

    #[test]
    fn program_level_sets_pattern() {
        let mut sa = small();
        let bits: Vec<Vec<bool>> = (0..4)
            .map(|r| (0..6).map(|c| (r + c) % 2 == 0).collect())
            .collect();
        sa.program_level(Level::Top, &bits);
        for r in 0..4 {
            assert_eq!(sa.top_row_bits(r), bits[r]);
        }
    }

    #[test]
    fn preset_clears_column_only() {
        let mut sa = small();
        for r in 0..4 {
            sa.write(Level::Bottom, r, 1, true);
            sa.write(Level::Bottom, r, 2, true);
        }
        sa.preset_output_column(1, true);
        for r in 0..4 {
            assert!(!sa.peek(Level::Bottom, r, 1));
            assert!(sa.peek(Level::Bottom, r, 2), "other columns untouched");
        }
    }

    #[test]
    fn conductance_tracks_bits() {
        let mut sa = small();
        let p = sa.design().device;
        assert!((sa.top_conductance(0, 0) - p.g_a).abs() / p.g_a < 1e-9);
        sa.write(Level::Top, 0, 0, true);
        assert!((sa.top_conductance(0, 0) - p.g_c).abs() / p.g_c < 1e-9);
    }

    #[test]
    fn packed_shadow_tracks_every_top_mutation() {
        let mut sa = small();
        let bits: Vec<Vec<bool>> = (0..4)
            .map(|r| (0..6).map(|c| (r * c) % 3 == 0).collect())
            .collect();
        sa.program_level(Level::Top, &bits);
        sa.write(Level::Top, 1, 5, true);
        sa.force_top(3, 0, true);
        sa.write(Level::Bottom, 0, 0, true); // must not touch the shadow
        for r in 0..4 {
            let from_words: Vec<bool> = (0..6)
                .map(|c| sa.top_row_words(r)[0] & (1 << c) != 0)
                .collect();
            assert_eq!(from_words, sa.top_row_bits(r), "row {r}");
            assert_eq!(sa.top_row_words(r)[0] >> 6, 0, "tail masked");
        }
    }

    #[test]
    #[should_panic]
    fn program_wrong_shape_panics() {
        let mut sa = small();
        sa.program_level(Level::Top, &vec![vec![true; 6]; 3]);
    }
}

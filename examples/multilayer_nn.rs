//! Multi-layer NN on two linked subarrays (paper §IV-D, Fig. 8): the
//! BL-to-WLT switch fabric pipelines per-image hidden vectors from
//! subarray 1 into subarray 2, where the second weight set is applied.
//!
//! Requires `make artifacts` (trained MLP weights); falls back to a
//! template-based MLP otherwise.
//!
//! ```bash
//! cargo run --release --example multilayer_nn
//! ```

use xpoint_imc::analysis::ArrayDesign;
use xpoint_imc::array::TmvmMode;
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::nn::mlp::MlpOnSubarrays;
use xpoint_imc::nn::{BinaryLayer, BinaryMlp};
use xpoint_imc::runtime::artifact::artifacts_available;
use xpoint_imc::runtime::ArtifactStore;
use xpoint_imc::util::si::{format_duration, format_pct, format_si};

fn load_mlp() -> BinaryMlp {
    if artifacts_available() {
        let store = ArtifactStore::open_default().expect("artifacts");
        let (l1, l2) = store.mlp_layers().expect("mlp weights");
        println!("using trained MLP weights from artifacts/ (121→{}→{})", l1.n_out(), l2.n_out());
        BinaryMlp::new(l1, l2)
    } else {
        println!("artifacts missing — template detectors + identity readout");
        let l1 = xpoint_imc::report::table2::template_layer();
        let eye: Vec<Vec<bool>> = (0..10).map(|r| (0..10).map(|c| r == c).collect()).collect();
        BinaryMlp::new(l1, BinaryLayer::new(eye, 1))
    }
}

fn main() {
    let mlp = load_mlp();
    let h = mlp.l1.n_out();

    // Fig. 8 layout: W1 stored in subarray 1; hidden vectors land
    // transposed in subarray 2's top level; W2 applied as pulses.
    let batch = 64usize;
    let d1 = ArrayDesign::new(h.max(batch), 128, LineConfig::config3(), 3.0, 1.0);
    let d2 = ArrayDesign::new(batch, h.max(16), LineConfig::config3(), 3.0, 1.0);
    println!(
        "subarray 1: {}×{} (stores W1), subarray 2: {}×{} (hidden matrix + outputs)",
        d1.n_row, d1.n_col, d2.n_row, d2.n_col
    );

    let mut pipe = MlpOnSubarrays::new(mlp.clone(), d1, d2);

    let mut gen = DigitGen::new(TEST_SEED);
    let n_batches = 8;
    let mut correct_hw = 0usize;
    let mut correct_fn = 0usize;
    let mut total = 0usize;
    let mut energy = 0.0;
    let mut time = 0.0;
    for _ in 0..n_batches {
        let samples: Vec<_> = (0..batch).map(|_| gen.next_sample()).collect();
        let images: Vec<Vec<bool>> = samples.iter().map(|s| s.pixels.clone()).collect();
        let run = pipe.run_batch(&images, TmvmMode::Ideal);
        assert!(run.clean, "electrically clean");
        for (s, bits) in samples.iter().zip(&run.outputs) {
            // hardware decision: unique firing class
            if let Some(class) = unique_fire(bits) {
                if class == s.label {
                    correct_hw += 1;
                }
            }
            if mlp.argmax(&s.pixels) == s.label {
                correct_fn += 1;
            }
            total += 1;
        }
        energy += run.energy;
        time += run.time;
    }
    println!("\nimages:                 {total}");
    println!(
        "functional accuracy:    {} (count-space argmax)",
        format_pct(correct_fn as f64 / total as f64)
    );
    println!(
        "hardware one-hot rate:  {} (unique firing class; shared-θ constraint)",
        format_pct(correct_hw as f64 / total as f64)
    );
    println!(
        "pipeline steps/batch:   {} ({} hidden + {} output)",
        batch + mlp.l2.n_out(),
        batch,
        mlp.l2.n_out()
    );
    println!("simulated energy:       {}", format_si(energy, "J"));
    println!("simulated array time:   {}", format_duration(time));
}

fn unique_fire(bits: &[bool]) -> Option<usize> {
    let mut it = bits.iter().enumerate().filter(|(_, &b)| b);
    match (it.next(), it.next()) {
        (Some((i, _)), None) => Some(i),
        _ => None,
    }
}

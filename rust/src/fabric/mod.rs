//! The multi-subarray fabric simulator (paper §IV scaled out): a
//! discrete-event model of a grid of 3D XPoint subarrays joined by
//! BL-to-BL / BL-to-WLT interlinks, executing multi-layer binary networks
//! tiled across the grid with image-level pipelining.
//!
//! Layer map:
//!
//! * [`event`] — integer-time event queue/clock (no wall-clock).
//! * [`placement`] — [`FabricConfig`] + round-robin mapping of
//!   [`scaling::Tiling`](crate::scaling::Tiling) tiles and
//!   [`nn::BinaryLayer`](crate::nn::BinaryLayer) weights onto subarrays.
//! * [`node`] — per-subarray occupancy + the count-space TMVM model
//!   (energy identical to the cell-level engine's ideal mode).
//! * [`link`] — nearest-neighbour interlink channels with FIFO occupancy,
//!   dimension-ordered routing and switch-loss energy.
//! * [`exec`] — the pipelined executor: bit-exact with the functional
//!   model, reporting makespan/cycles, utilization, traffic and energy.
//! * [`reprogram`] — live weight rewriting: the SET/RESET diff of a new
//!   network streamed over the spine and pulsed through each node's write
//!   driver ([`FabricExecutor::reprogram`]), atomically swapping the
//!   resident weights — the program-traffic class serving-layer rolling
//!   swaps are built on.
//!
//! The serving adapter lives one layer up:
//! [`FabricBackend`](crate::engine::FabricBackend) (re-exported here for
//! convenience) implements [`Engine`](crate::engine::Engine) so the
//! coordinator drives a whole fabric instead of one subarray; it is
//! constructed through [`EngineSpec::build`](crate::engine::EngineSpec::build).

pub mod event;
pub mod placement;
pub mod node;
pub mod link;
pub mod exec;
pub mod reprogram;

pub use crate::engine::FabricBackend;
pub use event::{secs_to_ticks, ticks_to_secs, EventQueue, Time};
pub use exec::{FabricExecutor, FabricRun};
pub use link::{Interlink, LinkFabric, LinkTraffic};
pub use node::{
    row_current, tile_step, tile_step_packed, tile_step_parasitic, vdd_for_theta, ParasiticStep,
    SubarrayNode, TileStep,
};
pub use placement::{place_layers, FabricConfig, Fidelity, Placement, PlacementStrategy, TileSlice};
pub use reprogram::{simulate_reprogram, ReprogramRun};

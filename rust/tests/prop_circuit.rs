//! Property tests for the circuit substrate: the MNA solver is checked
//! against physical invariants on randomized networks.

use xpoint_imc::circuit::{Netlist, GROUND};
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

/// Build a random connected ladder-ish network; returns (netlist, nodes).
fn random_network(rng: &mut Pcg32) -> (Netlist, Vec<usize>) {
    let mut nl = Netlist::new();
    let n = rng.range(2, 25);
    let mut nodes = vec![];
    let mut prev = GROUND;
    for _ in 0..n {
        let node = nl.node();
        nl.resistor(prev, node, rng.range_f64(1.0, 1e5));
        if rng.bernoulli(0.6) {
            nl.resistor(node, GROUND, rng.range_f64(10.0, 1e6));
        }
        // occasional cross-link for mesh-ness
        if !nodes.is_empty() && rng.bernoulli(0.3) {
            let other = *rng.choose(&nodes);
            nl.resistor(node, other, rng.range_f64(10.0, 1e6));
        }
        nodes.push(node);
        prev = node;
    }
    (nl, nodes)
}

#[test]
fn kcl_holds_at_every_node() {
    forall(Config::default().cases(60), "KCL", |rng| {
        let (mut nl, nodes) = random_network(rng);
        let drive = *rng.choose(&nodes);
        nl.current_source(GROUND, drive, rng.range_f64(1e-6, 1e-2));
        let sol = nl.solve().map_err(|e| e.to_string())?;
        for &node in &nodes {
            if node == drive {
                continue;
            }
            let mut sum = 0.0;
            for c in nl.conductance_elements() {
                if c.a == node {
                    sum -= sol.branch_current(c.a, c.b, c.g);
                } else if c.b == node {
                    sum += sol.branch_current(c.a, c.b, c.g);
                }
            }
            if sum.abs() > 1e-9 {
                return Err(format!("KCL violated at {node}: {sum:e}"));
            }
        }
        Ok(())
    });
}

#[test]
fn superposition_of_current_sources() {
    forall(Config::default().cases(40), "superposition", |rng| {
        let (nl, nodes) = random_network(rng);
        let a = *rng.choose(&nodes);
        let b = *rng.choose(&nodes);
        let (i1, i2) = (rng.range_f64(1e-6, 1e-3), rng.range_f64(1e-6, 1e-3));
        let probe = *rng.choose(&nodes);

        let mut nl1 = nl.clone();
        nl1.current_source(GROUND, a, i1);
        let v1 = nl1.solve().map_err(|e| e.to_string())?.v[probe];

        let mut nl2 = nl.clone();
        nl2.current_source(GROUND, b, i2);
        let v2 = nl2.solve().map_err(|e| e.to_string())?.v[probe];

        let mut nl12 = nl.clone();
        nl12.current_source(GROUND, a, i1);
        nl12.current_source(GROUND, b, i2);
        let v12 = nl12.solve().map_err(|e| e.to_string())?.v[probe];

        let err = (v12 - v1 - v2).abs() / v12.abs().max(1e-12);
        if err > 1e-9 {
            return Err(format!("superposition error {err:e}"));
        }
        Ok(())
    });
}

#[test]
fn reciprocity_of_resistive_networks() {
    // transfer resistance v(b)/i(a) must equal v(a)/i(b)
    forall(Config::default().cases(40), "reciprocity", |rng| {
        let (nl, nodes) = random_network(rng);
        let a = *rng.choose(&nodes);
        let b = *rng.choose(&nodes);
        let mut nl1 = nl.clone();
        nl1.current_source(GROUND, a, 1e-3);
        let vb = nl1.solve().map_err(|e| e.to_string())?.v[b];
        let mut nl2 = nl.clone();
        nl2.current_source(GROUND, b, 1e-3);
        let va = nl2.solve().map_err(|e| e.to_string())?.v[a];
        if (vb - va).abs() > 1e-9 * vb.abs().max(1e-9) {
            return Err(format!("reciprocity broken: {vb} vs {va}"));
        }
        Ok(())
    });
}

#[test]
fn thevenin_predicts_any_load() {
    forall(Config::default().cases(40), "thevenin-load", |rng| {
        let (mut nl, nodes) = random_network(rng);
        let src = *rng.choose(&nodes);
        nl.voltage_source(src, GROUND, rng.range_f64(0.1, 5.0));
        let port = *rng.choose(&nodes);
        if port == src {
            return Ok(());
        }
        let th = nl.thevenin(port, GROUND).map_err(|e| e.to_string())?;
        let r_load = rng.range_f64(1.0, 1e6);
        let mut loaded = nl.clone();
        loaded.resistor(port, GROUND, r_load);
        let sol = loaded.solve().map_err(|e| e.to_string())?;
        let i_full = sol.v[port] / r_load;
        let i_pred = th.load_current(1.0 / r_load);
        let err = (i_full - i_pred).abs() / i_full.abs().max(1e-15);
        if err > 1e-8 {
            return Err(format!("thevenin load error {err:e}"));
        }
        Ok(())
    });
}

#[test]
fn banded_solver_agrees_with_dense_on_ladders() {
    forall(Config::default().cases(30), "banded=dense", |rng| {
        let mut nl = Netlist::new();
        let n = rng.range(3, 60);
        let mut prev = GROUND;
        for _ in 0..n {
            let node = nl.node();
            nl.resistor(prev, node, rng.range_f64(1.0, 1e3));
            nl.resistor(node, GROUND, rng.range_f64(1e2, 1e6));
            prev = node;
        }
        nl.current_source(GROUND, 1, 1e-3);
        let dense = nl.solve().map_err(|e| e.to_string())?;
        let banded = nl.solve_banded(2).map_err(|e| e.to_string())?;
        for (i, (a, b)) in dense.v.iter().zip(banded.v.iter()).enumerate() {
            if (a - b).abs() > 1e-9 * a.abs().max(1e-9) {
                return Err(format!("node {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

//! L3 coordinator: the serving shell around the simulated accelerator —
//! request batching, subarray scheduling, worker threads and metrics.
//!
//! The paper's contribution is the in-memory compute substrate itself, so
//! the coordinator is deliberately thin: it owns process topology and the
//! batching policy (`⌊N_row/P⌋` images per computational step, Table II)
//! and treats the inference backend as pluggable behind the unified
//! [`Engine`](crate::engine::Engine) trait — workers are spawned from the
//! [`BackendFactory`] list produced by
//! [`EngineSpec::build_factories`](crate::engine::EngineSpec::build_factories).
//!
//! `Backend` is a re-export of `engine::Engine` (the engine API subsumed
//! the old coordinator-local trait); the concrete backends live in
//! [`crate::engine::backends`].

pub mod batcher;
pub mod engine;
pub mod metrics;

pub use crate::engine::{
    Engine as Backend, BackendFactory, InferenceResult, SimBackend, XlaBackend,
};
pub use batcher::Batcher;
pub use engine::{Coordinator, CoordinatorConfig, Prediction};
pub use metrics::{Metrics, MetricsSnapshot};

//! [`ReprogramPlan`] — the per-cell cost of rewriting a stored weight
//! matrix in place (paper §II: SET is slow and low-current, RESET fast and
//! high-current; both are orders of magnitude more expensive than a
//! computational step).
//!
//! A plan is the *diff* between the bits an array currently stores and the
//! bits a new network needs: every `0 → 1` flip costs one SET pulse, every
//! `1 → 0` flip one RESET pulse, and unchanged cells cost nothing (PCM is
//! non-volatile — no refresh, no rewrite of stable state). Time assumes
//! one write driver per subarray, so pulses serialize:
//! `T = n_set·t_SET + n_reset·t_RESET`. Pulse energies are taken through
//! the ON conductance `G_C` — a SET target is threshold-switched ON while
//! it crystallizes, and a RESET target is crystalline until it melts — the
//! same operating points [`PcmCell`](super::pcm::PcmCell) integrates.

use super::params::DeviceParams;
use super::pulse::Pulse;

/// The pulse-level cost of reprogramming one weight matrix (or any subset
/// of cells) from its current bits to a target.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReprogramPlan {
    /// `0 → 1` flips (one SET pulse each).
    pub set_pulses: u64,
    /// `1 → 0` flips (one RESET pulse each).
    pub reset_pulses: u64,
    /// Cells whose stored bit already matches the target.
    pub unchanged: u64,
    /// Serialized programming time on one write driver \[s\].
    pub time: f64,
    /// Total programming energy \[J\].
    pub energy: f64,
}

impl ReprogramPlan {
    /// Plan the rewrite `current → target`. Both matrices must have
    /// identical (possibly ragged) shapes — a reprogram never moves
    /// weights between cells, it only flips bits in place.
    pub fn diff(
        current: &[Vec<bool>],
        target: &[Vec<bool>],
        p: &DeviceParams,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            current.len() == target.len(),
            "reprogram shape mismatch: {} rows stored, {} rows targeted",
            current.len(),
            target.len()
        );
        let mut plan = Self::default();
        for (r, (cur, tgt)) in current.iter().zip(target).enumerate() {
            anyhow::ensure!(
                cur.len() == tgt.len(),
                "reprogram shape mismatch at row {r}: {} cells stored, {} targeted",
                cur.len(),
                tgt.len()
            );
            for (&c, &t) in cur.iter().zip(tgt) {
                match (c, t) {
                    (false, true) => plan.set_pulses += 1,
                    (true, false) => plan.reset_pulses += 1,
                    _ => plan.unchanged += 1,
                }
            }
        }
        plan.time = plan.set_pulses as f64 * p.t_set + plan.reset_pulses as f64 * p.t_reset;
        plan.energy = plan.set_pulses as f64 * Pulse::set(p).energy(p.g_c)
            + plan.reset_pulses as f64 * Pulse::reset(p).energy(p.g_c);
        Ok(plan)
    }

    /// Cells that actually flip.
    pub fn cells_changed(&self) -> u64 {
        self.set_pulses + self.reset_pulses
    }

    /// All cells covered by the plan.
    pub fn cells_total(&self) -> u64 {
        self.cells_changed() + self.unchanged
    }

    /// Fold another plan into this one (per-tile plans into a per-node or
    /// per-fabric total; time adds — one write driver serializes).
    pub fn merge(&mut self, other: &Self) {
        self.set_pulses += other.set_pulses;
        self.reset_pulses += other.reset_pulses;
        self.unchanged += other.unchanged;
        self.time += other.time;
        self.energy += other.energy;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> DeviceParams {
        DeviceParams::default()
    }

    #[test]
    fn diff_counts_each_flip_kind_exactly() {
        let cur = vec![vec![false, true, true], vec![false, false, true]];
        let tgt = vec![vec![true, true, false], vec![false, true, true]];
        let plan = ReprogramPlan::diff(&cur, &tgt, &p()).unwrap();
        assert_eq!(plan.set_pulses, 2, "0→1 at (0,0) and (1,1)");
        assert_eq!(plan.reset_pulses, 1, "1→0 at (0,2)");
        assert_eq!(plan.unchanged, 3);
        assert_eq!(plan.cells_changed(), 3);
        assert_eq!(plan.cells_total(), 6);
    }

    #[test]
    fn identical_matrices_cost_nothing() {
        let m = vec![vec![true, false], vec![false, true]];
        let plan = ReprogramPlan::diff(&m, &m, &p()).unwrap();
        assert_eq!(plan.cells_changed(), 0);
        assert_eq!(plan.time, 0.0);
        assert_eq!(plan.energy, 0.0);
        assert_eq!(plan.unchanged, 4);
    }

    #[test]
    fn time_and_energy_follow_the_pulse_waveforms() {
        let params = p();
        let cur = vec![vec![false, true]];
        let tgt = vec![vec![true, false]]; // one SET + one RESET
        let plan = ReprogramPlan::diff(&cur, &tgt, &params).unwrap();
        let want_t = params.t_set + params.t_reset;
        assert!((plan.time - want_t).abs() < 1e-18);
        let want_e = Pulse::set(&params).energy(params.g_c)
            + Pulse::reset(&params).energy(params.g_c);
        assert!((plan.energy - want_e).abs() < 1e-24);
        // programming dwarfs a read: pulse energies are pJ-scale
        assert!(plan.energy > 1e-13, "E = {}", plan.energy);
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let cur = vec![vec![true, false]];
        assert!(ReprogramPlan::diff(&cur, &[vec![true]], &p()).is_err());
        assert!(ReprogramPlan::diff(&cur, &[], &p()).is_err());
    }

    #[test]
    fn merge_accumulates_all_counters() {
        let params = p();
        let a = ReprogramPlan::diff(&[vec![false, true]], &[vec![true, true]], &params).unwrap();
        let b = ReprogramPlan::diff(&[vec![true]], &[vec![false]], &params).unwrap();
        let mut total = a;
        total.merge(&b);
        assert_eq!(total.set_pulses, 1);
        assert_eq!(total.reset_pulses, 1);
        assert_eq!(total.unchanged, 1);
        assert!((total.time - (a.time + b.time)).abs() < 1e-18);
        assert!((total.energy - (a.energy + b.energy)).abs() < 1e-24);
    }
}

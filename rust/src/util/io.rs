//! Minimal text I/O: results CSV emission and the whitespace matrix format
//! shared with the python compile path (`artifacts/*.txt`).
//!
//! Matrix text format (python `numpy.savetxt`-compatible subset):
//! one row per line, whitespace-separated decimal floats; `#`-prefixed
//! comment lines ignored.

use anyhow::{bail, Context};
use std::fs;
use std::path::{Path, PathBuf};

/// Write `contents` to `results/<name>`, creating the directory.
pub fn write_result(name: &str, contents: &str) -> crate::Result<PathBuf> {
    let dir = results_dir();
    fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    fs::write(&path, contents).with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

/// Directory for generated result files (CSV series for figures, etc.).
pub fn results_dir() -> PathBuf {
    repo_root().join("results")
}

/// Directory holding AOT artifacts produced by `make artifacts`.
pub fn artifacts_dir() -> PathBuf {
    repo_root().join("artifacts")
}

/// Best-effort repo root: honour `XPOINT_REPO_ROOT`, else the cargo
/// manifest directory at build time, else the current directory.
pub fn repo_root() -> PathBuf {
    if let Ok(root) = std::env::var("XPOINT_REPO_ROOT") {
        return PathBuf::from(root);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Read a whole text file with a path-labelled error (config/spec files,
/// e.g. `EngineSpec::from_json_file`).
pub fn read_text(path: &Path) -> crate::Result<String> {
    fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))
}

/// Load a whitespace-separated float matrix. All rows must have equal
/// length.
pub fn load_matrix(path: &Path) -> crate::Result<Vec<Vec<f64>>> {
    let text = fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    parse_matrix(&text).with_context(|| format!("parsing {}", path.display()))
}

/// Parse the matrix text format.
pub fn parse_matrix(text: &str) -> crate::Result<Vec<Vec<f64>>> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let row: Result<Vec<f64>, _> = line.split_whitespace().map(str::parse::<f64>).collect();
        let row = row.with_context(|| format!("line {}", lineno + 1))?;
        if let Some(first) = rows.first() {
            if first.len() != row.len() {
                bail!(
                    "ragged matrix: line {} has {} cols, expected {}",
                    lineno + 1,
                    row.len(),
                    first.len()
                );
            }
        }
        rows.push(row);
    }
    Ok(rows)
}

/// Serialize a matrix in the shared text format.
pub fn format_matrix(rows: &[Vec<f64>]) -> String {
    let mut out = String::new();
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        out.push_str(&cells.join(" "));
        out.push('\n');
    }
    out
}

/// Save a matrix to a file.
pub fn save_matrix(path: &Path, rows: &[Vec<f64>]) -> crate::Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    fs::write(path, format_matrix(rows)).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_roundtrip() {
        let m = vec![vec![1.0, 2.5, -3.0], vec![0.0, 1e-9, 4.0]];
        let parsed = parse_matrix(&format_matrix(&m)).unwrap();
        assert_eq!(parsed, m);
    }

    #[test]
    fn matrix_skips_comments_and_blanks() {
        let parsed = parse_matrix("# header\n\n1 2\n3 4\n").unwrap();
        assert_eq!(parsed, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
    }

    #[test]
    fn matrix_rejects_ragged() {
        assert!(parse_matrix("1 2\n3\n").is_err());
    }

    #[test]
    fn matrix_rejects_garbage() {
        assert!(parse_matrix("1 x\n").is_err());
    }
}

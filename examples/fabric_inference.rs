//! Pipelined inference on a simulated multi-subarray fabric, served
//! through the unified engine API: declare the fabric with an
//! `EngineSpec`, stream a batch of digit images through the resulting
//! engine, and read timing, per-subarray utilization, interlink traffic
//! and energy from its typed telemetry.
//!
//! ```bash
//! cargo run --release --example fabric_inference
//! ```

use xpoint_imc::engine::{BackendKind, EngineSpec};
use xpoint_imc::fabric::FabricExecutor;
use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::report::table2::template_layer;
use xpoint_imc::util::si::{format_duration, format_pct, format_si};

fn main() -> xpoint_imc::Result<()> {
    // 1. a three-layer network: the 10 digit templates as feature
    //    detectors, then two small random binary layers stacked on top
    let l1 = template_layer(); // 121 → 10, θ = 20
    let mut rng = xpoint_imc::util::Pcg32::seeded(2024);
    let mk = |n_out: usize, n_in: usize, theta: usize, rng: &mut xpoint_imc::util::Pcg32| {
        BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            theta,
        )
    };
    let l2 = mk(16, 10, 2, &mut rng);
    let l3 = mk(10, 16, 3, &mut rng);
    let layers = vec![l1, l2, l3];
    println!("network: 121 → 10 → 16 → 10 (binary weights, shared θ per layer)");

    // 2. declare the whole serving stack: a 2×2 fabric of 32×32-cell
    //    subarrays hosting the layer stack, behind one EngineSpec
    let spec = EngineSpec::new(BackendKind::Fabric)
        .with_layers(layers.clone())
        .with_grid(2, 2)
        .with_tile(32, 32);
    let mut engine = spec.build_engine()?;
    let caps = engine.capabilities();
    println!(
        "fabric:  2×2 subarrays (32×32 cells), {} weight tiles placed round-robin",
        caps.tiles
    );
    // the placement itself is a fabric-layer detail, still inspectable —
    // derived from the same spec so the two views can't drift apart
    let exec = FabricExecutor::new(layers.clone(), spec.fabric.config())?;
    for t in &exec.placement().tiles {
        println!(
            "         layer {} tile ({},{}) rows {:?} cols {:?} → subarray {}",
            t.layer, t.tile_row, t.tile_col, t.row_range, t.col_range, t.node
        );
    }

    // 3. per-image latency first: one image alone through a fresh engine
    let mut gen = DigitGen::new(TEST_SEED);
    let batch = 48;
    let images: Vec<Vec<bool>> = (0..batch).map(|_| gen.next_sample().pixels).collect();
    let one = spec.build_engine()?.infer_batch(&images[..1])?;

    // 4. stream the whole batch through the pipeline and read telemetry
    let res = engine.infer_batch(&images)?;
    let tel = engine.telemetry();
    println!("\nbatch of {batch} images:");
    println!(
        "  makespan:       {} ({} cycles)",
        format_duration(res.sim_time),
        tel.cycles
    );
    println!(
        "  throughput:     {} img/s (simulated)",
        format_si(batch as f64 / res.sim_time, "")
    );
    println!("  TMVM steps:     {}", res.steps);
    println!(
        "  energy:         {} compute + {} interlink = {} total ({}/image)",
        format_si(tel.compute_energy, "J"),
        format_si(tel.link_energy, "J"),
        format_si(res.energy, "J"),
        format_si(res.energy / batch as f64, "J"),
    );
    println!(
        "  interlink:      {} hop-transfers, {} line-hops of traffic",
        tel.link_transfers, tel.link_lines
    );
    for (n, u) in tel.utilization.iter().enumerate() {
        println!("  subarray {n}:     {} busy", format_pct(*u));
    }

    // 5. pipelining: the batch finishes far sooner than back-to-back
    println!(
        "\nper-image latency alone: {} — {} images pipelined in {} ({:.1}× over back-to-back)",
        format_duration(one.sim_time),
        batch,
        format_duration(res.sim_time),
        batch as f64 * one.sim_time / res.sim_time
    );

    // 6. the engine is bit-exact with the functional forward chain
    let mismatches = images
        .iter()
        .zip(&res.bits)
        .filter(|(img, out)| {
            let mut x = (*img).clone();
            for l in &layers {
                x = l.forward(&x);
            }
            &x != *out
        })
        .count();
    println!("functional cross-check: {mismatches} mismatches (must be 0)");
    assert_eq!(mismatches, 0);
    Ok(())
}

//! Substrate performance: the MNA solver (dense vs banded) on corner-case
//! ladders — the validation backbone's scaling behaviour.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, black_box, exhibit_header};
use xpoint_imc::analysis::corner_circuit::build_corner_circuit;
use xpoint_imc::analysis::{ladder_thevenin, ArrayDesign};
use xpoint_imc::interconnect::LineConfig;

fn main() {
    exhibit_header("Solver performance — analytic recursion vs full MNA");

    for n_row in [16usize, 64, 256] {
        let d = ArrayDesign::new(n_row, 64, LineConfig::config1(), 2.0, 1.0);
        bench(&format!("analytic ladder_thevenin (N={n_row})"), || {
            black_box(ladder_thevenin(&d, n_row));
        });
        bench(&format!("MNA dense solve (N={n_row}, {} nodes)", 2 * n_row + 3), || {
            let cc = build_corner_circuit(&d, n_row, 1.0, false);
            black_box(cc.thevenin().unwrap());
        });
        // the two-rail ladder has bandwidth ≤ 3 under natural ordering —
        // current-source drive keeps the MNA matrix banded (a voltage
        // source would add a dense border row)
        bench(&format!("MNA banded solve (N={n_row})"), || {
            black_box(banded_ladder(&d, n_row));
        });
    }

    // crossover demonstration: banded stays near-linear
    let d = ArrayDesign::new(1024, 64, LineConfig::config1(), 2.0, 1.0);
    bench("MNA banded solve (N=1024)", || {
        black_box(banded_ladder(&d, 1024));
    });
    bench("analytic ladder_thevenin (N=1024)", || {
        black_box(ladder_thevenin(&d, 1024));
    });
}

/// Current-driven two-rail ladder solved with the banded fast path.
fn banded_ladder(d: &ArrayDesign, n_row: usize) -> f64 {
    use xpoint_imc::circuit::{Netlist, GROUND};
    let seg = d.segments();
    let (r_wlt, r_wlb) = (1.0 / seg.g_wlt, 1.0 / seg.g_wlb);
    let r_branch = d.branch_resistance();
    let mut nl = Netlist::new();
    let mut prev_t = nl.node();
    nl.resistor(GROUND, prev_t, d.r_driver.max(1.0));
    let mut prev_b = nl.node();
    nl.resistor(prev_b, GROUND, d.r_driver.max(1.0));
    for _ in 0..n_row {
        let t = nl.node();
        let b = nl.node();
        nl.resistor(prev_t, t, r_wlt);
        nl.resistor(prev_b, b, r_wlb);
        nl.resistor(t, b, r_branch);
        prev_t = t;
        prev_b = b;
    }
    nl.current_source(GROUND, 1, 1e-3);
    let sol = nl.solve_banded(3).unwrap();
    sol.v[prev_t]
}

//! Sharded serving: wrap any backend in N asynchronous shards with one
//! spec field, drive them through the non-blocking submit/poll scheduler,
//! and read per-shard load balance from the telemetry — first hands-on at
//! the engine level, then end-to-end through the coordinator.
//!
//! ```bash
//! cargo run --release --example sharded_serving
//! ```

use std::time::Instant;
use xpoint_imc::coordinator::Coordinator;
use xpoint_imc::engine::{BackendKind, EngineSpec, NetworkSource};
use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::util::si::{format_duration, format_si};

fn main() -> xpoint_imc::Result<()> {
    // ------------------------------------------------------------------
    // 1. declare a sharded engine: 4 independent fabric shards (each a
    //    2×2 subarray grid) behind one asynchronous scheduler
    let spec = EngineSpec::new(BackendKind::Fabric)
        .with_network(NetworkSource::Template)
        .with_grid(2, 2)
        .with_tile(32, 32)
        .with_shards(4, BackendKind::Fabric)
        .with_workers(1) // the shards parallelize; one coordinator worker
        .with_batching(32, 200);
    println!("backend: {}", spec.describe());

    let mut engine = spec.build_engine()?;
    let caps = engine.capabilities();
    println!(
        "capabilities: {:?}, {} shards, {} subarrays total, batch ≤ {}\n",
        caps.kind, caps.shards, caps.nodes, caps.max_batch
    );

    // 2. submit several batches without waiting — each lands on the
    //    least-loaded shard and runs on that shard's own thread
    let mut gen = DigitGen::new(TEST_SEED);
    let mut batches = Vec::new();
    for size in [32, 8, 24, 16] {
        let images: Vec<Vec<bool>> = (0..size).map(|_| gen.next_sample().pixels).collect();
        batches.push(images);
    }
    let tickets: Vec<_> = batches
        .iter()
        .map(|images| engine.submit(images.clone()))
        .collect::<xpoint_imc::Result<_>>()?;
    println!("submitted {} batches: tickets {:?}", tickets.len(), tickets);

    // 3. poll out of order: redeem the last ticket first. Ok(None) means
    //    "still in flight on a shard thread" — no blocking, no panic.
    for &t in tickets.iter().rev() {
        let res = loop {
            match engine.poll(t)? {
                Some(res) => break res,
                None => std::thread::yield_now(),
            }
        };
        println!(
            "ticket {t}: {} images done, {} simulated, {}",
            res.bits.len(),
            format_duration(res.sim_time),
            format_si(res.energy, "J"),
        );
    }

    // 4. per-shard telemetry: the least-loaded dispatch spread the four
    //    batches over the four shards
    println!("\nper-shard load:");
    for (i, t) in engine.shard_telemetry().iter().enumerate() {
        println!(
            "  shard {i}: {} batches, {} images, {}",
            t.batches,
            t.images,
            format_si(t.energy, "J")
        );
    }

    // ------------------------------------------------------------------
    // 5. the same topology end-to-end: `xpoint serve --fabric --shards 4`
    //    in library form — the coordinator's scheduler keeps all shards
    //    busy and the snapshot carries the per-shard breakdown
    let n_images = 512;
    let mut coord = Coordinator::spawn(spec.build_factories()?, spec.coordinator_config());
    let mut gen = DigitGen::new(TEST_SEED);
    let started = Instant::now();
    let mut correct = 0usize;
    let rxs: Vec<_> = (0..n_images)
        .map(|_| {
            let s = gen.next_sample();
            (s.label, coord.submit(s.pixels, Some(s.label)).expect("submit"))
        })
        .collect();
    for (label, rx) in rxs {
        if rx.recv()?.class == label {
            correct += 1;
        }
    }
    let wall = started.elapsed().as_secs_f64();
    let snap = coord.shutdown();
    println!(
        "\nserved {n_images} digits through {} shards: {:.0} img/s host, {}/image simulated, {}/{} correct",
        snap.shards.len(),
        n_images as f64 / wall,
        format_si(snap.energy_per_image, "J"),
        correct,
        n_images,
    );
    for (i, t) in snap.shards.iter().enumerate() {
        println!("  shard {i}: {} images in {} batches", t.images, t.batches);
    }
    Ok(())
}

//! 2-D convolution lowered to TMVM (the paper's conclusion lists 2D
//! convolution among the implemented kernels): an im2col unroll turns each
//! output position's receptive field into a TMVM input vector, and each
//! binary filter into a stored weight row. For serving, the whole conv
//! fires as ONE stored layer over the flat image via the Toeplitz unroll
//! ([`BinaryConv2d::unrolled_layer`]), which the fabric places and tiles
//! like any dense layer.

use std::fmt;

use super::layer::BinaryLayer;
use crate::util::Pcg32;

/// A convolution was asked to run over an image smaller than its kernel —
/// valid padding leaves no output positions, so this is a typed error
/// rather than a panic or a silently empty result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShapeError {
    pub kh: usize,
    pub kw: usize,
    pub h: usize,
    pub w: usize,
}

impl fmt::Display for ConvShapeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "conv kernel {}x{} does not fit a {}x{} image (valid padding needs kh <= h and kw <= w)",
            self.kh, self.kw, self.h, self.w
        )
    }
}

impl std::error::Error for ConvShapeError {}

/// A binary 2-D convolution layer (single input channel, valid padding,
/// stride 1).
#[derive(Clone, Debug)]
pub struct BinaryConv2d {
    /// `filters[f][ky*kw + kx]` ∈ {0,1}.
    pub filters: Vec<Vec<bool>>,
    pub kh: usize,
    pub kw: usize,
    /// Shared firing threshold.
    pub theta: usize,
}

impl BinaryConv2d {
    pub fn new(filters: Vec<Vec<bool>>, kh: usize, kw: usize, theta: usize) -> Self {
        assert!(!filters.is_empty());
        assert!(kh >= 1 && kw >= 1);
        assert!(filters.iter().all(|f| f.len() == kh * kw));
        Self {
            filters,
            kh,
            kw,
            theta,
        }
    }

    /// Output spatial dimensions for an `h×w` input, or a typed error when
    /// the kernel doesn't fit.
    pub fn out_shape(&self, h: usize, w: usize) -> Result<(usize, usize), ConvShapeError> {
        if h < self.kh || w < self.kw {
            return Err(ConvShapeError {
                kh: self.kh,
                kw: self.kw,
                h,
                w,
            });
        }
        Ok((h - self.kh + 1, w - self.kw + 1))
    }

    /// im2col: unroll each output position's receptive field into a row of
    /// the patch matrix (`patches[pos][kidx]`).
    pub fn im2col(&self, image: &[bool], h: usize, w: usize) -> Result<Vec<Vec<bool>>, ConvShapeError> {
        assert_eq!(image.len(), h * w);
        let (oh, ow) = self.out_shape(h, w)?;
        let mut patches = Vec::with_capacity(oh * ow);
        for oy in 0..oh {
            for ox in 0..ow {
                let mut patch = Vec::with_capacity(self.kh * self.kw);
                for ky in 0..self.kh {
                    for kx in 0..self.kw {
                        patch.push(image[(oy + ky) * w + (ox + kx)]);
                    }
                }
                patches.push(patch);
            }
        }
        Ok(patches)
    }

    /// As a [`BinaryLayer`] over patch vectors — this is exactly what gets
    /// mapped onto the subarray (patches stored as rows, filters applied as
    /// word-line pulses).
    pub fn as_layer(&self) -> BinaryLayer {
        BinaryLayer::new(self.filters.clone(), self.theta)
    }

    /// The whole convolution as ONE dense layer over the flat `h×w` image:
    /// output neuron `(f, oy, ox)` stores filter `f` shifted to position
    /// `(oy, ox)` (a Toeplitz/doubly-blocked-circulant block). Popcount of
    /// that row against the raw image equals the receptive-field dot
    /// product, so the unrolled layer is bit-exact with
    /// [`forward_direct`](Self::forward_direct) — this is what serving
    /// places on the fabric (`n_in = h·w`, `n_out = filters·oh·ow`).
    pub fn unrolled_layer(&self, h: usize, w: usize) -> Result<BinaryLayer, ConvShapeError> {
        let (oh, ow) = self.out_shape(h, w)?;
        let mut rows = Vec::with_capacity(self.filters.len() * oh * ow);
        for filt in &self.filters {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut row = vec![false; h * w];
                    for ky in 0..self.kh {
                        for kx in 0..self.kw {
                            row[(oy + ky) * w + (ox + kx)] = filt[ky * self.kw + kx];
                        }
                    }
                    rows.push(row);
                }
            }
        }
        Ok(BinaryLayer::new(rows, self.theta))
    }

    /// Direct (reference) convolution: thresholded popcount per filter and
    /// output position. `out[f][pos]`.
    pub fn forward_direct(
        &self,
        image: &[bool],
        h: usize,
        w: usize,
    ) -> Result<Vec<Vec<bool>>, ConvShapeError> {
        let (oh, ow) = self.out_shape(h, w)?;
        let mut out = vec![vec![false; oh * ow]; self.filters.len()];
        for (f, filt) in self.filters.iter().enumerate() {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0usize;
                    for ky in 0..self.kh {
                        for kx in 0..self.kw {
                            if filt[ky * self.kw + kx] && image[(oy + ky) * w + (ox + kx)] {
                                acc += 1;
                            }
                        }
                    }
                    out[f][oy * ow + ox] = acc >= self.theta;
                }
            }
        }
        Ok(out)
    }

    /// Convolution through the im2col + TMVM path (functional).
    pub fn forward_im2col(
        &self,
        image: &[bool],
        h: usize,
        w: usize,
    ) -> Result<Vec<Vec<bool>>, ConvShapeError> {
        let patches = self.im2col(image, h, w)?;
        let layer = self.as_layer();
        let mut out = vec![vec![false; patches.len()]; self.filters.len()];
        for (pos, patch) in patches.iter().enumerate() {
            for (f, &bit) in layer.forward(patch).iter().enumerate() {
                out[f][pos] = bit;
            }
        }
        Ok(out)
    }
}

/// Deterministic filter bank for the `conv:FxKHxKW` network source: `n_f`
/// Bernoulli(½) binary filters drawn from a PCG stream seeded purely by
/// the shape, so every process (and every doc example) builds the same
/// network.
pub fn conv_bank(n_f: usize, kh: usize, kw: usize, theta: usize) -> BinaryConv2d {
    assert!(n_f >= 1 && kh >= 1 && kw >= 1);
    let seed = 0xc0de_2d00 ^ ((n_f as u64) << 32) ^ ((kh as u64) << 16) ^ kw as u64;
    let mut rng = Pcg32::seeded(seed);
    let filters = (0..n_f)
        .map(|_| (0..kh * kw).map(|_| rng.bernoulli(0.5)).collect())
        .collect();
    BinaryConv2d::new(filters, kh, kw, theta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_and_unroll_match_direct_convolution() {
        let mut rng = Pcg32::seeded(31);
        for _ in 0..25 {
            let h = rng.range(3, 12);
            let w = rng.range(3, 12);
            let kh = rng.range(1, h.min(4) + 1);
            let kw = rng.range(1, w.min(4) + 1);
            let n_f = rng.range(1, 5);
            let theta = rng.range(1, kh * kw + 1);
            let filters: Vec<Vec<bool>> = (0..n_f)
                .map(|_| (0..kh * kw).map(|_| rng.bernoulli(0.5)).collect())
                .collect();
            let conv = BinaryConv2d::new(filters, kh, kw, theta);
            let image: Vec<bool> = (0..h * w).map(|_| rng.bernoulli(0.5)).collect();
            let direct = conv.forward_direct(&image, h, w).unwrap();
            assert_eq!(
                direct,
                conv.forward_im2col(&image, h, w).unwrap(),
                "h={h} w={w} kh={kh} kw={kw} theta={theta}"
            );
            // the single-layer Toeplitz unroll agrees bit-for-bit too:
            // output neuron (f, pos) == direct[f][pos]
            let unrolled = conv.unrolled_layer(h, w).unwrap();
            let flat = unrolled.forward(&image);
            let (oh, ow) = conv.out_shape(h, w).unwrap();
            for (f, plane) in direct.iter().enumerate() {
                assert_eq!(&flat[f * oh * ow..(f + 1) * oh * ow], &plane[..]);
            }
        }
    }

    /// Kernels larger than the image are a typed error on every path —
    /// never a panic, never a silently empty output.
    #[test]
    fn oversized_kernels_are_a_typed_error() {
        let mut rng = Pcg32::seeded(32);
        for _ in 0..10 {
            let h = rng.range(1, 5);
            let w = rng.range(1, 5);
            // force at least one kernel dim past its image dim
            let kh = if rng.bernoulli(0.5) { h + rng.range(1, 4) } else { rng.range(1, h + 1) };
            let kw = if kh <= h { w + rng.range(1, 4) } else { rng.range(1, w + 5) };
            let conv = BinaryConv2d::new(vec![vec![true; kh * kw]], kh, kw, 1);
            if kh <= h && kw <= w {
                continue;
            }
            let err = ConvShapeError { kh, kw, h, w };
            let image = vec![true; h * w];
            assert_eq!(conv.out_shape(h, w), Err(err));
            assert_eq!(conv.im2col(&image, h, w).unwrap_err(), err);
            assert_eq!(conv.forward_direct(&image, h, w).unwrap_err(), err);
            assert_eq!(conv.forward_im2col(&image, h, w).unwrap_err(), err);
            assert_eq!(conv.unrolled_layer(h, w).unwrap_err(), err);
            assert!(err.to_string().contains("does not fit"));
        }
    }

    #[test]
    fn edge_detector_fires_on_edges() {
        // 3×1 vertical edge filter on an image with a vertical stripe
        let conv = BinaryConv2d::new(vec![vec![true, true, true]], 3, 1, 3);
        let (h, w) = (5usize, 4usize);
        let mut image = vec![false; h * w];
        for y in 0..h {
            image[y * w + 2] = true; // stripe at x = 2
        }
        let out = conv.forward_direct(&image, h, w).unwrap();
        let (oh, ow) = conv.out_shape(h, w).unwrap();
        assert_eq!((oh, ow), (3, 4));
        for oy in 0..oh {
            for ox in 0..ow {
                assert_eq!(out[0][oy * ow + ox], ox == 2, "({oy},{ox})");
            }
        }
    }

    #[test]
    fn patch_count_matches_output_shape() {
        let conv = BinaryConv2d::new(vec![vec![true; 9]], 3, 3, 1);
        let image = vec![true; 11 * 11];
        let patches = conv.im2col(&image, 11, 11).unwrap();
        assert_eq!(patches.len(), 9 * 9);
        assert!(patches.iter().all(|p| p.len() == 9));
    }

    #[test]
    fn conv_bank_is_deterministic_across_calls() {
        let a = conv_bank(4, 3, 3, 5);
        let b = conv_bank(4, 3, 3, 5);
        assert_eq!(a.filters, b.filters);
        assert_eq!(a.theta, 5);
        // different shapes draw from different streams
        let c = conv_bank(3, 3, 3, 5);
        assert_ne!(a.filters[0..3], c.filters[0..3]);
    }
}

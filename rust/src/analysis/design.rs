//! A complete subarray design point: dimensions, wiring, geometry, devices
//! — the input to every analysis routine and to the array simulator.

use crate::device::DeviceParams;
use crate::interconnect::config::SegmentConductances;
use crate::interconnect::{CellGeometry, LineConfig};

/// Conductance state assumed for the *output* PCM cells loading the word
/// lines in the worst-case ladder (Appendix A keeps `G_{O_i}` symbolic;
/// physically the outputs are preset amorphous and approach crystalline as
/// the SET completes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutputLoading {
    /// Outputs still in the preset (amorphous) state — light loading,
    /// start-of-computation.
    Preset,
    /// Outputs fully crystalline — heavy loading, end-of-computation.
    /// This is the conservative worst case and the default.
    Set,
}

/// A subarray design point.
#[derive(Clone, Debug)]
pub struct ArrayDesign {
    /// Number of rows (outputs per column / ladder length).
    pub n_row: usize,
    /// Number of columns (inputs / word-line count).
    pub n_col: usize,
    /// Metal-line configuration (Table I).
    pub config: LineConfig,
    /// Cell footprint.
    pub cell: CellGeometry,
    /// Device parameters.
    pub device: DeviceParams,
    /// Word-line driver resistance \[Ω\]. Not published in the paper; the
    /// default (100 Ω) is swept in `bench fig10` to show conclusions are
    /// insensitive over 10 Ω – 1 kΩ.
    pub r_driver: f64,
    /// Bit-line column span between the corner-case input and output cells.
    /// Defaults to `n_col` (the paper's "farthest possible distance");
    /// workload-aware analyses (Table II) use the engaged span instead.
    pub span_cols: usize,
    /// Worst-case output loading assumption.
    pub loading: OutputLoading,
}

impl ArrayDesign {
    /// Design with cell geometry expressed as multiples of the
    /// configuration minimum (`l_scale · L_min`, `w_scale · W_min`).
    pub fn new(n_row: usize, n_col: usize, config: LineConfig, l_scale: f64, w_scale: f64) -> Self {
        let cell = CellGeometry::scaled(&config, w_scale, l_scale);
        Self {
            n_row,
            n_col,
            config,
            cell,
            device: DeviceParams::default(),
            r_driver: 100.0,
            span_cols: n_col,
            loading: OutputLoading::Set,
        }
    }

    /// Override the corner-case column span (workload-aware analysis).
    pub fn with_span(mut self, span_cols: usize) -> Self {
        assert!(span_cols >= 1 && span_cols <= self.n_col);
        self.span_cols = span_cols;
        self
    }

    /// Override driver resistance.
    pub fn with_driver(mut self, r_driver: f64) -> Self {
        self.r_driver = r_driver;
        self
    }

    /// Override the output-loading assumption.
    pub fn with_loading(mut self, loading: OutputLoading) -> Self {
        self.loading = loading;
        self
    }

    /// Wire segment conductances for this design.
    pub fn segments(&self) -> SegmentConductances {
        SegmentConductances::of(&self.config, &self.cell)
    }

    /// Conductance assumed for output cells in the worst-case ladder.
    pub fn output_conductance(&self) -> f64 {
        match self.loading {
            OutputLoading::Preset => self.device.g_a,
            OutputLoading::Set => self.device.g_c,
        }
    }

    /// Resistance of one ladder row branch: the bit-line path across
    /// `span_cols` columns plus the input (crystalline) and output PCM
    /// cells in series (Appendix A, Eq. 8).
    pub fn branch_resistance(&self) -> f64 {
        let seg = self.segments();
        self.span_cols as f64 / seg.g_x + 1.0 / self.device.g_c + 1.0 / self.output_conductance()
    }

    /// Subarray footprint area \[m²\]: `N_col·L_cell × N_row·W_cell`
    /// (the CMOS periphery sits underneath and adds no footprint, §II).
    pub fn area(&self) -> f64 {
        (self.n_col as f64 * self.cell.l_cell) * (self.n_row as f64 * self.cell.w_cell)
    }

    /// Total PCM cell count: two stacked levels (paper §II).
    pub fn cell_count(&self) -> usize {
        2 * self.n_row * self.n_col
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_ii_smallest_design() {
        // 64×128, config 3, cell 36×240 nm (L = 3·L_min, W = W_min)
        let d = ArrayDesign::new(64, 128, LineConfig::config3(), 3.0, 1.0);
        assert!((d.cell.w_cell - 36e-9).abs() < 1e-15);
        assert!((d.cell.l_cell - 240e-9).abs() < 1e-15);
        assert_eq!(d.cell_count(), 2 * 64 * 128);
        // area ~ 128·240nm × 64·36nm = 30.7µm × 2.3µm ≈ 70.8 µm²
        let area_um2 = d.area() * 1e12;
        assert!(area_um2 > 50.0 && area_um2 < 90.0, "area {area_um2} µm²");
    }

    #[test]
    fn branch_is_pcm_dominated_at_small_span() {
        let d = ArrayDesign::new(64, 128, LineConfig::config3(), 3.0, 1.0).with_span(1);
        let r = d.branch_resistance();
        let r_pcm = 2.0 / d.device.g_c;
        assert!((r - r_pcm).abs() / r_pcm < 0.01, "r = {r}, pcm = {r_pcm}");
    }

    #[test]
    fn preset_loading_is_much_lighter() {
        let d = ArrayDesign::new(64, 128, LineConfig::config3(), 3.0, 1.0);
        let set = d.branch_resistance();
        let preset = d.with_loading(OutputLoading::Preset).branch_resistance();
        assert!(preset > 100.0 * set);
    }

    #[test]
    #[should_panic]
    fn span_cannot_exceed_columns() {
        let _ = ArrayDesign::new(4, 4, LineConfig::config1(), 1.0, 1.0).with_span(5);
    }
}

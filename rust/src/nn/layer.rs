//! A binary fully-connected layer and its subarray execution (paper §III-B).
//!
//! **Execution scheme** (derived from the paper's Table II arithmetic and
//! the Fig. 8 pipeline): the *images* are stored in the top PCM level (one
//! image per row, `N` pixel columns) and the *weights* are applied as
//! word-line voltage pulses — one computational step per output neuron,
//! storing that neuron's thresholded dot products for **all stored images
//! at once** in one bottom column. A batch of `M = N_row` images therefore
//! finishes in `P` steps, i.e. `N_row / P` images per step — exactly the
//! paper's "⌊N_row/P⌋ images per step" accounting.

use crate::array::{Level, Subarray, TmvmMode, TmvmReport};

/// A binary (0/1-weight) fully-connected layer with a shared integer
/// firing threshold.
#[derive(Clone, Debug, PartialEq)]
pub struct BinaryLayer {
    /// `weights[out][in]` ∈ {0, 1}.
    pub weights: Vec<Vec<bool>>,
    /// Shared firing threshold θ: neuron fires iff `Σ xᵢ·wᵢ ≥ θ`.
    pub theta: usize,
}

impl BinaryLayer {
    pub fn new(weights: Vec<Vec<bool>>, theta: usize) -> Self {
        assert!(!weights.is_empty());
        let n_in = weights[0].len();
        assert!(weights.iter().all(|w| w.len() == n_in));
        assert!(theta >= 1);
        Self { weights, theta }
    }

    /// Build from a 0/1 float matrix (artifact interchange format).
    pub fn from_matrix(m: &[Vec<f64>], theta: usize) -> Self {
        let weights = m
            .iter()
            .map(|row| row.iter().map(|&v| v >= 0.5).collect())
            .collect();
        Self::new(weights, theta)
    }

    pub fn n_out(&self) -> usize {
        self.weights.len()
    }

    pub fn n_in(&self) -> usize {
        self.weights[0].len()
    }

    /// Functional dot-product counts (the golden model).
    pub fn counts(&self, x: &[bool]) -> Vec<u32> {
        assert_eq!(x.len(), self.n_in());
        self.weights
            .iter()
            .map(|w| w.iter().zip(x).filter(|(&wi, &xi)| wi && xi).count() as u32)
            .collect()
    }

    /// Functional thresholded forward pass.
    pub fn forward(&self, x: &[bool]) -> Vec<bool> {
        self.counts(x)
            .into_iter()
            .map(|c| c as usize >= self.theta)
            .collect()
    }

    /// Functional classification: argmax of counts (first max wins).
    pub fn argmax(&self, x: &[bool]) -> usize {
        argmax_counts(&self.counts(x))
    }
}

/// Argmax over a count vector, first max wins — the tie-break every
/// classifier in the stack (functional, subarray, fabric) must share.
pub fn argmax_counts(counts: &[u32]) -> usize {
    let mut best = 0;
    for (i, &c) in counts.iter().enumerate() {
        if c > counts[best] {
            best = i;
        }
    }
    best
}

/// Result of running a batch of images through a layer on a subarray.
#[derive(Clone, Debug)]
pub struct BatchRun {
    /// `outputs[image][neuron]` — hardware thresholded bits.
    pub outputs: Vec<Vec<bool>>,
    /// Reports of the per-neuron computational steps.
    pub steps: Vec<TmvmReport>,
    /// Wall-clock of the batch \[s\].
    pub time: f64,
    /// Energy of the batch \[J\].
    pub energy: f64,
}

impl BinaryLayer {
    /// Run a batch of images (`images[i]` = pixel bits) through this layer
    /// on `sa`: images are programmed into the top level (one per row) and
    /// each neuron's weight vector is applied as a step of word-line
    /// pulses; neuron `p`'s results land in bottom column `p`.
    ///
    /// Requires `images.len() ≤ sa.n_row()`, `n_in ≤ sa.n_col()`,
    /// `n_out ≤ sa.n_col()`.
    pub fn run_batch(&self, sa: &mut Subarray, images: &[Vec<bool>], mode: TmvmMode) -> BatchRun {
        assert!(images.len() <= sa.n_row(), "batch exceeds rows");
        assert!(self.n_in() <= sa.n_col(), "image exceeds columns");
        assert!(self.n_out() <= sa.n_col(), "outputs exceed columns");
        let t0 = sa.ledger.time;
        let e0 = sa.ledger.energy;

        // program images into the top level (zero-padded)
        let mut grid = vec![vec![false; sa.n_col()]; sa.n_row()];
        for (i, img) in images.iter().enumerate() {
            assert_eq!(img.len(), self.n_in(), "image {i} size");
            grid[i][..self.n_in()].copy_from_slice(img);
        }
        sa.program_level(Level::Top, &grid);

        // one step per output neuron: weights as word-line voltages; rows
        // beyond the batch are floated (no leakage, Fig. 4(b))
        let v_dd = sa.vdd_for_threshold(self.theta);
        let mut steps = Vec::with_capacity(self.n_out());
        for (p, w) in self.weights.iter().enumerate() {
            let mut inputs = vec![false; sa.n_col()];
            inputs[..self.n_in()].copy_from_slice(w);
            steps.push(sa.tmvm_rows(&inputs, p, v_dd, mode, images.len()));
        }

        let outputs = (0..images.len())
            .map(|i| (0..self.n_out()).map(|p| steps[p].outputs[i]).collect())
            .collect();
        BatchRun {
            outputs,
            steps,
            time: sa.ledger.time - t0,
            energy: sa.ledger.energy - e0,
        }
    }

    /// [`BinaryLayer::run_batch`] over an `Arc`-shared packed batch. The
    /// images are unpacked once here to program the top level (cell
    /// programming is inherently per-bit); the per-neuron TMVM steps then
    /// run on the subarray's packed shadow, so the compute stays in
    /// popcount space end to end.
    pub fn run_batch_packed(
        &self,
        sa: &mut Subarray,
        batch: &crate::nn::packed::PackedBatch,
        mode: TmvmMode,
    ) -> BatchRun {
        assert_eq!(batch.width(), self.n_in(), "image width");
        let images = batch.to_images();
        self.run_batch(sa, &images, mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ArrayDesign;
    use crate::interconnect::LineConfig;
    use crate::util::Pcg32;

    fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
        BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            theta,
        )
    }

    #[test]
    fn counts_and_forward_agree() {
        let mut rng = Pcg32::seeded(3);
        let layer = random_layer(&mut rng, 5, 20, 4);
        let x: Vec<bool> = (0..20).map(|_| rng.bernoulli(0.5)).collect();
        let counts = layer.counts(&x);
        let fwd = layer.forward(&x);
        for (c, f) in counts.iter().zip(&fwd) {
            assert_eq!(*f, *c >= 4);
        }
    }

    #[test]
    fn hardware_batch_matches_functional_ideal() {
        let mut rng = Pcg32::seeded(8);
        let layer = random_layer(&mut rng, 10, 25, 5);
        let images: Vec<Vec<bool>> = (0..16)
            .map(|_| (0..25).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let design = ArrayDesign::new(16, 32, LineConfig::config3(), 3.0, 1.0);
        let mut sa = Subarray::new(design);
        let run = layer.run_batch(&mut sa, &images, TmvmMode::Ideal);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(run.outputs[i], layer.forward(img), "image {i}");
        }
        assert!(run.steps.iter().all(|s| s.is_clean()));
        // P steps of t_SET each (plus pipelined presets)
        let t_set = sa.design().device.t_set;
        assert!(
            run.time >= 10.0 * t_set && run.time < 10.0 * t_set + 16.0 * 1e-6,
            "time {}",
            run.time
        );
    }

    #[test]
    fn from_matrix_roundtrip() {
        let m = vec![vec![1.0, 0.0, 1.0], vec![0.0, 0.0, 1.0]];
        let l = BinaryLayer::from_matrix(&m, 1);
        assert_eq!(l.weights[0], vec![true, false, true]);
        assert_eq!(l.weights[1], vec![false, false, true]);
    }

    #[test]
    fn argmax_picks_strongest_neuron() {
        let l = BinaryLayer::new(
            vec![
                vec![true, false, false, false],
                vec![true, true, true, false],
                vec![true, true, false, false],
            ],
            1,
        );
        assert_eq!(l.argmax(&[true, true, true, true]), 1);
    }

    #[test]
    #[should_panic(expected = "batch exceeds rows")]
    fn oversize_batch_rejected() {
        let layer = BinaryLayer::new(vec![vec![true; 4]; 2], 1);
        let design = ArrayDesign::new(2, 8, LineConfig::config1(), 1.0, 1.0);
        let mut sa = Subarray::new(design);
        let images = vec![vec![true; 4]; 3];
        layer.run_batch(&mut sa, &images, TmvmMode::Ideal);
    }
}

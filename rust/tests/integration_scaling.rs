//! Integration: matrices larger than one subarray, tiled across linked
//! subarrays, must agree with the flat functional computation.

use xpoint_imc::analysis::ArrayDesign;
use xpoint_imc::array::{Level, Subarray, TmvmMode};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::scaling::tiling::{tiled_tmvm_counts, Tiling};
use xpoint_imc::util::Pcg32;

/// Electrical version of a column-tiled TMVM: each tile computes partial
/// counts on its own subarray; partials are merged (current summing across
/// the switch fabric) and thresholded once.
#[test]
fn electrically_tiled_tmvm_matches_flat() {
    let mut rng = Pcg32::seeded(123);
    for _case in 0..10 {
        let rows = rng.range(4, 20);
        let cols = rng.range(20, 60);
        let tile_cols = rng.range(8, 16);
        let theta = rng.range(2, 10);

        let g: Vec<Vec<bool>> = (0..rows)
            .map(|_| (0..cols).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let x: Vec<bool> = (0..cols).map(|_| rng.bernoulli(0.5)).collect();

        // functional flat result
        let flat: Vec<bool> = g
            .iter()
            .map(|row| {
                row.iter().zip(&x).filter(|(&w, &xi)| w && xi).count() >= theta
            })
            .collect();

        // tiled counts helper agrees
        let tiling = Tiling::new(rows, cols, rows, tile_cols);
        let counts = tiled_tmvm_counts(&tiling, &g, &x);
        for (r, &c) in counts.iter().enumerate() {
            assert_eq!(c as usize >= theta, flat[r]);
        }

        // electrical per-tile execution: partial currents from each tile
        // subarray, summed in count space then thresholded (the fabric
        // sums currents on the shared bit lines)
        let mut partial_counts = vec![0u32; rows];
        for tc in 0..tiling.grid_cols() {
            let range = tiling.col_range(tc);
            let width = range.len();
            let design = ArrayDesign::new(rows, width, LineConfig::config3(), 3.0, 1.0);
            let mut sa = Subarray::new(design);
            let bits: Vec<Vec<bool>> = g.iter().map(|row| row[range.clone()].to_vec()).collect();
            sa.program_level(Level::Top, &bits);
            let xt = x[range.clone()].to_vec();
            // count partials by sweeping the threshold: fire(θ') tells us
            // count ≥ θ' — recover exact counts from the current report
            let v = sa.vdd_for_threshold(1);
            let rep = sa.tmvm(&xt, 0, v, TmvmMode::Ideal);
            let p = sa.design().device;
            for (r, &i_t) in rep.currents.iter().enumerate() {
                // invert Eq. 3: gsum = I·G_C/(V·G_C − I), count ≈ gsum/G_C
                if i_t > 0.0 {
                    let gsum = i_t / (v - i_t / p.g_c);
                    partial_counts[r] += (gsum / p.g_c).round() as u32;
                }
            }
        }
        for r in 0..rows {
            assert_eq!(
                partial_counts[r] as usize >= theta,
                flat[r],
                "row {r}: tiled {} vs flat {}",
                partial_counts[r],
                flat[r]
            );
        }
    }
}

/// Row-tiling: a tall matrix split across two row-tiles concatenates.
#[test]
fn row_tiled_outputs_concatenate() {
    let mut rng = Pcg32::seeded(9);
    let rows = 30;
    let cols = 16;
    let theta = 4;
    let g: Vec<Vec<bool>> = (0..rows)
        .map(|_| (0..cols).map(|_| rng.bernoulli(0.5)).collect())
        .collect();
    let x: Vec<bool> = (0..cols).map(|_| rng.bernoulli(0.5)).collect();
    let tiling = Tiling::new(rows, cols, 16, cols);
    assert_eq!(tiling.grid_rows(), 2);

    let mut all_outputs = Vec::new();
    for tr in 0..tiling.grid_rows() {
        let range = tiling.row_range(tr);
        let height = range.len();
        let design = ArrayDesign::new(height, cols, LineConfig::config3(), 3.0, 1.0);
        let mut sa = Subarray::new(design);
        let bits: Vec<Vec<bool>> = g[range].to_vec();
        sa.program_level(Level::Top, &bits);
        let v = sa.vdd_for_threshold(theta);
        let rep = sa.tmvm(&x, 0, v, TmvmMode::Ideal);
        all_outputs.extend(rep.outputs);
    }
    for (r, row) in g.iter().enumerate() {
        let count = row.iter().zip(&x).filter(|(&w, &xi)| w && xi).count();
        assert_eq!(all_outputs[r], count >= theta, "row {r}");
    }
}

//! Integration: the event-driven fabric must be bit-exact with the
//! functional models — `tiled_tmvm_counts` for single layers and chained
//! `BinaryLayer::forward` for deep stacks — across random shapes and
//! fabric grids, while reporting physically sensible timing/energy. Also
//! drives a whole fabric through the L3 coordinator.

use std::time::Duration;
use xpoint_imc::coordinator::{Coordinator, CoordinatorConfig};
use xpoint_imc::engine::{BackendKind, EngineSpec, NetworkSource};
use xpoint_imc::fabric::{FabricConfig, FabricExecutor};
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::report::table2::template_layer;
use xpoint_imc::scaling::tiling::{tiled_tmvm_counts, Tiling};
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize) -> BinaryLayer {
    let theta = rng.range(1, 6);
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.45)).collect())
            .collect(),
        theta,
    )
}

/// Property: single-layer fabric counts equal `tiled_tmvm_counts` (same
/// tiling) and bits equal `BinaryLayer::forward`, for random shapes,
/// tile sizes and fabric grids.
#[test]
fn prop_fabric_matches_tiled_counts_and_forward() {
    forall(Config::default().cases(60), "fabric ≡ tiled counts", |rng| {
        let n_out = rng.range(1, 40);
        let n_in = rng.range(1, 60);
        let layer = random_layer(rng, n_out, n_in);
        let tile_rows = rng.range(1, 24);
        let tile_cols = rng.range(1, 24);
        let grid = (rng.range(1, 4), rng.range(1, 4));
        let cfg = FabricConfig::new(grid.0, grid.1, tile_rows, tile_cols);
        let exec = FabricExecutor::new(vec![layer.clone()], cfg)
            .map_err(|e| format!("placement: {e}"))?;

        let m = rng.range(1, 8);
        let images: Vec<Vec<bool>> = (0..m)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let run = exec.run_batch(&images).map_err(|e| format!("run: {e}"))?;

        let g: Vec<Vec<bool>> = layer.weights.clone();
        let tiling = Tiling::new(n_out, n_in, tile_rows, tile_cols);
        for (i, img) in images.iter().enumerate() {
            let want_counts = tiled_tmvm_counts(&tiling, &g, img);
            if run.final_counts[i] != want_counts {
                return Err(format!(
                    "image {i}: counts {:?} != tiled {:?} (grid {grid:?}, tile {tile_rows}×{tile_cols})",
                    run.final_counts[i], want_counts
                ));
            }
            if run.outputs[i] != layer.forward(img) {
                return Err(format!("image {i}: bits diverge from forward"));
            }
        }
        Ok(())
    });
}

/// Property: deep stacks (2–4 layers) through random fabrics equal the
/// chained functional forward pass, and the run reports are sane.
#[test]
fn prop_multilayer_fabric_matches_chained_forward() {
    forall(Config::default().cases(40), "deep fabric ≡ forward chain", |rng| {
        let depth = rng.range(2, 5);
        let mut widths = vec![rng.range(4, 40)];
        for _ in 0..depth {
            widths.push(rng.range(2, 30));
        }
        let mut layers = Vec::with_capacity(depth);
        for k in 0..depth {
            layers.push(random_layer(rng, widths[k + 1], widths[k]));
        }
        let cfg = FabricConfig::new(rng.range(1, 4), rng.range(1, 4), rng.range(2, 16), rng.range(2, 16));
        let exec = FabricExecutor::new(layers.clone(), cfg).map_err(|e| format!("{e}"))?;

        let m = rng.range(1, 6);
        let images: Vec<Vec<bool>> = (0..m)
            .map(|_| (0..widths[0]).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let run = exec.run_batch(&images).map_err(|e| format!("{e}"))?;

        for (i, img) in images.iter().enumerate() {
            let mut x = img.clone();
            for l in &layers {
                x = l.forward(&x);
            }
            if run.outputs[i] != x {
                return Err(format!("image {i} diverges (depth {depth})"));
            }
        }
        // report sanity (energy can legitimately be 0 when a sparse random
        // case yields all-zero counts — no current flows anywhere)
        if !(run.makespan > 0.0 && run.cycles > 0 && run.energy >= 0.0) {
            return Err("empty report".into());
        }
        if run.utilization.iter().any(|&u| !(0.0..=1.0).contains(&u)) {
            return Err(format!("utilization out of range: {:?}", run.utilization));
        }
        let expected_steps: u64 = (m * exec.placement().n_tiles()) as u64;
        if run.steps != expected_steps {
            return Err(format!("steps {} != m·tiles {expected_steps}", run.steps));
        }
        Ok(())
    });
}

/// Pipelining: on a fabric with one tile per node, a batch finishes far
/// sooner than images run back to back, and per-image completions are
/// staggered monotonically.
#[test]
fn pipeline_overlap_beats_serial_execution() {
    let mut rng = Pcg32::seeded(314);
    let layers = vec![
        random_layer(&mut rng, 16, 24),
        random_layer(&mut rng, 16, 16),
        random_layer(&mut rng, 8, 16),
    ];
    let exec = FabricExecutor::new(layers, FabricConfig::new(2, 2, 24, 24)).unwrap();
    let image = |rng: &mut Pcg32| -> Vec<bool> { (0..24).map(|_| rng.bernoulli(0.5)).collect() };
    let one = vec![image(&mut rng)];
    let latency = exec.run_batch(&one).unwrap().makespan;

    let m = 16;
    let many: Vec<Vec<bool>> = (0..m).map(|_| image(&mut rng)).collect();
    let run = exec.run_batch(&many).unwrap();
    assert!(
        run.makespan < 0.6 * m as f64 * latency,
        "batch {} vs serial {}",
        run.makespan,
        m as f64 * latency
    );
    // completions are monotone (FIFO injection) and all within the run
    for w in run.per_image_done.windows(2) {
        assert!(w[1] >= w[0], "completions out of order: {:?}", run.per_image_done);
    }
    assert!(run.per_image_done.iter().all(|&t| t <= run.makespan + 1e-15));
}

/// The serving shell drives a whole fabric: predictions through the
/// fabric engine match the functional layer exactly, with fabric
/// timing/energy flowing into the coordinator metrics.
#[test]
fn coordinator_serves_fabric_backend() {
    let factories = EngineSpec::new(BackendKind::Fabric)
        .with_workers(2)
        .with_network(NetworkSource::Template)
        .with_grid(2, 2)
        .with_tile(64, 32)
        .with_fabric_max_batch(1024)
        .build_factories()
        .expect("valid engine spec");
    let mut coord = Coordinator::spawn(
        factories,
        CoordinatorConfig {
            batch_capacity: 32,
            linger: Duration::from_micros(100),
            autoscale: None,
        },
    );
    let layer = template_layer();
    let mut gen = xpoint_imc::nn::dataset::DigitGen::new(xpoint_imc::nn::dataset::TEST_SEED);
    let n = 128;
    let mut expected = Vec::with_capacity(n);
    let mut rxs = Vec::with_capacity(n);
    for _ in 0..n {
        let s = gen.next_sample();
        expected.push((layer.forward(&s.pixels), layer.argmax(&s.pixels)));
        rxs.push(coord.submit(s.pixels, Some(s.label)).expect("submit"));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let pred = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
        assert_eq!(pred.bits, expected[i].0, "request {i} bits");
        assert_eq!(pred.class, expected[i].1, "request {i} class");
    }
    let snap = coord.shutdown();
    assert_eq!(snap.images, n as u64);
    assert!(snap.accuracy.expect("labelled") > 0.5);
    assert!(snap.energy > 0.0, "fabric energy reaches the metrics");
    assert!(snap.sim_time > 0.0, "fabric makespan reaches the metrics");
}

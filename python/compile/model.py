"""L2: the binary NN inference graph (calls the L1 Pallas kernel) and the
straight-through-estimator trainer that produces the binarized weights
shipped as artifacts.

Execution semantics match the rust coordinator's scheme (images stored as
subarray rows, weight pulses applied per neuron step): functionally, a
batch of images X (B, 121) against weights W (121, P) with a shared integer
firing threshold theta.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.tmvm import tmvm_pallas


# ----------------------------------------------------------------- inference

def single_layer_infer(x, w, alpha, r_th, v_dd):
    """Single-layer binary NN through the Pallas kernel.

    Returns (bits (B,P), currents (B,P)).
    """
    bits, i_t = tmvm_pallas(x, w, alpha, r_th, v_dd)
    return bits, i_t


def mlp_infer(x, w1, w2, v_dd1, v_dd2):
    """Three-layer (input-hidden-output) binary NN, ideal electrical
    conditions (alpha = 1, r_th = 0) - the functional golden model of the
    Fig. 8 two-subarray pipeline."""
    b = x.shape[0]
    ones = jnp.ones((b, 1), jnp.float32)
    zeros = jnp.zeros((b, 1), jnp.float32)
    h_bits, _ = tmvm_pallas(x, w1, ones, zeros, v_dd1)
    y_bits, i2 = tmvm_pallas(h_bits, w2, ones, zeros, v_dd2)
    return y_bits, i2


# ------------------------------------------------------------------ training

def _binarize_ste(w_real):
    """{0,1} binarization with a straight-through gradient."""
    w_bin = (w_real > 0.0).astype(jnp.float32)
    return w_real + jax.lax.stop_gradient(w_bin - w_real)


def train_single_layer(
    xs: np.ndarray,
    ys: np.ndarray,
    *,
    epochs: int = 300,
    lr: float = 0.1,
    ink_reg: float = 2e-4,
    seed: int = 0,
) -> np.ndarray:
    """Train a 121->10 binary layer with STE; returns w (121, 10) in {0,1}.

    Initialization is *discriminative*: (class prototype - global mean), so
    pixels shared by every digit start near zero weight. An ink-variance
    regularizer keeps per-class weight counts comparable, which matters for
    count-space argmax fairness. Reaches ~96% test argmax accuracy on the
    synthetic corpus.
    """
    n_in, n_out = xs.shape[1], int(ys.max()) + 1
    proto = np.zeros((n_in, n_out), dtype=np.float32)
    for c in range(n_out):
        proto[:, c] = xs[ys == c].mean(axis=0)
    w_real = jnp.asarray((proto - xs.mean(axis=0)[:, None]) * 4.0)

    x = jnp.asarray(xs)
    y = jnp.asarray(ys)

    def loss_fn(w):
        w_bin = _binarize_ste(w)
        logits = x @ w_bin
        logp = jax.nn.log_softmax(logits, axis=1)
        ce = -jnp.mean(logp[jnp.arange(x.shape[0]), y])
        return ce + ink_reg * jnp.var(w_bin.sum(axis=0))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    for _ in range(epochs):
        _, g = grad_fn(w_real)
        w_real = w_real - lr * g
    return np.asarray((w_real > 0.0).astype(jnp.float32))


def train_mlp(
    xs: np.ndarray,
    ys: np.ndarray,
    *,
    n_hidden: int = 64,
    theta1: int = 14,
    epochs: int = 200,
    lr: float = 0.1,
    seed: int = 1,
) -> tuple[np.ndarray, np.ndarray]:
    """Train a 121->H->10 binary MLP.

    First layer: 10 trained class-detector columns + random binary masks
    (density 0.3), all sharing the hardware firing threshold theta1; second
    layer: trained binary readout over the hidden bits. The shared
    threshold is a genuine hardware constraint (all neurons of a step see
    the same V_DD) and costs accuracy versus the single-layer network — a
    faithful trade-off recorded in EXPERIMENTS.md.

    Returns (w1 (121, H), w2 (H, 10)) in {0,1}.
    """
    detectors = train_single_layer(xs, ys, epochs=epochs, lr=lr)
    rng = np.random.default_rng(seed)
    n_extra = max(0, n_hidden - detectors.shape[1])
    w1 = np.concatenate(
        [detectors, (rng.random((xs.shape[1], n_extra)) < 0.3).astype(np.float32)],
        axis=1,
    )[:, :n_hidden]
    hidden = ((xs @ w1) >= theta1).astype(np.float32)
    w2 = train_single_layer(hidden, ys, epochs=epochs, lr=lr)
    return w1, w2


# ---------------------------------------------------------------- evaluation

def pick_theta(xs: np.ndarray, ys: np.ndarray, w: np.ndarray) -> int:
    """Choose the shared integer firing threshold maximizing the one-hot
    validity rate (correct neuron fires, all others quiet)."""
    counts = xs @ w  # (B, P)
    best_theta, best_rate = 1, -1.0
    for theta in range(1, int(counts.max()) + 2):
        fired = counts >= theta
        correct = fired[np.arange(len(ys)), ys]
        others = fired.sum(axis=1) - correct
        rate = float(np.mean(correct & (others == 0)))
        if rate > best_rate:
            best_theta, best_rate = theta, rate
    return best_theta


def accuracy_argmax(xs: np.ndarray, ys: np.ndarray, w: np.ndarray) -> float:
    """Functional argmax accuracy of the count space (ties -> lowest index,
    matching rust BinaryLayer::argmax)."""
    counts = xs @ w
    pred = np.argmax(counts, axis=1)
    return float(np.mean(pred == ys))


def mlp_accuracy(
    xs: np.ndarray, ys: np.ndarray, w1: np.ndarray, theta1: int, w2: np.ndarray
) -> float:
    hidden = ((xs @ w1) >= theta1).astype(np.float32)
    return accuracy_argmax(hidden, ys, w2)

//! The coordinator engine: a leader thread batches incoming requests and
//! dispatches them to scheduler threads, each driving one [`Engine`]
//! **purely through the non-blocking `submit`/`poll` pair**.
//!
//! The scheduler loop is backend-agnostic by construction: a synchronous
//! engine (one simulated subarray, a fabric, the XLA golden model)
//! completes its batch inside `submit` and the very next `poll` redeems
//! it — the `Completions`-backed submit/poll of those engines is the
//! trivial adapter. An asynchronous engine
//! ([`ShardedEngine`](crate::engine::ShardedEngine)) returns from
//! `submit` immediately while its shard threads work, so the scheduler
//! keeps several batches in flight (bounded by
//! [`Capabilities::shards`](crate::engine::Capabilities)) and drains
//! completions **out of order**, matching each ticket back to the jobs
//! that produced it — per-request identity is preserved by construction.
//!
//! std-thread based — the build is offline and the workload is CPU-bound
//! simulation, so threads + channels outperform an async reactor here.

use crate::engine::{BackendFactory, EngineError, Ticket};
use crate::nn::packed::PackedBatch;
use crate::nn::BinaryLayer;
use super::autoscale::{AutoscalePolicy, ScaleDecision};
use super::batcher::Batcher;
use super::metrics::{Metrics, MetricsSnapshot};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max images per batch (≤ backend max batch).
    pub batch_capacity: usize,
    /// How long a partial batch may wait before shipping.
    pub linger: Duration,
    /// Elastic autoscaling policy, evaluated in every scheduler's loop
    /// (engines that cannot scale just hold their fleet).
    pub autoscale: Option<AutoscalePolicy>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batch_capacity: 64,
            linger: Duration::from_micros(200),
            autoscale: None,
        }
    }
}

/// A classification response.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub id: u64,
    /// Hardware thresholded output bits.
    pub bits: Vec<bool>,
    /// Functional class prediction.
    pub class: usize,
}

struct Job {
    id: u64,
    image: Vec<bool>,
    label: Option<usize>,
    reply: mpsc::Sender<Prediction>,
}

enum Message {
    Job(Job),
    /// Rolling update: live-swap every worker engine to this network.
    Swap(Vec<BinaryLayer>),
    Shutdown,
}

/// What the leader hands a scheduler thread.
enum Work {
    Jobs(Vec<Job>),
    Swap(Vec<BinaryLayer>),
}

/// Upper bound on how long a scheduler parks waiting for engine-side
/// progress. Completions wake it immediately (asynchronous engines park
/// on their completion channel — `Engine::wait_event`); the bound only
/// caps how stale the intake check can get while nothing completes.
const WAIT_INTERVAL: Duration = Duration::from_micros(200);

/// How often an otherwise-idle scheduler wakes to evaluate the autoscale
/// policy (idle = nothing in flight; the only reason to wake at all is a
/// possible scale-down).
const IDLE_EVAL_INTERVAL: Duration = Duration::from_millis(1);

/// Minimum wall-clock gap between autoscale policy evaluations. Under
/// load the scheduler loop spins in microseconds; pacing the policy
/// keeps its cooldown (counted in evaluations) meaning real hysteresis
/// instead of a handful of loop passes.
const AUTOSCALE_EVAL_INTERVAL: Duration = Duration::from_millis(1);

/// One submitted batch the scheduler is waiting on. The packed buffer is
/// retained (`None` for ragged batches that went down the scalar path) so
/// an engine-side failure — a shard dying with the batch in flight — can
/// re-dispatch the *shared* buffer instead of recloning every image.
struct Pending {
    ticket: Ticket,
    jobs: Vec<Job>,
    batch: Option<PackedBatch>,
    submitted: Instant,
    /// One retry only: a second failure fails the batch for real.
    retried: bool,
}

/// Deliver one completed batch: replies to every job, then one metrics
/// record for the batch.
fn deliver(
    metrics: &Metrics,
    jobs: Vec<Job>,
    res: crate::engine::InferenceResult,
    submitted: Instant,
) {
    let latency = submitted.elapsed().as_secs_f64() / jobs.len().max(1) as f64;
    let mut correct = 0u64;
    let mut labelled = 0u64;
    for (j, job) in jobs.iter().enumerate() {
        if let Some(label) = job.label {
            labelled += 1;
            if res.classes[j] == label {
                correct += 1;
            }
        }
        let _ = job.reply.send(Prediction {
            id: job.id,
            bits: res.bits[j].clone(),
            class: res.classes[j],
        });
    }
    metrics.record_batch(
        jobs.len() as u64,
        res.steps,
        latency,
        res.sim_time,
        res.energy,
        correct,
        labelled,
    );
}

/// The scheduler loop: one per engine. Accepts job batches (and rolling
/// weight-swap orders) from the leader, submits them, and drains
/// completions out of order — the only engine surface it touches is
/// `submit`/`poll`/`begin_swap`/`poll_swap`/`wait_event` plus the elastic
/// `scale_load`/`spawn_shard`/`retire_shard` trio (+ introspection). A
/// rolling swap on an asynchronous engine proceeds *while* the loop keeps
/// submitting traffic, so aggregate throughput never hits zero; the
/// autoscale policy (when configured) is evaluated every pass against the
/// engine's live load.
///
/// The loop never spins a host core: when a pass makes no progress it
/// parks in `Engine::wait_event`, which blocks on the engine's completion
/// channel (asynchronous engines) until something actually happens — the
/// fix for the 100% CPU burn previously visible while a swap walk had
/// every shard out of service.
fn scheduler_main(
    wid: usize,
    factory: BackendFactory,
    wrx: mpsc::Receiver<Work>,
    metrics: Arc<Metrics>,
    mut policy: Option<AutoscalePolicy>,
) {
    let mut engine = match factory() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("worker {wid}: backend construction failed: {e:#}");
            return;
        }
    };
    let mut in_flight: Vec<Pending> = Vec::new();
    let mut swap_pending = false;
    let mut open = true;
    let mut last_eval: Option<Instant> = None;
    let mut last_scale_err = String::new();

    while open || !in_flight.is_empty() || swap_pending {
        let mut progressed = false;
        // keep enough batches in flight to cover every shard plus one
        // being formed; re-read each pass — an elastic engine's pool
        // grows and shrinks under the autoscaler. Synchronous engines
        // complete at submit, so for them this bound is never reached.
        // With autoscaling, allow extra backlog: the policy can only see
        // work already submitted to the engine, so without headroom the
        // high watermark would be unreachable past the first spawn.
        let headroom = policy.as_ref().map(|p| p.max_shards()).unwrap_or(0);
        let max_in_flight = engine.capabilities().shards.max(1) + 1 + headroom;

        // 1. intake — block only when nothing needs driving engine-side
        // (with autoscaling, wake periodically so an idle engine can
        // still scale down)
        if open && in_flight.len() < max_in_flight {
            let next = if in_flight.is_empty() && !swap_pending {
                let recv = match &policy {
                    None => wrx.recv().map_err(mpsc::RecvTimeoutError::from),
                    Some(_) => wrx.recv_timeout(IDLE_EVAL_INTERVAL),
                };
                match recv {
                    Ok(work) => Some(work),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        None
                    }
                }
            } else {
                // work is in flight: take whatever is already queued, but
                // never block here — step 5 parks on the engine instead
                match wrx.try_recv() {
                    Ok(work) => Some(work),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        None
                    }
                }
            };
            match next {
                Some(Work::Jobs(jobs)) => {
                    progressed = true;
                    // pack once at ingest: the jobs' bits land in one
                    // contiguous buffer, and every later hop — dispatch to
                    // a shard thread, reroute off a dead one — moves an
                    // `Arc`, not cloned images. Ragged job batches (mixed
                    // image widths) stay scalar; engines own shape policy.
                    let rows: Vec<&[bool]> = jobs.iter().map(|j| j.image.as_slice()).collect();
                    // stamp before submit: synchronous engines do the whole
                    // inference inside it, and that time is the latency
                    let submitted = Instant::now();
                    let (issued, batch) = match PackedBatch::from_rows(&rows) {
                        Some(b) => (engine.submit_packed(b.clone()), Some(b)),
                        None => {
                            let images: Vec<Vec<bool>> =
                                jobs.iter().map(|j| j.image.clone()).collect();
                            (engine.submit(images), None)
                        }
                    };
                    match issued {
                        Ok(ticket) => in_flight.push(Pending {
                            ticket,
                            jobs,
                            batch,
                            submitted,
                            retried: false,
                        }),
                        Err(e) => {
                            eprintln!(
                                "worker {wid}: submit of {} jobs failed: {e:#}",
                                jobs.len()
                            )
                        }
                    }
                }
                Some(Work::Swap(target)) => {
                    progressed = true;
                    match engine.begin_swap(target) {
                        // synchronous engines rewrite inline
                        Ok(Some(report)) => metrics.record_swap(&report),
                        // a rolling swap is now walking the shards
                        Ok(None) => swap_pending = true,
                        Err(e) => eprintln!("worker {wid}: weight swap rejected: {e:#}"),
                    }
                }
                None => {}
            }
        }

        // 2. drain — redeem every ready ticket, in whatever order the
        // engine finished them
        let mut i = 0;
        while i < in_flight.len() {
            match engine.poll(in_flight[i].ticket) {
                Ok(Some(res)) => {
                    progressed = true;
                    let p = in_flight.swap_remove(i);
                    deliver(&metrics, p.jobs, res, p.submitted);
                }
                Ok(None) => i += 1,
                Err(e) => {
                    progressed = true;
                    let mut p = in_flight.swap_remove(i);
                    // one retry when the packed buffer was retained (the
                    // shard owning the batch died mid-flight): the
                    // re-dispatch shares the buffer — an `Arc` clone,
                    // never a fresh copy of the images
                    let resubmit = match (&p.batch, p.retried) {
                        (Some(b), false) => Some(engine.submit_packed(b.clone())),
                        _ => None,
                    };
                    match resubmit {
                        Some(Ok(ticket)) => {
                            eprintln!(
                                "worker {wid}: batch (ticket {}, {} jobs) failed: {e:#}; \
                                 re-dispatched the shared buffer as ticket {ticket}",
                                p.ticket,
                                p.jobs.len()
                            );
                            p.ticket = ticket;
                            p.retried = true;
                            p.submitted = Instant::now();
                            in_flight.push(p);
                        }
                        Some(Err(re)) => {
                            eprintln!(
                                "worker {wid}: batch (ticket {}, {} jobs) failed: {e:#}; \
                                 retry also failed: {re:#}",
                                p.ticket,
                                p.jobs.len()
                            );
                        }
                        None => {
                            eprintln!(
                                "worker {wid}: batch (ticket {}, {} jobs) failed: {e:#}",
                                p.ticket,
                                p.jobs.len()
                            );
                        }
                    }
                }
            }
        }

        // 3. drive the rolling swap: every pass advances the walk
        // (drain → reprogram → rejoin) without blocking traffic
        if swap_pending {
            match engine.poll_swap() {
                Ok(Some(report)) => {
                    progressed = true;
                    metrics.record_swap(&report);
                    swap_pending = false;
                }
                Ok(None) => {}
                Err(e) => {
                    eprintln!("worker {wid}: rolling swap failed: {e:#}");
                    swap_pending = false;
                }
            }
        }

        // 4. autoscale — evaluate the policy against the engine's live
        // load (at most once per AUTOSCALE_EVAL_INTERVAL of wall clock)
        // and fold completed scale events into the metrics
        if let Some(p) = policy.as_mut() {
            let due = match last_eval {
                Some(t) => t.elapsed() >= AUTOSCALE_EVAL_INTERVAL,
                None => true,
            };
            if due {
                last_eval = Some(Instant::now());
                // pump the engine first: an otherwise-idle loop would
                // never drain a finishing walk's events (scale_load is a
                // pure snapshot), leaving a spawned slot un-rejoined
                engine.wait_event(Duration::ZERO);
                let decision = p.decide(&engine.scale_load());
                let acted = match decision {
                    ScaleDecision::Up => engine.spawn_shard().map(|_| ()),
                    ScaleDecision::Down => engine.retire_shard().map(|_| ()),
                    ScaleDecision::Hold => Ok(()),
                };
                match acted {
                    Ok(()) => last_scale_err.clear(),
                    Err(e) => {
                        // the engine rejected the decision — don't burn a
                        // cooldown window on a shard that never happened
                        p.rescind();
                        // a walk already in flight is expected back-pressure
                        // (EngineError::ScaleBusy — the vendored anyhow keeps
                        // messages, not types); anything else (budget
                        // exhausted, engine can't scale) is worth a line,
                        // once per distinct cause
                        let msg = format!("{e:#}");
                        let busy = msg == EngineError::ScaleBusy.to_string();
                        if !busy && msg != last_scale_err {
                            eprintln!(
                                "worker {wid}: autoscale {decision:?} rejected: {msg}"
                            );
                            last_scale_err = msg;
                        }
                    }
                }
            }
        }
        for event in engine.take_scale_events() {
            metrics.record_scale(&event);
        }

        // 5. park — nothing moved this pass and the engine owes us
        // progress: block on its completion channel instead of spinning
        if !progressed && (!in_flight.is_empty() || swap_pending) {
            engine.wait_event(WAIT_INTERVAL);
        }
    }
    // let an in-flight lifecycle walk land (bounded) so its event — and
    // the slot's final telemetry — aren't lost at shutdown
    let mut settle_budget = 100u32;
    while !engine.scale_settled() && settle_budget > 0 {
        engine.wait_event(WAIT_INTERVAL);
        settle_budget -= 1;
    }
    for event in engine.take_scale_events() {
        metrics.record_scale(&event);
    }
    // final per-shard telemetry into the shared metrics (one entry per
    // shard; plain engines contribute a single entry)
    metrics.record_shards(engine.shard_telemetry());
    // canary-carrying engines also fold their divergence tallies
    if let Some(report) = engine.canary_report() {
        metrics.record_canary(report);
    }
}

/// The running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Message>,
    leader: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: u64,
}

impl Coordinator {
    /// Spawn the leader and one scheduler per backend factory. Each
    /// factory runs on its scheduler thread (PJRT handles are
    /// thread-affine; sharded engines spawn their own shard threads from
    /// there).
    pub fn spawn(backends: Vec<BackendFactory>, config: CoordinatorConfig) -> Self {
        assert!(!backends.is_empty());
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Message>();

        // scheduler channels
        let mut worker_txs = Vec::new();
        let mut worker_handles = Vec::new();
        for (wid, factory) in backends.into_iter().enumerate() {
            let (wtx, wrx) = mpsc::channel::<Work>();
            let m = Arc::clone(&metrics);
            let policy = config.autoscale.clone();
            worker_txs.push(wtx);
            worker_handles.push(std::thread::spawn(move || {
                scheduler_main(wid, factory, wrx, m, policy)
            }));
        }

        // leader: batch + round-robin dispatch over the schedulers
        let cfg = config.clone();
        let leader = std::thread::spawn(move || {
            let mut batcher: Batcher<Job> = Batcher::new(cfg.batch_capacity, cfg.linger);
            let mut next_worker = 0usize;
            let dispatch = |batch: Vec<super::batcher::Request<Job>>,
                                next_worker: &mut usize| {
                let jobs: Vec<Job> = batch.into_iter().map(|r| r.payload).collect();
                let _ = worker_txs[*next_worker % worker_txs.len()].send(Work::Jobs(jobs));
                *next_worker += 1;
            };
            loop {
                // wait for work, but wake up to honour the linger deadline
                match rx.recv_timeout(cfg.linger.max(Duration::from_micros(50))) {
                    Ok(Message::Job(job)) => {
                        let id = job.id;
                        batcher.push(id, job);
                    }
                    Ok(Message::Swap(target)) => {
                        // rolling update: flush formed batches first so the
                        // swap lands between batches, then walk every
                        // worker engine (each rolls its own shards)
                        while let Some(batch) = batcher.take_batch(Instant::now()) {
                            dispatch(batch, &mut next_worker);
                        }
                        for wtx in &worker_txs {
                            let _ = wtx.send(Work::Swap(target.clone()));
                        }
                    }
                    Ok(Message::Shutdown) => break,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                while let Some(batch) = batcher.take_batch(Instant::now()) {
                    dispatch(batch, &mut next_worker);
                }
            }
            // drain on shutdown
            let rest = batcher.drain_all();
            if !rest.is_empty() {
                dispatch(rest, &mut next_worker);
            }
            drop(worker_txs);
            for h in worker_handles {
                let _ = h.join();
            }
        });

        Self {
            tx,
            leader: Some(leader),
            metrics,
            next_id: 0,
        }
    }

    /// Submit an image; returns a receiver for the prediction, or an
    /// error if the leader has already exited (instead of panicking —
    /// serving shells must be able to drain gracefully).
    pub fn submit(
        &mut self,
        image: Vec<bool>,
        label: Option<usize>,
    ) -> crate::Result<mpsc::Receiver<Prediction>> {
        let (reply, rx) = mpsc::channel();
        self.next_id += 1;
        let job = Job {
            id: self.next_id,
            image,
            label,
            reply,
        };
        self.tx
            .send(Message::Job(job))
            .map_err(|_| anyhow::anyhow!("coordinator is down: leader exited, not accepting jobs"))?;
        Ok(rx)
    }

    /// Start a rolling live weight swap: every worker engine reprograms
    /// to `target` — sharded engines walk their shards one at a time
    /// (drain → reprogram → rejoin) while the rest keep serving, so
    /// aggregate throughput never hits zero. Asynchronous: returns once
    /// the leader accepts the order; completion (pulse counts, energy,
    /// programming time) lands in [`MetricsSnapshot`]'s swap counters.
    pub fn swap_network(&mut self, target: Vec<BinaryLayer>) -> crate::Result<()> {
        anyhow::ensure!(!target.is_empty(), "swap target stack is empty");
        self.tx
            .send(Message::Swap(target))
            .map_err(|_| anyhow::anyhow!("coordinator is down: leader exited, not accepting swaps"))
    }

    /// Graceful shutdown: flush queues, join workers, return final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let _ = self.tx.send(Message::Shutdown);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Message::Shutdown);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArraySpec, BackendKind, EngineSpec};
    use crate::nn::BinaryLayer;
    use crate::util::Pcg32;

    fn make_backend(seed: u64) -> (BinaryLayer, BackendFactory) {
        let mut rng = Pcg32::seeded(seed);
        let layer = BinaryLayer::new(
            (0..10)
                .map(|_| (0..25).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            4,
        );
        let spec = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 32,
                span: Some(32),
                ..ArraySpec::default()
            })
            .with_batching(32, 200) // capacity may not exceed the 32 rows
            .with_layers(vec![layer.clone()]);
        (layer, spec.build().expect("valid spec"))
    }

    #[test]
    fn coordinator_roundtrip_matches_functional() {
        let (layer, be) = make_backend(5);
        let mut coord = Coordinator::spawn(
            vec![be],
            CoordinatorConfig {
                batch_capacity: 8,
                linger: Duration::from_micros(100),
                autoscale: None,
            },
        );
        let mut rng = Pcg32::seeded(9);
        let images: Vec<Vec<bool>> = (0..40)
            .map(|_| (0..25).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let receivers: Vec<_> = images
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit"))
            .collect();
        for (img, rx) in images.iter().zip(receivers) {
            let pred = rx.recv_timeout(Duration::from_secs(10)).expect("reply");
            assert_eq!(pred.bits, layer.forward(img));
            assert_eq!(pred.class, layer.argmax(img));
        }
        let snap = coord.shutdown();
        assert_eq!(snap.images, 40);
        assert!(snap.energy > 0.0);
        assert!(snap.batches >= 5, "batched into ≥5 batches of ≤8");
        assert_eq!(snap.shards.len(), 1, "one plain engine = one shard entry");
        assert_eq!(snap.shards[0].images, 40);
    }

    #[test]
    fn multiple_workers_share_load() {
        let (_, b1) = make_backend(5);
        let (_, b2) = make_backend(5);
        let mut coord = Coordinator::spawn(
            vec![b1, b2],
            CoordinatorConfig {
                batch_capacity: 4,
                linger: Duration::from_micros(50),
                autoscale: None,
            },
        );
        let mut rng = Pcg32::seeded(10);
        let rxs: Vec<_> = (0..32)
            .map(|_| {
                let img: Vec<bool> = (0..25).map(|_| rng.bernoulli(0.5)).collect();
                coord.submit(img, Some(3)).expect("submit")
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).expect("reply");
        }
        let snap = coord.shutdown();
        assert_eq!(snap.images, 32);
        assert!(snap.accuracy.is_some());
        assert_eq!(snap.shards.len(), 2, "one shard entry per worker engine");
    }

    /// The scheduler loop drives a genuinely asynchronous engine: a
    /// sharded backend whose batches complete on shard threads, out of
    /// order — every prediction must still reach its own requester.
    #[test]
    fn scheduler_serves_a_sharded_engine() {
        let mut rng = Pcg32::seeded(21);
        let layer = BinaryLayer::new(
            (0..10)
                .map(|_| (0..25).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            4,
        );
        let spec = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 32,
                span: Some(32),
                ..ArraySpec::default()
            })
            .with_batching(8, 100)
            .with_layers(vec![layer.clone()])
            .with_shards(3, BackendKind::Ideal)
            .with_workers(1);
        let mut coord = Coordinator::spawn(
            spec.build_factories().expect("sharded factories"),
            CoordinatorConfig {
                batch_capacity: 8,
                linger: Duration::from_micros(50),
                autoscale: None,
            },
        );
        let images: Vec<Vec<bool>> = (0..64)
            .map(|_| (0..25).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let rxs: Vec<_> = images
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit"))
            .collect();
        for (img, rx) in images.iter().zip(rxs) {
            let pred = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
            assert_eq!(pred.bits, layer.forward(img), "identity preserved");
        }
        let snap = coord.shutdown();
        assert_eq!(snap.images, 64);
        assert_eq!(snap.shards.len(), 3, "per-shard telemetry reaches metrics");
        let spread: u64 = snap.shards.iter().map(|t| t.images).sum();
        assert_eq!(spread, 64, "every image accounted to some shard");
    }

    /// The full rolling update path: serve → `swap_network` → keep
    /// serving. Every prediction is wholly-old or wholly-new, the swap's
    /// pulse accounting lands in the metrics, and traffic submitted while
    /// the shards roll still completes (throughput never hits zero).
    #[test]
    fn rolling_swap_through_the_scheduler_flips_predictions() {
        let mut rng = Pcg32::seeded(31);
        let mut random_layer = |theta: usize| {
            BinaryLayer::new(
                (0..10)
                    .map(|_| (0..25).map(|_| rng.bernoulli(0.5)).collect())
                    .collect(),
                theta,
            )
        };
        let old = random_layer(4);
        let new = random_layer(3);
        let spec = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 32,
                span: Some(32),
                ..ArraySpec::default()
            })
            .with_batching(8, 100)
            .with_layers(vec![old.clone()])
            .with_shards(2, BackendKind::Ideal)
            .with_workers(1);
        let mut coord = Coordinator::spawn(
            spec.build_factories().expect("factories"),
            CoordinatorConfig {
                batch_capacity: 8,
                linger: Duration::from_micros(50),
                autoscale: None,
            },
        );
        let mut rng2 = Pcg32::seeded(32);
        let mut image = move || -> Vec<bool> { (0..25).map(|_| rng2.bernoulli(0.4)).collect() };

        // phase 1 — old weights serve
        let imgs: Vec<Vec<bool>> = (0..8).map(|_| image()).collect();
        let rxs: Vec<_> = imgs
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit"))
            .collect();
        for (img, rx) in imgs.iter().zip(rxs) {
            let pred = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
            assert_eq!(pred.bits, old.forward(img), "pre-swap is wholly-old");
        }

        // phase 2 — order the rolling update and keep the traffic flowing;
        // every in-window prediction is wholly-old or wholly-new
        coord.swap_network(vec![new.clone()]).expect("swap accepted");
        let imgs: Vec<Vec<bool>> = (0..16).map(|_| image()).collect();
        let rxs: Vec<_> = imgs
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit during swap"))
            .collect();
        for (img, rx) in imgs.iter().zip(rxs) {
            let pred = rx.recv_timeout(Duration::from_secs(30)).expect("served during swap");
            let is_old = pred.bits == old.forward(img);
            let is_new = pred.bits == new.forward(img);
            assert!(is_old || is_new, "never a torn mix");
        }

        // phase 3 — wait for the swap to land, then everything is new
        let deadline = Instant::now() + Duration::from_secs(30);
        while coord.metrics.snapshot().swaps == 0 {
            assert!(Instant::now() < deadline, "rolling swap never completed");
            std::thread::sleep(Duration::from_millis(1));
        }
        let imgs: Vec<Vec<bool>> = (0..8).map(|_| image()).collect();
        let rxs: Vec<_> = imgs
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit"))
            .collect();
        for (img, rx) in imgs.iter().zip(rxs) {
            let pred = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
            assert_eq!(pred.bits, new.forward(img), "post-swap is wholly-new");
        }
        let snap = coord.shutdown();
        assert_eq!(snap.swaps, 1, "one engine-level rolling swap");
        assert!(snap.set_pulses + snap.reset_pulses > 0, "pulses accounted");
        assert!(snap.swap_energy > 0.0 && snap.swap_time > 0.0);
        assert_eq!(snap.images, 32);
    }

    /// The autoscaler runs live in the scheduler loop: a sustained burst
    /// over an elastic 1-shard engine crosses the (aggressively low) high
    /// watermark, the fleet grows, and every prediction stays correct.
    #[test]
    fn scheduler_autoscales_an_elastic_engine_under_burst() {
        use crate::engine::AutoscaleSpec;
        let mut rng = Pcg32::seeded(41);
        let layer = BinaryLayer::new(
            (0..10)
                .map(|_| (0..25).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            4,
        );
        let spec = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 32,
                span: Some(32),
                ..ArraySpec::default()
            })
            .with_batching(16, 100)
            .with_layers(vec![layer.clone()])
            .with_autoscale(AutoscaleSpec {
                min_shards: 1,
                max_shards: 3,
                high_watermark: 1,
                low_watermark: 0,
                cooldown: 0,
                pulse_budget: 0,
            })
            .with_workers(1);
        // low_watermark 0 can never undercut (backlog is never < 0), so
        // the fleet only grows — deterministic assertions below. The
        // burst is large enough that several paced policy evaluations
        // land while backlog is visible.
        let mut coord = Coordinator::spawn(
            spec.build_factories().expect("elastic factories"),
            spec.coordinator_config(),
        );
        const N: usize = 4096;
        let images: Vec<Vec<bool>> = (0..N)
            .map(|_| (0..25).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let rxs: Vec<_> = images
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit"))
            .collect();
        for (img, rx) in images.iter().zip(rxs) {
            let pred = rx.recv_timeout(Duration::from_secs(60)).expect("reply");
            assert_eq!(pred.bits, layer.forward(img), "identity preserved");
        }
        let snap = coord.shutdown();
        assert_eq!(snap.images, N as u64);
        assert!(
            snap.spawns >= 1,
            "a {N}-image burst over a 1-shard engine with watermark 1 must scale up"
        );
        assert!(snap.spawn_pulses > 0, "spawns paid their programming");
        assert_eq!(snap.retires, 0, "low watermark 0 never triggers");
        // final telemetry covers every slot, and each carries its wear
        assert!(snap.shards.len() >= 2);
        assert!(snap.shards.iter().all(|t| t.wear_pulses > 0));
        let spread: u64 = snap.shards.iter().map(|t| t.images).sum();
        assert_eq!(spread, N as u64, "every image accounted to some slot");
    }

    /// Regression: an engine-side batch failure (the shard owning it
    /// died mid-flight) re-dispatches the *same* shared packed buffer
    /// once — the jobs still answer, and the reroute moves an `Arc`,
    /// never a fresh copy of the images.
    #[test]
    fn dead_shard_retry_redispatches_the_shared_buffer() {
        use crate::engine::{Capabilities, Engine, InferenceResult, Telemetry};
        use crate::nn::packed::PackedBatch;
        use std::sync::Mutex;

        struct Flaky {
            layer: BinaryLayer,
            next: Ticket,
            pending: Vec<(Ticket, PackedBatch)>,
            failed_once: bool,
            /// Buffer addresses of every packed submission, shared with
            /// the test thread.
            seen: Arc<Mutex<Vec<usize>>>,
        }
        impl Engine for Flaky {
            fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
                Ok(InferenceResult {
                    bits: images.iter().map(|x| self.layer.forward(x)).collect(),
                    classes: images.iter().map(|x| self.layer.argmax(x)).collect(),
                    sim_time: 0.0,
                    energy: 0.0,
                    steps: images.len() as u64,
                })
            }
            fn max_batch(&self) -> usize {
                64
            }
            fn capabilities(&self) -> Capabilities {
                Capabilities {
                    kind: BackendKind::Ideal,
                    n_in: self.layer.n_in(),
                    n_out: self.layer.n_out(),
                    max_batch: 64,
                    nodes: 1,
                    tiles: 1,
                    shards: 1,
                    reports_energy: false,
                    pipelined: false,
                }
            }
            fn telemetry(&self) -> Telemetry {
                Telemetry::default()
            }
            fn submit(&mut self, images: Vec<Vec<bool>>) -> crate::Result<Ticket> {
                let b = PackedBatch::from_images(&images).expect("uniform batch");
                self.submit_packed(b)
            }
            fn submit_packed(&mut self, batch: PackedBatch) -> crate::Result<Ticket> {
                self.seen
                    .lock()
                    .unwrap()
                    .push(batch.row_words(0).as_ptr() as usize);
                self.next += 1;
                self.pending.push((self.next, batch));
                Ok(self.next)
            }
            fn poll(&mut self, ticket: Ticket) -> crate::Result<Option<InferenceResult>> {
                let Some(pos) = self.pending.iter().position(|(t, _)| *t == ticket) else {
                    return Ok(None);
                };
                let (_, batch) = self.pending.remove(pos);
                if !self.failed_once {
                    // first completion "dies" the way a shard thread does:
                    // the ticket fails and the batch is gone engine-side
                    self.failed_once = true;
                    anyhow::bail!("shard 0 worker thread is down");
                }
                self.infer_batch(&batch.to_images()).map(Some)
            }
        }

        let mut rng = Pcg32::seeded(51);
        let layer = BinaryLayer::new(
            (0..6)
                .map(|_| (0..12).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            2,
        );
        let seen: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let (l2, s2) = (layer.clone(), Arc::clone(&seen));
        let factory: BackendFactory = Box::new(move || {
            Ok(Box::new(Flaky {
                layer: l2,
                next: 0,
                pending: Vec::new(),
                failed_once: false,
                seen: s2,
            }) as Box<dyn Engine>)
        });
        let mut coord = Coordinator::spawn(
            vec![factory],
            CoordinatorConfig {
                batch_capacity: 4,
                // long linger: the batch must ship only once all 4 jobs
                // are queued, so exactly one engine submission happens
                // (plus exactly one retry — the addresses pin that)
                linger: Duration::from_secs(5),
                autoscale: None,
            },
        );
        let mut rng2 = Pcg32::seeded(52);
        let imgs: Vec<Vec<bool>> = (0..4)
            .map(|_| (0..12).map(|_| rng2.bernoulli(0.4)).collect())
            .collect();
        let rxs: Vec<_> = imgs
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit"))
            .collect();
        for (img, rx) in imgs.iter().zip(rxs) {
            let pred = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("the retried batch still answers its jobs");
            assert_eq!(pred.bits, layer.forward(img), "answered after the retry");
        }
        drop(coord);
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len(), 2, "original submission plus exactly one retry");
        assert_eq!(seen[0], seen[1], "the retry shared the packed buffer");
    }

    #[test]
    fn submit_after_leader_exit_errors_instead_of_panicking() {
        let (_, be) = make_backend(7);
        let mut coord = Coordinator::spawn(vec![be], CoordinatorConfig::default());
        let mut rng = Pcg32::seeded(12);
        let img: Vec<bool> = (0..25).map(|_| rng.bernoulli(0.5)).collect();
        assert!(coord.submit(img.clone(), None).is_ok());
        // force the leader down without consuming the coordinator (the
        // failure mode a serving shell sees when the leader dies under it)
        coord.tx.send(Message::Shutdown).unwrap();
        coord.leader.take().unwrap().join().unwrap();
        let err = coord.submit(img, None).unwrap_err();
        assert!(err.to_string().contains("coordinator is down"), "{err}");
    }

    #[test]
    fn shutdown_flushes_partial_batches() {
        let (_, be) = make_backend(6);
        let mut coord = Coordinator::spawn(
            vec![be],
            CoordinatorConfig {
                batch_capacity: 1000,
                linger: Duration::from_secs(60), // never ships on its own
                autoscale: None,
            },
        );
        let mut rng = Pcg32::seeded(11);
        let img: Vec<bool> = (0..25).map(|_| rng.bernoulli(0.5)).collect();
        let rx = coord.submit(img, None).expect("submit");
        let snap = coord.shutdown();
        assert_eq!(snap.images, 1);
        assert!(rx.recv_timeout(Duration::from_secs(1)).is_ok());
    }
}

//! Integration: the PJRT runtime executing the AOT artifacts, checked
//! against the rust functional golden model AND the cross-language
//! dataset contract. Requires `make artifacts`; tests skip (with a
//! message) when artifacts are absent.

use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::runtime::artifact::artifacts_available;
use xpoint_imc::runtime::{ArtifactStore, Runtime, TensorF32};

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts missing — run `make artifacts`");
            return;
        }
    };
}

/// The python-generated dataset_check must equal the rust generator's
/// stream bit-for-bit: this pins the SplitMix64 + draw-order contract.
#[test]
fn dataset_contract_rust_equals_python() {
    require_artifacts!();
    let store = ArtifactStore::open_default().unwrap();
    let (labels, images) = store.dataset_check().unwrap();
    let mut gen = DigitGen::new(TEST_SEED);
    for (i, (label, image)) in labels.iter().zip(&images).enumerate() {
        let s = gen.next_sample();
        assert_eq!(s.label, *label, "sample {i} label");
        assert_eq!(&s.pixels, image, "sample {i} pixels");
    }
    assert_eq!(labels.len(), 32);
}

/// Load + compile + execute the single-layer HLO; outputs must equal the
/// rust count-threshold semantics for every image.
#[test]
fn xla_single_layer_matches_rust_functional() {
    require_artifacts!();
    let store = ArtifactStore::open_default().unwrap();
    let layer = store.single_layer().unwrap();
    let v_dd = store.meta_f64("vdd_single").unwrap();
    let batch = store.meta_usize("batch").unwrap();
    assert_eq!(batch, 64);

    let runtime = Runtime::cpu().unwrap();
    let exe = runtime.load_hlo_text(&store.nn_infer_hlo()).unwrap();

    // batch of synthetic digits
    let mut gen = DigitGen::new(TEST_SEED);
    let images: Vec<Vec<bool>> = (0..batch).map(|_| gen.next_sample().pixels).collect();
    let n_in = layer.n_in();
    let n_out = layer.n_out();

    let mut x = vec![0.0f32; batch * n_in];
    for (i, img) in images.iter().enumerate() {
        for (j, &b) in img.iter().enumerate() {
            x[i * n_in + j] = b as u8 as f32;
        }
    }
    let mut w = vec![0.0f32; n_in * n_out];
    for (o, row) in layer.weights.iter().enumerate() {
        for (i, &bit) in row.iter().enumerate() {
            w[i * n_out + o] = bit as u8 as f32;
        }
    }
    let out = exe
        .run(&[
            TensorF32::new(vec![batch, n_in], x),
            TensorF32::new(vec![n_in, n_out], w),
            TensorF32::new(vec![batch, 1], vec![1.0; batch]),
            TensorF32::new(vec![batch, 1], vec![0.0; batch]),
            TensorF32::scalar(v_dd as f32),
        ])
        .unwrap();
    assert_eq!(out.len(), 2, "bits + currents");
    let bits = &out[0];
    assert_eq!(bits.dims, vec![batch, n_out]);

    for (i, img) in images.iter().enumerate() {
        let expect = layer.forward(img);
        for o in 0..n_out {
            let got = bits.data[i * n_out + o] >= 0.5;
            assert_eq!(
                got, expect[o],
                "image {i} neuron {o}: XLA vs rust functional"
            );
        }
    }
    // currents are physical: all within (0, I_RESET)
    let currents = &out[1];
    assert!(currents.data.iter().all(|&c| (0.0..100e-6).contains(&c)));
}

/// The trained artifact weights must classify the held-out corpus well.
#[test]
fn trained_weights_classify_digits() {
    require_artifacts!();
    let store = ArtifactStore::open_default().unwrap();
    let layer = store.single_layer().unwrap();
    let reported = store.meta_f64("acc_single").unwrap();
    let ds = DigitGen::new(TEST_SEED).dataset(1000);
    let correct = ds
        .samples
        .iter()
        .filter(|s| layer.argmax(&s.pixels) == s.label)
        .count();
    let acc = correct as f64 / ds.len() as f64;
    assert!(acc > 0.9, "trained accuracy {acc}");
    // and it must agree with what the python trainer measured (same data!)
    assert!(
        (acc - reported).abs() < 0.02,
        "rust-measured {acc} vs python-reported {reported}"
    );
}

/// MLP HLO loads and runs with the trained weights.
#[test]
fn xla_mlp_executes() {
    require_artifacts!();
    let store = ArtifactStore::open_default().unwrap();
    let (l1, l2) = store.mlp_layers().unwrap();
    let runtime = Runtime::cpu().unwrap();
    let exe = runtime.load_hlo_text(&store.mlp_infer_hlo()).unwrap();
    let batch = 64usize;
    let (n_in, n_h, n_out) = (l1.n_in(), l1.n_out(), l2.n_out());

    let mut gen = DigitGen::new(TEST_SEED);
    let images: Vec<Vec<bool>> = (0..batch).map(|_| gen.next_sample().pixels).collect();
    let mut x = vec![0.0f32; batch * n_in];
    for (i, img) in images.iter().enumerate() {
        for (j, &b) in img.iter().enumerate() {
            x[i * n_in + j] = b as u8 as f32;
        }
    }
    let to_graph = |layer: &xpoint_imc::nn::BinaryLayer| {
        let (ni, no) = (layer.n_in(), layer.n_out());
        let mut w = vec![0.0f32; ni * no];
        for (o, row) in layer.weights.iter().enumerate() {
            for (i, &bit) in row.iter().enumerate() {
                w[i * no + o] = bit as u8 as f32;
            }
        }
        TensorF32::new(vec![ni, no], w)
    };
    let v1 = store.meta_f64("vdd_mlp1").unwrap() as f32;
    let v2 = store.meta_f64("vdd_mlp2").unwrap() as f32;
    let out = exe
        .run(&[
            TensorF32::new(vec![batch, n_in], x),
            to_graph(&l1),
            to_graph(&l2),
            TensorF32::scalar(v1),
            TensorF32::scalar(v2),
        ])
        .unwrap();
    let bits = &out[0];
    assert_eq!(bits.dims, vec![batch, n_out]);
    assert_eq!(n_h, 64);
    // golden check against the rust functional MLP
    let mlp = xpoint_imc::nn::BinaryMlp::new(l1, l2);
    for (i, img) in images.iter().enumerate() {
        let expect = mlp.forward(img);
        for o in 0..n_out {
            assert_eq!(bits.data[i * n_out + o] >= 0.5, expect[o], "img {i} out {o}");
        }
    }
}

//! End-to-end coordinator throughput/latency on the digit workload — the
//! serving-shell performance exhibit (not a paper table; documents the L3
//! hot path for EXPERIMENTS.md §Perf).
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench_case, black_box, emit_bench_json, exhibit_header};
use std::time::{Duration, Instant};
use xpoint_imc::util::json::Json;
use xpoint_imc::analysis::ArrayDesign;
use xpoint_imc::array::{Level, Subarray, TmvmMode};
use xpoint_imc::coordinator::{BackendFactory, Coordinator, CoordinatorConfig};
use xpoint_imc::engine::{ArraySpec, BackendKind, EngineSpec, NetworkSource};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::nn::dataset::DigitGen;
use xpoint_imc::util::si::{format_duration, format_si};
use xpoint_imc::util::Pcg32;

fn factories(n: usize, n_row: usize, mode: TmvmMode) -> Vec<BackendFactory> {
    let kind = match mode {
        TmvmMode::Ideal => BackendKind::Ideal,
        TmvmMode::Parasitic => BackendKind::Parasitic,
    };
    EngineSpec::new(kind)
        .with_workers(n)
        .with_network(NetworkSource::Template)
        .with_array(ArraySpec {
            rows: n_row,
            cols: 128,
            span: Some(121),
            ..ArraySpec::default()
        })
        .build_factories()
        .expect("valid engine spec")
}

fn run(label: &str, workers: usize, batch: usize, n_images: usize, mode: TmvmMode) -> Json {
    let mut coord = Coordinator::spawn(
        factories(workers, batch.max(64), mode),
        CoordinatorConfig {
            batch_capacity: batch,
            linger: Duration::from_micros(100),
            autoscale: None,
        },
    );
    let mut gen = DigitGen::new(1);
    let images: Vec<_> = (0..n_images).map(|_| gen.next_sample()).collect();
    let started = Instant::now();
    let rxs: Vec<_> = images
        .into_iter()
        .map(|s| coord.submit(s.pixels, Some(s.label)).expect("submit"))
        .collect();
    for rx in rxs {
        rx.recv().expect("reply");
    }
    let wall = started.elapsed().as_secs_f64();
    let snap = coord.shutdown();
    println!(
        "{label:<42} {:>9.0} img/s  mean-latency {:>10}  sim-E/img {:>8}",
        n_images as f64 / wall,
        format_duration(snap.mean_latency),
        format_si(snap.energy_per_image, "J"),
    );
    // gate on *simulated* throughput (deterministic, machine-independent);
    // host img/s rides along informationally
    bench_case(
        label,
        n_images as f64 / snap.sim_time.max(1e-30),
        &[
            ("host_img_s", n_images as f64 / wall),
            ("energy_per_image_j", snap.energy_per_image),
        ],
    )
}

/// Packed-vs-scalar kernel exhibit (the bit-packed hot-path claim): the
/// same 10-step, 128-image ideal-mode TMVM batch on one 128×256
/// subarray, through `tmvm_rows` (the packed popcount fast path) vs
/// `tmvm_rows_scalar` (the per-cell reference oracle). The gated
/// throughput is SIMULATED img/s — identical for both by construction,
/// so the enforce gate stays deterministic — while the `host_img_s`
/// extra records the host-side speedup the packed representation buys.
fn run_kernel(label: &str, packed: bool) -> Json {
    const N_ROW: usize = 128;
    const N_COL: usize = 256;
    const STEPS: usize = 10;
    let mut rng = Pcg32::seeded(42);
    let mut sa = Subarray::new(ArrayDesign::new(N_ROW, N_COL, LineConfig::config3(), 3.0, 1.0));
    let grid: Vec<Vec<bool>> = (0..N_ROW)
        .map(|_| (0..N_COL).map(|_| rng.bernoulli(0.5)).collect())
        .collect();
    sa.program_level(Level::Top, &grid);
    let inputs: Vec<Vec<bool>> = (0..STEPS)
        .map(|_| (0..N_COL).map(|_| rng.bernoulli(0.5)).collect())
        .collect();
    let v_dd = sa.vdd_for_threshold(64);
    let sim0 = sa.ledger.time;
    let started = Instant::now();
    let mut batches = 0u64;
    while batches < 8 || started.elapsed() < Duration::from_millis(250) {
        for (p, x) in inputs.iter().enumerate() {
            let rep = if packed {
                sa.tmvm_rows(x, p, v_dd, TmvmMode::Ideal, N_ROW)
            } else {
                sa.tmvm_rows_scalar(x, p, v_dd, TmvmMode::Ideal, N_ROW)
            };
            black_box(rep.outputs.len());
        }
        batches += 1;
    }
    let wall = started.elapsed().as_secs_f64();
    let images = (batches as usize * N_ROW) as f64;
    let sim = (sa.ledger.time - sim0).max(1e-30);
    println!(
        "{label:<42} {:>9.0} img/s (host)  sim {:>11.4e} img/s",
        images / wall,
        images / sim,
    );
    bench_case(label, images / sim, &[("host_img_s", images / wall)])
}

/// Sharded fabric serving: one coordinator worker driving `shards`
/// independent fabric engines through the async submit/poll scheduler.
/// The sweep makes the sharding speedup visible in the perf trajectory:
/// wall-clock throughput should scale with shards (simulated energy per
/// image is shard-invariant).
fn run_sharded(label: &str, shards: usize, batch: usize, n_images: usize) -> Json {
    let spec = xpoint_imc::report::sharding::shard_scaling_spec(shards, batch);
    let mut coord = Coordinator::spawn(
        spec.build_factories().expect("sharded factories"),
        CoordinatorConfig {
            batch_capacity: batch,
            linger: Duration::from_micros(100),
            autoscale: None,
        },
    );
    let mut gen = DigitGen::new(1);
    let images: Vec<_> = (0..n_images).map(|_| gen.next_sample()).collect();
    let started = Instant::now();
    let rxs: Vec<_> = images
        .into_iter()
        .map(|s| coord.submit(s.pixels, Some(s.label)).expect("submit"))
        .collect();
    for rx in rxs {
        rx.recv().expect("reply");
    }
    let wall = started.elapsed().as_secs_f64();
    let snap = coord.shutdown();
    println!(
        "{label:<42} {:>9.0} img/s  mean-latency {:>10}  sim-E/img {:>8}",
        n_images as f64 / wall,
        format_duration(snap.mean_latency),
        format_si(snap.energy_per_image, "J"),
    );
    bench_case(
        label,
        n_images as f64 / snap.sim_time.max(1e-30),
        &[
            ("host_img_s", n_images as f64 / wall),
            ("energy_per_image_j", snap.energy_per_image),
        ],
    )
}

fn main() {
    exhibit_header("End-to-end coordinator throughput (simulator backends)");
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    println!("host parallelism: {cores} core(s)\n");

    let mut cases = Vec::new();
    cases.push(run("ideal, 1 worker, batch 64", 1, 64, 8192, TmvmMode::Ideal));
    cases.push(run("ideal, 2 workers, batch 64", 2, 64, 8192, TmvmMode::Ideal));
    cases.push(run(
        "ideal, 1 worker, batch 8 (latency-biased)",
        1,
        8,
        2048,
        TmvmMode::Ideal,
    ));
    cases.push(run("parasitic, 1 worker, batch 64", 1, 64, 2048, TmvmMode::Parasitic));
    cases.push(run("parasitic, 2 workers, batch 64", 2, 64, 2048, TmvmMode::Parasitic));

    println!();
    cases.push(run_kernel("kernel packed, 128x256, batch 128", true));
    cases.push(run_kernel("kernel scalar, 128x256, batch 128", false));

    println!();
    cases.push(run_sharded("fabric, 1 shard, batch 64", 1, 64, 1024));
    cases.push(run_sharded("fabric, 2 shards, batch 64", 2, 64, 1024));
    cases.push(run_sharded("fabric, 4 shards, batch 64", 4, 64, 1024));

    emit_bench_json("e2e_throughput", cases);
}

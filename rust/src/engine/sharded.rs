//! [`ShardedEngine`] — genuinely asynchronous serving over N independent
//! engine shards.
//!
//! The paper's §"system scalability" connects multiple 3D XPoint arrays
//! into a larger engine; the fabric layer simulates one such grid, and
//! this module scales *past* one grid: a `ShardedEngine` owns N inner
//! engines (any non-sharded [`BackendKind`]), each constructed from its
//! [`BackendFactory`] **on its own worker thread** (engines are not
//! `Send`; PJRT handles are thread-affine — the factory travels, the
//! engine never does).
//!
//! The submit/poll pair is where the asynchrony becomes real instead of
//! the synchronous-completion adapter the plain engines use:
//!
//! * [`submit`](Engine::submit) is **capability-aware least-loaded
//!   dispatch**: the batch goes to the shard with the fewest in-flight
//!   images among those whose `max_batch` admits it, and returns a
//!   [`Ticket`] immediately — the shard thread does the work later.
//! * [`poll`](Engine::poll) drains shard completion channels without
//!   blocking and redeems tickets **out of submission order** while
//!   preserving per-ticket identity; `Ok(None)` means genuinely still in
//!   flight on a shard thread.
//! * [`infer_batch`](Engine::infer_batch) is submit + a blocking drain of
//!   the owning shard's completions — the synchronous view of the same
//!   machinery.
//!
//! Telemetry sums across shards (energy and simulated time are additive;
//! per-subarray utilization concatenates in shard order), and
//! [`Engine::shard_telemetry`] exposes the per-shard breakdown so the
//! coordinator's metrics and the report exhibits can show load balance.

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::api::{BackendFactory, Capabilities, Engine, InferenceResult, Telemetry, Ticket};
use super::error::EngineError;
use super::spec::BackendKind;

/// Work order for a shard thread.
enum ShardRequest {
    Infer { ticket: Ticket, images: Vec<Vec<bool>> },
}

/// Message from a shard thread back to the `ShardedEngine`.
enum ShardEvent {
    /// Engine construction finished (capabilities) or failed (message).
    Built(Result<Capabilities, String>),
    /// One batch completed (or failed), with the shard's telemetry
    /// snapshot taken right after the batch.
    Done {
        ticket: Ticket,
        result: Result<InferenceResult, String>,
        telemetry: Telemetry,
    },
}

/// One shard: the channel pair to its worker thread plus the scheduler's
/// view of it (capabilities, last telemetry snapshot, in-flight load).
struct Shard {
    tx: Option<mpsc::Sender<ShardRequest>>,
    rx: mpsc::Receiver<ShardEvent>,
    join: Option<JoinHandle<()>>,
    caps: Capabilities,
    telemetry: Telemetry,
    /// Batches currently submitted to this shard and not yet drained.
    in_flight_batches: usize,
    /// Images in those batches — the least-loaded dispatch key.
    in_flight_images: usize,
    alive: bool,
}

/// Bookkeeping for one outstanding ticket.
struct InFlight {
    shard: usize,
    images: usize,
}

/// N engine shards behind one [`Engine`] — see the module docs.
pub struct ShardedEngine {
    shards: Vec<Shard>,
    caps: Capabilities,
    next_ticket: Ticket,
    /// Rotation origin for the least-loaded tie-break: equal loads
    /// round-robin instead of always favouring shard 0.
    next_pref: usize,
    in_flight: HashMap<Ticket, InFlight>,
    /// Drained completions awaiting redemption, in completion order.
    ready: Vec<(Ticket, Result<InferenceResult, String>)>,
}

fn shard_main(
    factory: BackendFactory,
    rx: mpsc::Receiver<ShardRequest>,
    tx: mpsc::Sender<ShardEvent>,
) {
    let mut engine = match factory() {
        Ok(engine) => {
            let _ = tx.send(ShardEvent::Built(Ok(engine.capabilities())));
            engine
        }
        Err(e) => {
            let _ = tx.send(ShardEvent::Built(Err(format!("{e:#}"))));
            return;
        }
    };
    while let Ok(ShardRequest::Infer { ticket, images }) = rx.recv() {
        let result = engine.infer_batch(&images).map_err(|e| format!("{e:#}"));
        if tx
            .send(ShardEvent::Done {
                ticket,
                result,
                telemetry: engine.telemetry(),
            })
            .is_err()
        {
            break; // owner gone — nothing left to report to
        }
    }
}

impl ShardedEngine {
    /// Spawn one worker thread per factory and construct each shard's
    /// engine on its own thread (builds run concurrently). Fails with the
    /// first shard's construction error if any factory fails.
    pub fn new(factories: Vec<BackendFactory>) -> crate::Result<Self> {
        anyhow::ensure!(
            !factories.is_empty(),
            "sharded engine needs at least one shard"
        );
        let mut pending = Vec::with_capacity(factories.len());
        for (i, factory) in factories.into_iter().enumerate() {
            let (req_tx, req_rx) = mpsc::channel::<ShardRequest>();
            let (evt_tx, evt_rx) = mpsc::channel::<ShardEvent>();
            let join = std::thread::Builder::new()
                .name(format!("xpoint-shard-{i}"))
                .spawn(move || shard_main(factory, req_rx, evt_tx))
                .map_err(|e| anyhow::anyhow!("spawning shard {i} thread: {e}"))?;
            pending.push((req_tx, evt_rx, join));
        }

        let mut shards = Vec::with_capacity(pending.len());
        for (i, (tx, rx, join)) in pending.into_iter().enumerate() {
            // the first event is always Built; dropping the remaining
            // `pending` senders on an early return unwinds the other
            // threads cleanly (their request channels close)
            let caps = match rx.recv() {
                Ok(ShardEvent::Built(Ok(caps))) => caps,
                Ok(ShardEvent::Built(Err(e))) => {
                    anyhow::bail!("shard {i}: backend construction failed: {e}")
                }
                Ok(ShardEvent::Done { .. }) => unreachable!("Done before Built"),
                Err(_) => anyhow::bail!("shard {i}: worker thread died during construction"),
            };
            shards.push(Shard {
                tx: Some(tx),
                rx,
                join: Some(join),
                caps,
                telemetry: Telemetry::default(),
                in_flight_batches: 0,
                in_flight_images: 0,
                alive: true,
            });
        }

        let first = shards[0].caps;
        let caps = Capabilities {
            kind: BackendKind::Sharded,
            n_in: first.n_in,
            n_out: first.n_out,
            // one batch lands on one shard, so the engine-level limit is
            // the largest single shard's (shards are normally identical)
            max_batch: shards.iter().map(|s| s.caps.max_batch).max().unwrap_or(0),
            nodes: shards.iter().map(|s| s.caps.nodes).sum(),
            tiles: shards.iter().map(|s| s.caps.tiles).sum(),
            shards: shards.len(),
            reports_energy: first.reports_energy,
            pipelined: first.pipelined,
        };
        Ok(Self {
            shards,
            caps,
            next_ticket: 0,
            next_pref: 0,
            in_flight: HashMap::new(),
            ready: Vec::new(),
        })
    }

    /// Shards behind this engine.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// In-flight images per shard — the live load the least-loaded
    /// dispatch balances (test/introspection hook).
    pub fn shard_loads(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.in_flight_images).collect()
    }

    /// Fail every outstanding ticket on a shard whose thread is gone.
    fn mark_shard_dead(&mut self, shard: usize) {
        if !self.shards[shard].alive {
            return;
        }
        self.shards[shard].alive = false;
        let dead: Vec<Ticket> = self
            .in_flight
            .iter()
            .filter(|(_, f)| f.shard == shard)
            .map(|(&t, _)| t)
            .collect();
        for t in dead {
            self.in_flight.remove(&t);
            self.ready
                .push((t, Err(format!("shard {shard} worker thread died"))));
        }
        self.shards[shard].in_flight_batches = 0;
        self.shards[shard].in_flight_images = 0;
    }

    fn apply_event(&mut self, shard: usize, evt: ShardEvent) {
        match evt {
            // Built is consumed in new(); afterwards the channel only
            // carries completions
            ShardEvent::Built(_) => {}
            ShardEvent::Done {
                ticket,
                result,
                telemetry,
            } => {
                self.shards[shard].telemetry = telemetry;
                if let Some(info) = self.in_flight.remove(&ticket) {
                    let s = &mut self.shards[info.shard];
                    s.in_flight_batches = s.in_flight_batches.saturating_sub(1);
                    s.in_flight_images = s.in_flight_images.saturating_sub(info.images);
                }
                self.ready.push((ticket, result));
            }
        }
    }

    /// Pull every completion that has already arrived, without blocking.
    fn drain_events(&mut self) {
        for i in 0..self.shards.len() {
            loop {
                match self.shards[i].rx.try_recv() {
                    Ok(evt) => self.apply_event(i, evt),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        if self.shards[i].in_flight_batches > 0 {
                            self.mark_shard_dead(i);
                        } else {
                            self.shards[i].alive = false;
                        }
                        break;
                    }
                }
            }
        }
    }

    /// Block until the shard owning `ticket` reports *something* (its
    /// completions arrive in order, so this makes progress toward the
    /// ticket without busy-waiting).
    fn block_on_owner(&mut self, ticket: Ticket) {
        let shard = match self.in_flight.get(&ticket) {
            Some(f) => f.shard,
            None => return, // already drained (or failed)
        };
        match self.shards[shard].rx.recv() {
            Ok(evt) => self.apply_event(shard, evt),
            Err(_) => self.mark_shard_dead(shard),
        }
    }
}

impl Engine for ShardedEngine {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        let ticket = self.submit(images.to_vec())?;
        loop {
            if let Some(res) = self.poll(ticket)? {
                return Ok(res);
            }
            self.block_on_owner(ticket);
        }
    }

    fn max_batch(&self) -> usize {
        self.caps.max_batch
    }

    fn capabilities(&self) -> Capabilities {
        self.caps
    }

    /// Aggregate across shards: counters and energy/time sum (both are
    /// physically additive over independent arrays); `utilization`
    /// concatenates the per-shard vectors in shard order. Snapshots are
    /// as of the most recently drained completion.
    fn telemetry(&self) -> Telemetry {
        let mut total = Telemetry::default();
        for s in &self.shards {
            let t = &s.telemetry;
            total.batches += t.batches;
            total.images += t.images;
            total.steps += t.steps;
            total.sim_time += t.sim_time;
            total.energy += t.energy;
            total.compute_energy += t.compute_energy;
            total.link_energy += t.link_energy;
            total.cycles += t.cycles;
            total.link_transfers += t.link_transfers;
            total.link_lines += t.link_lines;
            total.utilization.extend(t.utilization.iter().copied());
        }
        total
    }

    fn shard_telemetry(&self) -> Vec<Telemetry> {
        self.shards.iter().map(|s| s.telemetry.clone()).collect()
    }

    fn submit(&mut self, images: Vec<Vec<bool>>) -> crate::Result<Ticket> {
        self.drain_events();
        let n = images.len();
        // least-loaded shard among those whose max_batch admits the
        // batch; ties resolve in rotation order from `next_pref`, so an
        // all-idle engine round-robins instead of pinning shard 0
        let n_shards = self.shards.len();
        let mut best: Option<usize> = None;
        for k in 0..n_shards {
            let i = (self.next_pref + k) % n_shards;
            let s = &self.shards[i];
            if !s.alive || n > s.caps.max_batch {
                continue;
            }
            best = match best {
                Some(b) if self.shards[b].in_flight_images <= s.in_flight_images => Some(b),
                _ => Some(i),
            };
        }
        let Some(i) = best else {
            return Err(EngineError::NoShardFits {
                batch: n,
                max_batch: self.caps.max_batch,
            }
            .into());
        };
        self.next_pref = (i + 1) % n_shards;
        self.next_ticket += 1;
        let ticket = self.next_ticket;
        self.shards[i]
            .tx
            .as_ref()
            .expect("senders live until drop")
            .send(ShardRequest::Infer { ticket, images })
            .map_err(|_| anyhow::anyhow!("shard {i} worker thread is down"))?;
        self.shards[i].in_flight_batches += 1;
        self.shards[i].in_flight_images += n;
        self.in_flight.insert(ticket, InFlight { shard: i, images: n });
        Ok(ticket)
    }

    fn poll(&mut self, ticket: Ticket) -> crate::Result<Option<InferenceResult>> {
        self.drain_events();
        if let Some(pos) = self.ready.iter().position(|(t, _)| *t == ticket) {
            let (_, result) = self.ready.remove(pos);
            return result
                .map(Some)
                .map_err(|e| anyhow::anyhow!("sharded batch failed: {e}"));
        }
        if self.in_flight.contains_key(&ticket) {
            return Ok(None);
        }
        if self.next_ticket == 0 {
            return Err(EngineError::Empty.into());
        }
        Err(EngineError::UnknownTicket(ticket).into())
    }
}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        for s in &mut self.shards {
            s.tx.take(); // closing the request channel ends the thread
        }
        for s in &mut self.shards {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArraySpec, EngineSpec};
    use crate::nn::BinaryLayer;
    use crate::util::Pcg32;

    fn layer(seed: u64) -> BinaryLayer {
        let mut rng = Pcg32::seeded(seed);
        BinaryLayer::new(
            (0..8)
                .map(|_| (0..16).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            3,
        )
    }

    fn sharded(shards: usize, rows: usize) -> ShardedEngine {
        let factories = EngineSpec::new(BackendKind::Ideal)
            .with_workers(shards)
            .with_array(ArraySpec {
                rows,
                cols: 32,
                span: Some(16),
                ..ArraySpec::default()
            })
            .with_batching(rows.min(64), 200)
            .with_layers(vec![layer(3)])
            .build_factories()
            .expect("valid spec");
        ShardedEngine::new(factories).expect("shards build")
    }

    fn images(seed: u64, m: usize) -> Vec<Vec<bool>> {
        let mut rng = Pcg32::seeded(seed);
        (0..m)
            .map(|_| (0..16).map(|_| rng.bernoulli(0.4)).collect())
            .collect()
    }

    #[test]
    fn sharded_infer_matches_functional_layer() {
        let l = layer(3);
        let mut e = sharded(3, 32);
        assert_eq!(e.n_shards(), 3);
        let caps = e.capabilities();
        assert_eq!(caps.kind, BackendKind::Sharded);
        assert_eq!(caps.shards, 3);
        assert_eq!(caps.nodes, 3, "one subarray per shard");
        let imgs = images(4, 6);
        let res = e.infer_batch(&imgs).unwrap();
        for (i, img) in imgs.iter().enumerate() {
            assert_eq!(res.bits[i], l.forward(img));
            assert_eq!(res.classes[i], l.argmax(img));
        }
        let tel = e.telemetry();
        assert_eq!((tel.batches, tel.images), (1, 6));
        assert!(tel.energy > 0.0);
        assert_eq!(e.shard_telemetry().len(), 3);
    }

    #[test]
    fn tickets_redeem_out_of_order_with_identity() {
        let l = layer(3);
        let mut e = sharded(2, 32);
        let a = images(5, 5);
        let b = images(6, 2);
        let ta = e.submit(a.clone()).unwrap();
        let tb = e.submit(b.clone()).unwrap();
        assert_ne!(ta, tb);
        // redeem in reverse submission order; blocking helper drives both
        let rb = loop {
            match e.poll(tb).unwrap() {
                Some(r) => break r,
                None => e.block_on_owner(tb),
            }
        };
        let ra = loop {
            match e.poll(ta).unwrap() {
                Some(r) => break r,
                None => e.block_on_owner(ta),
            }
        };
        assert_eq!(rb.bits.len(), 2);
        assert_eq!(ra.bits.len(), 5);
        for (img, bits) in a.iter().zip(&ra.bits) {
            assert_eq!(bits, &l.forward(img), "batch a identity");
        }
        for (img, bits) in b.iter().zip(&rb.bits) {
            assert_eq!(bits, &l.forward(img), "batch b identity");
        }
        // dispatch rotation: two consecutive submits land on different
        // shards deterministically (ties round-robin from next_pref)
        let per_shard = e.shard_telemetry();
        assert_eq!(per_shard.iter().map(|t| t.batches).sum::<u64>(), 2);
        assert!(per_shard.iter().all(|t| t.batches == 1), "one batch each");
        // each ticket redeems exactly once
        assert!(e.poll(ta).is_err());
    }

    #[test]
    fn poll_contract_empty_then_unknown() {
        let mut e = sharded(2, 16);
        let err = e.poll(1).unwrap_err();
        assert!(
            err.to_string().contains("nothing submitted"),
            "fresh engine: {err}"
        );
        let t = e.submit(images(7, 3)).unwrap();
        loop {
            match e.poll(t).unwrap() {
                Some(_) => break,
                None => e.block_on_owner(t),
            }
        }
        let err = e.poll(t).unwrap_err();
        assert!(err.to_string().contains("never issued"), "{err}");
    }

    #[test]
    fn oversized_batch_is_a_typed_error() {
        let mut e = sharded(2, 8);
        let err = e.submit(images(8, 9)).unwrap_err();
        assert!(
            err.to_string().contains("exceeds every shard"),
            "{err}"
        );
    }
}

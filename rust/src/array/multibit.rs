//! Multi-bit TMVM implementation schemes (paper §IV-C, Fig. 7, Table III).
//!
//! * **Area-efficient** (Fig. 7(a)): one cell per weight bit; the word line
//!   of bit `k` is driven at `2^k · V_DD`, so the bit-k cell current is
//!   weighted by its significance. Needs `b` multi-level drivers; the top
//!   voltage `2^(b−1)·V_DD` becomes infeasible (> 5 V inside the subarray)
//!   past a few bits — exactly the paper's cutoff at 3 bits.
//! * **Low-power** (Fig. 7(b)): bit `k` is *replicated* in `2^k` adjacent
//!   cells, all driven at the plain `V_DD`: significance is realized by
//!   copy count. Area grows as `2^b − 1` cells per weight, but no voltage
//!   scaling is needed.
//!
//! Cost model (per dot-product column of `n_inputs` weights, documented in
//! DESIGN.md §7): the output-cell current is pinned near `I_SET` at the
//! operating point; input-side dissipation follows the effective input
//! resistance of each scheme, and each engaged word line books a drive
//! overhead.

use crate::analysis::ArrayDesign;

/// The two multi-bit schemes of Fig. 7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MultibitScheme {
    AreaEfficient,
    LowPower,
}

impl MultibitScheme {
    /// Canonical spec-string token, as used in `--network multibit:B:SCHEME`.
    pub fn name(self) -> &'static str {
        match self {
            MultibitScheme::AreaEfficient => "area",
            MultibitScheme::LowPower => "lowpower",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "area" => Some(MultibitScheme::AreaEfficient),
            "lowpower" => Some(MultibitScheme::LowPower),
            _ => None,
        }
    }
}

/// Cost estimate for one multi-bit TMVM dot product.
#[derive(Clone, Copy, Debug)]
pub struct MultibitCost {
    pub scheme: MultibitScheme,
    pub bits: usize,
    /// Energy per TMVM dot product \[J\].
    pub energy: f64,
    /// Array area consumed by the weights \[m²\].
    pub area: f64,
    /// Cells used per weight element.
    pub cells_per_weight: usize,
    /// Highest word-line voltage required \[V\].
    pub max_voltage: f64,
    /// Feasible within the subarray voltage ceiling (5 V)?
    pub feasible: bool,
}

/// Maximum voltage deliverable inside the subarray (paper §VI-B: the
/// area-efficient scheme beyond 3 bits "requires applying a large voltage
/// level (>5V) within the subarray, making the implementation infeasible").
pub const V_CEILING: f64 = 5.0;

/// Estimate energy and area of a `bits`-bit TMVM dot product over
/// `n_inputs` weights (paper Table III uses `n_inputs = 121`).
pub fn multibit_tmvm_cost(
    design: &ArrayDesign,
    scheme: MultibitScheme,
    bits: usize,
    n_inputs: usize,
    v_dd: f64,
) -> MultibitCost {
    assert!(bits >= 1 && n_inputs >= 1);
    let p = design.device;
    let cell_area = design.cell.area();
    let t = p.t_set;
    // Output current pinned at the SET threshold at the operating point;
    // base drive energy of a binary (1-bit) dot product.
    let i_out = p.i_set;
    let e_base = v_dd * i_out * t;
    // Per-word-line drive overhead (charging the line through the driver).
    let e_line = 0.08 * e_base;

    match scheme {
        MultibitScheme::AreaEfficient => {
            // bit k driven at 2^k·V_DD; its share of the output current is
            // ∝ 2^k. Energy = Σ_k (2^k·V_DD)·(i_out·2^k/(2^b−1))·t plus one
            // line drive per bit plane.
            let total_weight = (1u64 << bits) as f64 - 1.0;
            let mut e = 0.0;
            for k in 0..bits {
                let w_k = (1u64 << k) as f64;
                e += (w_k * v_dd) * (i_out * w_k / total_weight) * t;
            }
            e += bits as f64 * e_line;
            let max_voltage = v_dd * (1u64 << (bits - 1)) as f64;
            MultibitCost {
                scheme,
                bits,
                energy: e,
                area: bits as f64 * n_inputs as f64 * cell_area,
                cells_per_weight: bits,
                max_voltage,
                feasible: max_voltage <= V_CEILING,
            }
        }
        MultibitScheme::LowPower => {
            // bit k replicated 2^k times at plain V_DD: cells per weight =
            // 2^b − 1. Output current unchanged; line-drive overhead grows
            // with the (log₂-many) engaged word-line groups, saturating.
            let copies = (1u64 << bits) as f64 - 1.0;
            // drive overhead saturates: 2 − 2^{1−b} engaged line groups
            let e = e_base + e_line * (2.0 - (2.0f64).powi(1 - (bits as i32)));
            MultibitCost {
                scheme,
                bits,
                energy: e,
                area: copies * n_inputs as f64 * cell_area,
                cells_per_weight: copies as usize,
                max_voltage: v_dd,
                feasible: v_dd <= V_CEILING,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::LineConfig;

    fn design() -> ArrayDesign {
        ArrayDesign::new(128, 128, LineConfig::config3(), 3.0, 1.0)
    }

    fn cost(scheme: MultibitScheme, bits: usize) -> MultibitCost {
        multibit_tmvm_cost(&design(), scheme, bits, 121, 0.9)
    }

    #[test]
    fn one_bit_schemes_coincide() {
        let ae = cost(MultibitScheme::AreaEfficient, 1);
        let lp = cost(MultibitScheme::LowPower, 1);
        assert_eq!(ae.cells_per_weight, 1);
        assert_eq!(lp.cells_per_weight, 1);
        assert!((ae.area - lp.area).abs() / lp.area < 1e-12);
        assert!((ae.energy - lp.energy).abs() / lp.energy < 0.05);
    }

    #[test]
    fn area_efficient_area_is_linear_in_bits() {
        let a1 = cost(MultibitScheme::AreaEfficient, 1).area;
        for b in 2..=6 {
            let ab = cost(MultibitScheme::AreaEfficient, b).area;
            assert!((ab / a1 - b as f64).abs() < 1e-9, "b={b}");
        }
    }

    #[test]
    fn low_power_area_is_exponential_in_bits() {
        let a1 = cost(MultibitScheme::LowPower, 1).area;
        for b in 2..=6 {
            let ab = cost(MultibitScheme::LowPower, b).area;
            let expect = ((1u64 << b) - 1) as f64;
            assert!((ab / a1 - expect).abs() < 1e-9, "b={b}");
        }
    }

    #[test]
    fn area_efficient_energy_grows_fast_low_power_stays_flat() {
        let ae2 = cost(MultibitScheme::AreaEfficient, 2).energy;
        let ae3 = cost(MultibitScheme::AreaEfficient, 3).energy;
        let ae1 = cost(MultibitScheme::AreaEfficient, 1).energy;
        assert!(ae2 > 1.5 * ae1, "AE energy superlinear: {ae2} vs {ae1}");
        assert!(ae3 > 1.5 * ae2);
        assert!(ae3 > 2.5 * ae1, "cumulative growth");
        let lp1 = cost(MultibitScheme::LowPower, 1).energy;
        let lp6 = cost(MultibitScheme::LowPower, 6).energy;
        assert!(lp6 < 1.5 * lp1, "LP energy ~flat: {lp6} vs {lp1}");
        assert!(lp6 >= lp1, "LP energy non-decreasing");
    }

    #[test]
    fn area_efficient_infeasible_past_three_bits() {
        // paper §VI-B: > 5 V needed beyond 3 bits at the Table II operating
        // point (~0.9 V): 0.9·2^3 = 7.2 V > 5 V at 4 bits.
        assert!(cost(MultibitScheme::AreaEfficient, 1).feasible);
        assert!(cost(MultibitScheme::AreaEfficient, 2).feasible);
        assert!(cost(MultibitScheme::AreaEfficient, 3).feasible);
        assert!(!cost(MultibitScheme::AreaEfficient, 4).feasible);
        // the low-power scheme never needs voltage scaling
        for b in 1..=6 {
            assert!(cost(MultibitScheme::LowPower, b).feasible);
        }
    }

    #[test]
    fn energies_in_picojoule_regime() {
        for b in 1..=3 {
            let e = cost(MultibitScheme::AreaEfficient, b).energy;
            assert!(e > 0.1e-12 && e < 100e-12, "E = {e}");
        }
    }
}

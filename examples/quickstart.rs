//! Quickstart: program a small 3D XPoint subarray, run a thresholded
//! matrix–vector multiply in-memory, and inspect the result.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use xpoint_imc::analysis::{ideal_window, noise_margin, ArrayDesign};
use xpoint_imc::array::{Level, Subarray, TmvmMode};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::util::si::{format_pct, format_si};

fn main() {
    // 1. a subarray design: 8×8, configuration 3 wiring, cell 36×240 nm
    let design = ArrayDesign::new(8, 8, LineConfig::config3(), 3.0, 1.0);
    println!(
        "design: {}×{} cells, config {}, cell {:.0}×{:.0} nm, area {:.3} µm²",
        design.n_row,
        design.n_col,
        design.config.id,
        design.cell.w_cell * 1e9,
        design.cell.l_cell * 1e9,
        design.area() * 1e12
    );

    // 2. feasibility first: the paper's noise-margin analysis
    let nm = noise_margin(&design);
    println!(
        "noise margin: {} (window [{}, {}])",
        format_pct(nm.noise_margin()),
        format_si(nm.v_lo(), "V"),
        format_si(nm.v_hi(), "V"),
    );

    // 3. program a binary matrix G into the top PCM level
    let mut sa = Subarray::new(design);
    let g: Vec<Vec<bool>> = (0..8)
        .map(|r| (0..8).map(|c| (r + c) % 3 == 0).collect())
        .collect();
    sa.program_level(Level::Top, &g);
    println!("\nG (top PCM level):");
    for row in &g {
        let line: String = row.iter().map(|&b| if b { '#' } else { '.' }).collect();
        println!("  {line}");
    }

    // 4. choose an operating voltage realizing firing threshold θ = 2
    let theta = 2;
    let v_dd = sa.vdd_for_threshold(theta);
    println!("\nθ = {theta} ⇒ V_DD = {}", format_si(v_dd, "V"));

    // 5. apply an input vector as word-line pulses; thresholded dot
    //    products land in bottom-level column 0
    let x = vec![true, false, true, true, false, false, true, false];
    let report = sa.tmvm(&x, 0, v_dd, TmvmMode::Ideal);
    println!(
        "x = {:?}\ncurrents = [{}]",
        x.iter().map(|&b| b as u8).collect::<Vec<_>>(),
        report
            .currents
            .iter()
            .map(|&i| format_si(i, "A"))
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!(
        "O = {:?}   (electrically clean: {})",
        report.outputs.iter().map(|&b| b as u8).collect::<Vec<_>>(),
        report.is_clean()
    );

    // 6. verify against exact integer counts
    for (r, row) in g.iter().enumerate() {
        let count = row.iter().zip(&x).filter(|(&w, &xi)| w && xi).count();
        assert_eq!(report.outputs[r], count >= theta);
    }
    println!("\nverified: outputs equal exact count-thresholding ✓");

    // 7. energy/latency ledger
    println!(
        "energy booked: {}, busy time: {}",
        format_si(sa.ledger.energy, "J"),
        format_si(sa.ledger.time, "s")
    );

    // 8. the ideal operating window for a 121-input TMVM (Eqs. 4–5)
    let w = ideal_window(121, &sa.design().device);
    println!(
        "\nideal window for 121 inputs: [{}, {}] (NM {})",
        format_si(w.v_min(), "V"),
        format_si(w.v_max(), "V"),
        format_pct(w.noise_margin())
    );
}

//! Phase-change memory element: state, conductance, and SET/RESET pulse
//! dynamics (paper Fig. 2(a)).
//!
//! The model keeps a continuous crystalline fraction `x ∈ [0, 1]` and
//! integrates a behavioural electro-thermal transition: Joule power raises
//! the cell temperature; above `T_cryst` the amorphous region crystallizes
//! with time constant `tau_cryst`; above `T_melt` it melt-quenches back to
//! amorphous with `tau_melt`. Amorphous GST under sufficient bias undergoes
//! electronic threshold switching to a conductive ON state — that is what
//! allows a SET pulse to heat an amorphous (high-resistance) cell at all.

use super::params::DeviceParams;

/// Discrete logic state of a PCM cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PcmState {
    /// Low conductance `G_A` — logic 0.
    Amorphous,
    /// High conductance `G_C` — logic 1.
    Crystalline,
}

impl PcmState {
    pub fn to_bit(self) -> bool {
        matches!(self, PcmState::Crystalline)
    }

    pub fn from_bit(bit: bool) -> Self {
        if bit {
            PcmState::Crystalline
        } else {
            PcmState::Amorphous
        }
    }
}

/// A single PCM storage element.
#[derive(Clone, Debug)]
pub struct PcmCell {
    /// Crystalline fraction `x ∈ [0, 1]`.
    cryst_frac: f64,
    /// Cumulative SET+RESET cycles (endurance accounting; the paper cites
    /// 1e12-cycle endurance for state-of-the-art devices).
    cycles: u64,
}

impl PcmCell {
    /// New cell in the amorphous (logic 0) state.
    pub fn new() -> Self {
        Self {
            cryst_frac: 0.0,
            cycles: 0,
        }
    }

    /// New cell holding `bit`.
    pub fn with_bit(bit: bool) -> Self {
        Self {
            cryst_frac: if bit { 1.0 } else { 0.0 },
            cycles: 0,
        }
    }

    /// Crystalline fraction (continuous state).
    pub fn cryst_frac(&self) -> f64 {
        self.cryst_frac
    }

    /// Programming cycle count.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Discretized state: crystalline iff the crystalline fraction is past
    /// the percolation midpoint.
    pub fn state(&self) -> PcmState {
        if self.cryst_frac >= 0.5 {
            PcmState::Crystalline
        } else {
            PcmState::Amorphous
        }
    }

    /// Stored logic bit.
    pub fn bit(&self) -> bool {
        self.state().to_bit()
    }

    /// Static (small-signal) conductance: log-space interpolation between
    /// `G_A` and `G_C` — resistance of GST mixtures is dominated by the
    /// amorphous series fraction, which log-interpolation captures.
    ///
    /// Fully-written cells (the overwhelmingly common case on the TMVM hot
    /// path) skip the transcendental interpolation.
    #[inline]
    pub fn conductance(&self, p: &DeviceParams) -> f64 {
        if self.cryst_frac == 0.0 {
            return p.g_a;
        }
        if self.cryst_frac == 1.0 {
            return p.g_c;
        }
        let ln = (1.0 - self.cryst_frac) * p.g_a.ln() + self.cryst_frac * p.g_c.ln();
        ln.exp()
    }

    /// Conductance seen by a programming pulse: if the voltage across the
    /// cell exceeds the electronic threshold-switching voltage, the
    /// amorphous region snaps ON and conducts like the crystalline phase.
    pub fn dynamic_conductance(&self, p: &DeviceParams, v_across: f64) -> f64 {
        if v_across.abs() >= p.v_switch {
            p.g_c
        } else {
            self.conductance(p)
        }
    }

    /// Force the cell to a logic state (ideal write, no dynamics). Counts a
    /// cycle when the state flips.
    pub fn write_bit(&mut self, bit: bool) {
        let target = if bit { 1.0 } else { 0.0 };
        if self.bit() != bit {
            self.cycles += 1;
        }
        self.cryst_frac = target;
    }

    /// Cell temperature under a forced current `i` with effective
    /// conductance `g_eff` (°C).
    pub fn temperature(&self, p: &DeviceParams, i: f64, g_eff: f64) -> f64 {
        p.t_ambient + p.r_thermal * i * i / g_eff
    }

    /// Apply a current pulse of amplitude `i` for duration `dt`, integrating
    /// the electro-thermal transition in `steps` sub-steps. Returns the peak
    /// temperature reached (°C).
    ///
    /// The pulse is treated as a current source through the cell, with
    /// threshold switching active (the cell is being driven hard enough that
    /// the amorphous phase is ON whenever meaningful current flows).
    pub fn apply_current_pulse(&mut self, p: &DeviceParams, i: f64, dt: f64, steps: usize) -> f64 {
        let steps = steps.max(1);
        let h = dt / steps as f64;
        let before = self.bit();
        let mut peak_t = p.t_ambient;
        for _ in 0..steps {
            // Meaningful programming currents imply the device was biased
            // past threshold switching, so Joule power is computed against
            // the ON conductance; sub-threshold currents heat the static
            // phase instead.
            let g_eff = if i >= 0.5 * p.i_set {
                p.g_c
            } else {
                self.conductance(p)
            };
            let t = self.temperature(p, i, g_eff);
            peak_t = peak_t.max(t);
            if t >= p.t_melt {
                // melt + quench: crystalline fraction decays fast
                self.cryst_frac -= self.cryst_frac * (h / p.tau_melt).min(1.0);
            } else if t >= p.t_cryst {
                // anneal: amorphous fraction crystallizes
                self.cryst_frac += (1.0 - self.cryst_frac) * (h / p.tau_cryst).min(1.0);
            }
            self.cryst_frac = self.cryst_frac.clamp(0.0, 1.0);
        }
        if self.bit() != before {
            self.cycles += 1;
        }
        peak_t
    }

    /// Standard SET pulse (I_SET for t_SET). Returns peak temperature.
    pub fn set_pulse(&mut self, p: &DeviceParams) -> f64 {
        self.apply_current_pulse(p, p.i_set, p.t_set, 32)
    }

    /// Standard RESET pulse (I_RESET for t_RESET). Returns peak temperature.
    pub fn reset_pulse(&mut self, p: &DeviceParams) -> f64 {
        self.apply_current_pulse(p, p.i_reset, p.t_reset, 32)
    }

    /// Non-destructive read: returns the stored bit; asserts the read
    /// current is in the safe window.
    pub fn read(&self, p: &DeviceParams) -> bool {
        debug_assert!(p.i_read < 0.5 * p.i_set, "read must not disturb state");
        self.bit()
    }
}

impl Default for PcmCell {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> DeviceParams {
        DeviceParams::default()
    }

    #[test]
    fn fresh_cell_is_logic0() {
        let c = PcmCell::new();
        assert_eq!(c.state(), PcmState::Amorphous);
        assert!(!c.bit());
        assert!((c.conductance(&p()) - p().g_a).abs() / p().g_a < 1e-12);
    }

    #[test]
    fn set_pulse_crystallizes() {
        let mut c = PcmCell::new();
        let peak = c.set_pulse(&p());
        assert!(c.bit(), "SET should flip 0 -> 1 (frac={})", c.cryst_frac());
        assert!(peak >= p().t_cryst && peak < p().t_melt, "peak {peak}");
        assert!(c.cryst_frac() > 0.9);
    }

    #[test]
    fn reset_pulse_amorphizes() {
        let mut c = PcmCell::with_bit(true);
        let peak = c.reset_pulse(&p());
        assert!(!c.bit(), "RESET should flip 1 -> 0");
        assert!(peak >= p().t_melt, "peak {peak} must reach melt");
        assert!(c.cryst_frac() < 0.1);
    }

    #[test]
    fn sub_threshold_current_is_nondestructive() {
        let params = p();
        for bit in [false, true] {
            let mut c = PcmCell::with_bit(bit);
            // a read-magnitude pulse, much longer than t_set
            c.apply_current_pulse(&params, params.i_read, 10.0 * params.t_set, 64);
            assert_eq!(c.bit(), bit, "read disturbed the cell");
        }
    }

    #[test]
    fn set_reset_cycling_counts_cycles() {
        let params = p();
        let mut c = PcmCell::new();
        for _ in 0..5 {
            c.set_pulse(&params);
            c.reset_pulse(&params);
        }
        assert_eq!(c.cycles(), 10);
        assert!(!c.bit());
    }

    #[test]
    fn conductance_is_monotone_in_cryst_frac() {
        let params = p();
        let mut prev = 0.0;
        for i in 0..=10 {
            let mut c = PcmCell::new();
            c.cryst_frac = i as f64 / 10.0;
            let g = c.conductance(&params);
            assert!(g > prev);
            prev = g;
        }
        assert!((prev - params.g_c).abs() / params.g_c < 1e-12);
    }

    #[test]
    fn dynamic_conductance_threshold_switches() {
        let params = p();
        let c = PcmCell::new(); // amorphous
        let g_low = c.dynamic_conductance(&params, 0.2);
        assert!((g_low - params.g_a).abs() / params.g_a < 1e-12);
        assert_eq!(c.dynamic_conductance(&params, 1.2), params.g_c);
    }

    #[test]
    fn half_set_pulse_leaves_partial_state() {
        let params = p();
        let mut c = PcmCell::new();
        c.apply_current_pulse(&params, params.i_set, params.t_set / 8.0, 8);
        assert!(c.cryst_frac() > 0.0 && c.cryst_frac() < 0.9);
    }
}

//! Full netlist of the worst-case corner circuit (paper Fig. 9 / Fig. 15)
//! for numeric validation of the analytic recursion.
//!
//! Physical picture: in the corner case a single word line `WLT_0` is
//! driven, all its input cells are crystalline, and the engaged outputs sit
//! in one column whose shared return line `WLB_k` is grounded at the
//! periphery. Both lines cross all rows, so each row adds one WLT and one
//! WLB segment; each row's branch is input cell → `span_cols` bit-line
//! segments → output cell.

use super::design::ArrayDesign;
use crate::circuit::{Netlist, NodeId, TheveninEquivalent, GROUND};

/// The corner-case netlist plus the victim-row terminal nodes.
pub struct CornerCircuit {
    pub netlist: Netlist,
    /// Victim row's WLT-side terminal (after the BL path): where the victim
    /// branch would attach on the driven side.
    pub victim_wlt: NodeId,
    /// Victim row's WLB-side terminal.
    pub victim_wlb: NodeId,
    /// Midpoint node between the victim's bit-line path and its output
    /// cell (present only when the victim branch is included).
    pub victim_mid: Option<NodeId>,
    /// Applied source voltage.
    pub v_dd: f64,
}

/// Build the corner circuit with the victim row's branch **removed** (for
/// Thevenin observation), or kept (for operating-point checks).
pub fn build_corner_circuit(
    design: &ArrayDesign,
    victim_row: usize,
    v_dd: f64,
    include_victim_branch: bool,
) -> CornerCircuit {
    assert!((1..=design.n_row).contains(&victim_row));
    let seg = design.segments();
    let r_wlt = 1.0 / seg.g_wlt;
    let r_wlb = 1.0 / seg.g_wlb;
    let r_bl = design.span_cols as f64 / seg.g_x;
    let r_in = 1.0 / design.device.g_c;
    let r_out = 1.0 / design.output_conductance();
    // Split the lumped strap-via resistance evenly between the two rails'
    // driver ends (it enters the analytic model as part of R_0).
    let r_d_wlt = design.r_driver + 0.5 * seg.r_via;
    let r_d_wlb = design.r_driver + 0.5 * seg.r_via;

    let mut nl = Netlist::new();
    let src = nl.labelled_node("vdd");
    nl.voltage_source(src, GROUND, v_dd);

    // driver ends of the two rails
    let wlt0 = nl.labelled_node("wlt_drv");
    nl.resistor(src, wlt0, r_d_wlt);
    let wlb0 = nl.labelled_node("wlb_drv");
    nl.resistor(wlb0, GROUND, r_d_wlb);

    let mut prev_t = wlt0;
    let mut prev_b = wlb0;
    let mut victim = (GROUND, GROUND);
    let mut victim_mid = None;
    for row in 1..=design.n_row {
        let t = nl.node();
        let b = nl.node();
        nl.resistor(prev_t, t, r_wlt);
        nl.resistor(prev_b, b, r_wlb);
        if row == victim_row {
            victim = (t, b);
            if include_victim_branch {
                let mid = nl.node();
                nl.resistor(t, mid, r_in + r_bl);
                nl.resistor(mid, b, r_out);
                victim_mid = Some(mid);
            }
        } else {
            // aggregated branch: input cell + BL span + output cell
            nl.resistor(t, b, r_in + r_bl + r_out);
        }
        prev_t = t;
        prev_b = b;
    }

    CornerCircuit {
        netlist: nl,
        victim_wlt: victim.0,
        victim_wlb: victim.1,
        victim_mid,
        v_dd,
    }
}

impl CornerCircuit {
    /// Numeric Thevenin equivalent seen between the victim terminals
    /// (requires the circuit built with `include_victim_branch = false`).
    pub fn thevenin(&self) -> crate::Result<TheveninEquivalent> {
        self.netlist.thevenin(self.victim_wlt, self.victim_wlb)
    }

    /// Numeric α_th.
    pub fn alpha(&self) -> crate::Result<f64> {
        Ok(self.thevenin()?.v_th / self.v_dd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::thevenin::ladder_thevenin;
    use crate::interconnect::LineConfig;

    /// The analytic recursion must match full MNA simulation. (The broader
    /// randomized sweep lives in `rust/tests/prop_analysis.rs`.)
    #[test]
    fn analytic_matches_numeric_small() {
        for n_row in [1usize, 2, 3, 8, 33] {
            let d = ArrayDesign::new(n_row, 16, LineConfig::config1(), 2.0, 1.0);
            let cc = build_corner_circuit(&d, n_row, 1.0, false);
            let num = cc.thevenin().unwrap();
            let ana = ladder_thevenin(&d, n_row);
            let seg = d.segments();
            let r_bl = d.span_cols as f64 / seg.g_x;
            let num_r_th = num.r_th + r_bl; // analytic includes victim BL
            assert!(
                (ana.r_th - num_r_th).abs() / num_r_th < 1e-9,
                "n={n_row}: r_th {} vs {}",
                ana.r_th,
                num_r_th
            );
            let num_alpha = num.v_th / 1.0;
            assert!(
                (ana.alpha - num_alpha).abs() < 1e-9,
                "n={n_row}: alpha {} vs {num_alpha}",
                ana.alpha
            );
        }
    }

    #[test]
    fn victim_in_the_middle_matches_numeric() {
        let d = ArrayDesign::new(21, 8, LineConfig::config2(), 1.5, 1.0);
        for victim in [1usize, 5, 11, 20, 21] {
            let cc = build_corner_circuit(&d, victim, 1.0, false);
            let num = cc.thevenin().unwrap();
            let ana = ladder_thevenin(&d, victim);
            let seg = d.segments();
            let num_r_th = num.r_th + d.span_cols as f64 / seg.g_x;
            assert!(
                (ana.r_th - num_r_th).abs() / num_r_th < 1e-9,
                "victim={victim}: {} vs {num_r_th}",
                ana.r_th
            );
            assert!(
                (ana.alpha - num.v_th).abs() < 1e-9,
                "victim={victim}: {} vs {}",
                ana.alpha,
                num.v_th
            );
        }
    }

    #[test]
    fn loaded_victim_current_matches_thevenin_prediction() {
        let d = ArrayDesign::new(12, 8, LineConfig::config1(), 2.0, 1.0);
        let v_dd = 1.0;
        let ana = ladder_thevenin(&d, 12);
        let r_cells = 1.0 / d.device.g_c + 1.0 / d.output_conductance();
        let i_pred = ana.cell_current(v_dd, r_cells);

        let cc = build_corner_circuit(&d, 12, v_dd, true);
        let sol = cc.netlist.solve().unwrap();
        // current through the victim output cell = vdiff across it * G_O
        let mid = cc.victim_mid.unwrap();
        let i_num = sol.vdiff(mid, cc.victim_wlb) * d.output_conductance();
        assert!(
            (i_pred - i_num).abs() / i_num.abs() < 1e-9,
            "{i_pred} vs {i_num}"
        );
    }
}

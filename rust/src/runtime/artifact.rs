//! Artifact store: discovery and typed loading of `make artifacts` outputs.

use anyhow::Context;
use std::collections::HashMap;
use std::fs;
use std::path::PathBuf;

use crate::nn::BinaryLayer;
use crate::util::io;

/// Typed access to the artifacts directory.
#[derive(Clone, Debug)]
pub struct ArtifactStore {
    dir: PathBuf,
}

impl ArtifactStore {
    /// Open the default artifacts directory (repo `artifacts/`).
    pub fn open_default() -> crate::Result<Self> {
        Self::open(io::artifacts_dir())
    }

    /// Open a specific directory.
    pub fn open(dir: PathBuf) -> crate::Result<Self> {
        anyhow::ensure!(
            dir.is_dir(),
            "artifacts directory {} missing — run `make artifacts`",
            dir.display()
        );
        Ok(Self { dir })
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Path to the single-layer inference HLO.
    pub fn nn_infer_hlo(&self) -> PathBuf {
        self.path("nn_infer.hlo.txt")
    }

    /// Path to the MLP inference HLO.
    pub fn mlp_infer_hlo(&self) -> PathBuf {
        self.path("mlp_infer.hlo.txt")
    }

    /// Load the `meta.txt` key-value metadata.
    pub fn meta(&self) -> crate::Result<HashMap<String, String>> {
        let path = self.path("meta.txt");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts`", path.display()))?;
        Ok(parse_meta(&text))
    }

    /// Typed metadata lookup.
    pub fn meta_f64(&self, key: &str) -> crate::Result<f64> {
        let meta = self.meta()?;
        let v = meta
            .get(key)
            .with_context(|| format!("meta key {key} missing"))?;
        v.parse().with_context(|| format!("meta {key}={v} not a number"))
    }

    pub fn meta_usize(&self, key: &str) -> crate::Result<usize> {
        Ok(self.meta_f64(key)? as usize)
    }

    /// Load a binary weight matrix in rust layout (`[out][in]`).
    pub fn weights(&self, name: &str) -> crate::Result<Vec<Vec<f64>>> {
        io::load_matrix(&self.path(name))
    }

    /// The trained single-layer network, threshold included.
    pub fn single_layer(&self) -> crate::Result<BinaryLayer> {
        let w = self.weights("w_single.txt")?;
        let theta = self.meta_usize("theta_single")?;
        Ok(BinaryLayer::from_matrix(&w, theta))
    }

    /// The trained MLP layers `(l1, l2)`.
    pub fn mlp_layers(&self) -> crate::Result<(BinaryLayer, BinaryLayer)> {
        let w1 = self.weights("w_mlp1.txt")?;
        let w2 = self.weights("w_mlp2.txt")?;
        let t1 = self.meta_usize("theta_mlp1")?;
        let t2 = self.meta_usize("theta_mlp2")?;
        Ok((
            BinaryLayer::from_matrix(&w1, t1),
            BinaryLayer::from_matrix(&w2, t2),
        ))
    }

    /// The cross-language dataset check samples: `(labels, images)`.
    pub fn dataset_check(&self) -> crate::Result<(Vec<usize>, Vec<Vec<bool>>)> {
        let m = io::load_matrix(&self.path("dataset_check.txt"))?;
        let labels = m.iter().map(|row| row[0] as usize).collect();
        let images = m
            .iter()
            .map(|row| row[1..].iter().map(|&v| v >= 0.5).collect())
            .collect();
        Ok((labels, images))
    }
}

/// Parse `key value` lines.
pub fn parse_meta(text: &str) -> HashMap<String, String> {
    let mut out = HashMap::new();
    for line in text.lines() {
        if let Some((k, v)) = line.trim().split_once(' ') {
            out.insert(k.to_string(), v.trim().to_string());
        }
    }
    out
}

/// Does the default artifacts directory look populated? (Used by tests to
/// skip gracefully with a pointer to `make artifacts`.)
pub fn artifacts_available() -> bool {
    let dir = io::artifacts_dir();
    dir.join("meta.txt").exists() && dir.join("nn_infer.hlo.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_meta_lines() {
        let m = parse_meta("theta_single 27\nvdd_single 0.324\n# junk\n");
        assert_eq!(m.get("theta_single").unwrap(), "27");
        assert_eq!(m.get("vdd_single").unwrap(), "0.324");
    }

    #[test]
    fn open_missing_dir_errors_helpfully() {
        let err = ArtifactStore::open(PathBuf::from("/nonexistent/xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}

//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and execute them
//! from rust. Python never runs on this path.

pub mod artifact;
pub mod pjrt;

pub use artifact::ArtifactStore;
pub use pjrt::{Executable, Runtime, TensorF32};

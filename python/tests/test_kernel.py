"""Kernel vs reference oracle - the CORE L1 correctness signal.

hypothesis sweeps shapes and input distributions; assert_allclose against
the pure-jnp ref for currents and exact agreement for bits.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.tmvm import tmvm_pallas, vmem_report


def run_both(x, w, alpha, r_th, v_dd, **kw):
    bits_k, i_k = tmvm_pallas(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(alpha), jnp.asarray(r_th), jnp.asarray(v_dd), **kw
    )
    bits_r, i_r = ref.tmvm_ref(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(alpha), jnp.asarray(r_th), jnp.asarray(v_dd)
    )
    return np.asarray(bits_k), np.asarray(i_k), np.asarray(bits_r), np.asarray(i_r)


def make_case(rng, b, n, p, density=0.5, parasitic=False):
    x = (rng.random((b, n)) < density).astype(np.float32)
    w = (rng.random((n, p)) < density).astype(np.float32)
    if parasitic:
        alpha = rng.uniform(0.3, 1.0, (b, 1)).astype(np.float32)
        r_th = rng.uniform(0.0, 20e3, (b, 1)).astype(np.float32)
    else:
        alpha = np.ones((b, 1), np.float32)
        r_th = np.zeros((b, 1), np.float32)
    v_dd = np.array([[ref.vdd_for_threshold(max(1, n // 4))]], np.float32)
    return x, w, alpha, r_th, v_dd


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 96),
    n=st.integers(1, 150),
    p=st.integers(1, 40),
    density=st.floats(0.05, 0.95),
    parasitic=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_swept(b, n, p, density, parasitic, seed):
    rng = np.random.default_rng(seed)
    x, w, alpha, r_th, v_dd = make_case(rng, b, n, p, density, parasitic)
    bits_k, i_k, bits_r, i_r = run_both(x, w, alpha, r_th, v_dd)
    np.testing.assert_allclose(i_k, i_r, rtol=1e-6, atol=1e-12)
    np.testing.assert_array_equal(bits_k, bits_r)


def test_kernel_matches_ref_at_odd_block_edges():
    rng = np.random.default_rng(7)
    # force multi-tile grids with ragged edges
    x, w, alpha, r_th, v_dd = make_case(rng, 130, 121, 37, 0.4, True)
    bits_k, i_k, bits_r, i_r = run_both(x, w, alpha, r_th, v_dd, block_b=32, block_p=16)
    np.testing.assert_allclose(i_k, i_r, rtol=1e-6, atol=1e-12)
    np.testing.assert_array_equal(bits_k, bits_r)


def test_zero_input_row_yields_zero_current():
    x = np.zeros((4, 10), np.float32)
    w = np.ones((10, 3), np.float32)
    alpha = np.ones((4, 1), np.float32)
    r_th = np.zeros((4, 1), np.float32)
    v_dd = np.array([[0.9]], np.float32)
    bits, i_t, *_ = run_both(x, w, alpha, r_th, v_dd)
    assert np.all(i_t == 0.0) and np.all(bits == 0.0)


def test_threshold_semantics_integer_counts():
    # exact count thresholds: theta crystalline products fire, theta-1 don't
    n, theta = 20, 5
    x = np.zeros((2, n), np.float32)
    x[0, :theta] = 1.0
    x[1, : theta - 1] = 1.0
    w = np.zeros((n, 1), np.float32)
    w[:, 0] = 1.0
    alpha = np.ones((2, 1), np.float32)
    r_th = np.zeros((2, 1), np.float32)
    v_dd = np.array([[ref.vdd_for_threshold(theta)]], np.float32)
    bits, i_t, *_ = run_both(x, w, alpha, r_th, v_dd)
    assert bits[0, 0] == 1.0, f"theta products must fire ({i_t[0,0]:.3e} A)"
    assert bits[1, 0] == 0.0, f"theta-1 products must not ({i_t[1,0]:.3e} A)"


def test_reset_violation_suppresses_output():
    # far above the window: I_T >= I_RESET melts the output back to 0
    x = np.ones((1, 50), np.float32)
    w = np.ones((50, 1), np.float32)
    alpha = np.ones((1, 1), np.float32)
    r_th = np.zeros((1, 1), np.float32)
    v_dd = np.array([[5.0]], np.float32)
    bits, i_t, bits_r, _ = run_both(x, w, alpha, r_th, v_dd)
    assert i_t[0, 0] >= ref.I_RESET
    assert bits[0, 0] == 0.0 and bits_r[0, 0] == 0.0


def test_attenuation_starves_far_rows():
    # same image at two ladder depths: the attenuated row loses its bit
    n, theta = 30, 10
    x = np.tile((np.arange(n) < theta).astype(np.float32), (2, 1))
    w = np.ones((n, 1), np.float32)
    alpha = np.array([[1.0], [0.5]], np.float32)
    r_th = np.array([[0.0], [10e3]], np.float32)
    v_dd = np.array([[ref.vdd_for_threshold(theta)]], np.float32)
    bits, *_ = run_both(x, w, alpha, r_th, v_dd)
    assert bits[0, 0] == 1.0 and bits[1, 0] == 0.0


def test_vmem_report_sane():
    r = vmem_report(1024, 121, 128)
    assert r["fits_16MiB_vmem"]
    assert r["tile_macs"] == 64 * 121 * 128
    assert 0.0 < r["edge_utilization"] <= 1.0

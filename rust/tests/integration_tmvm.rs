//! Integration: the TMVM engine against the analysis layer — operating
//! points chosen from the ideal window must compute correctly; voltages
//! outside it must fail in the predicted direction.

use xpoint_imc::analysis::{ideal_window, noise_margin, ArrayDesign};
use xpoint_imc::array::{Level, Subarray, TmvmMode, TmvmOutcome};
use xpoint_imc::interconnect::LineConfig;

fn full_ones_array(n_row: usize, n_col: usize) -> Subarray {
    let design = ArrayDesign::new(n_row, n_col, LineConfig::config3(), 3.0, 1.0);
    let mut sa = Subarray::new(design);
    sa.program_level(Level::Top, &vec![vec![true; n_col]; n_row]);
    sa
}

/// Inside the Eq.-4/5 window, the all-ones TMVM must SET every output and
/// the all-zeros TMVM must hold every output — at both window edges.
#[test]
fn ideal_window_edges_compute_correctly() {
    let n_col = 121;
    let p = xpoint_imc::device::DeviceParams::default();
    let w = ideal_window(n_col, &p);
    assert!(w.is_valid());
    for v in [w.v_min() * 1.001, w.v_mid(), w.v_max() * 0.999] {
        // all weights 1: every row fires
        let mut sa = full_ones_array(8, n_col);
        let rep = sa.tmvm(&vec![true; n_col], 0, v, TmvmMode::Ideal);
        assert!(rep.is_clean(), "v={v}: {:?}", rep.outcomes[0]);
        assert!(rep.outputs.iter().all(|&b| b), "v={v} must fire all rows");

        // all weights 0: no row may fire (R2 condition)
        let design = ArrayDesign::new(8, n_col, LineConfig::config3(), 3.0, 1.0);
        let mut sa0 = Subarray::new(design);
        let rep0 = sa0.tmvm(&vec![true; n_col], 0, v, TmvmMode::Ideal);
        assert!(rep0.outputs.iter().all(|&b| !b), "v={v} must hold zeros");
    }
}

/// Above max(R1) the engine must flag accidental-RESET violations.
#[test]
fn overdrive_flags_violations() {
    let n_col = 121;
    let p = xpoint_imc::device::DeviceParams::default();
    let w = ideal_window(n_col, &p);
    let mut sa = full_ones_array(4, n_col);
    let rep = sa.tmvm(&vec![true; n_col], 0, w.r1_max * 1.1, TmvmMode::Ideal);
    assert!(!rep.is_clean());
    assert!(rep
        .outcomes
        .iter()
        .all(|o| matches!(o, TmvmOutcome::ResetViolation)));
}

/// The NM analysis predicts parasitic behaviour: operating at the window
/// midpoint of an acceptable design, the corner pattern (single input)
/// computes correctly in parasitic mode on first AND last row.
#[test]
fn nm_window_midpoint_works_in_parasitic_mode() {
    let design = ArrayDesign::new(256, 128, LineConfig::config3(), 4.0, 1.0).with_span(121);
    let nm = noise_margin(&design);
    assert!(nm.is_acceptable(), "design must be acceptable");
    let v = nm.v_mid();

    let n_row = design.n_row;
    let n_col = design.n_col;
    let mut sa = Subarray::new(design);
    // single crystalline input column (the corner case): all rows store a
    // 1 in column 0
    let bits: Vec<Vec<bool>> = (0..n_row)
        .map(|_| {
            let mut row = vec![false; n_col];
            row[0] = true;
            row
        })
        .collect();
    sa.program_level(Level::Top, &bits);
    let mut x = vec![false; n_col];
    x[0] = true;
    let rep = sa.tmvm(&x, 0, v, TmvmMode::Parasitic);
    assert!(rep.is_clean());
    assert!(rep.outputs[0], "first row fires at v_mid");
    assert!(rep.outputs[n_row - 1], "last row fires at v_mid");
}

/// Below the last-row window edge, the last row starves while the first
/// row still computes — exactly the failure mode NM guards against.
#[test]
fn below_window_last_row_starves_first() {
    let design = ArrayDesign::new(1024, 128, LineConfig::config1(), 1.0, 1.0).with_span(121);
    let nm = noise_margin(&design);
    let n_row = design.n_row;
    let n_col = design.n_col;
    // pick a voltage above the first-row minimum (but below its RESET
    // bound at 2×) and below the last-row minimum
    assert!(nm.v_min_last > nm.v_min_first);
    let v = 1.4 * nm.v_min_first;
    assert!(v < nm.v_min_last, "design must have a real gap");

    let mut sa = Subarray::new(design);
    let bits: Vec<Vec<bool>> = (0..n_row)
        .map(|_| {
            let mut row = vec![false; n_col];
            row[0] = true;
            row
        })
        .collect();
    sa.program_level(Level::Top, &bits);
    let mut x = vec![false; n_col];
    x[0] = true;
    let rep = sa.tmvm(&x, 0, v, TmvmMode::Parasitic);
    assert!(rep.outputs[0], "first row fires below the combined window");
    assert!(!rep.outputs[n_row - 1], "last row starves");
}

/// Linked subarrays: a computation in subarray 1 deposits correct results
/// in subarray 2 through both Fig. 6 configurations.
#[test]
fn linked_pair_respects_both_configurations() {
    use xpoint_imc::scaling::interlink::{LinkConfig, LinkedPair};
    let n = 6;
    for link in [LinkConfig::BlToBl, LinkConfig::BlToWlt] {
        let design = ArrayDesign::new(n, n, LineConfig::config3(), 3.0, 1.0);
        let mut src = Subarray::new(design.clone());
        let eye: Vec<Vec<bool>> = (0..n).map(|r| (0..n).map(|c| r == c).collect()).collect();
        src.program_level(Level::Top, &eye);
        let v = src.vdd_for_threshold(1);
        let dst = Subarray::new(design);
        let mut pair = LinkedPair::new(src, dst, link);
        let mut x = vec![false; n];
        x[3] = true;
        pair.tmvm_into(&x, 2, v, TmvmMode::Ideal);
        match link {
            LinkConfig::BlToBl => {
                for r in 0..n {
                    assert_eq!(pair.dst.peek(Level::Bottom, r, 2), r == 3);
                }
            }
            LinkConfig::BlToWlt => {
                for c in 0..n {
                    assert_eq!(pair.dst.peek(Level::Top, 2, c), c == 3);
                }
            }
        }
    }
}

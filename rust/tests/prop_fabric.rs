//! Property tests for the fabric placement layer: the locality-aware
//! serpentine keeps consecutive tiles (and therefore consecutive layers)
//! at most one interlink hop apart for **arbitrary** grid dimensions, and
//! placement strategy never changes what the fabric computes — only
//! where the traffic flows.

use xpoint_imc::fabric::{place_layers, FabricConfig, FabricExecutor, PlacementStrategy};
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize) -> BinaryLayer {
    let theta = rng.range(1, 3);
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
            .collect(),
        theta,
    )
}

/// A random layer chain: `l` layers with matching inner dimensions, each
/// dimension drawn from `[lo, hi)`.
fn random_chain(rng: &mut Pcg32, l: usize, lo: usize, hi: usize) -> Vec<BinaryLayer> {
    let dims: Vec<usize> = (0..=l).map(|_| rng.range(lo, hi)).collect();
    (0..l)
        .map(|k| random_layer(rng, dims[k + 1], dims[k]))
        .collect()
}

fn hops(cfg: &FabricConfig, a: usize, b: usize) -> usize {
    let (r0, c0) = cfg.node_coords(a);
    let (r1, c1) = cfg.node_coords(b);
    r0.abs_diff(r1) + c0.abs_diff(c1)
}

/// The serpentine node order is a permutation of the grid in which every
/// pair of consecutive entries is grid-adjacent — for arbitrary grid
/// dimensions, not just the square cases the unit tests pin.
#[test]
fn locality_order_is_an_adjacent_permutation_for_arbitrary_grids() {
    forall(
        Config::default().cases(150),
        "serpentine adjacency",
        |rng: &mut Pcg32| {
            let gr = rng.range(1, 8);
            let gc = rng.range(1, 8);
            let cfg = FabricConfig::new(gr, gc, 8, 8);
            let order = PlacementStrategy::Locality.node_order(gr, gc);
            let mut seen = vec![false; gr * gc];
            for &n in &order {
                if n >= gr * gc || seen[n] {
                    return Err(format!("{gr}×{gc}: node {n} repeated or out of range"));
                }
                seen[n] = true;
            }
            if !seen.iter().all(|&s| s) {
                return Err(format!("{gr}×{gc}: not a permutation"));
            }
            for w in order.windows(2) {
                let h = hops(&cfg, w[0], w[1]);
                if h != 1 {
                    return Err(format!(
                        "{gr}×{gc}: consecutive order nodes {} -> {} are {h} hops apart",
                        w[0], w[1]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// When the network's tiles fit the grid (no wrap-around), serpentine
/// placement keeps every pair of consecutive tiles — including across
/// layer boundaries — at most one interlink hop apart.
#[test]
fn locality_keeps_consecutive_tiles_and_layers_one_hop_apart() {
    forall(
        Config::default().cases(100),
        "one-hop placement",
        |rng: &mut Pcg32| {
            let gr = rng.range(1, 6);
            let gc = rng.range(1, 6);
            let n_nodes = gr * gc;
            // single-tile layers (dims ≤ the 8×8 tile), one per node at most
            let l = rng.range(1, n_nodes + 1);
            let layers = random_chain(rng, l, 2, 9);
            let cfg = FabricConfig::new(gr, gc, 8, 8).with_strategy(PlacementStrategy::Locality);
            let p = place_layers(&layers, &cfg).map_err(|e| format!("placement: {e:#}"))?;
            if p.n_tiles() != l {
                return Err(format!("expected {l} single-tile layers, got {}", p.n_tiles()));
            }
            for w in p.tiles.windows(2) {
                let h = hops(&cfg, w[0].node, w[1].node);
                if h > 1 {
                    return Err(format!(
                        "{gr}×{gc}, {l} layers: tiles (layer {}, {},{}) -> (layer {}, {},{}) \
                         are {h} hops apart",
                        w[0].layer, w[0].tile_row, w[0].tile_col,
                        w[1].layer, w[1].tile_row, w[1].tile_col
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Placement is a performance decision, never a semantic one: for random
/// multi-tile chains (wrap-around included), round-robin and locality
/// produce bit-identical outputs and final counts.
#[test]
fn predictions_are_placement_invariant() {
    forall(
        Config::default().cases(30),
        "placement invariance",
        |rng: &mut Pcg32| {
            let gr = rng.range(1, 4);
            let gc = rng.range(1, 4);
            let l = rng.range(1, 4);
            // dims up to 20 over 8×8 tiles: layers tile and often wrap
            let layers = random_chain(rng, l, 3, 21);
            let m = rng.range(1, 6);
            let n_in = layers[0].n_in();
            let images: Vec<Vec<bool>> = (0..m)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect();
            let run = |strategy: PlacementStrategy| {
                let cfg = FabricConfig::new(gr, gc, 8, 8).with_strategy(strategy);
                let exec = FabricExecutor::new(layers.clone(), cfg).expect("placement");
                exec.run_batch(&images).expect("run")
            };
            let rr = run(PlacementStrategy::RoundRobin);
            let loc = run(PlacementStrategy::Locality);
            if rr.outputs != loc.outputs {
                return Err(format!("{gr}×{gc}, {l} layers: outputs differ"));
            }
            if rr.final_counts != loc.final_counts {
                return Err(format!("{gr}×{gc}, {l} layers: final counts differ"));
            }
            Ok(())
        },
    );
}

//! Seeded offered-load traces for trace-driven serving and exhibits.
//!
//! A [`TrafficTrace`] is the declarative form of "what arrives when":
//! per-wave image counts for one or more tenants sharing a fleet. The
//! serving shell (`xpoint serve --trace`) and the autoscale exhibit
//! replay a trace wave by wave, so scheduling policies can be judged on
//! *identical* offered load — change the policy, keep the trace, diff
//! the timelines.
//!
//! Traces come from seeded generators (uniform / bursty / diurnal /
//! multi-tenant) or from a JSON file, and record back to JSON
//! ([`to_json_string`](TrafficTrace::to_json_string) /
//! [`from_json`](TrafficTrace::from_json)) with the repo-wide config
//! contract: unknown fields are rejected, parse ∘ pretty is the
//! identity, and everything derived from the trace (digit streams
//! included) is a pure function of its fields — replays are
//! byte-deterministic across runs and machines.

use crate::util::json::Json;
use crate::util::Pcg32;

/// The canonical burst: ramps, plateaus, decays to silence (in batches;
/// generators scale it by the batch size). The trailing idle waves are
/// what lets a low autoscale watermark retire shards.
pub const BURST_SHAPE: [usize; 14] = [1, 1, 2, 5, 8, 8, 6, 4, 2, 1, 0, 0, 0, 0];

/// Default wave count of the seeded diurnal / multi-tenant generators.
pub const TRACE_WAVES: usize = 12;

/// Multiplier folding a tenant index into its digit-stream seed (the
/// 64-bit golden-ratio constant; tenant 0 keeps the trace seed exactly,
/// so single-tenant traces reproduce the historical `DigitGen` stream).
const TENANT_SEED_MIX: u64 = 0x9e37_79b9_7f4a_7c15;

/// A deterministic offered-load trace: `waves[w][t]` images from tenant
/// `t` in wave `w`. Every wave row spans all tenants (zeros for idle
/// tenants), so the shape is rectangular and the total load per wave is
/// a plain row sum.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TrafficTrace {
    /// Generator name or file stem — lands in exhibit JSON so replays
    /// are attributable.
    pub name: String,
    /// Seed for everything derived from the trace (wave jitter at
    /// generation time, per-tenant digit streams at replay time).
    pub seed: u64,
    /// Tenant names, indexing the columns of `waves`.
    pub tenants: Vec<String>,
    /// Images per wave per tenant.
    pub waves: Vec<Vec<usize>>,
}

impl TrafficTrace {
    /// Steady load: one tenant offering `images` per wave for `waves`
    /// waves.
    pub fn uniform(seed: u64, waves: usize, images: usize) -> Self {
        Self {
            name: "uniform".into(),
            seed,
            tenants: vec!["default".into()],
            waves: (0..waves.max(1)).map(|_| vec![images]).collect(),
        }
    }

    /// The canonical burst ([`BURST_SHAPE`] × `batch` images per wave) —
    /// exactly the offered load the autoscale exhibit has always
    /// replayed, now in declarative form.
    pub fn bursty(seed: u64, batch: usize) -> Self {
        Self {
            name: "bursty".into(),
            seed,
            tenants: vec!["default".into()],
            waves: BURST_SHAPE.iter().map(|&b| vec![b * batch.max(1)]).collect(),
        }
    }

    /// A quantized day: load follows one sinusoid period from trough to
    /// peak (`peak` images) and back, with seeded per-wave jitter of up
    /// to a quarter of the peak.
    pub fn diurnal(seed: u64, waves: usize, peak: usize) -> Self {
        let waves = waves.max(1);
        let mut rng = Pcg32::seeded(seed ^ 0x00d1_0b17);
        let rows = (0..waves)
            .map(|w| {
                let phase = w as f64 / waves as f64 * std::f64::consts::TAU;
                let base = (peak as f64 * 0.5 * (1.0 - phase.cos())).round() as usize;
                vec![base + rng.range(0, peak / 4 + 1)]
            })
            .collect();
        Self {
            name: "diurnal".into(),
            seed,
            tenants: vec!["default".into()],
            waves: rows,
        }
    }

    /// Three tenants sharing one fleet: phase-shifted diurnal curves
    /// (peaks a third of a period apart) with independent seeded jitter —
    /// the aggregate stays busy while each tenant's own load swings.
    pub fn multi_tenant(seed: u64, waves: usize, peak: usize) -> Self {
        let waves = waves.max(1);
        let tenants: Vec<String> =
            ["tenant-a", "tenant-b", "tenant-c"].iter().map(|s| s.to_string()).collect();
        let mut rng = Pcg32::seeded(seed ^ 0x0031_7e4a);
        let rows = (0..waves)
            .map(|w| {
                (0..tenants.len())
                    .map(|t| {
                        let phase = (w as f64 / waves as f64
                            + t as f64 / tenants.len() as f64)
                            * std::f64::consts::TAU;
                        let base =
                            (peak as f64 * 0.5 * (1.0 - phase.cos())).round() as usize;
                        base + rng.range(0, peak / 4 + 1)
                    })
                    .collect()
            })
            .collect();
        Self {
            name: "multitenant".into(),
            seed,
            tenants,
            waves: rows,
        }
    }

    /// Resolve a `--trace` argument: a generator name (`uniform` |
    /// `bursty` | `diurnal` | `multitenant`, sized from the serving
    /// batch) or a path to a recorded trace JSON file.
    pub fn parse_arg(arg: &str, batch: usize, seed: u64) -> crate::Result<Self> {
        let batch = batch.max(1);
        match arg {
            "uniform" => Ok(Self::uniform(seed, TRACE_WAVES, batch)),
            "bursty" => Ok(Self::bursty(seed, batch)),
            "diurnal" => Ok(Self::diurnal(seed, TRACE_WAVES, 4 * batch)),
            "multitenant" => Ok(Self::multi_tenant(seed, TRACE_WAVES, 2 * batch)),
            path if path.ends_with(".json") => {
                let text = crate::util::io::read_text(std::path::Path::new(path))?;
                Self::from_json(&text)
                    .map_err(|e| anyhow::anyhow!("trace file {path}: {e}"))
            }
            other => anyhow::bail!(
                "unknown trace '{other}' (expected uniform|bursty|diurnal|multitenant \
                 or a recorded trace .json file)"
            ),
        }
    }

    /// Waves in the trace.
    pub fn n_waves(&self) -> usize {
        self.waves.len()
    }

    /// Tenants sharing the fleet.
    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Total images offered in `wave`, across all tenants.
    pub fn offered(&self, wave: usize) -> usize {
        self.waves.get(wave).map(|row| row.iter().sum()).unwrap_or(0)
    }

    /// Total images across the whole trace.
    pub fn total_images(&self) -> usize {
        (0..self.n_waves()).map(|w| self.offered(w)).sum()
    }

    /// Seed of tenant `t`'s digit stream — a pure function of the trace
    /// seed, so replays regenerate identical per-tenant request streams.
    /// Tenant 0 keeps the trace seed itself (single-tenant traces
    /// reproduce the historical serve stream bit for bit).
    pub fn tenant_seed(&self, t: usize) -> u64 {
        self.seed ^ (t as u64).wrapping_mul(TENANT_SEED_MIX)
    }

    /// Structural validation: rectangular waves over at least one named,
    /// uniquely-named tenant.
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("trace name is empty".into());
        }
        if self.tenants.is_empty() {
            return Err("trace has no tenants".into());
        }
        for (i, t) in self.tenants.iter().enumerate() {
            if t.is_empty() {
                return Err(format!("tenant {i} has an empty name"));
            }
            if self.tenants[..i].contains(t) {
                return Err(format!("duplicate tenant name '{t}'"));
            }
        }
        if self.waves.is_empty() {
            return Err("trace has no waves".into());
        }
        for (w, row) in self.waves.iter().enumerate() {
            if row.len() != self.tenants.len() {
                return Err(format!(
                    "wave {w} has {} tenant column(s), expected {}",
                    row.len(),
                    self.tenants.len()
                ));
            }
        }
        Ok(())
    }

    /// The JSON tree (stable key order; the seed renders as a hex string
    /// because JSON numbers are f64 and would corrupt 64-bit seeds).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("seed".into(), Json::Str(format!("{:#x}", self.seed))),
            (
                "tenants".into(),
                Json::Arr(self.tenants.iter().map(|t| Json::Str(t.clone())).collect()),
            ),
            (
                "waves".into(),
                Json::Arr(
                    self.waves
                        .iter()
                        .map(|row| {
                            Json::Arr(row.iter().map(|&n| Json::Num(n as f64)).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Pretty-printed JSON document (what `--trace-out` records).
    pub fn to_json_string(&self) -> String {
        let mut s = self.to_json().pretty();
        s.push('\n');
        s
    }

    /// Parse a recorded trace. Unknown fields are rejected (typo
    /// protection, like every config surface in this repo); `seed`
    /// accepts `0x…` hex or decimal.
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let entries = match &v {
            Json::Obj(entries) => entries,
            _ => return Err("trace must be a JSON object".into()),
        };
        let mut name: Option<String> = None;
        let mut seed: Option<u64> = None;
        let mut tenants: Option<Vec<String>> = None;
        let mut waves: Option<Vec<Vec<usize>>> = None;
        for (key, val) in entries {
            match key.as_str() {
                "name" => {
                    name = Some(
                        val.as_str().ok_or("field 'name': expected a string")?.to_string(),
                    )
                }
                "seed" => seed = Some(parse_seed(val)?),
                "tenants" => {
                    let items = match val {
                        Json::Arr(items) => items,
                        _ => return Err("field 'tenants': expected an array".into()),
                    };
                    tenants = Some(
                        items
                            .iter()
                            .map(|t| {
                                t.as_str()
                                    .map(str::to_string)
                                    .ok_or_else(|| "tenant names must be strings".to_string())
                            })
                            .collect::<Result<_, _>>()?,
                    );
                }
                "waves" => {
                    let rows = match val {
                        Json::Arr(rows) => rows,
                        _ => return Err("field 'waves': expected an array".into()),
                    };
                    waves = Some(
                        rows.iter()
                            .enumerate()
                            .map(|(w, row)| match row {
                                Json::Arr(cells) => cells
                                    .iter()
                                    .map(|c| {
                                        c.as_usize().ok_or_else(|| {
                                            format!(
                                                "wave {w}: image counts must be \
                                                 non-negative integers"
                                            )
                                        })
                                    })
                                    .collect::<Result<Vec<usize>, _>>(),
                                _ => Err(format!("wave {w} must be an array")),
                            })
                            .collect::<Result<_, _>>()?,
                    );
                }
                other => return Err(format!("unknown field '{other}'")),
            }
        }
        let trace = Self {
            name: name.ok_or("missing field 'name'")?,
            seed: seed.unwrap_or(crate::nn::dataset::TEST_SEED),
            tenants: tenants.ok_or("missing field 'tenants'")?,
            waves: waves.ok_or("missing field 'waves'")?,
        };
        trace.validate()?;
        Ok(trace)
    }
}

fn parse_seed(v: &Json) -> Result<u64, String> {
    let s = v.as_str().ok_or("field 'seed': expected a string (\"0x…\" or decimal)")?;
    let parsed = match s.strip_prefix("0x") {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => s.parse::<u64>(),
    };
    parsed.map_err(|_| format!("field 'seed': '{s}' is not a u64"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bursty_reproduces_the_canonical_burst() {
        let t = TrafficTrace::bursty(7, 16);
        assert_eq!(t.n_waves(), BURST_SHAPE.len());
        assert_eq!(t.n_tenants(), 1);
        for (w, &b) in BURST_SHAPE.iter().enumerate() {
            assert_eq!(t.offered(w), b * 16, "wave {w}");
        }
        assert_eq!(t.total_images(), BURST_SHAPE.iter().sum::<usize>() * 16);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn generators_are_seed_deterministic() {
        assert_eq!(
            TrafficTrace::diurnal(42, 12, 64),
            TrafficTrace::diurnal(42, 12, 64)
        );
        assert_ne!(
            TrafficTrace::diurnal(42, 12, 64).waves,
            TrafficTrace::diurnal(43, 12, 64).waves,
            "seed moves the jitter"
        );
        let mt = TrafficTrace::multi_tenant(9, 12, 32);
        assert_eq!(mt, TrafficTrace::multi_tenant(9, 12, 32));
        assert_eq!(mt.n_tenants(), 3);
        for row in &mt.waves {
            assert_eq!(row.len(), 3);
        }
        // phase shift: the tenants do not peak in the same wave
        let peaks: Vec<usize> = (0..3)
            .map(|t| {
                (0..mt.n_waves())
                    .max_by_key(|&w| mt.waves[w][t])
                    .unwrap()
            })
            .collect();
        assert!(
            peaks[0] != peaks[1] || peaks[1] != peaks[2],
            "phase-shifted tenants should peak apart: {peaks:?}"
        );
    }

    #[test]
    fn tenant_seeds_are_distinct_and_anchor_tenant_zero() {
        let t = TrafficTrace::multi_tenant(0x3d_c0ffee, 8, 16);
        assert_eq!(t.tenant_seed(0), t.seed, "tenant 0 keeps the trace seed");
        assert_ne!(t.tenant_seed(0), t.tenant_seed(1));
        assert_ne!(t.tenant_seed(1), t.tenant_seed(2));
    }

    #[test]
    fn json_roundtrip_is_the_identity() {
        for t in [
            TrafficTrace::uniform(1, 4, 8),
            TrafficTrace::bursty(0xdead_beef_dead_beef, 32),
            TrafficTrace::diurnal(5, 10, 40),
            TrafficTrace::multi_tenant(6, 9, 24),
        ] {
            let text = t.to_json_string();
            let parsed = TrafficTrace::from_json(&text).expect("parse");
            assert_eq!(parsed, t, "value roundtrip");
            assert_eq!(parsed.to_json_string(), text, "serialization is a fixed point");
            // parse ∘ pretty at the JSON-tree level too
            assert_eq!(Json::parse(&text).unwrap(), t.to_json());
        }
    }

    #[test]
    fn json_rejects_unknown_fields_and_bad_shapes() {
        let err = TrafficTrace::from_json(
            r#"{"name":"x","tenants":["a"],"waves":[[1]],"tennants":["b"]}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown field 'tennants'"), "{err}");
        let err =
            TrafficTrace::from_json(r#"{"name":"x","tenants":["a"],"waves":[[1,2]]}"#)
                .unwrap_err();
        assert!(err.contains("tenant column"), "{err}");
        let err = TrafficTrace::from_json(r#"{"tenants":["a"],"waves":[[1]]}"#).unwrap_err();
        assert!(err.contains("missing field 'name'"), "{err}");
        let err = TrafficTrace::from_json(
            r#"{"name":"x","tenants":["a","a"],"waves":[[1,1]]}"#,
        )
        .unwrap_err();
        assert!(err.contains("duplicate tenant"), "{err}");
        let err = TrafficTrace::from_json(
            r#"{"name":"x","seed":"zz","tenants":["a"],"waves":[[1]]}"#,
        )
        .unwrap_err();
        assert!(err.contains("not a u64"), "{err}");
        // a 64-bit seed survives the hex encoding exactly
        let t = TrafficTrace {
            seed: u64::MAX,
            ..TrafficTrace::uniform(0, 2, 1)
        };
        let parsed = TrafficTrace::from_json(&t.to_json_string()).unwrap();
        assert_eq!(parsed.seed, u64::MAX);
    }

    #[test]
    fn parse_arg_resolves_generators_and_rejects_nonsense() {
        let t = TrafficTrace::parse_arg("bursty", 16, 3).unwrap();
        assert_eq!((t.name.as_str(), t.seed), ("bursty", 3));
        assert_eq!(t.offered(4), 8 * 16);
        assert!(TrafficTrace::parse_arg("uniform", 8, 0).is_ok());
        assert!(TrafficTrace::parse_arg("diurnal", 8, 0).is_ok());
        let mt = TrafficTrace::parse_arg("multitenant", 8, 0).unwrap();
        assert_eq!(mt.n_tenants(), 3);
        let err = TrafficTrace::parse_arg("sawtooth", 16, 0).unwrap_err();
        assert!(err.to_string().contains("unknown trace"), "{err}");
        let err = TrafficTrace::parse_arg("/nonexistent/trace.json", 16, 0).unwrap_err();
        assert!(err.to_string().contains("nonexistent"), "{err}");
    }
}

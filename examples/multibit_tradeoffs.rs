//! Multi-bit TMVM trade-off study (paper §IV-C / Table III): the
//! area-efficient (voltage-scaled) vs low-power (cell-replicated) schemes,
//! with the drive-voltage feasibility cliff.
//!
//! ```bash
//! cargo run --release --example multibit_tradeoffs
//! ```

use xpoint_imc::analysis::ArrayDesign;
use xpoint_imc::array::multibit::V_CEILING;
use xpoint_imc::array::{multibit_tmvm_cost, MultibitScheme};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::report::table3_rows;
use xpoint_imc::util::si::format_si;
use xpoint_imc::util::Table;

fn main() {
    println!("Multi-bit TMVM: area-efficient vs low-power (121-input dot product)\n");
    let (_, _, table) = table3_rows(0.9);
    print!("{}", table.render());

    // operating-voltage sensitivity: where does the AE cliff move?
    let design = ArrayDesign::new(128, 128, LineConfig::config3(), 3.0, 1.0);
    let mut t = Table::new("area-efficient feasibility vs operating V_DD (ceiling 5 V)")
        .header(&["V_DD", "max feasible bits", "energy at max", "top drive voltage"]);
    for v in [0.4, 0.65, 0.9, 1.2] {
        let mut max_bits = 0;
        for b in 1..=8 {
            if multibit_tmvm_cost(&design, MultibitScheme::AreaEfficient, b, 121, v).feasible {
                max_bits = b;
            }
        }
        let at_max = multibit_tmvm_cost(&design, MultibitScheme::AreaEfficient, max_bits, 121, v);
        t.row(&[
            format_si(v, "V"),
            max_bits.to_string(),
            format_si(at_max.energy, "J"),
            format_si(at_max.max_voltage, "V"),
        ]);
    }
    print!("{}", t.render());
    println!("subarray drive ceiling: {} V", V_CEILING);

    // crossover guidance: which scheme wins at each width?
    let mut t = Table::new("scheme guidance (energy × area product)")
        .header(&["bits", "AE E·A", "LP E·A", "recommendation"]);
    for b in 1..=6 {
        let ae = multibit_tmvm_cost(&design, MultibitScheme::AreaEfficient, b, 121, 0.9);
        let lp = multibit_tmvm_cost(&design, MultibitScheme::LowPower, b, 121, 0.9);
        let ae_score = if ae.feasible { ae.energy * ae.area } else { f64::INFINITY };
        let lp_score = lp.energy * lp.area;
        let rec = if ae_score < lp_score { "area-efficient" } else { "low-power" };
        t.row(&[
            b.to_string(),
            if ae.feasible {
                format!("{ae_score:.2e}")
            } else {
                ">5V".into()
            },
            format!("{lp_score:.2e}"),
            rec.to_string(),
        ]);
    }
    print!("{}", t.render());
}

//! Discrete-event core of the fabric simulator: an integer-time event
//! queue with deterministic FIFO tie-breaking and **no wall-clock
//! dependence** — simulated time is `u64` picoseconds, so runs are
//! bit-reproducible across hosts and repetitions.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated time in picoseconds.
pub type Time = u64;

/// Picoseconds per second.
pub const PS_PER_SEC: f64 = 1e12;

/// Convert seconds to simulator ticks (rounded to the nearest ps).
pub fn secs_to_ticks(s: f64) -> Time {
    debug_assert!(s >= 0.0 && s.is_finite());
    (s * PS_PER_SEC).round() as Time
}

/// Convert simulator ticks back to seconds.
pub fn ticks_to_secs(t: Time) -> f64 {
    t as f64 / PS_PER_SEC
}

struct Scheduled<T> {
    at: Time,
    seq: u64,
    payload: T,
}

// BinaryHeap is a max-heap; invert the (time, seq) ordering so the
// earliest event (FIFO within a tick) pops first.
impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        (self.at, self.seq) == (other.at, other.seq)
    }
}

impl<T> Eq for Scheduled<T> {}

/// Event queue + clock. The clock only moves forward, to the timestamp of
/// the event being popped.
pub struct EventQueue<T> {
    heap: BinaryHeap<Scheduled<T>>,
    seq: u64,
    now: Time,
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0,
        }
    }

    /// Current simulated time (timestamp of the last popped event).
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `payload` at absolute time `at` (must not be in the past).
    pub fn schedule(&mut self, at: Time, payload: T) {
        assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, T)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "time went backwards");
        self.now = ev.at;
        Some((ev.at, ev.payload))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(30, 'c');
        q.schedule(10, 'a');
        q.schedule(20, 'b');
        let order: Vec<char> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec!['a', 'b', 'c']);
        assert_eq!(q.now(), 30);
    }

    #[test]
    fn same_tick_is_fifo() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.schedule(42, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, p)| p)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn clock_is_monotonic_and_schedulable_mid_run() {
        let mut q = EventQueue::new();
        q.schedule(5, "first");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5);
        q.schedule(5, "same-time ok");
        q.schedule(9, "later");
        assert_eq!(q.pop().unwrap().1, "same-time ok");
        assert_eq!(q.pop().unwrap().1, "later");
        assert!(q.pop().is_none());
        assert_eq!(q.now(), 9);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_rejected() {
        let mut q = EventQueue::new();
        q.schedule(10, ());
        q.pop();
        q.schedule(9, ());
    }

    #[test]
    fn tick_conversions_roundtrip() {
        assert_eq!(secs_to_ticks(80e-9), 80_000);
        assert_eq!(secs_to_ticks(0.0), 0);
        let s = 1.25e-6;
        assert!((ticks_to_secs(secs_to_ticks(s)) - s).abs() < 1e-15);
    }
}

//! ASAP7 7-nm predictive PDK interconnect data (supplementary Tables V and
//! VI; Clark et al. [25], [26]). All lengths in meters, resistivity in Ω·m.

/// One ASAP7 metal layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetalLayer {
    /// 1-based layer index (M1..M9).
    pub index: usize,
    /// Preferred routing direction alternates V/H; `vertical == true` for
    /// M1, M3, M5, M7, M9.
    pub vertical: bool,
    /// Metal thickness t_M \[m\].
    pub thickness: f64,
    /// Minimum line spacing S_min \[m\].
    pub s_min: f64,
    /// Minimum line width W_min \[m\].
    pub w_min: f64,
    /// Resistivity ρ_M \[Ω·m\].
    pub rho: f64,
}

impl MetalLayer {
    /// Minimum routing pitch (width + spacing) \[m\].
    pub fn pitch_min(&self) -> f64 {
        self.w_min + self.s_min
    }

    /// Sheet-style segment resistance for a wire of `length` and `width`
    /// on this layer \[Ω\]: `ρ·L / (t·W)`.
    pub fn wire_resistance(&self, length: f64, width: f64) -> f64 {
        assert!(length > 0.0 && width > 0.0);
        self.rho * length / (self.thickness * width)
    }
}

const NM: f64 = 1e-9;

/// Supplementary Table V. `ρ` is given in Ω·nm in the paper; stored here in
/// Ω·m (1 Ω·nm = 1e-9 Ω·m).
pub const ASAP7_METALS: [MetalLayer; 9] = [
    MetalLayer { index: 1, vertical: true,  thickness: 36.0 * NM, s_min: 18.0 * NM, w_min: 18.0 * NM, rho: 43.2 * NM },
    MetalLayer { index: 2, vertical: false, thickness: 36.0 * NM, s_min: 18.0 * NM, w_min: 18.0 * NM, rho: 43.2 * NM },
    MetalLayer { index: 3, vertical: true,  thickness: 36.0 * NM, s_min: 18.0 * NM, w_min: 18.0 * NM, rho: 43.2 * NM },
    MetalLayer { index: 4, vertical: false, thickness: 48.0 * NM, s_min: 24.0 * NM, w_min: 24.0 * NM, rho: 36.9 * NM },
    MetalLayer { index: 5, vertical: true,  thickness: 48.0 * NM, s_min: 24.0 * NM, w_min: 24.0 * NM, rho: 36.9 * NM },
    MetalLayer { index: 6, vertical: false, thickness: 64.0 * NM, s_min: 32.0 * NM, w_min: 32.0 * NM, rho: 32.0 * NM },
    MetalLayer { index: 7, vertical: true,  thickness: 64.0 * NM, s_min: 32.0 * NM, w_min: 32.0 * NM, rho: 32.0 * NM },
    MetalLayer { index: 8, vertical: false, thickness: 80.0 * NM, s_min: 40.0 * NM, w_min: 40.0 * NM, rho: 28.8 * NM },
    MetalLayer { index: 9, vertical: true,  thickness: 80.0 * NM, s_min: 40.0 * NM, w_min: 40.0 * NM, rho: 28.8 * NM },
];

/// A via between adjacent metal layers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Via {
    /// Connects M\[lower\] and M\[lower+1\].
    pub lower: usize,
    /// Via resistance \[Ω\].
    pub r: f64,
    /// Via edge size \[m\] (square).
    pub size: f64,
    /// Minimum spacing \[m\].
    pub s_min: f64,
}

/// Supplementary Table VI.
pub const ASAP7_VIAS: [Via; 8] = [
    Via { lower: 1, r: 17.0, size: 18.0 * NM, s_min: 18.0 * NM },
    Via { lower: 2, r: 17.0, size: 18.0 * NM, s_min: 18.0 * NM },
    Via { lower: 3, r: 17.0, size: 18.0 * NM, s_min: 18.0 * NM },
    Via { lower: 4, r: 12.0, size: 24.0 * NM, s_min: 33.0 * NM },
    Via { lower: 5, r: 12.0, size: 24.0 * NM, s_min: 33.0 * NM },
    Via { lower: 6, r: 8.0,  size: 32.0 * NM, s_min: 45.0 * NM },
    Via { lower: 7, r: 8.0,  size: 32.0 * NM, s_min: 45.0 * NM },
    Via { lower: 8, r: 6.0,  size: 40.0 * NM, s_min: 57.0 * NM },
];

/// Look up a metal layer by 1-based index.
pub fn metal(index: usize) -> &'static MetalLayer {
    &ASAP7_METALS[index - 1]
}

/// Resistance of a stacked via chain connecting layer `from` to layer `to`
/// (sum of all intermediate vias) \[Ω\].
pub fn via_chain_resistance(from: usize, to: usize) -> f64 {
    let (lo, hi) = if from <= to { (from, to) } else { (to, from) };
    ASAP7_VIAS
        .iter()
        .filter(|v| v.lower >= lo && v.lower < hi)
        .map(|v| v.r)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-6 * b.abs()
    }

    #[test]
    fn table_v_values() {
        assert!(close(metal(1).thickness, 36e-9));
        assert!(close(metal(4).w_min, 24e-9));
        assert!(close(metal(9).rho, 28.8e-9));
        assert!(metal(1).vertical && !metal(2).vertical);
    }

    #[test]
    fn pitch_is_width_plus_space() {
        assert!((metal(1).pitch_min() - 36e-9).abs() < 1e-18);
        assert!((metal(8).pitch_min() - 80e-9).abs() < 1e-18);
    }

    #[test]
    fn wire_resistance_m1_cell_segment() {
        // ρL/(tW) with L = 36nm, W = 18nm, t = 36nm, ρ = 43.2 Ω·nm -> 2.4 Ω
        let r = metal(1).wire_resistance(36e-9, 18e-9);
        assert!((r - 2.4).abs() < 1e-9, "r = {r}");
    }

    #[test]
    fn upper_layers_are_better_conductors() {
        // per unit length at min width, higher layers have lower resistance
        let r1 = metal(1).wire_resistance(1e-6, metal(1).w_min);
        let r9 = metal(9).wire_resistance(1e-6, metal(9).w_min);
        assert!(r9 < r1 / 3.0);
    }

    #[test]
    fn via_chain_sums() {
        assert_eq!(via_chain_resistance(1, 2), 17.0);
        assert_eq!(via_chain_resistance(2, 5), 17.0 + 17.0 + 12.0);
        assert_eq!(via_chain_resistance(5, 2), 17.0 + 17.0 + 12.0);
        assert_eq!(via_chain_resistance(3, 3), 0.0);
        assert_eq!(via_chain_resistance(1, 9), 17.0 * 3.0 + 12.0 * 2.0 + 8.0 * 2.0 + 6.0);
    }
}

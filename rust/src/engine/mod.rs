//! The unified engine layer: **one declarative spec, one serving API,
//! every backend fidelity**.
//!
//! The paper's accelerator is a single substrate exposed at several
//! fidelities — the ideal Eq. 3 TMVM, the parasitic-aware ladder model,
//! the multi-subarray fabric, the AOT-compiled XLA golden model. This
//! module makes that a first-class idea instead of four ad-hoc entry
//! points:
//!
//! * [`spec`] — [`EngineSpec`]: a declarative, builder-style configuration
//!   unifying the subarray design, the fabric geometry, the batching
//!   policy, the network source and the [`BackendKind`]; constructible
//!   from code, from CLI flags ([`EngineSpec::from_args`]) and from JSON
//!   ([`EngineSpec::from_json_file`], `--engine path.json`). Its
//!   [`build`](EngineSpec::build) method is the one registry every
//!   serving path goes through.
//! * [`api`] — the [`Engine`] trait (batched inference + [`Capabilities`]
//!   introspection + typed [`Telemetry`] + the non-blocking
//!   [`submit`](Engine::submit)/[`poll`](Engine::poll) pair) and the
//!   [`BackendFactory`] the coordinator spawns workers from.
//! * [`backends`] — the concrete engines: [`SimBackend`],
//!   [`FabricBackend`], [`XlaBackend`].
//! * [`sharded`] — [`ShardedEngine`]: N inner engines on their own worker
//!   threads behind an asynchronous, capability-aware least-loaded
//!   submit/poll scheduler (the `Sharded` backend kind), with rolling
//!   live weight swaps through the [`ShardState`] lifecycle
//!   (`Serving → Draining → Reprogramming → Rejoining`) and — when built
//!   from an [`AutoscaleSpec`] — an elastic spawn/retire lifecycle
//!   (`Serving → Draining → Parked` / `Spawning → Programming →
//!   Rejoining`) with per-slot pulse-endurance wear budgets.
//! * [`error`] — [`EngineError`], the typed error surface (implements
//!   `std::error::Error`, lifts into `anyhow` via `?`).
//!
//! A fifth fidelity lives out-of-process: [`crate::net`] contributes the
//! `Remote` backend ([`RemoteSpec`], `--remote host:port|unix:/path`),
//! one shard's worth of fabric served by an `xpoint shard-host` behind a
//! socket — to the scheduler it is just another [`BackendFactory`].
//!
//! Adding a new backend fidelity = one [`BackendKind`] variant + one arm
//! in [`EngineSpec::build`] — no new `main.rs` special case.

pub mod api;
pub mod backends;
pub mod error;
pub mod sharded;
pub mod spec;

pub use api::{
    BackendFactory, Batch, CanaryReport, Capabilities, Completions, Engine, InferenceResult,
    ScaleEvent, ScaleEventKind, ScaleLoad, SwapReport, Telemetry, Ticket,
};
pub use backends::{FabricBackend, SimBackend, XlaBackend, XLA_GRAPH_BATCH};
pub use error::EngineError;
pub use sharded::{ShardBuilder, ShardState, ShardedEngine};
pub use spec::{
    ArraySpec, AutoscaleSpec, BackendKind, BatchPolicy, EngineSpec, FabricSpec, NetworkSource,
    RemoteSpec, ShardSpec,
};

//! `xpoint` — CLI entry point for the 3D XPoint in-memory-computing stack.
//!
//! Subcommands regenerate the paper's exhibits from the same library code
//! used by `cargo bench`, and `serve` runs the L3 coordinator on the
//! synthetic digit workload (simulator or XLA backend).

use xpoint_imc::analysis::{max_rows_for_nm, noise_margin, ArrayDesign};
use xpoint_imc::cli::Args;
use xpoint_imc::coordinator::{Coordinator, TrafficTrace};
use xpoint_imc::engine::{BackendKind, EngineError, EngineSpec, NetworkSource};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::net::{serve_factory, Listener, RemoteAddr};
use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::nn::expand_unary;
use xpoint_imc::report;
use xpoint_imc::runtime::artifact::artifacts_available;
use xpoint_imc::runtime::ArtifactStore;
use xpoint_imc::util::si::{format_duration, format_pct, format_si};

const USAGE: &str = "\
xpoint — 3D XPoint in-memory computing accelerator (Zabihi et al., 2021)

USAGE: xpoint <command> [options]

COMMANDS:
  nm        noise-margin analysis of one design
            --rows N --cols N --config 1|2|3 --lscale X --wscale X --span N
  maxsize   largest N_row meeting an NM target
            --config 1|2|3 --lscale X --target PCT
  table1    metal-line configurations (paper Table I)
  fig10     R_th / alpha_th vs N_row (paper Fig. 10)
  fig11     voltage windows + acceptable region (paper Fig. 11)
  fig13     NM sweeps, all four panels (paper Fig. 13)
  table2    digit-recognition evaluation (paper Table II)
  table3    multi-bit TMVM costs (paper Table III)
  fabric    pipelined multi-subarray fabric scaling exhibit
            --batch N (default 32)
  shards    sharded-serving exhibit: throughput + load balance over
            1|2|4 fabric shards  --images N (default 1024) --batch N
  reprogram live-reprogramming exhibit: rolling shard drain → reprogram →
            rejoin timeline, pulse counts, energy, throughput dip
            --shards N (default 2) --waves N (default 6) --batch N
  autoscale shard-autoscaling exhibit: replay an offered-load trace
            against an elastic engine — scale-up/down decisions,
            spawn/retire events, wear budgets
            --min N --max N --batch N --budget PULSES
            [--trace uniform|bursty|diurnal|multitenant|FILE.json]
            (offered load; default: the canonical burst)
            [--trace-seed N] (trace + digit-stream seed)
            [--json] (machine-readable timeline via util::json)
  montecarlo Monte Carlo variability sweep: device corners + resistance
            variation over the array sizes — noise-margin distribution,
            margin failure rate and digit-accuracy distribution per size
            --seed N --trials N [--json] (seed-deterministic, byte-stable)
  serve     run the coordinator on synthetic digits
            --images N --workers N --batch N [--xla] [--parasitic]
            [--network auto|template|artifact|multibit:BITS[:SCHEME]|
             conv:FxKHxKW[:tN]]  (what the fabric serves: multibit N-ary
            inputs via unary lowering + Table III energy premium, or a
            binary conv bank via im2col lowering; SCHEME is
            lowpower|area, tN the conv vote threshold)
            [--trace uniform|bursty|diurnal|multitenant|FILE.json]
            (replay a seeded offered-load trace wave by wave instead of
            a flat --images stream; per-tenant accounting in the report)
            [--trace-seed N]     (trace + digit-stream seed)
            [--trace-out PATH]   (record the resolved trace as JSON)
            [--fabric] [--grid N] (fabric backend on an N×N subarray grid)
            [--shards N]          (N async engine shards per worker)
            [--autoscale MIN,MAX] (elastic shards: queue-driven
            spawn/retire between MIN and MAX, evaluated live)
            [--canary FRACTION]   (one parasitic-fidelity shard mirrors
            FRACTION of traffic behind the ideal fleet; divergence and
            noise-margin telemetry land in the serve report)
            [--remote ADDR[,ADDR..]] (remote shard hosts, host:port or
            unix:/path — alone: the whole engine; with --shards or
            --autoscale: extra shards joining the local fleet)
            [--placement roundrobin|locality] (fabric tile placement)
            [--swap-to SPEC] (live-swap the network mid-run, same
            grammar as --network; shards drain + reprogram one at a
            time; both endpoints must share substrate geometry)
            [--engine spec.json]  (declarative EngineSpec; flags override)
  shard-host serve one shard's worth of fabric behind a socket
            --listen host:port|unix:/path (required; TCP port 0 picks a
            free port, printed as `listening on ...`)
            [--conns N] (exit after N connections; default: serve until
            a shutdown order arrives)
            backend flags as for serve (--parasitic --fabric --grid
            --batch --engine ...); --shards/--autoscale/--remote are
            rejected — fleets are composed on the serve side
  help      this text
";

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn design_from_args(args: &Args) -> xpoint_imc::Result<ArrayDesign> {
    let rows = args.get_usize("rows", 64)?;
    let cols = args.get_usize("cols", 128)?;
    let config = match args.get_or("config", "3").as_str() {
        "1" => LineConfig::config1(),
        "2" => LineConfig::config2(),
        "3" => LineConfig::config3(),
        other => anyhow::bail!("unknown config {other}"),
    };
    let l = args.get_f64("lscale", 4.0)?;
    let w = args.get_f64("wscale", 1.0)?;
    let mut d = ArrayDesign::new(rows, cols, config, l, w);
    if let Some(span) = args.get("span") {
        d = d.with_span(span.parse()?);
    }
    Ok(d)
}

fn run(args: &Args) -> xpoint_imc::Result<()> {
    match args.command.as_deref() {
        Some("nm") => {
            let d = design_from_args(args)?;
            let nm = noise_margin(&d);
            println!(
                "design: config {} {}×{} cell {:.0}×{:.0} nm span {}",
                d.config.id,
                d.n_row,
                d.n_col,
                d.cell.w_cell * 1e9,
                d.cell.l_cell * 1e9,
                d.span_cols
            );
            println!(
                "first row: [{}, {}]",
                format_si(nm.v_min_first, "V"),
                format_si(nm.v_max_first, "V")
            );
            println!(
                "last row:  [{}, {}]",
                format_si(nm.v_min_last, "V"),
                format_si(nm.v_max_last, "V")
            );
            println!(
                "window:    [{}, {}]  NM = {}",
                format_si(nm.v_lo(), "V"),
                format_si(nm.v_hi(), "V"),
                format_pct(nm.noise_margin())
            );
            Ok(())
        }
        Some("maxsize") => {
            let d = design_from_args(args)?;
            let target = args.get_f64("target", 0.0)? / 100.0;
            let max = max_rows_for_nm(&d, target);
            println!(
                "config {} at L={:.0}nm: max N_row with NM ≥ {} is {}",
                d.config.id,
                d.cell.l_cell * 1e9,
                format_pct(target),
                max
            );
            Ok(())
        }
        Some("table1") => {
            print!("{}", report::table1_rows().render());
            Ok(())
        }
        Some("fig10") => {
            let rows = report::fig10_series(&[16, 32, 64, 128, 256, 512, 1024, 2048], 100.0);
            let mut t = xpoint_imc::util::Table::new("Fig. 10 — Thevenin vs N_row (config 1)")
                .header(&["N_row", "R_th", "alpha_th"]);
            for r in &rows {
                t.row(&[
                    r.n_row.to_string(),
                    format_si(r.r_th, "Ω"),
                    format!("{:.4}", r.alpha),
                ]);
            }
            print!("{}", t.render());
            Ok(())
        }
        Some("fig11") => {
            let d = design_from_args(args)?;
            let data = report::fig11_regions(&d, &[0.0, 2e3, 5e3, 10e3, 20e3]);
            println!("design: {}", data.design);
            println!(
                "first-row window [{}, {}], last-row window [{}, {}]",
                format_si(data.v_min_first, "V"),
                format_si(data.v_max_first, "V"),
                format_si(data.v_min_last, "V"),
                format_si(data.v_max_last, "V")
            );
            println!("NM = {}", format_pct(data.nm));
            println!("NM=0 boundary (alpha_min at R_th):");
            for (r, a) in &data.boundary {
                println!("  R_th = {:>8}: alpha ≥ {a:.3}", format_si(*r, "Ω"));
            }
            Ok(())
        }
        Some("fig13") => {
            print!("{}", report::exhibits::fig13_table('a', "N_row").render());
            print!("{}", report::exhibits::fig13_table('b', "L_cell/L_min").render());
            print!("{}", report::exhibits::fig13_table('c', "W_cell/W_min").render());
            print!("{}", report::exhibits::fig13_table('d', "N_column").render());
            Ok(())
        }
        Some("table2") => {
            let (layer, _) = load_layer_or_template()?;
            let rows = report::table2_rows(&layer);
            print!("{}", report::table2::table2_table(&rows).render());
            Ok(())
        }
        Some("table3") => {
            let (_, _, t) = report::table3_rows(0.9);
            print!("{}", t.render());
            Ok(())
        }
        Some("fabric") => {
            let batch = args.get_usize("batch", 32)?;
            let rows = report::fabric_scaling_rows(&report::FABRIC_GRIDS, batch)?;
            print!("{}", report::fabric_scaling_table(&rows).render());
            Ok(())
        }
        Some("shards") => {
            let images = args.get_usize("images", 1024)?;
            let batch = args.get_usize("batch", 64)?;
            let rows = report::shard_scaling_rows(&report::SHARD_SWEEP, images, batch)?;
            print!("{}", report::shard_scaling_table(&rows).render());
            Ok(())
        }
        Some("reprogram") => {
            let shards = args.get_usize("shards", report::REPROGRAM_SHARDS)?;
            let waves = args.get_usize("waves", report::REPROGRAM_WAVES)?;
            let batch = args.get_usize("batch", 32)?;
            let (rows, swap) = report::reprogram_timeline(shards, waves, batch)?;
            print!("{}", report::reprogram_table(&rows).render());
            println!("{}", report::reprogram_summary(&swap));
            Ok(())
        }
        Some("autoscale") => {
            let min = args.get_usize("min", report::AUTOSCALE_MIN)?;
            let max = args.get_usize("max", report::AUTOSCALE_MAX)?;
            let batch = args.get_usize("batch", 32)?.clamp(1, 64);
            let budget = args.get_usize("budget", 0)? as u64;
            let seed = args.get_usize("trace-seed", TEST_SEED as usize)? as u64;
            let trace = match args.get("trace") {
                Some(arg) => TrafficTrace::parse_arg(arg, batch, seed)?,
                None => TrafficTrace::bursty(seed, batch),
            };
            let (rows, summary) =
                report::autoscale_timeline_trace(&trace, min, max, batch, budget)?;
            if args.has_flag("json") {
                println!(
                    "{}",
                    report::autoscale_json(&trace.name, &rows, &summary).pretty()
                );
            } else {
                print!("{}", report::autoscale_table(&rows).render());
                println!("{}", report::autoscale_summary_line(&summary));
            }
            Ok(())
        }
        Some("montecarlo") => {
            let seed = args.get_usize("seed", report::MC_SEED as usize)? as u64;
            let trials = args.get_usize("trials", report::MC_TRIALS)?;
            let rows = report::montecarlo_rows(seed, trials)?;
            if args.has_flag("json") {
                println!("{}", report::montecarlo_json(seed, trials, &rows).pretty());
            } else {
                print!("{}", report::montecarlo_table(&rows).render());
                println!("{}", report::montecarlo_summary_line(&rows));
            }
            Ok(())
        }
        Some("serve") => serve(args),
        Some("shard-host") => shard_host(args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown command {other} (try `xpoint help`)"),
    }
}

/// The trained single-layer artifact network when `make artifacts` has
/// run, the self-contained template layer otherwise.
fn load_layer_or_template(
) -> xpoint_imc::Result<(xpoint_imc::nn::BinaryLayer, Option<ArtifactStore>)> {
    match ArtifactStore::open_default() {
        Ok(store) => Ok((store.single_layer()?, Some(store))),
        Err(_) => {
            eprintln!("(artifacts missing — using template weights)");
            Ok((report::table2::template_layer(), None))
        }
    }
}

/// `xpoint shard-host` — one shard's worth of fabric behind a socket.
/// The remote end (`serve --remote`) drives it over the wire protocol;
/// killing the process mid-serve is the failure mode the sharded
/// scheduler's dead-shard routing is built for.
fn shard_host(args: &Args) -> xpoint_imc::Result<()> {
    let listen = args
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("shard-host needs --listen host:port or unix:/path"))?;
    let addr = RemoteAddr::parse(listen)?;
    let mut spec = EngineSpec::from_args(args)?;
    if matches!(spec.kind, BackendKind::Sharded | BackendKind::Remote) {
        return Err(EngineError::Spec {
            field: "backend",
            detail: "shard-host serves one shard's worth of fabric — compose \
                     fleets with --shards/--remote on the serve side"
                .into(),
        }
        .into());
    }
    // the socket is this host's one client; a worker pool has nothing to do
    spec.workers = 1;
    if spec.network == NetworkSource::Auto && !artifacts_available() {
        eprintln!("(artifacts missing — using template weights)");
    }
    let max_conns = match args.get("conns") {
        None => None,
        Some(_) => Some(args.get_usize("conns", 0)?),
    };
    let factory = spec.build()?;
    let listener = Listener::bind(&addr)?;
    println!("shard-host: {}", spec.describe());
    // the resolved address (port 0 → the actual port) goes out before the
    // accept loop so a launcher can read it and point --remote at it
    println!("listening on {}", listener.local_addr_string());
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    serve_factory(factory, listener, max_conns)
}

fn serve(args: &Args) -> xpoint_imc::Result<()> {
    // one declarative spec unifies backend kind, array design, fabric
    // geometry and batching policy; flags overlay an optional --engine
    // spec.json and conflicting combinations fail with typed errors
    let spec = EngineSpec::from_args(args)?;
    // the XLA backend never falls back to template weights — it fails fast
    // in build_factories instead, so no misleading notice there
    if spec.kind != BackendKind::Xla
        && spec.network == NetworkSource::Auto
        && !artifacts_available()
    {
        eprintln!("(artifacts missing — using template weights)");
    }
    println!("backend: {}", spec.describe());
    if let NetworkSource::Multibit { bits, scheme } = spec.network {
        println!(
            "multibit:        {bits}-bit {} inputs, +{} resolution premium per image",
            scheme.name(),
            format_si(spec.multibit_premium(), "J"),
        );
    }

    // resolve the live-swap target up front: a bad --swap-to must fail
    // before any traffic is served
    let swap_target = spec.resolve_swap_layers()?;

    // the resolved offered-load trace, when serving is trace-driven
    let trace = match args.get("trace") {
        Some(arg) => {
            anyhow::ensure!(
                args.get("images").is_none(),
                "--images conflicts with --trace (the trace decides the offered load)"
            );
            let seed = args.get_usize("trace-seed", TEST_SEED as usize)? as u64;
            Some(TrafficTrace::parse_arg(arg, spec.batching.capacity, seed)?)
        }
        None => None,
    };
    if let Some(path) = args.get("trace-out") {
        let t = trace
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("--trace-out needs --trace"))?;
        std::fs::write(path, t.to_json_string())?;
        eprintln!("(trace recorded to {path})");
    }

    // multibit N-ary inputs are unary-lowered client-side to match the
    // lowered weight stack; conv outputs are feature maps, not classes,
    // so no labels ride along there
    let expansion = spec.network.input_expansion();
    let classifier = spec.network.is_classifier();

    let backends = spec.build_factories()?;
    let mut coord = Coordinator::spawn(backends, spec.coordinator_config());

    let started = std::time::Instant::now();
    let (n_images, dropped) = match &trace {
        Some(t) => serve_trace(&mut coord, t, &swap_target, expansion, classifier)?,
        None => {
            let n_images = args.get_usize("images", 1000)?;
            let mut gen = DigitGen::new(TEST_SEED);
            let mut receivers = Vec::with_capacity(n_images);
            // with a swap target, the rolling update kicks in halfway
            // through the stream — shards drain and reprogram one at a
            // time under load
            let swap_after = swap_target.as_ref().map(|_| n_images / 2);
            for i in 0..n_images {
                if Some(i) == swap_after {
                    let target = swap_target.clone().expect("target resolved");
                    eprintln!("(rolling swap to the --swap-to network at image {i})");
                    coord.swap_network(target)?;
                }
                let s = gen.next_sample();
                let pixels = if expansion > 1 {
                    expand_unary(&s.pixels, expansion)
                } else {
                    s.pixels
                };
                receivers.push(coord.submit(pixels, classifier.then_some(s.label))?);
            }
            let mut dropped = 0usize;
            for rx in receivers {
                if rx.recv().is_err() {
                    dropped += 1;
                }
            }
            (n_images, dropped)
        }
    };
    let wall = started.elapsed().as_secs_f64();
    let snap = coord.shutdown();
    anyhow::ensure!(
        dropped == 0,
        "{dropped}/{n_images} requests got no prediction — worker backend(s) failed \
         (see errors above)"
    );

    println!("images:          {}", snap.images);
    println!("batches:         {}", snap.batches);
    println!(
        "host wall:       {} ({:.0} img/s)",
        format_duration(wall),
        n_images as f64 / wall
    );
    println!("host p(mean):    {}", format_duration(snap.mean_latency));
    println!("simulated time:  {}", format_duration(snap.sim_time));
    println!("sim energy:      {}", format_si(snap.energy, "J"));
    println!("energy/image:    {}", format_si(snap.energy_per_image, "J"));
    if snap.multibit_energy > 0.0 {
        println!(
            "multibit energy: {} (N-ary resolution premium, included above)",
            format_si(snap.multibit_energy, "J")
        );
    }
    if let Some(acc) = snap.accuracy {
        println!("accuracy:        {}", format_pct(acc));
    }
    if swap_target.is_some() {
        println!(
            "live swaps:      {} ({} SET + {} RESET pulses, {} programming, {})",
            snap.swaps,
            snap.set_pulses,
            snap.reset_pulses,
            format_duration(snap.swap_time),
            format_si(snap.swap_energy, "J"),
        );
    }
    if spec.autoscale.is_some() {
        println!(
            "autoscale:       {} spawn(s) ({} pulses, {} programming, {}), \
             {} retire(s), {} wear veto(es)",
            snap.spawns,
            snap.spawn_pulses,
            format_duration(snap.spawn_time),
            format_si(snap.spawn_energy, "J"),
            snap.retires,
            snap.scale_vetoes,
        );
    }
    if let Some(c) = snap.canary {
        println!(
            "canary:          {} images sampled, {} batches compared, {} divergent ({})",
            c.sampled_images,
            c.compared_batches,
            c.divergent_images,
            format_pct(c.divergence_rate()),
        );
        if c.margin_min.is_finite() {
            println!("canary margin:   {:.4} V worst-case noise margin", c.margin_min);
        }
    }
    // per-shard breakdown (one line per engine shard, across all workers)
    if snap.shards.len() > 1 {
        for (i, t) in snap.shards.iter().enumerate() {
            println!(
                "shard {i}:         {} images, {} batches, {} ({}/image)",
                t.images,
                t.batches,
                format_si(t.energy, "J"),
                format_si(t.energy_per_image(), "J"),
            );
        }
    }
    Ok(())
}

/// Trace-driven serving: replay the [`TrafficTrace`] wave by wave, each
/// tenant drawing from its own seeded digit stream, and report
/// per-tenant image counts (and accuracy, for classifier workloads).
/// Returns (total images offered, requests that got no prediction).
fn serve_trace(
    coord: &mut Coordinator,
    trace: &TrafficTrace,
    swap_target: &Option<Vec<xpoint_imc::nn::BinaryLayer>>,
    expansion: usize,
    classifier: bool,
) -> xpoint_imc::Result<(usize, usize)> {
    trace.validate().map_err(|e| anyhow::anyhow!("trace: {e}"))?;
    let mut gens: Vec<DigitGen> = (0..trace.n_tenants())
        .map(|t| DigitGen::new(trace.tenant_seed(t)))
        .collect();
    let mut images = vec![0usize; trace.n_tenants()];
    let mut correct = vec![0usize; trace.n_tenants()];
    let mut dropped = 0usize;
    // with a swap target, the rolling update kicks in at the trace's
    // halfway wave
    let swap_wave = swap_target.as_ref().map(|_| trace.n_waves() / 2);
    for wave in 0..trace.n_waves() {
        if Some(wave) == swap_wave {
            let target = swap_target.clone().expect("target resolved");
            eprintln!("(rolling swap to the --swap-to network at wave {wave})");
            coord.swap_network(target)?;
        }
        // submit the whole wave, then drain it — waves don't overlap, so
        // the replay is deterministic
        let mut wave_rx = Vec::with_capacity(trace.offered(wave));
        for (t, gen) in gens.iter_mut().enumerate() {
            for _ in 0..trace.waves[wave][t] {
                let s = gen.next_sample();
                let pixels = if expansion > 1 {
                    expand_unary(&s.pixels, expansion)
                } else {
                    s.pixels
                };
                let rx = coord.submit(pixels, classifier.then_some(s.label))?;
                wave_rx.push((t, s.label, rx));
            }
        }
        for (t, label, rx) in wave_rx {
            match rx.recv() {
                Ok(p) => {
                    images[t] += 1;
                    if classifier && p.class == label {
                        correct[t] += 1;
                    }
                }
                Err(_) => dropped += 1,
            }
        }
    }
    println!(
        "trace:           {} ({} waves, {} tenants, {} images, seed {:#x})",
        trace.name,
        trace.n_waves(),
        trace.n_tenants(),
        trace.total_images(),
        trace.seed,
    );
    for (t, name) in trace.tenants.iter().enumerate() {
        if classifier && images[t] > 0 {
            println!(
                "tenant {name}: {} images, accuracy {}",
                images[t],
                format_pct(correct[t] as f64 / images[t] as f64),
            );
        } else {
            println!("tenant {name}: {} images", images[t]);
        }
    }
    Ok((trace.total_images(), dropped))
}

//! Device parameters from the paper's supplementary material (Table IV) and
//! §II. All quantities are SI (siemens, amps, seconds, kelvin-ish °C).

/// Logic values stored in a PCM cell (paper §II: crystalline = 1,
/// amorphous = 0).
pub const PCM_LOGIC1: bool = true;
/// See [`PCM_LOGIC1`].
pub const PCM_LOGIC0: bool = false;

/// PCM + OTS + programming parameters.
///
/// Defaults reproduce the paper exactly:
/// `G_A = 660 nS`, `G_C = 160 µS`, `I_RESET = 100 µA` (15 ns),
/// `I_SET = 50 µA` (80 ns).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceParams {
    /// PCM conductance, amorphous state (logic 0) \[S\].
    pub g_a: f64,
    /// PCM conductance, crystalline state (logic 1) \[S\].
    pub g_c: f64,
    /// SET programming current threshold \[A\].
    pub i_set: f64,
    /// RESET programming current threshold \[A\].
    pub i_reset: f64,
    /// SET pulse duration \[s\].
    pub t_set: f64,
    /// RESET pulse duration \[s\].
    pub t_reset: f64,
    /// Read pulse amplitude \[A\] — small enough to not disturb state.
    pub i_read: f64,
    /// Read pulse duration \[s\].
    pub t_read: f64,

    // --- thermal behavioural model (device-level dynamics only; the
    // array-level TMVM decision uses the published I_SET/I_RESET threshold
    // comparison, not the thermal model) ---
    /// Ambient temperature \[°C\].
    pub t_ambient: f64,
    /// Crystallization temperature T_cryst \[°C\] (~400 °C, §II).
    pub t_cryst: f64,
    /// Melting temperature T_melt \[°C\] (~600 °C, §II).
    pub t_melt: f64,
    /// Effective thermal resistance \[°C/W\] coupling Joule power to cell
    /// temperature. Calibrated so a sustained I_SET through a crystalline
    /// cell sits midway between T_cryst and T_melt.
    pub r_thermal: f64,
    /// Crystallization time constant \[s\] (fraction of t_set so a full SET
    /// pulse completes the transition).
    pub tau_cryst: f64,
    /// Amorphization (melt-quench) time constant \[s\].
    pub tau_melt: f64,
    /// Electronic threshold-switching voltage of amorphous GST \[V\]: above
    /// it the amorphous region snaps to a conductive ON state (this is what
    /// makes SET possible at all).
    pub v_switch: f64,

    // --- OTS selector (Table IV voltage-controlled switches) ---
    /// OTS conductance when OFF \[S\] (S1 below threshold: 100 nS).
    pub ots_g_off: f64,
    /// OTS conductance when ON \[S\] (S1 above threshold: 10 S).
    pub ots_g_on: f64,
    /// OTS threshold voltage \[V\] (S1: 0.3 V).
    pub ots_v_th: f64,
}

impl Default for DeviceParams {
    fn default() -> Self {
        let g_c = 160e-6;
        let i_set = 50e-6;
        let t_set = 80e-9;
        let t_reset = 15e-9;
        let t_ambient = 25.0;
        let t_cryst = 400.0;
        let t_melt = 600.0;
        // Midpoint calibration: T(I_SET, G_C) = (T_cryst + T_melt)/2.
        let target = (t_cryst + t_melt) / 2.0 - t_ambient;
        let r_thermal = target * g_c / (i_set * i_set);
        Self {
            g_a: 660e-9,
            g_c,
            i_set,
            i_reset: 100e-6,
            t_set,
            t_reset,
            i_read: 2e-6,
            t_read: 10e-9,
            t_ambient,
            t_cryst,
            t_melt,
            r_thermal,
            tau_cryst: t_set / 3.0,
            tau_melt: t_reset / 3.0,
            v_switch: 1.0,
            ots_g_off: 100e-9,
            ots_g_on: 10.0,
            ots_v_th: 0.3,
        }
    }
}

impl DeviceParams {
    /// Sanity-check invariants the rest of the stack relies on.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.g_a > 0.0 && self.g_c > self.g_a, "G_C > G_A > 0");
        anyhow::ensure!(
            self.i_reset > self.i_set && self.i_set > 0.0,
            "I_RESET > I_SET > 0"
        );
        anyhow::ensure!(self.t_set > self.t_reset, "SET is the slow pulse");
        anyhow::ensure!(self.t_melt > self.t_cryst, "T_melt > T_cryst");
        anyhow::ensure!(
            self.ots_g_on / self.ots_g_off >= 1e6,
            "OTS on/off ratio should be large (paper: up to 1e8)"
        );
        Ok(())
    }

    /// On/off conductance ratio of the storage element.
    pub fn pcm_ratio(&self) -> f64 {
        self.g_c / self.g_a
    }

    /// The operating voltage that realizes an integer firing threshold
    /// `theta` ("fire when ≥ θ crystalline products"): from Eq. 3,
    /// `I_T(θ·G_C) = I_SET` at `V = I_SET·(θ+1)/(θ·G_C)`. Shared by the
    /// cell-level TMVM engine and the fabric simulator so their operating
    /// points can never drift apart.
    pub fn vdd_for_threshold(&self, theta: usize) -> f64 {
        assert!(theta >= 1);
        let t = theta as f64;
        self.i_set * (t + 1.0) / (t * self.g_c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_table_iv() {
        let p = DeviceParams::default();
        assert_eq!(p.g_a, 660e-9);
        assert_eq!(p.g_c, 160e-6);
        assert_eq!(p.i_set, 50e-6);
        assert_eq!(p.i_reset, 100e-6);
        assert_eq!(p.t_set, 80e-9);
        assert_eq!(p.t_reset, 15e-9);
        p.validate().unwrap();
    }

    #[test]
    fn i_set_is_half_i_reset() {
        // supplementary: I_SET = I_RESET / 2
        let p = DeviceParams::default();
        assert!((p.i_set - p.i_reset / 2.0).abs() < 1e-12);
    }

    #[test]
    fn thermal_calibration_midpoint() {
        let p = DeviceParams::default();
        let t = p.t_ambient + p.r_thermal * p.i_set * p.i_set / p.g_c;
        assert!((t - 500.0).abs() < 1e-6, "T = {t}");
        // RESET current through a crystalline cell must exceed T_melt.
        let t_reset = p.t_ambient + p.r_thermal * p.i_reset * p.i_reset / p.g_c;
        assert!(t_reset > p.t_melt);
    }

    #[test]
    fn validate_catches_bad_params() {
        let mut p = DeviceParams::default();
        p.g_a = p.g_c * 2.0;
        assert!(p.validate().is_err());
    }
}

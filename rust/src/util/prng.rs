//! Deterministic PRNGs: SplitMix64 (seeding / cross-language streams) and
//! PCG32 (general purpose).
//!
//! `SplitMix64` is implemented bit-identically in
//! `python/compile/dataset.py`; the synthetic digit workload is generated
//! from the same stream on both sides so the rust simulator and the JAX
//! golden model see the same data without shipping a dataset file.

/// SplitMix64 — tiny, high-quality 64-bit generator (Steele et al., 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in `[0, 1)` using the top 53 bits.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (bound > 0), by rejection-free
    /// modulo reduction of the high bits (sufficient for workload gen).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift keeps the distribution near-uniform and is
        // reproducible in python via (x * bound) >> 64.
        (((self.next_u64() as u128) * (bound as u128)) >> 64) as u64
    }

    /// Bernoulli draw with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

/// PCG32 (XSH-RR 64/32) — the general-purpose generator for tests and
/// property-based generation.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed with a stream selector. Two different `(seed, stream)` pairs
    /// give independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed from a single value.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in `[lo, hi)` — panics if the range is empty.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + (((self.next_u64() as u128 * (hi - lo) as u128) >> 64) as usize)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw with probability `p`.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values from the canonical SplitMix64 (seed = 0):
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(g.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(g.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn splitmix_f64_in_unit_interval() {
        let mut g = SplitMix64::new(42);
        for _ in 0..1000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn splitmix_below_is_bounded_and_covers() {
        let mut g = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = g.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn pcg_streams_are_independent() {
        let mut a = Pcg32::new(1, 1);
        let mut b = Pcg32::new(1, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should not track each other");
    }

    #[test]
    fn pcg_range_endpoints() {
        let mut g = Pcg32::seeded(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            match g.range(5, 8) {
                5 => lo_seen = true,
                7 => hi_seen = true,
                6 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn pcg_shuffle_is_a_permutation() {
        let mut g = Pcg32::seeded(11);
        let mut xs: Vec<u32> = (0..100).collect();
        g.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn pcg_f64_mean_is_near_half() {
        let mut g = Pcg32::seeded(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| g.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }
}

//! Aligned ASCII table rendering for report/bench output.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn header<S: AsRef<str>>(mut self, cols: &[S]) -> Self {
        self.header = cols.iter().map(|c| c.as_ref().to_string()).collect();
        self
    }

    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) -> &mut Self {
        self.rows
            .push(cells.iter().map(|c| c.as_ref().to_string()).collect());
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a string with box-drawing separators.
    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(display_width(h));
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(display_width(c));
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+\n";
        if !self.header.is_empty() {
            out.push_str(&sep);
            out.push_str(&render_row(&self.header, &widths));
        }
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&render_row(row, &widths));
        }
        out.push_str(&sep);
        out
    }

    /// Render as CSV (no quoting of separators inside cells — callers keep
    /// cells simple).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        if !self.header.is_empty() {
            out.push_str(&self.header.join(","));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

fn display_width(s: &str) -> usize {
    s.chars().count()
}

fn render_row(cells: &[String], widths: &[usize]) -> String {
    let mut line = String::new();
    for (i, w) in widths.iter().enumerate() {
        let cell = cells.get(i).map(String::as_str).unwrap_or("");
        let pad = w - display_width(cell);
        line.push_str(&format!("| {}{} ", cell, " ".repeat(pad)));
    }
    line.push_str("|\n");
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["a", "long-col"]);
        t.row(&["1", "2"]);
        t.row(&["333", "4"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        // all data lines have equal length
        let lens: Vec<usize> = s
            .lines()
            .skip(1)
            .map(|l| l.chars().count())
            .collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x").header(&["c1", "c2"]);
        t.row(&["1", "2"]);
        let csv = t.to_csv();
        assert_eq!(csv, "c1,c2\n1,2\n");
    }

    #[test]
    fn unicode_cells_align() {
        let mut t = Table::new("u").header(&["val"]);
        t.row(&["21.5µJ"]);
        t.row(&["1J"]);
        let s = t.render();
        let lens: Vec<usize> = s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }
}

//! Paper Table I: metal-line configurations and derived minimum cells.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, black_box, exhibit_header};
use xpoint_imc::interconnect::config::SegmentConductances;
use xpoint_imc::interconnect::{CellGeometry, LineConfig};
use xpoint_imc::report::table1_rows;

fn main() {
    exhibit_header("Paper Table I — metal-line configurations (ASAP7)");
    print!("{}", table1_rows().render());

    // segment conductances at the Fig. 13 geometry, for reference
    println!("\nderived per-segment conductances at L=4·L_min, W=W_min:");
    for cfg in LineConfig::all() {
        let cell = CellGeometry::scaled(&cfg, 1.0, 4.0);
        let s = SegmentConductances::of(&cfg, &cell);
        println!(
            "  config {}: G_y = {:.3} S (R_step {:.3} Ω), G_x = {:.3} S, R_via {:.1} Ω",
            cfg.id,
            s.g_y(),
            s.r_wl_step(),
            s.g_x,
            s.r_via
        );
    }

    println!();
    bench("segment_conductances(config3)", || {
        let cfg = LineConfig::config3();
        let cell = CellGeometry::scaled(&cfg, 1.0, 4.0);
        black_box(SegmentConductances::of(&cfg, &cell));
    });
}

//! The concrete [`Engine`] implementations, one per backend fidelity:
//!
//! * [`SimBackend`] — one circuit-level subarray (ideal Eq. 3 or
//!   parasitic-aware TMVM).
//! * [`FabricBackend`] — a whole event-driven multi-subarray fabric.
//! * [`XlaBackend`] — the AOT-compiled XLA golden model on PJRT.
//!
//! Construction validates dimensions with [`EngineError`] (no `assert!`
//! panics on bad shapes — a misconfigured spec must fail the build, not
//! kill a worker thread). Everything here is normally reached through
//! [`EngineSpec::build`](super::spec::EngineSpec::build) rather than
//! direct constructor calls.

use super::api::{
    Capabilities, Completions, Engine, InferenceResult, SwapReport, Telemetry, Ticket,
};
use super::error::EngineError;
use super::spec::BackendKind;
use crate::analysis::ArrayDesign;
use crate::array::{Subarray, TmvmMode};
use crate::device::ReprogramPlan;
use crate::fabric::{FabricConfig, FabricExecutor, FabricRun, Fidelity};
use crate::nn::packed::{PackedBatch, PackedLayer};
use crate::nn::{argmax_counts, BinaryLayer};
use crate::runtime::{Executable, Runtime, TensorF32};

/// Fixed batch dimension of the AOT-lowered XLA inference graph.
pub const XLA_GRAPH_BATCH: usize = 64;

// ------------------------------------------------------------- simulator

/// Circuit-level engine: one subarray running the single-layer network.
pub struct SimBackend {
    layer: BinaryLayer,
    /// The resident layer packed once (rebuilt on swap) — classification
    /// on the packed path runs popcount argmax against it.
    packed: PackedLayer,
    subarray: Subarray,
    mode: TmvmMode,
    /// Per-image energy surcharge of an N-ary multibit workload (0 for
    /// binary networks) — see [`EngineSpec::multibit_premium`].
    ///
    /// [`EngineSpec::multibit_premium`]: super::spec::EngineSpec::multibit_premium
    multibit_premium: f64,
    telemetry: Telemetry,
    completions: Completions,
}

impl SimBackend {
    /// Shape validation shared with [`EngineSpec::build`]: the layer's
    /// inputs and outputs must both fit the design's columns (images are
    /// stored one per row; weights are applied as word-line pulses and
    /// outputs land in bottom-level columns).
    pub fn validate_shapes(
        layer: &BinaryLayer,
        design: &ArrayDesign,
    ) -> Result<(), EngineError> {
        if layer.n_in() > design.n_col || layer.n_out() > design.n_col {
            return Err(EngineError::LayerTooLarge {
                n_in: layer.n_in(),
                n_out: layer.n_out(),
                n_col: design.n_col,
            });
        }
        Ok(())
    }

    pub fn new(
        layer: BinaryLayer,
        design: ArrayDesign,
        mode: TmvmMode,
    ) -> Result<Self, EngineError> {
        Self::validate_shapes(&layer, &design)?;
        let mut telemetry = Telemetry::default();
        if mode == TmvmMode::Parasitic {
            // margin telemetry is what the parasitic fidelity is *for* —
            // evaluated once at construction (it is a property of the
            // design, not of the traffic)
            telemetry.margin_min = crate::analysis::noise_margin(&design).noise_margin();
        }
        Ok(Self {
            packed: PackedLayer::from(&layer),
            layer,
            subarray: Subarray::new(design),
            mode,
            multibit_premium: 0.0,
            telemetry,
            completions: Completions::default(),
        })
    }

    /// Price every served image with a multibit energy surcharge \[J\]
    /// (booked into `energy` and broken out as `multibit_energy`).
    pub fn with_multibit_premium(mut self, premium: f64) -> Self {
        self.multibit_premium = premium;
        self
    }

    pub fn layer(&self) -> &BinaryLayer {
        &self.layer
    }
}

impl Engine for SimBackend {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        let run = self.layer.run_batch(&mut self.subarray, images, self.mode);
        let classes = images.iter().map(|img| self.layer.argmax(img)).collect();
        // Table II accounting: compute (TMVM step) energy only — image
        // programming is the array's storage role, shared with memory use.
        let compute_energy: f64 = run.steps.iter().map(|s| s.energy).sum();
        let premium = self.multibit_premium * images.len() as f64;
        let res = InferenceResult {
            bits: run.outputs,
            classes,
            sim_time: run.time,
            energy: compute_energy + premium,
            steps: self.layer.n_out() as u64,
        };
        self.telemetry.record(&res);
        self.telemetry.multibit_energy += premium;
        Ok(res)
    }

    fn max_batch(&self) -> usize {
        self.subarray.n_row()
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            kind: match self.mode {
                TmvmMode::Ideal => BackendKind::Ideal,
                TmvmMode::Parasitic => BackendKind::Parasitic,
            },
            n_in: self.layer.n_in(),
            n_out: self.layer.n_out(),
            max_batch: self.subarray.n_row(),
            nodes: 1,
            tiles: 1,
            shards: 1,
            reports_energy: true,
            pipelined: false,
        }
    }

    fn telemetry(&self) -> Telemetry {
        self.telemetry.clone()
    }

    fn submit(&mut self, images: Vec<Vec<bool>>) -> crate::Result<Ticket> {
        let res = self.infer_batch(&images)?;
        Ok(self.completions.push(res))
    }

    /// The packed popcount fast path — **ideal fidelity only**. At
    /// parasitic fidelity the per-cell electrical walk is the model, so
    /// packed dispatch is refused with the typed
    /// [`EngineError::PackedFidelity`] instead of silently serving
    /// ideal-mode results (callers that hold packed batches — e.g. the
    /// canary mirror — unpack and take the scalar path).
    fn infer_packed(&mut self, batch: &PackedBatch) -> crate::Result<InferenceResult> {
        if self.mode == TmvmMode::Parasitic {
            return Err(EngineError::PackedFidelity {
                kind: self.capabilities().kind.name(),
            }
            .into());
        }
        let run = self.layer.run_batch_packed(&mut self.subarray, batch, self.mode);
        // popcount argmax over the shared buffer — no scalar images built
        let classes = (0..batch.len())
            .map(|i| self.packed.argmax_words(batch.row_words(i)))
            .collect();
        let compute_energy: f64 = run.steps.iter().map(|s| s.energy).sum();
        let premium = self.multibit_premium * batch.len() as f64;
        let res = InferenceResult {
            bits: run.outputs,
            classes,
            sim_time: run.time,
            energy: compute_energy + premium,
            steps: self.layer.n_out() as u64,
        };
        self.telemetry.record(&res);
        self.telemetry.multibit_energy += premium;
        Ok(res)
    }

    fn submit_packed(&mut self, batch: PackedBatch) -> crate::Result<Ticket> {
        let res = self.infer_packed(&batch)?;
        Ok(self.completions.push(res))
    }

    fn poll(&mut self, ticket: Ticket) -> crate::Result<Option<InferenceResult>> {
        Ok(Some(self.completions.take(ticket)?))
    }

    /// In-place swap to a same-shape single layer. The pulse accounting is
    /// the per-cell SET/RESET diff of the weight store ([`ReprogramPlan`]);
    /// validation happens before any mutation, so a failed swap leaves the
    /// old layer serving and a successful one is atomic.
    fn swap_network(&mut self, target: Vec<BinaryLayer>) -> crate::Result<SwapReport> {
        if target.len() != 1 {
            return Err(EngineError::SwapShape {
                detail: format!(
                    "the {} backend serves exactly one layer, got {}",
                    self.capabilities().kind.name(),
                    target.len()
                ),
            }
            .into());
        }
        let new = target.into_iter().next().expect("one layer");
        if new.n_out() != self.layer.n_out() || new.n_in() != self.layer.n_in() {
            return Err(EngineError::SwapShape {
                detail: format!(
                    "resident layer is {}×{} but the target is {}×{}",
                    self.layer.n_out(),
                    self.layer.n_in(),
                    new.n_out(),
                    new.n_in()
                ),
            }
            .into());
        }
        let plan = ReprogramPlan::diff(
            &self.layer.weights,
            &new.weights,
            &self.subarray.design().device,
        )?;
        self.packed = PackedLayer::from(&new);
        self.layer = new;
        self.telemetry.swaps += 1;
        self.telemetry.program_time += plan.time;
        self.telemetry.program_energy += plan.energy;
        self.telemetry.wear_pulses += plan.cells_changed();
        Ok(SwapReport::from(&plan))
    }
}

// ---------------------------------------------------------------- fabric

/// Engine running batches through a pipelined multi-subarray
/// [`FabricExecutor`].
pub struct FabricBackend {
    exec: FabricExecutor,
    max_batch: usize,
    /// Per-image multibit energy surcharge (0 for binary workloads).
    multibit_premium: f64,
    telemetry: Telemetry,
    completions: Completions,
}

impl FabricBackend {
    /// Place `layers` on the fabric described by `cfg`. `max_batch` caps
    /// the images accepted per `infer_batch` call (the pipeline itself has
    /// no hard limit; the cap bounds per-batch simulation memory).
    pub fn new(
        layers: Vec<BinaryLayer>,
        cfg: FabricConfig,
        max_batch: usize,
    ) -> Result<Self, EngineError> {
        cfg.validate()?;
        if max_batch < 1 {
            return Err(EngineError::ZeroBatch);
        }
        let exec = FabricExecutor::new(layers, cfg)
            .map_err(|e| EngineError::Placement(format!("{e:#}")))?;
        let telemetry = Telemetry {
            // +∞ at ideal fidelity; the per-tile minimum at parasitic
            margin_min: exec.margin_min(),
            ..Telemetry::default()
        };
        Ok(Self {
            exec,
            max_batch,
            multibit_premium: 0.0,
            telemetry,
            completions: Completions::default(),
        })
    }

    /// Price every served image with a multibit energy surcharge \[J\]
    /// (booked into `energy` and broken out as `multibit_energy`).
    pub fn with_multibit_premium(mut self, premium: f64) -> Self {
        self.multibit_premium = premium;
        self
    }

    pub fn executor(&self) -> &FabricExecutor {
        &self.exec
    }

    /// The run's argmax classes from fabric-accumulated counts (shared
    /// first-max-wins tie-break with [`BinaryLayer::argmax`]).
    fn classes(run: &FabricRun) -> Vec<usize> {
        run.final_counts
            .iter()
            .map(|counts| argmax_counts(counts))
            .collect()
    }
}

impl Engine for FabricBackend {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        anyhow::ensure!(
            images.len() <= self.max_batch,
            "batch of {} exceeds fabric max_batch {}",
            images.len(),
            self.max_batch
        );
        let run = self.exec.run_batch(images)?;
        let classes = Self::classes(&run);
        let premium = self.multibit_premium * images.len() as f64;
        let res = InferenceResult {
            bits: run.outputs,
            classes,
            sim_time: run.makespan,
            energy: run.energy + premium,
            steps: run.steps,
        };
        self.telemetry.record(&res);
        self.telemetry.multibit_energy += premium;
        self.telemetry.compute_energy += run.compute_energy;
        self.telemetry.link_energy += run.link_energy;
        self.telemetry.cycles += run.cycles;
        self.telemetry.link_transfers += run.traffic.transfers;
        self.telemetry.link_lines += run.traffic.lines;
        self.telemetry.utilization = run.utilization;
        Ok(res)
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn capabilities(&self) -> Capabilities {
        let layers = self.exec.layers();
        Capabilities {
            kind: BackendKind::Fabric,
            n_in: layers.first().map_or(0, |l| l.n_in()),
            n_out: layers.last().map_or(0, |l| l.n_out()),
            max_batch: self.max_batch,
            nodes: self.exec.config().n_nodes(),
            tiles: self.exec.placement().n_tiles(),
            shards: 1,
            reports_energy: true,
            pipelined: true,
        }
    }

    fn telemetry(&self) -> Telemetry {
        self.telemetry.clone()
    }

    fn submit(&mut self, images: Vec<Vec<bool>>) -> crate::Result<Ticket> {
        let res = self.infer_batch(&images)?;
        Ok(self.completions.push(res))
    }

    fn poll(&mut self, ticket: Ticket) -> crate::Result<Option<InferenceResult>> {
        Ok(Some(self.completions.take(ticket)?))
    }

    /// Packed dispatch on the fabric unpacks and takes the scalar pipeline
    /// (the executor's popcount fast path is internal) — but only at ideal
    /// fidelity. A parasitic-fidelity fabric refuses with the typed
    /// [`EngineError::PackedFidelity`] so no caller can mistake an
    /// unpack-and-delegate for the packed kernel it asked for.
    fn infer_packed(&mut self, batch: &PackedBatch) -> crate::Result<InferenceResult> {
        if self.exec.config().fidelity == Fidelity::Parasitic {
            return Err(EngineError::PackedFidelity { kind: "fabric" }.into());
        }
        self.infer_batch(&batch.to_images())
    }

    fn submit_packed(&mut self, batch: PackedBatch) -> crate::Result<Ticket> {
        if self.exec.config().fidelity == Fidelity::Parasitic {
            return Err(EngineError::PackedFidelity { kind: "fabric" }.into());
        }
        self.submit(batch.to_images())
    }

    /// In-place swap of the whole placed stack: the executor streams the
    /// diff over the spine, pulses it through each node's write driver,
    /// and swaps the resident weights atomically
    /// ([`FabricExecutor::reprogram`]).
    fn swap_network(&mut self, target: Vec<BinaryLayer>) -> crate::Result<SwapReport> {
        let run = self.exec.reprogram(target)?;
        self.telemetry.swaps += 1;
        self.telemetry.program_time += run.makespan;
        self.telemetry.program_energy += run.energy;
        self.telemetry.wear_pulses += run.plan.cells_changed();
        let mut report = SwapReport::from(&run.plan);
        // the fabric's rewrite is spine-streamed and node-parallel: report
        // the simulated makespan and the full (pulse + link) energy
        report.time = run.makespan;
        report.energy = run.energy;
        Ok(report)
    }
}

// ------------------------------------------------------------------ XLA

/// XLA golden-model engine: executes the AOT-lowered JAX graph (which
/// itself wraps the Pallas kernel) on the PJRT CPU client.
pub struct XlaBackend {
    exe: Executable,
    weights: TensorF32, // (n_in, n_out), column-major classes
    layer: BinaryLayer, // for functional argmax + shapes
    batch: usize,
    v_dd: f32,
    telemetry: Telemetry,
    completions: Completions,
}

impl XlaBackend {
    /// Load from the artifact store outputs.
    pub fn new(
        runtime: &Runtime,
        hlo_path: &std::path::Path,
        layer: BinaryLayer,
        batch: usize,
        v_dd: f64,
    ) -> crate::Result<Self> {
        let exe = runtime.load_hlo_text(hlo_path)?;
        // rust layout [out][in] -> graph layout (n_in, n_out)
        let n_in = layer.n_in();
        let n_out = layer.n_out();
        let mut w = vec![0.0f32; n_in * n_out];
        for (o, row) in layer.weights.iter().enumerate() {
            for (i, &bit) in row.iter().enumerate() {
                w[i * n_out + o] = bit as u8 as f32;
            }
        }
        Ok(Self {
            exe,
            weights: TensorF32::new(vec![n_in, n_out], w),
            layer,
            batch,
            v_dd: v_dd as f32,
            telemetry: Telemetry::default(),
            completions: Completions::default(),
        })
    }
}

impl Engine for XlaBackend {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        anyhow::ensure!(images.len() <= self.batch, "batch too large for graph");
        let n_in = self.layer.n_in();
        // zero-pad the batch to the graph's fixed shape
        let mut x = vec![0.0f32; self.batch * n_in];
        for (i, img) in images.iter().enumerate() {
            anyhow::ensure!(img.len() == n_in, "image {i} size");
            for (j, &b) in img.iter().enumerate() {
                x[i * n_in + j] = b as u8 as f32;
            }
        }
        let alpha = TensorF32::new(vec![self.batch, 1], vec![1.0; self.batch]);
        let r_th = TensorF32::new(vec![self.batch, 1], vec![0.0; self.batch]);
        let out = self.exe.run(&[
            TensorF32::new(vec![self.batch, n_in], x),
            self.weights.clone(),
            alpha,
            r_th,
            TensorF32::scalar(self.v_dd),
        ])?;
        let bits_t = &out[0];
        let n_out = self.layer.n_out();
        let bits = (0..images.len())
            .map(|i| {
                (0..n_out)
                    .map(|o| bits_t.data[i * n_out + o] >= 0.5)
                    .collect()
            })
            .collect();
        let classes = images.iter().map(|img| self.layer.argmax(img)).collect();
        let res = InferenceResult {
            bits,
            classes,
            sim_time: 0.0,
            energy: 0.0,
            steps: n_out as u64,
        };
        self.telemetry.record(&res);
        Ok(res)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            kind: BackendKind::Xla,
            n_in: self.layer.n_in(),
            n_out: self.layer.n_out(),
            max_batch: self.batch,
            nodes: 1,
            tiles: 1,
            shards: 1,
            reports_energy: false,
            pipelined: false,
        }
    }

    fn telemetry(&self) -> Telemetry {
        self.telemetry.clone()
    }

    fn submit(&mut self, images: Vec<Vec<bool>>) -> crate::Result<Ticket> {
        let res = self.infer_batch(&images)?;
        Ok(self.completions.push(res))
    }

    fn poll(&mut self, ticket: Ticket) -> crate::Result<Option<InferenceResult>> {
        Ok(Some(self.completions.take(ticket)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::LineConfig;
    use crate::util::Pcg32;

    fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
        BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            theta,
        )
    }

    #[test]
    fn sim_backend_matches_functional_layer() {
        let mut rng = Pcg32::seeded(77);
        let layer = random_layer(&mut rng, 10, 20, 4);
        let design = ArrayDesign::new(32, 32, LineConfig::config3(), 3.0, 1.0);
        let mut be = SimBackend::new(layer.clone(), design, TmvmMode::Ideal).unwrap();
        let images: Vec<Vec<bool>> = (0..8)
            .map(|_| (0..20).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let res = be.infer_batch(&images).unwrap();
        for (i, img) in images.iter().enumerate() {
            assert_eq!(res.bits[i], layer.forward(img));
            assert_eq!(res.classes[i], layer.argmax(img));
        }
        assert!(res.energy > 0.0 && res.sim_time > 0.0);
        assert_eq!(res.steps, 10);
        assert_eq!(be.max_batch(), 32);
        let caps = be.capabilities();
        assert_eq!(caps.kind, BackendKind::Ideal);
        assert_eq!((caps.n_in, caps.n_out), (20, 10));
        assert!(caps.reports_energy && !caps.pipelined);
        let tel = be.telemetry();
        assert_eq!((tel.batches, tel.images), (1, 8));
        assert!(tel.energy > 0.0);
    }

    /// Regression (was an `assert!` panic): a layer wider than the design
    /// errors out of `new` instead of killing the worker thread.
    #[test]
    fn sim_backend_rejects_oversized_layer() {
        let mut rng = Pcg32::seeded(78);
        let layer = random_layer(&mut rng, 10, 40, 4);
        let design = ArrayDesign::new(32, 32, LineConfig::config3(), 3.0, 1.0);
        let err = SimBackend::new(layer, design, TmvmMode::Ideal).unwrap_err();
        assert_eq!(
            err,
            EngineError::LayerTooLarge {
                n_in: 40,
                n_out: 10,
                n_col: 32
            }
        );
    }

    /// A fabric hosting a single tiled layer must agree with the
    /// single-subarray `SimBackend` on bits, classes — and on compute
    /// energy (the step decompositions differ, weights-applied vs
    /// weights-stored, but the summed Eq. 3 currents are identical).
    #[test]
    fn fabric_backend_matches_sim_backend() {
        let mut rng = Pcg32::seeded(61);
        let layer = random_layer(&mut rng, 10, 40, 4);
        let images: Vec<Vec<bool>> = (0..12)
            .map(|_| (0..40).map(|_| rng.bernoulli(0.4)).collect())
            .collect();

        let design = ArrayDesign::new(16, 64, LineConfig::config3(), 3.0, 1.0);
        let mut sim = SimBackend::new(layer.clone(), design, TmvmMode::Ideal).unwrap();
        let sim_res = sim.infer_batch(&images).unwrap();

        // untiled fabric (layer fits one subarray): bits and classes agree
        // exactly, and compute energy agrees to sub-percent — the crystalline
        // current terms are identical whether steps sweep neurons
        // (SimBackend, images stored / weights applied) or images (fabric,
        // weights stored / images applied); only the tiny G_A leakage term
        // differs between the two orientations.
        let mut fab1 =
            FabricBackend::new(vec![layer.clone()], FabricConfig::new(1, 1, 16, 64), 64).unwrap();
        let res1 = fab1.infer_batch(&images).unwrap();
        assert_eq!(res1.bits, sim_res.bits);
        assert_eq!(res1.classes, sim_res.classes);
        let run1 = fab1.executor().run_batch(&images).unwrap();
        let rel = (run1.compute_energy - sim_res.energy).abs() / sim_res.energy;
        assert!(
            rel < 0.01,
            "compute energy drift: fabric {} vs sim {}",
            run1.compute_energy,
            sim_res.energy
        );

        // column-tiled fabric (40 cols over 16-wide tiles → 3 tiles):
        // still bit-exact; compute energy is ≥ the flat value because each
        // tile's local current I(c) = G_C·V·c/(c+1) is concave in c —
        // partial paths book more than the merged path would
        let mut fab3 =
            FabricBackend::new(vec![layer], FabricConfig::new(2, 2, 16, 16), 64).unwrap();
        let res3 = fab3.infer_batch(&images).unwrap();
        assert_eq!(res3.bits, sim_res.bits);
        assert_eq!(res3.classes, sim_res.classes);
        let run3 = fab3.executor().run_batch(&images).unwrap();
        assert!(run3.compute_energy >= sim_res.energy * (1.0 - 1e-12));
        assert!(run3.link_energy > 0.0, "partials crossed the fabric");
        assert!(res3.sim_time > 0.0);
        assert!(res3.steps >= sim_res.steps, "tiled steps ≥ per-neuron steps");

        // telemetry mirrors the run report
        let tel = fab3.telemetry();
        assert_eq!(tel.batches, 1);
        assert!(tel.link_transfers > 0 && tel.cycles > 0);
        assert_eq!(tel.utilization.len(), 4);
        let caps = fab3.capabilities();
        assert_eq!(caps.kind, BackendKind::Fabric);
        assert_eq!(caps.nodes, 4);
        assert!(caps.pipelined);
    }

    #[test]
    fn oversized_batch_rejected() {
        let mut rng = Pcg32::seeded(62);
        let layer = random_layer(&mut rng, 4, 8, 2);
        let mut fab =
            FabricBackend::new(vec![layer], FabricConfig::new(1, 1, 8, 8), 2).unwrap();
        let images: Vec<Vec<bool>> = (0..3).map(|_| vec![true; 8]).collect();
        assert!(fab.infer_batch(&images).is_err());
    }

    /// Regression (was an `assert!` panic inside `FabricConfig::new`): a
    /// zero grid or tile dimension — e.g. a bad `--grid` — returns a typed
    /// error instead of panicking the worker thread.
    #[test]
    fn fabric_backend_rejects_degenerate_dimensions() {
        let mut rng = Pcg32::seeded(63);
        let layer = random_layer(&mut rng, 4, 8, 2);
        let err = FabricBackend::new(
            vec![layer.clone()],
            FabricConfig::new(0, 2, 8, 8),
            16,
        )
        .unwrap_err();
        assert_eq!(err, EngineError::EmptyGrid { rows: 0, cols: 2 });

        let err = FabricBackend::new(
            vec![layer.clone()],
            FabricConfig::new(2, 2, 8, 0),
            16,
        )
        .unwrap_err();
        assert_eq!(err, EngineError::EmptyTile { rows: 8, cols: 0 });

        let err =
            FabricBackend::new(vec![layer], FabricConfig::new(2, 2, 8, 8), 0).unwrap_err();
        assert_eq!(err, EngineError::ZeroBatch);
    }

    #[test]
    fn sim_backend_swaps_in_place_with_pulse_accounting() {
        let mut rng = Pcg32::seeded(65);
        let old = random_layer(&mut rng, 6, 12, 2);
        let new = random_layer(&mut rng, 6, 12, 3);
        let design = ArrayDesign::new(16, 16, LineConfig::config3(), 3.0, 1.0);
        let mut be = SimBackend::new(old.clone(), design, TmvmMode::Ideal).unwrap();
        let images: Vec<Vec<bool>> = (0..4)
            .map(|_| (0..12).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        be.infer_batch(&images).unwrap();
        let report = be.swap_network(vec![new.clone()]).unwrap();
        // hand-computed diff: the report's pulse counts are exactly the
        // cellwise flips between the two weight matrices
        let mut set = 0u64;
        let mut reset = 0u64;
        for (a, b) in old.weights.iter().zip(&new.weights) {
            for (&x, &y) in a.iter().zip(b) {
                match (x, y) {
                    (false, true) => set += 1,
                    (true, false) => reset += 1,
                    _ => {}
                }
            }
        }
        assert_eq!(report.set_pulses, set);
        assert_eq!(report.reset_pulses, reset);
        assert_eq!(report.cells_total, 72);
        assert_eq!(report.shards, 1);
        assert!(report.energy > 0.0 && report.time > 0.0);
        // post-swap inference is wholly-new
        let res = be.infer_batch(&images).unwrap();
        for (i, img) in images.iter().enumerate() {
            assert_eq!(res.bits[i], new.forward(img), "image {i}");
        }
        let tel = be.telemetry();
        assert_eq!(tel.swaps, 1);
        assert!(tel.program_energy > 0.0 && tel.program_time > 0.0);
    }

    /// Satellite contract: a swap target with mismatched dimensions is a
    /// typed error and leaves the resident network untouched.
    #[test]
    fn swap_to_mismatched_dims_is_a_typed_error() {
        let mut rng = Pcg32::seeded(66);
        let layer = random_layer(&mut rng, 6, 12, 2);
        let design = ArrayDesign::new(16, 16, LineConfig::config3(), 3.0, 1.0);
        let mut be = SimBackend::new(layer.clone(), design, TmvmMode::Ideal).unwrap();
        let err = be
            .swap_network(vec![random_layer(&mut rng, 6, 10, 2)])
            .unwrap_err();
        assert!(err.to_string().contains("swap target shape mismatch"), "{err}");
        let err = be
            .swap_network(vec![layer.clone(), layer.clone()])
            .unwrap_err();
        assert!(err.to_string().contains("exactly one layer"), "{err}");
        // still serving the old network, telemetry unchanged
        assert_eq!(be.telemetry().swaps, 0);
        let mut fab = FabricBackend::new(
            vec![random_layer(&mut rng, 4, 8, 2)],
            FabricConfig::new(1, 2, 8, 8),
            16,
        )
        .unwrap();
        let err = fab
            .swap_network(vec![random_layer(&mut rng, 5, 8, 2)])
            .unwrap_err();
        assert!(err.to_string().contains("swap target shape mismatch"), "{err}");
    }

    #[test]
    fn fabric_backend_swap_is_bit_exact_with_a_fresh_engine() {
        let mut rng = Pcg32::seeded(67);
        let old = vec![
            random_layer(&mut rng, 10, 20, 3),
            random_layer(&mut rng, 6, 10, 2),
        ];
        let new = vec![
            random_layer(&mut rng, 10, 20, 3),
            random_layer(&mut rng, 6, 10, 2),
        ];
        let images: Vec<Vec<bool>> = (0..6)
            .map(|_| (0..20).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let cfg = FabricConfig::new(2, 2, 8, 8);
        let mut fab = FabricBackend::new(old, cfg.clone(), 16).unwrap();
        fab.infer_batch(&images).unwrap();
        let report = fab.swap_network(new.clone()).unwrap();
        assert!(report.cells_changed > 0);
        assert!(report.time > 0.0 && report.energy > 0.0);
        let got = fab.infer_batch(&images).unwrap();
        let mut fresh = FabricBackend::new(new, cfg, 16).unwrap();
        let want = fresh.infer_batch(&images).unwrap();
        assert_eq!(got.bits, want.bits);
        assert_eq!(got.classes, want.classes);
        assert_eq!(fab.telemetry().swaps, 1);
    }

    /// The packed submit path must be bit-exact with the scalar one —
    /// same outputs, classes, and telemetry accounting.
    #[test]
    fn packed_inference_matches_scalar_inference() {
        let mut rng = Pcg32::seeded(68);
        let layer = random_layer(&mut rng, 10, 21, 4);
        let design = ArrayDesign::new(32, 32, LineConfig::config3(), 3.0, 1.0);
        let mut scalar = SimBackend::new(layer.clone(), design.clone(), TmvmMode::Ideal).unwrap();
        let mut packed = SimBackend::new(layer, design, TmvmMode::Ideal).unwrap();
        let images: Vec<Vec<bool>> = (0..8)
            .map(|_| (0..21).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let want = scalar.infer_batch(&images).unwrap();
        let batch = PackedBatch::from_images(&images).expect("uniform");
        let t = packed.submit_packed(batch).unwrap();
        let got = packed.poll(t).unwrap().expect("sync completion");
        assert_eq!(got.bits, want.bits);
        assert_eq!(got.classes, want.classes);
        assert_eq!(got.steps, want.steps);
        assert!((got.energy - want.energy).abs() <= 1e-9 * want.energy.abs() + 1e-24);
    }

    /// Satellite contract: packed dispatch on a parasitic-fidelity engine
    /// is the typed [`EngineError::PackedFidelity`] — never a silent
    /// fallback to the ideal kernel. The scalar path keeps serving.
    #[test]
    fn packed_dispatch_on_parasitic_engines_is_a_typed_error() {
        let mut rng = Pcg32::seeded(69);
        let layer = random_layer(&mut rng, 8, 16, 3);
        let images: Vec<Vec<bool>> = (0..4)
            .map(|_| (0..16).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let batch = PackedBatch::from_images(&images).expect("uniform");

        let design = ArrayDesign::new(32, 32, LineConfig::config3(), 3.0, 1.0);
        let mut sim = SimBackend::new(layer.clone(), design, TmvmMode::Parasitic).unwrap();
        // the vendored anyhow stub flattens errors to message chains, so
        // the pin is the typed variant's exact Display text
        let refused = |kind| EngineError::PackedFidelity { kind }.to_string();
        let err = sim.infer_packed(&batch).unwrap_err();
        assert_eq!(err.to_string(), refused("parasitic"));
        let err = sim.submit_packed(batch.clone()).unwrap_err();
        assert_eq!(err.to_string(), refused("parasitic"));
        // the refusal is a routing decision, not a failure: scalar images
        // still serve through the per-cell walk
        let res = sim.infer_batch(&images).unwrap();
        assert_eq!(res.bits.len(), 4);

        let cfg = FabricConfig::new(2, 2, 8, 8).with_fidelity(Fidelity::Parasitic);
        let mut fab = FabricBackend::new(vec![layer], cfg, 16).unwrap();
        let err = fab.infer_packed(&batch).unwrap_err();
        assert_eq!(err.to_string(), refused("fabric"));
        let err = fab.submit_packed(batch).unwrap_err();
        assert_eq!(err.to_string(), refused("fabric"));
        let res = fab.infer_batch(&images).unwrap();
        assert_eq!(res.bits.len(), 4);
    }

    #[test]
    fn submit_poll_roundtrip() {
        let mut rng = Pcg32::seeded(64);
        let layer = random_layer(&mut rng, 6, 12, 2);
        let design = ArrayDesign::new(16, 16, LineConfig::config3(), 3.0, 1.0);
        let mut be = SimBackend::new(layer.clone(), design, TmvmMode::Ideal).unwrap();
        let images: Vec<Vec<bool>> = (0..4)
            .map(|_| (0..12).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let t1 = be.submit(images.clone()).unwrap();
        let t2 = be.submit(images[..2].to_vec()).unwrap();
        // out-of-order redemption is fine
        let r2 = be.poll(t2).unwrap().expect("sync engines complete at submit");
        assert_eq!(r2.bits.len(), 2);
        let r1 = be.poll(t1).unwrap().expect("sync engines complete at submit");
        assert_eq!(r1.bits.len(), 4);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(r1.bits[i], layer.forward(img));
        }
        // each ticket redeems exactly once
        assert!(be.poll(t1).is_err());
        assert_eq!(be.telemetry().batches, 2);
    }
}

//! Multi-host serving: the wire protocol and socket plumbing that turn a
//! shard fleet into a cluster.
//!
//! Three pieces, layered bottom-up:
//!
//! * [`wire`] — a small length-prefixed, versioned frame format and the
//!   [`Msg`] vocabulary for everything that already drives a shard:
//!   inference batches, live weight swaps, telemetry snapshots and
//!   orderly shutdown. Decoding never panics on untrusted bytes — every
//!   malformed frame is a typed [`WireError`].
//! * [`host`] — `xpoint shard-host`: a [`Listener`] (TCP or Unix socket)
//!   and [`serve_factory`], which puts one shard's worth of fabric
//!   behind it, one connection at a time.
//! * [`remote`] — [`RemoteBackend`], an [`Engine`](crate::engine::Engine)
//!   whose substrate lives behind a socket. It speaks the wire protocol
//!   with connect/read timeouts, surfaces application failures as typed
//!   [`EngineError::Remote`](crate::engine::EngineError::Remote) errors,
//!   and reports `healthy() == false` once the transport itself dies so
//!   the sharded scheduler routes around the dead host.
//!
//! The scheduler, rolling reprogramming swaps and autoscaling in
//! [`coordinator`](crate::coordinator) and
//! [`ShardedEngine`](crate::engine::ShardedEngine) run unchanged against
//! a mixed local+remote fleet: a remote shard is just another
//! [`BackendFactory`](crate::engine::BackendFactory) (see
//! [`remote_factory`]), built on a worker thread like any local engine.

pub mod host;
pub mod remote;
pub mod wire;

pub use host::{serve_factory, Listener};
pub use remote::{remote_factory, RemoteAddr, RemoteBackend};
pub use wire::{
    read_frame, write_frame, Msg, WireError, MAGIC, MAX_FRAME, MIN_PROTOCOL_VERSION,
    PROTOCOL_VERSION,
};

//! Inter-subarray links (paper Fig. 6): switches connect the bit lines of
//! subarray 1 to either the bit lines (BL-to-BL) or the top word lines
//! (BL-to-WLT) of subarray 2, so a TMVM computed in subarray 1 deposits its
//! thresholded results directly into a PCM level of subarray 2.
//!
//! The line-state tables here reproduce supplementary Table VII.

use crate::array::{Level, Subarray, TmvmMode, TmvmReport};

/// The two switch configurations of Fig. 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkConfig {
    /// Fig. 6(a): BLs of subarray 1 → BLs of subarray 2; results land in
    /// the **bottom** PCM level of subarray 2.
    BlToBl,
    /// Fig. 6(b): BLs of subarray 1 → WLTs of subarray 2; results land in
    /// the **top** PCM level of subarray 2.
    BlToWlt,
}

/// Line groups of a subarray.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LineGroup {
    Wlt,
    Bl,
    Wlb,
}

/// Electrical state of a line group during a linked computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LineState {
    /// Input voltages applied.
    Driven,
    /// Carrying computation current.
    Active,
    /// High-impedance.
    Floated,
    /// Floated except the output row/column, which is grounded.
    FloatedExceptOutputGrounded,
}

impl LinkConfig {
    /// Supplementary Table VII: the state of each line group in each
    /// subarray during the linked computation.
    pub fn line_state(&self, subarray: u8, group: LineGroup) -> LineState {
        use LineGroup::*;
        use LineState::*;
        match (self, subarray, group) {
            (LinkConfig::BlToBl, 1, Wlt) => Driven,
            (LinkConfig::BlToBl, 2, Wlt) => Floated,
            (LinkConfig::BlToBl, 1, Bl) => Active,
            (LinkConfig::BlToBl, 2, Bl) => Active,
            (LinkConfig::BlToBl, 1, Wlb) => Floated,
            (LinkConfig::BlToBl, 2, Wlb) => FloatedExceptOutputGrounded,
            (LinkConfig::BlToWlt, 1, Wlt) => Driven,
            (LinkConfig::BlToWlt, 2, Wlt) => Active,
            (LinkConfig::BlToWlt, 1, Bl) => Active,
            (LinkConfig::BlToWlt, 2, Bl) => FloatedExceptOutputGrounded,
            (LinkConfig::BlToWlt, 1, Wlb) => Floated,
            (LinkConfig::BlToWlt, 2, Wlb) => Floated,
            _ => panic!("subarray must be 1 or 2"),
        }
    }

    /// PCM level of subarray 2 receiving the results.
    pub fn destination_level(&self) -> Level {
        match self {
            LinkConfig::BlToBl => Level::Bottom,
            LinkConfig::BlToWlt => Level::Top,
        }
    }
}

/// Two subarrays joined by a switch fabric.
pub struct LinkedPair {
    pub src: Subarray,
    pub dst: Subarray,
    pub link: LinkConfig,
    /// Per-switch series resistance \[Ω\] (adds a small drop to the linked
    /// path; kept for energy accounting).
    pub r_switch: f64,
}

impl LinkedPair {
    pub fn new(src: Subarray, dst: Subarray, link: LinkConfig) -> Self {
        match link {
            // BL-to-BL: src bit lines continue into dst bit lines — rows
            // align, results land in a dst *column*.
            LinkConfig::BlToBl => assert!(
                dst.n_row() >= src.n_row(),
                "BL-to-BL: dst must have at least src's rows"
            ),
            // BL-to-WLT: src bit line j drives dst word line j — the link
            // *transposes*: src row j lands in dst column j of one dst row.
            LinkConfig::BlToWlt => assert!(
                dst.n_col() >= src.n_row(),
                "BL-to-WLT: dst must have at least src's rows as columns"
            ),
        }
        Self {
            src,
            dst,
            link,
            r_switch: 50.0,
        }
    }

    /// Run a TMVM in the source subarray and deposit the thresholded
    /// results into the destination subarray (Fig. 6):
    ///
    /// * `BlToBl` — results land in bottom-level **column** `dst_idx`
    ///   (row-aligned).
    /// * `BlToWlt` — results land in top-level **row** `dst_idx` (the link
    ///   transposes: src row `j` → dst column `j`). This is what makes the
    ///   Fig. 8 multi-layer pipeline work: per-image hidden vectors arrive
    ///   as rows of subarray 2, ready for weights-applied layer-2 TMVM.
    ///
    /// Returns the TMVM report of the source computation.
    pub fn tmvm_into(
        &mut self,
        inputs: &[bool],
        dst_idx: usize,
        v_dd: f64,
        mode: TmvmMode,
    ) -> TmvmReport {
        // The physical current path crosses the switches into subarray 2;
        // electrically the destination cells act as the output cells. The
        // simulator computes the thresholded currents in the source array
        // (scratch column 0) and programs the destination level.
        let report = self.src.tmvm(inputs, 0, v_dd, mode);
        let level = self.link.destination_level();
        for (j, &bit) in report.outputs.iter().enumerate() {
            // destination writes ride the same computation pulse: book only
            // the (tiny) switch losses, not an extra write slot.
            match self.link {
                LinkConfig::BlToBl => self.dst.force_level_bit(level, j, dst_idx, bit),
                LinkConfig::BlToWlt => self.dst.force_level_bit(level, dst_idx, j, bit),
            }
        }
        let i_total: f64 = report.currents.iter().sum();
        self.dst.ledger.energy += i_total * i_total * self.r_switch * self.src.design().device.t_set;
        report
    }
}

impl Subarray {
    /// Directly set a destination cell during a linked computation (the
    /// programming energy is carried by the source pulse).
    pub(crate) fn force_level_bit(&mut self, level: Level, row: usize, col: usize, bit: bool) {
        match level {
            Level::Bottom => self.force_bottom(row, col, bit),
            Level::Top => self.force_top(row, col, bit),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ArrayDesign;
    use crate::interconnect::LineConfig;

    fn sa(n_row: usize, n_col: usize) -> Subarray {
        Subarray::new(ArrayDesign::new(n_row, n_col, LineConfig::config3(), 3.0, 1.0))
    }

    #[test]
    fn table_vii_line_states() {
        use LineGroup::*;
        use LineState::*;
        let a = LinkConfig::BlToBl;
        assert_eq!(a.line_state(1, Wlt), Driven);
        assert_eq!(a.line_state(2, Wlt), Floated);
        assert_eq!(a.line_state(2, Wlb), FloatedExceptOutputGrounded);
        let b = LinkConfig::BlToWlt;
        assert_eq!(b.line_state(2, Wlt), Active);
        assert_eq!(b.line_state(2, Bl), FloatedExceptOutputGrounded);
        assert_eq!(b.line_state(2, Wlb), Floated);
    }

    #[test]
    fn destination_levels_match_fig6() {
        assert_eq!(LinkConfig::BlToBl.destination_level(), Level::Bottom);
        assert_eq!(LinkConfig::BlToWlt.destination_level(), Level::Top);
    }

    #[test]
    fn linked_tmvm_lands_in_destination() {
        let n = 4;
        let mut src = sa(n, n);
        let eye: Vec<Vec<bool>> = (0..n).map(|r| (0..n).map(|c| r == c).collect()).collect();
        src.program_level(Level::Top, &eye);
        let v = src.vdd_for_threshold(1);
        let dst = sa(3, n);
        let mut pair = LinkedPair::new(src, dst, LinkConfig::BlToWlt);
        let mut x = vec![false; n];
        x[2] = true;
        let rep = pair.tmvm_into(&x, 1, v, TmvmMode::Ideal);
        assert!(rep.is_clean());
        // transposed landing: src row j → dst (row 1, col j)
        for j in 0..n {
            assert_eq!(pair.dst.peek(Level::Top, 1, j), j == 2);
            assert!(!pair.dst.peek(Level::Bottom, 1, j), "top-level landing");
        }
    }

    #[test]
    fn bl_to_bl_lands_in_bottom() {
        let n = 3;
        let mut src = sa(n, n);
        src.program_level(Level::Top, &vec![vec![true; n]; n]);
        let v = src.vdd_for_threshold(n);
        let dst = sa(n, 2);
        let mut pair = LinkedPair::new(src, dst, LinkConfig::BlToBl);
        pair.tmvm_into(&vec![true; n], 0, v, TmvmMode::Ideal);
        for r in 0..n {
            assert!(pair.dst.peek(Level::Bottom, r, 0));
        }
    }

    #[test]
    #[should_panic(expected = "BL-to-BL")]
    fn undersized_destination_rejected() {
        let _ = LinkedPair::new(sa(8, 4), sa(4, 4), LinkConfig::BlToBl);
    }

    #[test]
    #[should_panic(expected = "BL-to-WLT")]
    fn undersized_transposed_destination_rejected() {
        let _ = LinkedPair::new(sa(8, 4), sa(8, 4), LinkConfig::BlToWlt);
    }
}

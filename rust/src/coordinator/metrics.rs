//! Lock-cheap metrics aggregation for the coordinator.

use crate::engine::{CanaryReport, ScaleEvent, ScaleEventKind, SwapReport, Telemetry};
use crate::util::stats::Welford;
use std::sync::Mutex;

/// Shared metrics sink (one per coordinator; workers push batch results).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latency: Welford,       // per-image host latency [s]
    sim_time: f64,          // accumulated simulated array time [s]
    energy: f64,            // accumulated simulated energy [J]
    images: u64,
    batches: u64,
    steps: u64,
    correct: u64,
    labelled: u64,
    shards: Vec<Telemetry>, // final per-shard telemetry, worker by worker
    swaps: u64,             // completed live weight swaps (engine-level)
    set_pulses: u64,        // SET pulses across those swaps
    reset_pulses: u64,      // RESET pulses across those swaps
    swap_time: f64,         // simulated programming time [s]
    swap_energy: f64,       // programming energy [J]
    spawns: u64,            // shards spawned by the autoscaler
    retires: u64,           // shards retired (drained → parked)
    scale_vetoes: u64,      // spawns vetoed by the pulse-endurance budget
    spawn_pulses: u64,      // programming pulses across those spawns
    spawn_time: f64,        // simulated spawn-programming time [s]
    spawn_energy: f64,      // spawn-programming energy [J]
    canary: Option<CanaryReport>, // folded canary divergence telemetry
}

/// A point-in-time copy of the aggregated metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub images: u64,
    pub batches: u64,
    pub steps: u64,
    pub mean_latency: f64,
    pub max_latency: f64,
    pub sim_time: f64,
    pub energy: f64,
    /// Energy per image [J].
    pub energy_per_image: f64,
    /// Functional accuracy over labelled requests (if any).
    pub accuracy: Option<f64>,
    /// Per-shard [`Telemetry`], concatenated across workers (one entry
    /// per plain engine, one per shard of a sharded engine) — recorded at
    /// scheduler exit, so it is complete after `shutdown`.
    pub shards: Vec<Telemetry>,
    /// Multibit N-ary resolution surcharge folded across the per-shard
    /// telemetry \[J\]. Already included in each shard's `energy`; broken
    /// out so operators can see what the resolution upgrade costs. Like
    /// `shards`, complete only after `shutdown`. 0 on binary workloads.
    pub multibit_energy: f64,
    /// Completed live weight swaps (one per worker engine per rolling
    /// update).
    pub swaps: u64,
    /// SET pulses executed across those swaps.
    pub set_pulses: u64,
    /// RESET pulses executed across those swaps.
    pub reset_pulses: u64,
    /// Simulated time the arrays spent programming \[s\].
    pub swap_time: f64,
    /// Programming energy across those swaps \[J\].
    pub swap_energy: f64,
    /// Shards the autoscaler spawned into the serving pool.
    pub spawns: u64,
    /// Shards the autoscaler drained and parked.
    pub retires: u64,
    /// Slots vetoed because their pulse-endurance budget would be
    /// exceeded — recorded once per slot per park / resident change, not
    /// per spawn attempt (per-shard wear itself is in
    /// `shards[..].wear_pulses`).
    pub scale_vetoes: u64,
    /// Programming pulses spent spawning shards (full images into fresh
    /// cells + deltas into re-activated parked slots).
    pub spawn_pulses: u64,
    /// Simulated time spent on spawn programming \[s\].
    pub spawn_time: f64,
    /// Energy spent on spawn programming \[J\].
    pub spawn_energy: f64,
    /// Canary fidelity sampling: divergence tallies and the canary's
    /// worst noise margin, folded across worker engines (counters sum,
    /// margins min-merge). `None` when no worker carried a canary.
    pub canary: Option<CanaryReport>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed batch.
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        images: u64,
        steps: u64,
        per_image_latency: f64,
        sim_time: f64,
        energy: f64,
        correct: u64,
        labelled: u64,
    ) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        for _ in 0..images {
            m.latency.push(per_image_latency);
        }
        m.sim_time += sim_time;
        m.energy += energy;
        m.images += images;
        m.batches += 1;
        m.steps += steps;
        m.correct += correct;
        m.labelled += labelled;
    }

    /// Append a worker engine's final per-shard telemetry (called once
    /// per scheduler thread, at exit).
    pub fn record_shards(&self, telemetry: Vec<Telemetry>) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        m.shards.extend(telemetry);
    }

    /// Record one completed live weight swap (a worker engine finished
    /// its rolling update).
    pub fn record_swap(&self, report: &SwapReport) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        m.swaps += 1;
        m.set_pulses += report.set_pulses;
        m.reset_pulses += report.reset_pulses;
        m.swap_time += report.time;
        m.swap_energy += report.energy;
    }

    /// Fold a worker engine's canary divergence report (recorded once
    /// per scheduler thread at exit, alongside the final shard
    /// telemetry): counters sum, margins min-merge.
    pub fn record_canary(&self, report: CanaryReport) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        let c = m.canary.get_or_insert_with(CanaryReport::default);
        c.sampled_images += report.sampled_images;
        c.compared_batches += report.compared_batches;
        c.divergent_images += report.divergent_images;
        c.margin_min = c.margin_min.min(report.margin_min);
    }

    /// Record one elastic lifecycle event (spawn / retire / budget veto)
    /// drained from an autoscaling engine.
    pub fn record_scale(&self, event: &ScaleEvent) {
        let mut m = self.inner.lock().expect("metrics poisoned");
        match event.kind {
            ScaleEventKind::Spawn { .. } => {
                m.spawns += 1;
                m.spawn_pulses += event.pulses;
                m.spawn_time += event.time;
                m.spawn_energy += event.energy;
            }
            ScaleEventKind::Retire => m.retires += 1,
            ScaleEventKind::Veto => m.scale_vetoes += 1,
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().expect("metrics poisoned");
        MetricsSnapshot {
            images: m.images,
            batches: m.batches,
            steps: m.steps,
            mean_latency: m.latency.mean(),
            max_latency: if m.images > 0 { m.latency.max() } else { 0.0 },
            sim_time: m.sim_time,
            energy: m.energy,
            energy_per_image: if m.images > 0 {
                m.energy / m.images as f64
            } else {
                0.0
            },
            accuracy: if m.labelled > 0 {
                Some(m.correct as f64 / m.labelled as f64)
            } else {
                None
            },
            shards: m.shards.clone(),
            multibit_energy: m.shards.iter().map(|t| t.multibit_energy).sum(),
            swaps: m.swaps,
            set_pulses: m.set_pulses,
            reset_pulses: m.reset_pulses,
            swap_time: m.swap_time,
            swap_energy: m.swap_energy,
            spawns: m.spawns,
            retires: m.retires,
            scale_vetoes: m.scale_vetoes,
            spawn_pulses: m.spawn_pulses,
            spawn_time: m.spawn_time,
            spawn_energy: m.spawn_energy,
            canary: m.canary,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_batch(10, 10, 1e-3, 800e-9, 215e-12, 9, 10);
        m.record_batch(6, 10, 2e-3, 800e-9, 130e-12, 6, 6);
        let s = m.snapshot();
        assert_eq!(s.images, 16);
        assert_eq!(s.batches, 2);
        assert_eq!(s.steps, 20);
        assert!((s.energy - 345e-12).abs() < 1e-18);
        assert!((s.energy_per_image - 345e-12 / 16.0).abs() < 1e-18);
        assert!((s.accuracy.unwrap() - 15.0 / 16.0).abs() < 1e-12);
        assert!(s.mean_latency > 1e-3 && s.mean_latency < 2e-3);
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.images, 0);
        assert_eq!(s.energy_per_image, 0.0);
        assert!(s.accuracy.is_none());
        assert!(s.shards.is_empty());
        assert_eq!(s.multibit_energy, 0.0);
        assert_eq!(s.swaps, 0);
        assert_eq!(s.swap_energy, 0.0);
        assert_eq!((s.spawns, s.retires, s.scale_vetoes), (0, 0, 0));
        assert_eq!(s.spawn_pulses, 0);
    }

    #[test]
    fn scale_events_split_by_kind() {
        let m = Metrics::new();
        m.record_scale(&ScaleEvent {
            kind: ScaleEventKind::Spawn { fresh: true },
            shard: 1,
            pulses: 64,
            energy: 2e-12,
            time: 1e-6,
            serving_after: 2,
        });
        m.record_scale(&ScaleEvent {
            kind: ScaleEventKind::Spawn { fresh: false },
            shard: 2,
            pulses: 16,
            energy: 5e-13,
            time: 2e-7,
            serving_after: 3,
        });
        m.record_scale(&ScaleEvent {
            kind: ScaleEventKind::Retire,
            shard: 2,
            pulses: 0,
            energy: 0.0,
            time: 0.0,
            serving_after: 2,
        });
        m.record_scale(&ScaleEvent {
            kind: ScaleEventKind::Veto,
            shard: 0,
            pulses: 128,
            energy: 0.0,
            time: 0.0,
            serving_after: 2,
        });
        let s = m.snapshot();
        assert_eq!(s.spawns, 2);
        assert_eq!(s.retires, 1);
        assert_eq!(s.scale_vetoes, 1);
        assert_eq!(s.spawn_pulses, 80, "veto pulses are projections, not spent");
        assert!((s.spawn_energy - 2.5e-12).abs() < 1e-24);
        assert!((s.spawn_time - 1.2e-6).abs() < 1e-18);
    }

    #[test]
    fn swap_reports_accumulate() {
        let m = Metrics::new();
        m.record_swap(&SwapReport {
            set_pulses: 10,
            reset_pulses: 4,
            cells_changed: 14,
            cells_total: 100,
            time: 1e-6,
            energy: 3e-12,
            shards: 2,
        });
        m.record_swap(&SwapReport {
            set_pulses: 1,
            reset_pulses: 1,
            cells_changed: 2,
            cells_total: 100,
            time: 1e-7,
            energy: 1e-13,
            shards: 1,
        });
        let s = m.snapshot();
        assert_eq!(s.swaps, 2);
        assert_eq!(s.set_pulses, 11);
        assert_eq!(s.reset_pulses, 5);
        assert!((s.swap_time - 1.1e-6).abs() < 1e-18);
        assert!((s.swap_energy - 3.1e-12).abs() < 1e-24);
    }

    #[test]
    fn canary_reports_fold_across_workers() {
        let m = Metrics::new();
        assert!(m.snapshot().canary.is_none(), "no canary → None");
        m.record_canary(CanaryReport {
            sampled_images: 10,
            compared_batches: 3,
            divergent_images: 1,
            margin_min: 0.2,
        });
        m.record_canary(CanaryReport {
            sampled_images: 4,
            compared_batches: 2,
            divergent_images: 0,
            margin_min: 0.1,
        });
        let c = m.snapshot().canary.expect("folded");
        assert_eq!(c.sampled_images, 14);
        assert_eq!(c.compared_batches, 5);
        assert_eq!(c.divergent_images, 1);
        assert_eq!(c.margin_min, 0.1, "min-merge");
        assert!((c.divergence_rate() - 1.0 / 14.0).abs() < 1e-12);
    }

    #[test]
    fn shard_telemetry_concatenates_across_workers() {
        let m = Metrics::new();
        m.record_shards(vec![
            Telemetry {
                images: 10,
                energy: 1.0,
                multibit_energy: 0.25,
                ..Telemetry::default()
            },
            Telemetry {
                images: 6,
                energy: 0.5,
                multibit_energy: 0.5,
                ..Telemetry::default()
            },
        ]);
        m.record_shards(vec![Telemetry {
            images: 4,
            ..Telemetry::default()
        }]);
        let s = m.snapshot();
        assert_eq!(s.shards.len(), 3);
        assert_eq!(s.shards.iter().map(|t| t.images).sum::<u64>(), 20);
        assert!((s.multibit_energy - 0.75).abs() < 1e-12, "surcharge folds");
    }
}

"""L1 Pallas kernel: thresholded-crossbar TMVM.

Hardware adaptation (DESIGN.md section 3): the analog crossbar's free
current summation maps onto an MXU matmul over {0,1} operands; the Eq.-3
current divider and the I_SET/I_RESET thresholding are elementwise VPU work
fused behind the matmul. BlockSpec tiles (batch-rows x neuron-columns)
mirror the physical subarray tiling: one grid step computes one subarray's
worth of outputs.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU efficiency is *estimated* in DESIGN.md section 8
from the VMEM footprint and MXU utilization reported by
`vmem_report`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import G_A, G_C, I_RESET, I_SET


def _tmvm_kernel(x_ref, w_ref, alpha_ref, rth_ref, vdd_ref, bits_ref, i_ref):
    """One (block_b x block_p) tile of the thresholded crossbar."""
    x = x_ref[...]
    w = w_ref[...]
    # MXU work: crystalline-product counts for this tile.
    s1 = jnp.dot(x, w, preferred_element_type=jnp.float32)
    # amorphous (leakage) products: row-sum minus crystalline counts
    xsum = jnp.sum(x, axis=1, keepdims=True)
    s0 = xsum - s1
    # Eq. 3 current divider with per-row Thevenin attenuation (VPU work)
    gsum = s1 * G_C + s0 * G_A
    safe = jnp.maximum(gsum, 1e-30)
    denom = rth_ref[...] + 1.0 / safe + 1.0 / G_C
    i_t = alpha_ref[...] * vdd_ref[0, 0] / denom
    i_t = jnp.where(gsum > 0.0, i_t, 0.0)
    i_ref[...] = i_t.astype(jnp.float32)
    fired = jnp.logical_and(i_t >= I_SET, i_t < I_RESET)
    bits_ref[...] = fired.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_p"))
def tmvm_pallas(x, w, alpha, r_th, v_dd, *, block_b: int = 64, block_p: int = 128):
    """Thresholded TMVM via the Pallas kernel. Shapes as in ref.tmvm_ref.

    The batch and neuron dimensions are tiled by (block_b, block_p); the
    reduction dimension N stays resident per tile (N <= a few hundred for
    the paper's workloads, well inside VMEM).
    """
    b, n = x.shape
    n2, p = w.shape
    assert n == n2, f"shape mismatch: {x.shape} @ {w.shape}"
    assert alpha.shape == (b, 1) and r_th.shape == (b, 1)
    assert v_dd.shape == (1, 1)
    bb = min(block_b, b)
    bp = min(block_p, p)
    grid = (pl.cdiv(b, bb), pl.cdiv(p, bp))
    return pl.pallas_call(
        _tmvm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n), lambda i, j: (i, 0)),  # x tile: rows
            pl.BlockSpec((n, bp), lambda i, j: (0, j)),  # w tile: cols
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),  # alpha per row
            pl.BlockSpec((bb, 1), lambda i, j: (i, 0)),  # r_th per row
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),   # v_dd scalar
        ],
        out_specs=[
            pl.BlockSpec((bb, bp), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bp), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, p), jnp.float32),  # bits
            jax.ShapeDtypeStruct((b, p), jnp.float32),  # currents
        ],
        interpret=True,
    )(x, w, alpha, r_th, v_dd)


def vmem_report(b: int, n: int, p: int, block_b: int = 64, block_p: int = 128) -> dict:
    """Static VMEM-footprint / MXU-utilization estimate for a tile (the
    L1 performance model recorded in DESIGN.md section 8 - interpret-mode
    wallclock is NOT a TPU proxy).
    """
    bb, bp = min(block_b, b), min(block_p, p)
    f32 = 4
    tile_bytes = (bb * n + n * bp + 2 * bb + 1 + 2 * bb * bp) * f32
    # MXU does bb x n x bp MACs per tile; useful MACs are the same matmul,
    # so utilization losses come only from edge padding.
    full_tiles = (b // bb) * (p // bp)
    total_tiles = -(-b // bb) * (-(-p) // bp)
    return {
        "tile_vmem_bytes": tile_bytes,
        "tile_macs": bb * n * bp,
        "edge_utilization": full_tiles / max(total_tiles, 1),
        "fits_16MiB_vmem": tile_bytes < 16 * 1024 * 1024,
    }

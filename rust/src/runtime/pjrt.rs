//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::Context;
use std::path::Path;

/// A host-side f32 tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "shape/data");
        Self { dims, data }
    }

    /// 2-D constructor from nested rows.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, Vec::len);
        assert!(rows.iter().all(|row| row.len() == c), "ragged rows");
        Self::new(vec![r, c], rows.iter().flatten().copied().collect())
    }

    /// Scalar as a (1,1) tensor (the AOT graphs take scalars this way).
    pub fn scalar(v: f32) -> Self {
        Self::new(vec![1, 1], vec![v])
    }

    fn to_literal(&self) -> crate::Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }
}

/// PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// Construct the CPU PJRT client.
    pub fn cpu() -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it (once; execution is cheap).
    pub fn load_hlo_text(&self, path: &Path) -> crate::Result<Executable> {
        anyhow::ensure!(
            path.exists(),
            "artifact {} missing — run `make artifacts` first",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

impl Executable {
    /// Execute with f32 inputs; returns the flattened tuple outputs as
    /// host tensors (jax graphs are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[TensorF32]) -> crate::Result<Vec<TensorF32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(TensorF32::to_literal)
            .collect::<crate::Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let elems = result.to_tuple().context("untupling result")?;
        elems
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().context("result shape")?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().context("result data")?;
                Ok(TensorF32::new(dims, data))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checked() {
        let t = TensorF32::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dims, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "shape/data")]
    fn tensor_shape_mismatch_panics() {
        let _ = TensorF32::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn from_rows_flattens_row_major() {
        let t = TensorF32::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(t.dims, vec![2, 2]);
        assert_eq!(t.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    // PJRT execution is covered by rust/tests/integration_runtime.rs
    // (needs artifacts on disk).
}

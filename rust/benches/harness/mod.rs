//! Micro-benchmark harness (offline criterion replacement).
//!
//! Each `cargo bench` target regenerates one paper exhibit (printing the
//! same rows/series the paper reports) and times its hot path with
//! warmup + repeated measurement.
//!
//! When the `BENCH_JSON_DIR` environment variable is set, benches that
//! call [`emit_bench_json`] additionally write machine-readable
//! `BENCH_<name>.json` files there (one per bench, schema
//! `{"bench": .., "cases": [{"name", "throughput", ..}]}`) — the input
//! of the `bench_gate` CI perf-regression gate.

use std::time::Instant;

/// Timing result of one benchmark case.
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} {:>12} /iter (min {:>12}, {} iters)",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.min_s),
            self.iters
        );
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Time `f` with auto-scaled iteration counts (~0.5 s budget per case).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((0.5 / once) as u32).clamp(1, 10_000);
    let mut min_s = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..iters {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        min_s = min_s.min(dt);
        total += dt;
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: total / iters as f64,
        min_s,
    };
    r.report();
    r
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard header for an exhibit bench.
pub fn exhibit_header(title: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("================================================================");
}

/// One gate-readable case: `name` + `throughput` (the gated metric —
/// simulated images/s, machine-independent) + any extra metrics
/// (cycles, energy, host img/s, …) recorded for the artifact.
#[allow(dead_code)] // shared harness: not every bench emits JSON
pub fn bench_case(
    name: &str,
    throughput: f64,
    extra: &[(&str, f64)],
) -> xpoint_imc::util::json::Json {
    use xpoint_imc::util::json::Json;
    let mut obj = vec![
        ("name".to_string(), Json::Str(name.into())),
        ("throughput".to_string(), Json::Num(throughput)),
    ];
    for (k, v) in extra {
        obj.push(((*k).to_string(), Json::Num(*v)));
    }
    Json::Obj(obj)
}

/// Write `BENCH_<bench>.json` into `$BENCH_JSON_DIR` (no-op when the
/// variable is unset — interactive `cargo bench` stays file-free).
#[allow(dead_code)] // shared harness: not every bench emits JSON
pub fn emit_bench_json(bench: &str, cases: Vec<xpoint_imc::util::json::Json>) {
    use xpoint_imc::util::json::Json;
    let Some(dir) = std::env::var_os("BENCH_JSON_DIR") else {
        return;
    };
    let dir = std::path::PathBuf::from(dir);
    if let Err(e) = std::fs::create_dir_all(&dir) {
        eprintln!("bench-json: cannot create {}: {e}", dir.display());
        return;
    }
    let doc = Json::Obj(vec![
        ("bench".to_string(), Json::Str(bench.into())),
        ("cases".to_string(), Json::Arr(cases)),
    ]);
    let path = dir.join(format!("BENCH_{bench}.json"));
    let mut text = doc.pretty();
    text.push('\n');
    match std::fs::write(&path, text) {
        Ok(()) => println!("bench-json: wrote {}", path.display()),
        Err(e) => eprintln!("bench-json: cannot write {}: {e}", path.display()),
    }
}

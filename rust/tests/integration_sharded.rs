//! Integration: the sharded serving path. Pins the refactor's core
//! contracts — a `ShardedEngine` is **bit-exact** with a single engine of
//! the same inner spec (bits/classes identical per batch; energy, time
//! and steps *sum* across shards), completions drain out of order under
//! unequal shard loads, and `poll` with nothing submitted is a typed
//! error on every backend kind.

use std::time::Duration;
use xpoint_imc::coordinator::{Coordinator, CoordinatorConfig};
use xpoint_imc::engine::{ArraySpec, BackendKind, EngineSpec, NetworkSource};
use xpoint_imc::fabric::PlacementStrategy;
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::util::Pcg32;

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.45)).collect())
            .collect(),
        theta,
    )
}

fn random_images(rng: &mut Pcg32, m: usize, n_in: usize) -> Vec<Vec<bool>> {
    (0..m)
        .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
        .collect()
}

/// A 3-layer fabric spec over a 2×2 grid (deterministic weights).
fn fabric_spec(rng: &mut Pcg32) -> EngineSpec {
    let layers = vec![
        random_layer(rng, 24, 40, 6),
        random_layer(rng, 16, 24, 4),
        random_layer(rng, 10, 16, 3),
    ];
    EngineSpec::new(BackendKind::Fabric)
        .with_layers(layers)
        .with_grid(2, 2)
        .with_tile(16, 16)
        .with_fabric_max_batch(64)
        .with_batching(32, 200)
}

/// Sharded vs single: identical predictions per batch, and the summed
/// per-shard telemetry equals what one engine accumulates over the same
/// batches (energy and simulated time are additive across independent
/// arrays).
#[test]
fn sharded_engine_is_bit_exact_with_a_single_engine() {
    let mut rng = Pcg32::seeded(0x5a4d);
    let spec = fabric_spec(&mut rng);
    let mut single = spec.build_engine().expect("single engine");
    let sharded_spec = spec.clone().with_shards(4, BackendKind::Fabric);
    let mut sharded = sharded_spec.build_engine().expect("sharded engine");
    assert_eq!(sharded.capabilities().shards, 4);
    assert_eq!(sharded.capabilities().kind, BackendKind::Sharded);

    // phase 1 — blocking calls: batch-for-batch equality of predictions
    // *and* physics (each batch runs complete on one identical shard)
    let batches: Vec<Vec<Vec<bool>>> = (0..6)
        .map(|i| random_images(&mut rng, 3 + 5 * (i % 3), 40))
        .collect();
    for (b, images) in batches.iter().enumerate() {
        let want = single.infer_batch(images).expect("single batch");
        let got = sharded.infer_batch(images).expect("sharded batch");
        assert_eq!(got.bits, want.bits, "batch {b} bits");
        assert_eq!(got.classes, want.classes, "batch {b} classes");
        assert_eq!(got.energy, want.energy, "batch {b} energy");
        assert_eq!(got.sim_time, want.sim_time, "batch {b} time");
        assert_eq!(got.steps, want.steps, "batch {b} steps");
    }

    // phase 2 — concurrent submits that may spread over several shards:
    // the engine-level totals must still equal the single engine's
    let spread: Vec<Vec<Vec<bool>>> =
        (0..4).map(|_| random_images(&mut rng, 8, 40)).collect();
    let tickets: Vec<_> = spread
        .iter()
        .map(|imgs| sharded.submit(imgs.clone()).expect("submit"))
        .collect();
    for (k, t) in tickets.into_iter().enumerate() {
        let got = loop {
            match sharded.poll(t).expect("poll") {
                Some(res) => break res,
                None => std::thread::yield_now(),
            }
        };
        let want = single.infer_batch(&spread[k]).expect("single batch");
        assert_eq!(got.bits, want.bits, "spread batch {k}");
        assert_eq!(got.energy, want.energy, "spread batch {k} energy");
    }

    // telemetry: the shard sum equals the single engine's accumulation —
    // energy and simulated time are additive across independent arrays
    let one = single.telemetry();
    let agg = sharded.telemetry();
    assert_eq!(agg.batches, one.batches);
    assert_eq!(agg.images, one.images);
    assert_eq!(agg.steps, one.steps);
    assert!(
        (agg.energy - one.energy).abs() <= 1e-9 * one.energy.abs(),
        "energy sums across shards: {} vs {}",
        agg.energy,
        one.energy
    );
    assert!(
        (agg.sim_time - one.sim_time).abs() <= 1e-9 * one.sim_time.abs(),
        "sim time sums across shards: {} vs {}",
        agg.sim_time,
        one.sim_time
    );
    let per_shard = sharded.shard_telemetry();
    assert_eq!(per_shard.len(), 4);
    assert_eq!(per_shard.iter().map(|t| t.batches).sum::<u64>(), 10);
    // utilization concatenates the 2×2 grid of every shard that ran work
    assert!(!agg.utilization.is_empty());
    assert_eq!(agg.utilization.len() % 4, 0);
}

/// Unequal shard loads: a large batch pins one shard while small batches
/// flow through the others; the small tickets redeem before the large one
/// even though it was submitted first, and every result keeps its own
/// request identity.
#[test]
fn completions_drain_out_of_order_under_unequal_load() {
    let mut rng = Pcg32::seeded(0x00d3);
    let layer = random_layer(&mut rng, 12, 20, 3);
    let spec = EngineSpec::new(BackendKind::Parasitic) // heavy per-image compute
        .with_array(ArraySpec {
            rows: 64,
            cols: 32,
            span: Some(20),
            ..ArraySpec::default()
        })
        .with_batching(64, 200)
        .with_layers(vec![layer.clone()])
        .with_shards(2, BackendKind::Parasitic)
        .with_workers(1);
    let mut engine = spec.build_engine().expect("sharded engine");

    let big = random_images(&mut rng, 48, 20);
    let small: Vec<Vec<Vec<bool>>> =
        (0..3).map(|_| random_images(&mut rng, 2, 20)).collect();
    let t_big = engine.submit(big.clone()).expect("big submit");
    let t_small: Vec<_> = small
        .iter()
        .map(|imgs| engine.submit(imgs.clone()).expect("small submit"))
        .collect();

    // redeem the small tickets first (they were submitted later); the
    // big ticket may legitimately still be in flight — Ok(None), not Err
    for (k, &t) in t_small.iter().enumerate() {
        let res = loop {
            match engine.poll(t).expect("poll small") {
                Some(res) => break res,
                None => std::thread::yield_now(),
            }
        };
        for (img, bits) in small[k].iter().zip(&res.bits) {
            assert_eq!(bits, &layer.forward(img), "small batch {k} identity");
        }
    }
    let res_big = loop {
        match engine.poll(t_big).expect("poll big") {
            Some(res) => break res,
            None => std::thread::yield_now(),
        }
    };
    assert_eq!(res_big.bits.len(), 48);
    for (img, bits) in big.iter().zip(&res_big.bits) {
        assert_eq!(bits, &layer.forward(img), "big batch identity");
    }
    // least-loaded dispatch sent the small batches around the busy shard
    let per_shard = engine.shard_telemetry();
    assert_eq!(per_shard.iter().map(|t| t.batches).sum::<u64>(), 4);
    assert!(
        per_shard.iter().all(|t| t.batches > 0),
        "both shards served work: {:?}",
        per_shard.iter().map(|t| t.batches).collect::<Vec<_>>()
    );
}

/// Satellite contract: `poll` with nothing submitted returns the typed
/// `EngineError::Empty` — it neither blocks nor panics — on every
/// buildable backend kind (XLA needs artifacts; covered by construction
/// through the same `Completions` path).
#[test]
fn poll_with_nothing_submitted_is_a_typed_error_for_every_kind() {
    let mut rng = Pcg32::seeded(0xe44e);
    let specs = vec![
        EngineSpec::new(BackendKind::Ideal).with_network(NetworkSource::Template),
        EngineSpec::new(BackendKind::Parasitic).with_network(NetworkSource::Template),
        EngineSpec::new(BackendKind::Fabric).with_network(NetworkSource::Template),
        fabric_spec(&mut rng).with_shards(2, BackendKind::Fabric),
    ];
    for spec in specs {
        let mut engine = spec.build_engine().expect("build");
        let kind = engine.capabilities().kind;
        let err = engine.poll(1).expect_err("fresh poll must error");
        assert!(
            err.to_string().contains("nothing submitted"),
            "kind {kind:?}: {err}"
        );
        // after one submit/poll cycle, stale tickets are UnknownTicket
        let n_in = engine.capabilities().n_in;
        let t = engine
            .submit(random_images(&mut rng, 2, n_in))
            .expect("submit");
        loop {
            match engine.poll(t).expect("poll") {
                Some(_) => break,
                None => std::thread::yield_now(),
            }
        }
        let err = engine.poll(t).expect_err("redeemed tickets are gone");
        assert!(
            err.to_string().contains("never issued or already collected"),
            "kind {kind:?}: {err}"
        );
    }
}

/// End to end: the serve flags `--fabric --shards N` build a coordinator
/// that returns exactly the predictions of a single fabric engine, and
/// the sharded run's total simulated energy matches (energy sums across
/// shards; each request is computed exactly once).
#[test]
fn serve_with_shards_matches_single_fabric_predictions() {
    let mut rng = Pcg32::seeded(0x5eed);
    let spec = fabric_spec(&mut rng);
    let mut single = spec.build_engine().expect("single engine");
    let images = random_images(&mut rng, 48, 40);
    let want = single.infer_batch(&images).expect("single batch");

    let sharded = spec.clone().with_shards(4, BackendKind::Fabric).with_workers(1);
    let mut coord = Coordinator::spawn(
        sharded.build_factories().expect("factories"),
        CoordinatorConfig {
            batch_capacity: 12, // 48 images → 4 batches over 4 shards
            linger: Duration::from_micros(100),
            autoscale: None,
        },
    );
    let rxs: Vec<_> = images
        .iter()
        .map(|img| coord.submit(img.clone(), None).expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let pred = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
        assert_eq!(pred.bits, want.bits[i], "request {i} bits");
        assert_eq!(pred.class, want.classes[i], "request {i} class");
    }
    let snap = coord.shutdown();
    assert_eq!(snap.images, 48);
    assert_eq!(snap.shards.len(), 4, "per-shard telemetry in the snapshot");
    assert_eq!(
        snap.shards.iter().map(|t| t.images).sum::<u64>(),
        48,
        "every image served by exactly one shard"
    );
}

/// Seeded fuzz/soak for the sharded scheduler: randomized submit/poll
/// interleavings across shards ∈ {1, 2, 4}, checked against a single
/// engine of the same spec. Covers the out-of-order redemption paths the
/// tests above only spot-check: every completed batch is bit-exact with
/// the single engine, every ticket completes exactly once, and redeemed
/// tickets become typed `UnknownTicket` errors.
#[test]
fn seeded_soak_random_interleavings_are_bit_exact_with_a_single_engine() {
    for seed in [0xf0a1u64, 0xf0a2, 0xf0a3] {
        for shards in [1usize, 2, 4] {
            let mut rng = Pcg32::seeded(seed);
            let layer = random_layer(&mut rng, 10, 20, 3);
            let base = EngineSpec::new(BackendKind::Ideal)
                .with_array(ArraySpec {
                    rows: 16,
                    cols: 32,
                    span: Some(20),
                    ..ArraySpec::default()
                })
                .with_batching(16, 200)
                .with_layers(vec![layer.clone()]);
            let mut single = base.clone().build_engine().expect("single engine");
            let mut engine = base
                .with_shards(shards, BackendKind::Ideal)
                .with_workers(1)
                .build_engine()
                .expect("sharded engine");

            // Vec (not HashMap) keeps the interleaving seed-deterministic
            let mut outstanding: Vec<(u64, Vec<Vec<bool>>)> = Vec::new();
            let mut redeemed: Vec<u64> = Vec::new();
            for _ in 0..200 {
                if rng.bernoulli(0.5) {
                    let m = rng.range(1, 8);
                    let imgs = random_images(&mut rng, m, 20);
                    let t = engine.submit(imgs.clone()).expect("submit");
                    outstanding.push((t, imgs));
                } else if !outstanding.is_empty() {
                    let k = rng.range(0, outstanding.len());
                    let t = outstanding[k].0;
                    if let Some(res) = engine.poll(t).expect("poll") {
                        let (t, imgs) = outstanding.swap_remove(k);
                        let want = single.infer_batch(&imgs).expect("single batch");
                        assert_eq!(res.bits, want.bits, "seed {seed:#x} shards {shards}");
                        assert_eq!(res.classes, want.classes);
                        redeemed.push(t);
                    }
                }
            }
            // drain the tail
            while let Some((t, imgs)) = outstanding.pop() {
                let res = loop {
                    match engine.poll(t).expect("poll") {
                        Some(res) => break res,
                        None => std::thread::yield_now(),
                    }
                };
                let want = single.infer_batch(&imgs).expect("single batch");
                assert_eq!(res.bits, want.bits, "seed {seed:#x} shards {shards}");
                redeemed.push(t);
            }
            // exactly-once per ticket
            let mut unique = redeemed.clone();
            unique.sort_unstable();
            unique.dedup();
            assert_eq!(unique.len(), redeemed.len(), "a ticket completed twice");
            for &t in redeemed.iter().take(3) {
                let err = engine.poll(t).expect_err("redeemed tickets are gone");
                assert!(
                    err.to_string().contains("never issued or already collected"),
                    "{err}"
                );
            }
            // the aggregate image count matches what the single engine saw
            let agg = engine.telemetry();
            assert_eq!(agg.images, single.telemetry().images);
            assert_eq!(agg.batches, redeemed.len() as u64);
        }
    }
}

/// The locality placement changes only where tiles live: predictions are
/// bit-identical to round-robin, while the serpentine walk moves the
/// same traffic over no more interlink hops.
#[test]
fn locality_placement_is_bit_exact_and_no_worse_on_traffic() {
    let mut rng = Pcg32::seeded(0x10ca);
    let layers = vec![
        random_layer(&mut rng, 12, 24, 4),
        random_layer(&mut rng, 12, 12, 3),
        random_layer(&mut rng, 8, 12, 2),
        random_layer(&mut rng, 6, 8, 2),
        random_layer(&mut rng, 4, 6, 1),
    ];
    let images = random_images(&mut rng, 10, 24);
    let run = |placement: PlacementStrategy| {
        let spec = EngineSpec::new(BackendKind::Fabric)
            .with_layers(layers.clone())
            .with_grid(2, 2)
            .with_tile(24, 24)
            .with_placement(placement)
            .with_batching(32, 200);
        let mut engine = spec.build_engine().expect("fabric engine");
        let res = engine.infer_batch(&images).expect("batch");
        (res, engine.telemetry())
    };
    let (rr, rr_tel) = run(PlacementStrategy::RoundRobin);
    let (loc, loc_tel) = run(PlacementStrategy::Locality);
    assert_eq!(loc.bits, rr.bits, "placement never changes predictions");
    assert_eq!(loc.classes, rr.classes);
    assert!(
        loc_tel.link_transfers < rr_tel.link_transfers,
        "the 5-layer chain wraps the 2×2 grid: locality must actually win \
         ({} vs {})",
        loc_tel.link_transfers,
        rr_tel.link_transfers
    );
}

//! Property tests on the subarray TMVM engine and the multi-bit schemes.

use xpoint_imc::analysis::ArrayDesign;
use xpoint_imc::array::{
    multibit_tmvm_cost, Level, MultibitScheme, Subarray, TmvmMode,
};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

fn random_subarray(rng: &mut Pcg32) -> (Subarray, Vec<Vec<bool>>) {
    let n_row = rng.range(1, 24);
    let n_col = rng.range(1, 40);
    let config = match rng.range(0, 3) {
        0 => LineConfig::config1(),
        1 => LineConfig::config2(),
        _ => LineConfig::config3(),
    };
    let design = ArrayDesign::new(n_row, n_col, config, rng.range_f64(1.0, 6.0), 1.0);
    let mut sa = Subarray::new(design);
    let bits: Vec<Vec<bool>> = (0..n_row)
        .map(|_| (0..n_col).map(|_| rng.bernoulli(0.5)).collect())
        .collect();
    sa.program_level(Level::Top, &bits);
    (sa, bits)
}

/// Ideal-mode TMVM must implement exact integer-count thresholding
/// (the amorphous leakage never promotes a sub-threshold count for the
/// paper's G_C/G_A ratio and realistic fan-ins).
#[test]
fn ideal_tmvm_is_count_thresholding() {
    forall(Config::default().cases(60), "tmvm == counts", |rng| {
        let (mut sa, bits) = random_subarray(rng);
        let n_col = sa.n_col();
        let x: Vec<bool> = (0..n_col).map(|_| rng.bernoulli(0.5)).collect();
        let theta = rng.range(1, n_col + 2);
        let v = sa.vdd_for_threshold(theta);
        let rep = sa.tmvm(&x, 0, v, TmvmMode::Ideal);
        for (row, row_bits) in bits.iter().enumerate() {
            let count = row_bits
                .iter()
                .zip(&x)
                .filter(|(&w, &xi)| w && xi)
                .count();
            let expect = count >= theta;
            if rep.outputs[row] != expect {
                return Err(format!(
                    "row {row}: count {count}, theta {theta}, got {}",
                    rep.outputs[row]
                ));
            }
        }
        Ok(())
    });
}

/// Parasitic currents can never exceed ideal currents, and outputs can
/// only be lost, never gained.
#[test]
fn parasitics_only_weaken() {
    forall(Config::default().cases(40), "parasitic ⊆ ideal", |rng| {
        let (mut sa, _) = random_subarray(rng);
        let n_col = sa.n_col();
        let x: Vec<bool> = (0..n_col).map(|_| rng.bernoulli(0.6)).collect();
        let theta = rng.range(1, n_col + 1);
        let v = sa.vdd_for_threshold(theta) * rng.range_f64(1.0, 1.5);
        let ideal = sa.tmvm(&x, 0, v, TmvmMode::Ideal);
        let para = sa.tmvm(&x, 0, v, TmvmMode::Parasitic);
        for row in 0..sa.n_row() {
            if para.currents[row] > ideal.currents[row] * (1.0 + 1e-9) {
                return Err(format!(
                    "row {row}: parasitic current {} > ideal {}",
                    para.currents[row], ideal.currents[row]
                ));
            }
            if para.outputs[row] && !ideal.outputs[row] && ideal.is_clean() {
                return Err(format!("row {row}: parasitic gained a bit"));
            }
        }
        Ok(())
    });
}

/// The bottom level holds exactly the TMVM outputs afterwards; other
/// columns are untouched.
#[test]
fn outputs_land_only_in_target_column() {
    forall(Config::default().cases(30), "column isolation", |rng| {
        let (mut sa, _) = random_subarray(rng);
        if sa.n_col() < 2 {
            return Ok(());
        }
        let n_col = sa.n_col();
        let out_col = rng.range(0, n_col);
        let other = (out_col + 1) % n_col;
        // pre-mark the other column
        for r in 0..sa.n_row() {
            sa.write(Level::Bottom, r, other, true);
        }
        let x: Vec<bool> = (0..n_col).map(|_| rng.bernoulli(0.5)).collect();
        let v = sa.vdd_for_threshold(2);
        let rep = sa.tmvm(&x, out_col, v, TmvmMode::Ideal);
        for r in 0..sa.n_row() {
            if sa.peek(Level::Bottom, r, out_col) != rep.outputs[r] {
                return Err(format!("row {r}: stored bit disagrees with report"));
            }
            if !sa.peek(Level::Bottom, r, other) {
                return Err(format!("row {r}: neighbouring column clobbered"));
            }
        }
        Ok(())
    });
}

/// Energy/time ledgers are non-negative, additive, and scale with work.
#[test]
fn ledger_accounting_is_sane() {
    forall(Config::default().cases(30), "ledger", |rng| {
        let (mut sa, _) = random_subarray(rng);
        let n_col = sa.n_col();
        let e0 = sa.ledger.energy;
        let t0 = sa.ledger.time;
        let x: Vec<bool> = (0..n_col).map(|_| rng.bernoulli(0.5)).collect();
        let v = sa.vdd_for_threshold(1);
        let rep = sa.tmvm(&x, 0, v, TmvmMode::Ideal);
        if rep.energy < 0.0 {
            return Err("negative step energy".into());
        }
        if sa.ledger.energy < e0 || sa.ledger.time < t0 {
            return Err("ledger went backwards".into());
        }
        if sa.ledger.time - t0 < sa.design().device.t_set * 0.99 {
            return Err("step must take at least t_SET".into());
        }
        Ok(())
    });
}

/// Multi-bit invariants across all bit widths.
#[test]
fn multibit_invariants() {
    forall(Config::default().cases(30), "multibit", |rng| {
        let design = ArrayDesign::new(64, 128, LineConfig::config3(), 3.0, 1.0);
        let v = rng.range_f64(0.4, 1.2);
        let n_inputs = rng.range(1, 256);
        let mut prev_ae_area = 0.0;
        let mut prev_lp_area = 0.0;
        for bits in 1..=6 {
            let ae = multibit_tmvm_cost(&design, MultibitScheme::AreaEfficient, bits, n_inputs, v);
            let lp = multibit_tmvm_cost(&design, MultibitScheme::LowPower, bits, n_inputs, v);
            if !(ae.area > prev_ae_area && lp.area > prev_lp_area) {
                return Err(format!("area must grow with bits ({bits})"));
            }
            if bits > 1 && lp.area <= ae.area {
                return Err(format!("LP must cost more area than AE at {bits} bits"));
            }
            if ae.energy <= 0.0 || lp.energy <= 0.0 {
                return Err("energies must be positive".into());
            }
            if lp.max_voltage > ae.max_voltage + 1e-12 && bits > 1 {
                return Err("AE needs the higher drive voltage".into());
            }
            if ae.cells_per_weight != bits || lp.cells_per_weight != (1 << bits) - 1 {
                return Err("cell counts wrong".into());
            }
            prev_ae_area = ae.area;
            prev_lp_area = lp.area;
        }
        Ok(())
    });
}

//! Fabric pipeline exhibit + hot-path timing: pipelined multi-layer
//! inference throughput as a function of fabric size (the §IV scalability
//! story as a throughput claim), and the host-side cost of the
//! discrete-event simulation itself.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, bench_case, black_box, emit_bench_json, exhibit_header};
use xpoint_imc::device::DeviceParams;
use xpoint_imc::fabric::{tile_step, tile_step_packed, vdd_for_theta, FabricConfig, FabricExecutor};
use xpoint_imc::nn::{BitMatrix, BitVec};
use xpoint_imc::report::fabric::{
    fabric_scaling_rows, fabric_scaling_table, fabric_workload, FABRIC_GRIDS,
};
use xpoint_imc::util::Pcg32;

fn main() {
    exhibit_header("Fabric scaling — pipelined tiled inference vs fabric size");
    let rows = fabric_scaling_rows(&FABRIC_GRIDS, 32).expect("fabric exhibit");
    print!("{}", fabric_scaling_table(&rows).render());
    let t1 = rows.first().expect("rows").throughput;
    let tn = rows.last().expect("rows").throughput;
    println!(
        "simulated speedup {:.1}× from 1 to {} subarrays\n",
        tn / t1,
        rows.last().expect("rows").nodes
    );
    // machine-readable exhibit for the CI perf gate: simulated
    // throughput is deterministic and hardware-independent
    let mut cases: Vec<_> = rows
        .iter()
        .map(|r| {
            bench_case(
                &format!("grid {}x{} batch {}", r.grid_rows, r.grid_cols, r.batch),
                r.throughput,
                &[
                    ("cycles", r.cycles as f64),
                    ("energy_per_image_j", r.energy_per_image),
                    ("mean_util", r.mean_util),
                ],
            )
        })
        .collect();

    // host-side hot path: the event-driven simulation itself
    let layers = fabric_workload();
    let mut rng = Pcg32::seeded(7);
    let images: Vec<Vec<bool>> = (0..64)
        .map(|_| (0..layers[0].n_in()).map(|_| rng.bernoulli(0.4)).collect())
        .collect();
    for (gr, gc) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let exec = FabricExecutor::new(layers.clone(), FabricConfig::new(gr, gc, 32, 32))
            .expect("placement");
        bench(&format!("run_batch 64 images, {gr}×{gc} fabric"), || {
            let run = exec.run_batch(black_box(&images)).expect("run");
            black_box(run.makespan);
        });
    }

    // live weight reprogramming hot path: per-tile diff + spine/write-driver
    // event sim + in-place swap (alternating A→B→A so every iteration
    // rewrites a real diff)
    let a = layers;
    let b = xpoint_imc::report::perturbed_workload();
    let mut exec = FabricExecutor::new(a.clone(), FabricConfig::new(2, 2, 32, 32))
        .expect("placement");
    let mut to_b = true;
    bench("reprogram 3-layer stack, 2×2 fabric", || {
        let target = if to_b { b.clone() } else { a.clone() };
        let run = exec.reprogram(target).expect("reprogram");
        black_box(run.plan.cells_changed());
        to_b = !to_b;
    });

    // packed-vs-scalar tile kernel: the executor's per-tile inner loop,
    // bool-matrix walk vs `AND + count_ones` over pre-packed lanes. The
    // gated throughput is the SIMULATED tile rate (t_SET per step —
    // deterministic, identical for both); `host_img_s` carries the
    // measured host kernel rate, where the packed speedup shows up.
    let p = DeviceParams::default();
    let tile: Vec<Vec<bool>> = (0..64)
        .map(|_| (0..256).map(|_| rng.bernoulli(0.5)).collect())
        .collect();
    let x: Vec<bool> = (0..256).map(|_| rng.bernoulli(0.5)).collect();
    let wm = BitMatrix::from_rows(&tile);
    let xv = BitVec::from_bools(&x);
    let v_dd = vdd_for_theta(64, &p);
    let scalar = bench("tile_step scalar, 64x256", || {
        black_box(tile_step(&tile, &x, v_dd, &p).current_sum);
    });
    let packed = bench("tile_step packed, 64x256", || {
        black_box(tile_step_packed(&wm, &xv, v_dd, &p).current_sum);
    });
    println!(
        "packed tile kernel speedup: {:.1}× (host)",
        scalar.min_s / packed.min_s
    );
    let sim_rate = 64.0 / p.t_set;
    cases.push(bench_case(
        "tile_step scalar, 64x256",
        sim_rate,
        &[("host_img_s", 64.0 / scalar.min_s)],
    ));
    cases.push(bench_case(
        "tile_step packed, 64x256",
        sim_rate,
        &[("host_img_s", 64.0 / packed.min_s)],
    ));

    emit_bench_json("fabric_pipeline", cases);
}

//! [`AutoscalePolicy`] — queue-driven shard autoscaling with hysteresis.
//!
//! The paper's §"system scalability" grows the accelerator by connecting
//! more 3D XPoint arrays; this policy decides *when*: the coordinator's
//! scheduler loop feeds it the engine's [`ScaleLoad`] every pass, and it
//! answers spawn / retire / hold. Decisions are deliberately simple and
//! fully deterministic — watermark thresholds on backlog per serving
//! shard, bounded by `[min_shards, max_shards]`, with a cooldown
//! (counted in evaluations) between consecutive scale events so a bursty
//! queue doesn't flap the fleet. The *eligibility* side of scaling —
//! which slot to program, and whether its pulse-endurance budget admits
//! it — lives in the engine
//! ([`ShardedEngine`](crate::engine::ShardedEngine)): the policy says
//! "one more shard", the engine says which cells can still take the
//! pulses.

use crate::engine::{AutoscaleSpec, ScaleLoad};

/// What the policy wants done right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Load is between the watermarks (or the cooldown is still
    /// running): leave the fleet alone.
    Hold,
    /// Backlog per serving shard crossed the high watermark: spawn.
    Up,
    /// Backlog per serving shard fell below the low watermark: retire.
    Down,
}

/// The evaluated policy: spec parameters plus the cooldown state.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    min_shards: usize,
    max_shards: usize,
    high_watermark: usize,
    low_watermark: usize,
    cooldown: u64,
    /// Evaluations since the last non-`Hold` decision (starts past the
    /// cooldown so a cold engine can scale immediately).
    since_event: u64,
}

impl AutoscalePolicy {
    /// Build the runtime policy from its spec section.
    pub fn from_spec(spec: &AutoscaleSpec) -> Self {
        Self {
            min_shards: spec.min_shards.max(1),
            max_shards: spec.max_shards.max(spec.min_shards.max(1)),
            high_watermark: spec.high_watermark,
            low_watermark: spec.low_watermark,
            cooldown: spec.cooldown,
            since_event: spec.cooldown,
        }
    }

    /// Serving-shard floor.
    pub fn min_shards(&self) -> usize {
        self.min_shards
    }

    /// Serving-shard ceiling.
    pub fn max_shards(&self) -> usize {
        self.max_shards
    }

    /// The engine rejected the last decision (walk in flight, budget
    /// exhausted): give the cooldown back so the policy can retry at the
    /// next evaluation instead of idling out a window for nothing.
    pub fn rescind(&mut self) {
        self.since_event = self.cooldown;
    }

    /// One evaluation: compare the engine's load against the watermarks.
    /// Returns `Up`/`Down` at most once per cooldown window, and only
    /// when the resulting shard count stays within `[min, max]` — so a
    /// caller that applies every decision can never leave the bounds.
    pub fn decide(&mut self, load: &ScaleLoad) -> ScaleDecision {
        if self.since_event < self.cooldown {
            self.since_event += 1;
            return ScaleDecision::Hold;
        }
        if load.serving == 0 {
            // nothing serving (transient mid-walk view): never pile on
            return ScaleDecision::Hold;
        }
        let backlog = load.backlog_per_shard();
        if backlog > self.high_watermark as f64 && load.serving < self.max_shards {
            self.since_event = 0;
            return ScaleDecision::Up;
        }
        if backlog < self.low_watermark as f64 && load.serving > self.min_shards {
            self.since_event = 0;
            return ScaleDecision::Down;
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(serving: usize, backlog: usize) -> ScaleLoad {
        ScaleLoad {
            serving,
            parked: 0,
            queued_images: 0,
            in_flight_images: backlog,
        }
    }

    fn policy(min: usize, max: usize, low: usize, high: usize, cooldown: u64) -> AutoscalePolicy {
        AutoscalePolicy::from_spec(&AutoscaleSpec {
            min_shards: min,
            max_shards: max,
            high_watermark: high,
            low_watermark: low,
            cooldown,
            pulse_budget: 0,
        })
    }

    #[test]
    fn scales_up_above_high_and_down_below_low() {
        let mut p = policy(1, 4, 4, 32, 0);
        assert_eq!(p.decide(&load(1, 40)), ScaleDecision::Up);
        assert_eq!(p.decide(&load(2, 40)), ScaleDecision::Hold, "20/shard is in band");
        assert_eq!(p.decide(&load(2, 200)), ScaleDecision::Up);
        assert_eq!(p.decide(&load(3, 0)), ScaleDecision::Down);
        assert_eq!(p.decide(&load(1, 0)), ScaleDecision::Hold, "at the floor");
        assert_eq!(p.decide(&load(4, 400)), ScaleDecision::Hold, "at the ceiling");
    }

    #[test]
    fn cooldown_forces_holds_between_events() {
        let mut p = policy(1, 4, 4, 32, 3);
        assert_eq!(p.decide(&load(1, 100)), ScaleDecision::Up, "cold start may act");
        for k in 0..3 {
            assert_eq!(p.decide(&load(1, 100)), ScaleDecision::Hold, "cooldown tick {k}");
        }
        assert_eq!(p.decide(&load(1, 100)), ScaleDecision::Up);
    }

    #[test]
    fn zero_serving_is_a_hold() {
        let mut p = policy(1, 4, 4, 32, 0);
        assert_eq!(p.decide(&load(0, 500)), ScaleDecision::Hold);
    }

    #[test]
    fn rescind_returns_the_cooldown() {
        let mut p = policy(1, 4, 4, 32, 3);
        assert_eq!(p.decide(&load(1, 100)), ScaleDecision::Up);
        // the engine rejected it (e.g. ScaleBusy): no cooldown burned
        p.rescind();
        assert_eq!(p.decide(&load(1, 100)), ScaleDecision::Up, "retry immediately");
        // accepted this time: the cooldown applies as usual
        assert_eq!(p.decide(&load(1, 100)), ScaleDecision::Hold);
    }

    #[test]
    fn bounds_accessors_clamp_degenerate_specs() {
        let p = policy(3, 1, 4, 32, 0); // max < min (validate() rejects, but stay safe)
        assert_eq!(p.min_shards(), 3);
        assert_eq!(p.max_shards(), 3);
    }
}

//! Pluggable inference backends for the coordinator: the circuit-level
//! subarray simulator (request path) and the AOT-compiled XLA golden model
//! (functional verification / fast path).

use crate::analysis::ArrayDesign;
use crate::array::{Subarray, TmvmMode};
use crate::nn::BinaryLayer;
use crate::runtime::{Executable, Runtime, TensorF32};

/// Output of a batched inference.
#[derive(Clone, Debug)]
pub struct InferenceResult {
    /// Hardware thresholded bits, `[image][neuron]`.
    pub bits: Vec<Vec<bool>>,
    /// Functional class prediction per image (count-space argmax, realized
    /// on hardware by a θ-sweep of `V_DD`).
    pub classes: Vec<usize>,
    /// Simulated array busy time for the batch \[s\] (0 for XLA).
    pub sim_time: f64,
    /// Simulated energy for the batch \[J\] (0 for XLA).
    pub energy: f64,
    /// Computational steps consumed.
    pub steps: u64,
}

/// A batched binary-NN inference backend.
///
/// Not `Send`: PJRT handles are thread-affine, so the coordinator
/// constructs each backend *inside* its worker thread via a
/// [`BackendFactory`].
pub trait Backend {
    /// Infer a batch of images (each `n_in` bits).
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult>;
    /// Largest batch the backend can take at once.
    fn max_batch(&self) -> usize;
}

/// Constructs a backend on the worker thread that will own it.
pub type BackendFactory =
    Box<dyn FnOnce() -> crate::Result<Box<dyn Backend>> + Send + 'static>;

// ------------------------------------------------------------- simulator

/// Circuit-level backend: one subarray running the single-layer network.
pub struct SimBackend {
    layer: BinaryLayer,
    subarray: Subarray,
    mode: TmvmMode,
}

impl SimBackend {
    pub fn new(layer: BinaryLayer, design: ArrayDesign, mode: TmvmMode) -> Self {
        assert!(layer.n_in() <= design.n_col && layer.n_out() <= design.n_col);
        Self {
            layer,
            subarray: Subarray::new(design),
            mode,
        }
    }

    pub fn layer(&self) -> &BinaryLayer {
        &self.layer
    }
}

impl Backend for SimBackend {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        let run = self.layer.run_batch(&mut self.subarray, images, self.mode);
        let classes = images.iter().map(|img| self.layer.argmax(img)).collect();
        // Table II accounting: compute (TMVM step) energy only — image
        // programming is the array's storage role, shared with memory use.
        let compute_energy: f64 = run.steps.iter().map(|s| s.energy).sum();
        Ok(InferenceResult {
            bits: run.outputs,
            classes,
            sim_time: run.time,
            energy: compute_energy,
            steps: self.layer.n_out() as u64,
        })
    }

    fn max_batch(&self) -> usize {
        self.subarray.n_row()
    }
}

// ------------------------------------------------------------------ XLA

/// XLA golden-model backend: executes the AOT-lowered JAX graph (which
/// itself wraps the Pallas kernel) on the PJRT CPU client.
pub struct XlaBackend {
    exe: Executable,
    weights: TensorF32, // (n_in, n_out), column-major classes
    layer: BinaryLayer, // for functional argmax + shapes
    batch: usize,
    v_dd: f32,
}

impl XlaBackend {
    /// Load from the artifact store outputs.
    pub fn new(
        runtime: &Runtime,
        hlo_path: &std::path::Path,
        layer: BinaryLayer,
        batch: usize,
        v_dd: f64,
    ) -> crate::Result<Self> {
        let exe = runtime.load_hlo_text(hlo_path)?;
        // rust layout [out][in] -> graph layout (n_in, n_out)
        let n_in = layer.n_in();
        let n_out = layer.n_out();
        let mut w = vec![0.0f32; n_in * n_out];
        for (o, row) in layer.weights.iter().enumerate() {
            for (i, &bit) in row.iter().enumerate() {
                w[i * n_out + o] = bit as u8 as f32;
            }
        }
        Ok(Self {
            exe,
            weights: TensorF32::new(vec![n_in, n_out], w),
            layer,
            batch,
            v_dd: v_dd as f32,
        })
    }
}

impl Backend for XlaBackend {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        anyhow::ensure!(images.len() <= self.batch, "batch too large for graph");
        let n_in = self.layer.n_in();
        // zero-pad the batch to the graph's fixed shape
        let mut x = vec![0.0f32; self.batch * n_in];
        for (i, img) in images.iter().enumerate() {
            anyhow::ensure!(img.len() == n_in, "image {i} size");
            for (j, &b) in img.iter().enumerate() {
                x[i * n_in + j] = b as u8 as f32;
            }
        }
        let alpha = TensorF32::new(vec![self.batch, 1], vec![1.0; self.batch]);
        let r_th = TensorF32::new(vec![self.batch, 1], vec![0.0; self.batch]);
        let out = self.exe.run(&[
            TensorF32::new(vec![self.batch, n_in], x),
            self.weights.clone(),
            alpha,
            r_th,
            TensorF32::scalar(self.v_dd),
        ])?;
        let bits_t = &out[0];
        let n_out = self.layer.n_out();
        let bits = (0..images.len())
            .map(|i| {
                (0..n_out)
                    .map(|o| bits_t.data[i * n_out + o] >= 0.5)
                    .collect()
            })
            .collect();
        let classes = images.iter().map(|img| self.layer.argmax(img)).collect();
        Ok(InferenceResult {
            bits,
            classes,
            sim_time: 0.0,
            energy: 0.0,
            steps: n_out as u64,
        })
    }

    fn max_batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::LineConfig;
    use crate::util::Pcg32;

    #[test]
    fn sim_backend_matches_functional_layer() {
        let mut rng = Pcg32::seeded(77);
        let layer = BinaryLayer::new(
            (0..10)
                .map(|_| (0..20).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            4,
        );
        let design = ArrayDesign::new(32, 32, LineConfig::config3(), 3.0, 1.0);
        let mut be = SimBackend::new(layer.clone(), design, TmvmMode::Ideal);
        let images: Vec<Vec<bool>> = (0..8)
            .map(|_| (0..20).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let res = be.infer_batch(&images).unwrap();
        for (i, img) in images.iter().enumerate() {
            assert_eq!(res.bits[i], layer.forward(img));
            assert_eq!(res.classes[i], layer.argmax(img));
        }
        assert!(res.energy > 0.0 && res.sim_time > 0.0);
        assert_eq!(res.steps, 10);
        assert_eq!(be.max_batch(), 32);
    }
}

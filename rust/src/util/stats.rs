//! Small statistics helpers for benchmarks and metrics.

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute summary statistics. Returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Self> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        Some(Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        })
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, `q ∈ [0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Online mean/variance accumulator (Welford) — allocation-free metrics.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Relative error |a-b| / max(|a|,|b|,eps); symmetric and scale-free.
pub fn rel_err(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() / denom
}

/// True when `a` and `b` agree to relative tolerance `tol` (or absolutely
/// within `tol` near zero).
pub fn approx_eq(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    #[test]
    fn approx_eq_near_zero() {
        assert!(approx_eq(0.0, 1e-13, 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-3));
    }
}

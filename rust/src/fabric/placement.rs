//! Fabric geometry and the placement layer: maps multi-layer
//! [`BinaryLayer`] weights, tiled by [`Tiling`](crate::scaling::Tiling),
//! onto the physical grid of subarrays.
//!
//! Tiles are assigned to nodes in (layer, tile-row, tile-col) order,
//! walking the grid in the order chosen by the configured
//! [`PlacementStrategy`]: consecutive tiles — and therefore consecutive
//! layers — land on different subarrays, which is what lets the executor
//! overlap layer *k* of image *i* with layer *k−1* of image *i+1*. When
//! there are more tiles than subarrays, several tiles share a node and
//! the node's occupancy serializes them (visible as utilization in the
//! run report).

use crate::analysis::ArrayDesign;
use crate::device::DeviceParams;
use crate::engine::EngineError;
use crate::interconnect::LineConfig;
use crate::nn::BinaryLayer;
use crate::scaling::Tiling;
use std::ops::Range;

/// Electrical fidelity of a fabric tile step.
///
/// * [`Ideal`](Fidelity::Ideal) — Eq. 3 row currents, no wire parasitics;
///   tile steps take the packed popcount fast path. The historical
///   behavior and the default.
/// * [`Parasitic`](Fidelity::Parasitic) — every tile step runs the
///   per-cell electrical walk through the Appendix-A Thevenin ladder of
///   its own subarray position (driver + interlink switch resistance,
///   engaged column span), booking attenuated row currents and reporting
///   per-tile noise-margin minima. Bit-exact with the
///   `tmvm_rows_scalar` parasitic oracle (pinned by
///   `tests/prop_parasitic.rs`); the packed fast path is refused behind
///   the typed `EngineError::PackedFidelity` guard.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Fidelity {
    /// Ideal Eq. 3 currents, packed fast path (the default).
    #[default]
    Ideal,
    /// Per-cell parasitic walk: attenuated currents + margin telemetry.
    Parasitic,
}

impl Fidelity {
    pub fn name(self) -> &'static str {
        match self {
            Self::Ideal => "ideal",
            Self::Parasitic => "parasitic",
        }
    }
}

/// How tiles walk the node grid during placement.
///
/// Both strategies hand out nodes round-robin from a fixed node *order*;
/// they differ in what that order is — and therefore in how far apart
/// (in interlink hops, dimension-ordered routing) consecutive tiles land:
///
/// * [`RoundRobin`](PlacementStrategy::RoundRobin) — flat node-id order
///   `0, 1, …, n−1`. Row-major, so the wrap from the end of one grid row
///   to the start of the next costs `grid_cols − 1` extra hops. The
///   historical default; keeps every pre-existing placement bit-stable.
/// * [`Locality`](PlacementStrategy::Locality) — serpentine
///   (boustrophedon) order: even grid rows left→right, odd rows
///   right→left. Consecutive order positions are always grid-adjacent
///   (one hop), so the partial-sum and activation traffic between
///   consecutive tiles and layers crosses the minimum number of
///   interlink hops. Placement is still deterministic and the executor
///   stays bit-exact — only timing, traffic and link energy change.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacementStrategy {
    /// Flat node-id order (the historical default).
    #[default]
    RoundRobin,
    /// Serpentine grid walk: consecutive tiles are always one hop apart.
    Locality,
}

impl PlacementStrategy {
    pub fn name(self) -> &'static str {
        match self {
            Self::RoundRobin => "roundrobin",
            Self::Locality => "locality",
        }
    }

    pub fn parse(s: &str) -> Result<Self, EngineError> {
        match s.to_ascii_lowercase().as_str() {
            "roundrobin" | "round-robin" => Ok(Self::RoundRobin),
            "locality" => Ok(Self::Locality),
            _ => Err(EngineError::UnknownPlacement(s.to_string())),
        }
    }

    /// The node order this strategy walks: a permutation of `0..n_nodes`.
    pub fn node_order(self, grid_rows: usize, grid_cols: usize) -> Vec<usize> {
        match self {
            Self::RoundRobin => (0..grid_rows * grid_cols).collect(),
            Self::Locality => {
                let mut order = Vec::with_capacity(grid_rows * grid_cols);
                for r in 0..grid_rows {
                    if r % 2 == 0 {
                        order.extend((0..grid_cols).map(|c| r * grid_cols + c));
                    } else {
                        order.extend((0..grid_cols).rev().map(|c| r * grid_cols + c));
                    }
                }
                order
            }
        }
    }
}

/// Physical fabric description: a `grid_rows × grid_cols` grid of
/// identical subarrays (each `tile_rows × tile_cols` cells), plus the
/// interlink timing/electrical parameters.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    /// Subarray grid height.
    pub grid_rows: usize,
    /// Subarray grid width.
    pub grid_cols: usize,
    /// Rows per subarray (logical matrix rows a tile can hold).
    pub tile_rows: usize,
    /// Columns per subarray.
    pub tile_cols: usize,
    /// Device parameters shared by every subarray (energy model).
    pub device: DeviceParams,
    /// Per-hop interlink latency \[s\] (switch fabric traversal between
    /// adjacent subarrays, Fig. 6).
    pub t_hop: f64,
    /// Per-switch series resistance \[Ω\] — same default as
    /// [`crate::scaling::interlink::LinkedPair`].
    pub r_switch: f64,
    /// Host injection interval between consecutive images \[s\]. Defaults
    /// to one computational step (`t_SET`), the paper's pipeline cadence.
    pub t_inject: f64,
    /// Node-order strategy used by [`place_layers`].
    pub strategy: PlacementStrategy,
    /// Electrical fidelity of every tile step (default: ideal).
    pub fidelity: Fidelity,
    /// Metal-line configuration of each subarray's parasitic ladder
    /// (Table I; default config 3, the paper's best).
    pub line_config: LineConfig,
    /// Cell length multiple of the configuration minimum (Table II
    /// best-design default: 3).
    pub l_scale: f64,
    /// Cell width multiple of the configuration minimum (default: 1).
    pub w_scale: f64,
    /// Word-line driver resistance at the grid origin \[Ω\]; each
    /// interlink hop from the origin adds one `r_switch` in series (the
    /// switch fabric sits between the drivers and a far subarray).
    pub r_driver: f64,
}

impl FabricConfig {
    /// Dimensions are *not* asserted here: a config is plain data, and a
    /// zero grid/tile dimension (e.g. a bad `--grid`) must surface as a
    /// typed error from [`validate`](FabricConfig::validate) — which every
    /// consumer ([`place_layers`], `FabricBackend::new`) calls — instead
    /// of panicking the thread that builds the backend.
    pub fn new(grid_rows: usize, grid_cols: usize, tile_rows: usize, tile_cols: usize) -> Self {
        let device = DeviceParams::default();
        Self {
            grid_rows,
            grid_cols,
            tile_rows,
            tile_cols,
            t_hop: 10e-9,
            r_switch: 50.0,
            t_inject: device.t_set,
            strategy: PlacementStrategy::RoundRobin,
            fidelity: Fidelity::Ideal,
            line_config: LineConfig::config3(),
            l_scale: 3.0,
            w_scale: 1.0,
            r_driver: 100.0,
            device,
        }
    }

    /// Same config with a different [`PlacementStrategy`].
    pub fn with_strategy(mut self, strategy: PlacementStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Same config at a different [`Fidelity`].
    pub fn with_fidelity(mut self, fidelity: Fidelity) -> Self {
        self.fidelity = fidelity;
        self
    }

    /// The [`ArrayDesign`] a placed tile's subarray realizes: the shared
    /// tile geometry and electrical template, a driver resistance grown by
    /// one interlink switch per hop from the grid origin, and the engaged
    /// column span the tile actually drives. This is the design the
    /// parasitic tile step's Thevenin ladder — and the scalar oracle it is
    /// pinned against — are computed from.
    pub fn tile_design(&self, tile: &TileSlice) -> ArrayDesign {
        let (gr, gc) = self.node_coords(tile.node);
        let hops = (gr + gc) as f64;
        let mut design = ArrayDesign::new(
            self.tile_rows,
            self.tile_cols,
            self.line_config.clone(),
            self.l_scale,
            self.w_scale,
        )
        .with_driver(self.r_driver + hops * self.r_switch)
        .with_span(tile.col_range.len().clamp(1, self.tile_cols));
        design.device = self.device;
        design
    }

    /// Reject zero grid/tile dimensions with a typed error.
    pub fn validate(&self) -> Result<(), EngineError> {
        if self.grid_rows == 0 || self.grid_cols == 0 {
            return Err(EngineError::EmptyGrid {
                rows: self.grid_rows,
                cols: self.grid_cols,
            });
        }
        if self.tile_rows == 0 || self.tile_cols == 0 {
            return Err(EngineError::EmptyTile {
                rows: self.tile_rows,
                cols: self.tile_cols,
            });
        }
        Ok(())
    }

    /// Total subarrays in the fabric.
    pub fn n_nodes(&self) -> usize {
        self.grid_rows * self.grid_cols
    }

    /// Grid coordinates of flat node id `n`.
    pub fn node_coords(&self, n: usize) -> (usize, usize) {
        debug_assert!(n < self.n_nodes());
        (n / self.grid_cols, n % self.grid_cols)
    }

    /// Flat node id of grid position `(r, c)`.
    pub fn node_id(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.grid_rows && c < self.grid_cols);
        r * self.grid_cols + c
    }
}

/// One weight tile: a slice of one layer's weight matrix resident on one
/// physical subarray.
#[derive(Clone, Debug)]
pub struct TileSlice {
    /// Which network layer this tile belongs to.
    pub layer: usize,
    /// Tile grid coordinates within the layer's [`Tiling`].
    pub tile_row: usize,
    pub tile_col: usize,
    /// Physical node (flat id) hosting the tile.
    pub node: usize,
    /// Logical output rows this tile covers.
    pub row_range: Range<usize>,
    /// Logical input columns this tile covers.
    pub col_range: Range<usize>,
    /// The weight slice, `weights[local_row][local_col]`.
    pub weights: Vec<Vec<bool>>,
}

/// A complete placement of a layer stack onto a fabric.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Per-layer logical tiling (`n_out × n_in` over `tile_rows × tile_cols`).
    pub tilings: Vec<Tiling>,
    /// All weight tiles, in (layer, tile_row, tile_col) order.
    pub tiles: Vec<TileSlice>,
    /// Tile indices grouped by layer.
    pub by_layer: Vec<Vec<usize>>,
    /// `heads[layer][tile_row]` — the node hosting tile `(tile_row, 0)`,
    /// where the row group's partial counts accumulate (linked bit lines)
    /// and are thresholded.
    pub heads: Vec<Vec<usize>>,
    /// Row-group id offset per layer (row groups are numbered globally).
    pub group_offset: Vec<usize>,
    /// Total row groups across all layers.
    pub n_groups: usize,
}

impl Placement {
    /// Global row-group id of `(layer, tile_row)`.
    pub fn group_id(&self, layer: usize, tile_row: usize) -> usize {
        self.group_offset[layer] + tile_row
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles.len()
    }

    pub fn n_layers(&self) -> usize {
        self.tilings.len()
    }
}

/// Tile a stack of layers and place the tiles on the fabric, walking the
/// node grid in the order chosen by `cfg.strategy` (flat round-robin or
/// the locality-aware serpentine — see [`PlacementStrategy`]).
///
/// Validates the layer chain (`layers[k+1].n_in == layers[k].n_out`).
/// Arbitrarily large layers are accepted — when a layer needs more tiles
/// than the fabric has subarrays, placement wraps around and the shared
/// nodes serialize (shown as utilization/occupancy in the run report).
pub fn place_layers(layers: &[BinaryLayer], cfg: &FabricConfig) -> crate::Result<Placement> {
    cfg.validate()?;
    anyhow::ensure!(!layers.is_empty(), "fabric placement needs at least one layer");
    for (k, layer) in layers.iter().enumerate() {
        if layer.n_out() == 0 || layer.n_in() == 0 {
            return Err(EngineError::EmptyLayer {
                index: k,
                n_out: layer.n_out(),
                n_in: layer.n_in(),
            }
            .into());
        }
    }
    for (k, pair) in layers.windows(2).enumerate() {
        anyhow::ensure!(
            pair[1].n_in() == pair[0].n_out(),
            "layer {} shape mismatch: layer {} outputs {} but layer {} expects {}",
            k + 1,
            k,
            pair[0].n_out(),
            k + 1,
            pair[1].n_in()
        );
    }
    let n_nodes = cfg.n_nodes();
    let order = cfg.strategy.node_order(cfg.grid_rows, cfg.grid_cols);
    let mut tilings = Vec::with_capacity(layers.len());
    let mut tiles = Vec::new();
    let mut by_layer = Vec::with_capacity(layers.len());
    let mut heads = Vec::with_capacity(layers.len());
    let mut group_offset = Vec::with_capacity(layers.len());
    let mut n_groups = 0usize;
    let mut next_node = 0usize;

    for (l, layer) in layers.iter().enumerate() {
        let tiling = Tiling::new(layer.n_out(), layer.n_in(), cfg.tile_rows, cfg.tile_cols);
        let mut layer_tiles = Vec::with_capacity(tiling.n_tiles());
        let mut layer_heads = vec![0usize; tiling.grid_rows()];
        for tr in 0..tiling.grid_rows() {
            for tc in 0..tiling.grid_cols() {
                let node = order[next_node % n_nodes];
                next_node += 1;
                let row_range = tiling.row_range(tr);
                let col_range = tiling.col_range(tc);
                let weights: Vec<Vec<bool>> = row_range
                    .clone()
                    .map(|r| layer.weights[r][col_range.clone()].to_vec())
                    .collect();
                if tc == 0 {
                    layer_heads[tr] = node;
                }
                layer_tiles.push(tiles.len());
                tiles.push(TileSlice {
                    layer: l,
                    tile_row: tr,
                    tile_col: tc,
                    node,
                    row_range,
                    col_range,
                    weights,
                });
            }
        }
        group_offset.push(n_groups);
        n_groups += tiling.grid_rows();
        by_layer.push(layer_tiles);
        heads.push(layer_heads);
        tilings.push(tiling);
    }

    Ok(Placement {
        tilings,
        tiles,
        by_layer,
        heads,
        group_offset,
        n_groups,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize) -> BinaryLayer {
        BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            2,
        )
    }

    #[test]
    fn tiles_cover_every_weight_exactly_once() {
        let mut rng = Pcg32::seeded(41);
        let layer = random_layer(&mut rng, 37, 53);
        let cfg = FabricConfig::new(3, 3, 16, 16);
        let p = place_layers(std::slice::from_ref(&layer), &cfg).unwrap();
        let mut seen = vec![vec![0u32; 53]; 37];
        for t in &p.tiles {
            for (lr, r) in t.row_range.clone().enumerate() {
                for (lc, c) in t.col_range.clone().enumerate() {
                    assert_eq!(t.weights[lr][lc], layer.weights[r][c]);
                    seen[r][c] += 1;
                }
            }
        }
        assert!(seen.iter().flatten().all(|&n| n == 1), "exact cover");
        // 37 rows / 16 = 3 row groups, 53 cols / 16 = 4 col tiles
        assert_eq!(p.tilings[0].grid_rows(), 3);
        assert_eq!(p.tilings[0].grid_cols(), 4);
        assert_eq!(p.n_tiles(), 12);
        assert_eq!(p.n_groups, 3);
    }

    #[test]
    fn round_robin_spreads_consecutive_layers() {
        let mut rng = Pcg32::seeded(42);
        let layers = vec![
            random_layer(&mut rng, 8, 16),
            random_layer(&mut rng, 8, 8),
            random_layer(&mut rng, 4, 8),
        ];
        let cfg = FabricConfig::new(2, 2, 16, 16);
        let p = place_layers(&layers, &cfg).unwrap();
        // 1 tile per layer, 4 nodes: layers land on distinct nodes
        assert_eq!(p.n_tiles(), 3);
        let nodes: Vec<usize> = p.tiles.iter().map(|t| t.node).collect();
        assert_eq!(nodes, vec![0, 1, 2]);
        // heads point at the (tr, 0) tiles
        assert_eq!(p.heads[0], vec![0]);
        assert_eq!(p.heads[2], vec![2]);
        // group ids are globally consecutive
        assert_eq!(p.group_id(0, 0), 0);
        assert_eq!(p.group_id(2, 0), 2);
    }

    #[test]
    fn more_tiles_than_nodes_wraps_around() {
        let mut rng = Pcg32::seeded(43);
        let layer = random_layer(&mut rng, 20, 20);
        let cfg = FabricConfig::new(1, 2, 8, 8); // 2 nodes, 3×3 = 9 tiles
        let p = place_layers(std::slice::from_ref(&layer), &cfg).unwrap();
        assert_eq!(p.n_tiles(), 9);
        assert!(p.tiles.iter().all(|t| t.node < 2));
        let on0 = p.tiles.iter().filter(|t| t.node == 0).count();
        assert_eq!(on0, 5, "round robin: ⌈9/2⌉ tiles on node 0");
    }

    #[test]
    fn mismatched_chain_rejected() {
        let mut rng = Pcg32::seeded(44);
        let layers = vec![random_layer(&mut rng, 6, 10), random_layer(&mut rng, 3, 7)];
        let cfg = FabricConfig::new(2, 2, 16, 16);
        let err = place_layers(&layers, &cfg).unwrap_err();
        assert!(err.to_string().contains("shape mismatch"), "{err}");
    }

    /// Regression (was an `assert!` panic in `FabricConfig::new` /
    /// `Tiling::new`): degenerate fabric or layer dimensions come back as
    /// typed errors.
    #[test]
    fn degenerate_dimensions_error_instead_of_panicking() {
        let mut rng = Pcg32::seeded(45);
        let layer = random_layer(&mut rng, 4, 8);
        let err = place_layers(std::slice::from_ref(&layer), &FabricConfig::new(0, 1, 8, 8))
            .unwrap_err();
        assert!(err.to_string().contains("grid"), "{err}");
        let err = place_layers(std::slice::from_ref(&layer), &FabricConfig::new(1, 1, 8, 0))
            .unwrap_err();
        assert!(err.to_string().contains("tile"), "{err}");
        let empty = BinaryLayer::new(vec![vec![]; 2], 1);
        let err = place_layers(std::slice::from_ref(&empty), &FabricConfig::new(1, 1, 8, 8))
            .unwrap_err();
        assert!(err.to_string().contains("empty shape"), "{err}");
    }

    #[test]
    fn node_coordinate_mapping_roundtrips() {
        let cfg = FabricConfig::new(3, 5, 8, 8);
        for n in 0..cfg.n_nodes() {
            let (r, c) = cfg.node_coords(n);
            assert_eq!(cfg.node_id(r, c), n);
        }
    }

    #[test]
    fn strategy_names_parse_and_roundtrip() {
        assert_eq!(
            PlacementStrategy::parse("roundrobin").unwrap(),
            PlacementStrategy::RoundRobin
        );
        assert_eq!(
            PlacementStrategy::parse("Locality").unwrap(),
            PlacementStrategy::Locality
        );
        assert_eq!(
            PlacementStrategy::parse("snake").unwrap_err(),
            EngineError::UnknownPlacement("snake".into())
        );
        for s in [PlacementStrategy::RoundRobin, PlacementStrategy::Locality] {
            assert_eq!(PlacementStrategy::parse(s.name()).unwrap(), s);
        }
        assert_eq!(PlacementStrategy::default(), PlacementStrategy::RoundRobin);
    }

    /// Serpentine order: a permutation of the nodes where every pair of
    /// consecutive entries is grid-adjacent (one interlink hop), which is
    /// exactly the property the round-robin flat order lacks at row wraps.
    #[test]
    fn locality_order_is_an_adjacent_permutation() {
        for (gr, gc) in [(1, 4), (2, 2), (3, 3), (2, 5)] {
            let cfg = FabricConfig::new(gr, gc, 8, 8);
            let order = PlacementStrategy::Locality.node_order(gr, gc);
            let mut seen = vec![false; gr * gc];
            for &n in &order {
                assert!(!seen[n], "node {n} repeated");
                seen[n] = true;
            }
            assert!(seen.iter().all(|&s| s), "not a permutation");
            for w in order.windows(2) {
                let (r0, c0) = cfg.node_coords(w[0]);
                let (r1, c1) = cfg.node_coords(w[1]);
                let hops = r0.abs_diff(r1) + c0.abs_diff(c1);
                assert_eq!(hops, 1, "{:?} -> {:?} is {hops} hops", w[0], w[1]);
            }
        }
    }

    /// Locality placement puts a chain of single-tile layers on an
    /// adjacent path; round-robin pays the row-wrap detour. Bit-level
    /// results are placement-independent (pinned by the executor tests) —
    /// the win is in hop distance, and therefore link traffic and time.
    #[test]
    fn locality_shortens_consecutive_layer_hops() {
        let mut rng = Pcg32::seeded(46);
        // 5 single-tile layers on a 2×2 grid: placement wraps once
        let layers: Vec<BinaryLayer> = {
            let mut v = vec![random_layer(&mut rng, 8, 8)];
            for _ in 0..4 {
                let l = random_layer(&mut rng, 8, 8);
                v.push(l);
            }
            v
        };
        let hops_for = |strategy: PlacementStrategy| -> usize {
            let cfg = FabricConfig::new(2, 2, 16, 16).with_strategy(strategy);
            let p = place_layers(&layers, &cfg).unwrap();
            p.tiles
                .windows(2)
                .map(|w| {
                    let (r0, c0) = cfg.node_coords(w[0].node);
                    let (r1, c1) = cfg.node_coords(w[1].node);
                    r0.abs_diff(r1) + c0.abs_diff(c1)
                })
                .sum()
        };
        let rr = hops_for(PlacementStrategy::RoundRobin);
        let loc = hops_for(PlacementStrategy::Locality);
        assert_eq!(loc, 4, "serpentine chain: one hop per layer transition");
        assert!(loc < rr, "locality {loc} hops vs round-robin {rr}");
    }
}

//! Table II: digit-recognition evaluation across subarray sizes.
//!
//! Each design processes the 10K-image synthetic corpus: `M = N_row` images
//! per batch, `P = 10` steps per batch ⇒ `⌊N_row/P⌋` images per step in the
//! paper's accounting. Energy per image is measured by actually running a
//! batch through the circuit-level simulator; NM comes from the
//! workload-aware corner analysis (`span = 121` engaged columns).

use crate::analysis::{noise_margin, ArrayDesign};
use crate::array::{Subarray, TmvmMode};
use crate::interconnect::LineConfig;
use crate::nn::dataset::{DigitGen, TEST_SEED};
use crate::nn::BinaryLayer;
use crate::util::si::{format_duration, format_pct, format_si};
use crate::util::Table;

/// The paper's five design points: `(n_row, n_col, l_scale)` with
/// `W = W_min = 36 nm` and `L = l_scale · L_min` (config 3, L_min = 80 nm):
/// cell sizes 36×240 … 36×640 nm as in Table II.
pub const TABLE2_DESIGNS: [(usize, usize, f64); 5] = [
    (64, 128, 3.0),
    (128, 256, 4.0),
    (256, 512, 5.0),
    (512, 1024, 6.0),
    (1024, 2048, 8.0),
];

/// Number of classes (P) and corpus size from the paper.
pub const P_OUT: usize = 10;
pub const CORPUS: usize = 10_000;

/// One evaluated row of Table II.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub n_row: usize,
    pub n_col: usize,
    pub cell_w_nm: f64,
    pub cell_l_nm: f64,
    pub images_per_step: usize,
    pub energy_per_image: f64,
    pub area_um2: f64,
    pub exec_time: f64,
    pub nm: f64,
}

/// Evaluate Table II with the given layer (trained artifact weights, or a
/// self-contained fallback for artifact-free runs).
pub fn table2_rows(layer: &BinaryLayer) -> Vec<Table2Row> {
    assert_eq!(layer.n_out(), P_OUT);
    let mut rows = Vec::new();
    for &(n_row, n_col, l_scale) in &TABLE2_DESIGNS {
        let design = ArrayDesign::new(n_row, n_col, LineConfig::config3(), l_scale, 1.0)
            .with_span(layer.n_in());
        let nm = noise_margin(&design).noise_margin();

        // measure energy on one real batch (cap the batch for the big
        // arrays — energy per image is size-independent, Table II)
        let m = n_row.min(256);
        let mut gen = DigitGen::new(TEST_SEED);
        let images: Vec<Vec<bool>> = (0..m).map(|_| gen.next_sample().pixels).collect();
        let mut sa = Subarray::new(design.clone());
        let run = layer.run_batch(&mut sa, &images, TmvmMode::Ideal);
        // per-image compute energy: the TMVM steps only (programming the
        // images is a memory write shared with the storage role)
        let step_energy: f64 = run.steps.iter().map(|s| s.energy).sum();
        let energy_per_image = step_energy / m as f64;

        let images_per_step = n_row / P_OUT;
        let steps = CORPUS.div_ceil(images_per_step);
        let exec_time = steps as f64 * design.device.t_set;

        rows.push(Table2Row {
            n_row,
            n_col,
            cell_w_nm: design.cell.w_cell * 1e9,
            cell_l_nm: design.cell.l_cell * 1e9,
            images_per_step,
            energy_per_image,
            area_um2: design.area() * 1e12,
            exec_time,
            nm,
        });
    }
    rows
}

/// Render Table II.
pub fn table2_table(rows: &[Table2Row]) -> Table {
    let mut t = Table::new("Table II — digit recognition across subarray sizes (config 3)")
        .header(&[
            "Subarray",
            "Cell (nm×nm)",
            "#Img/Step",
            "Energy/Image",
            "Area (µm²)",
            "Exec Time",
            "NM",
        ]);
    for r in rows {
        t.row(&[
            format!("{}×{}", r.n_row, r.n_col),
            format!("{:.0}×{:.0}", r.cell_w_nm, r.cell_l_nm),
            r.images_per_step.to_string(),
            format_si(r.energy_per_image, "J"),
            format!("{:.1}", r.area_um2),
            format_duration(r.exec_time),
            format_pct(r.nm),
        ]);
    }
    t
}

/// Self-contained fallback layer (glyph templates as weights) for runs
/// without artifacts. The trained artifact layer is preferred.
pub fn template_layer() -> BinaryLayer {
    use crate::nn::dataset::{DigitGen as G, IMAGE_SIDE, N_CLASSES};
    let weights = (0..N_CLASSES)
        .map(|c| {
            (0..IMAGE_SIDE * IMAGE_SIDE)
                .map(|i| G::template_pixel(c, i / IMAGE_SIDE, i % IMAGE_SIDE))
                .collect()
        })
        .collect();
    BinaryLayer::new(weights, 20)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_reproduces_paper_shapes() {
        let rows = table2_rows(&template_layer());
        assert_eq!(rows.len(), 5);
        // images/step: 6, 12, 25, 51, 102 (Table II)
        let ips: Vec<usize> = rows.iter().map(|r| r.images_per_step).collect();
        assert_eq!(ips, vec![6, 12, 25, 51, 102]);
        // exec time: 133.3µs down to ~7.8µs, ≈17× speedup
        assert!((rows[0].exec_time - 133.4e-6).abs() < 1e-6, "{}", rows[0].exec_time);
        assert!((rows[4].exec_time - 7.9e-6).abs() < 2e-7, "{}", rows[4].exec_time);
        let speedup = rows[0].exec_time / rows[4].exec_time;
        assert!(speedup > 15.0 && speedup < 19.0, "speedup {speedup}");
        // energy/image ~constant (tens of pJ), size-independent
        let e0 = rows[0].energy_per_image;
        assert!(e0 > 1e-12 && e0 < 100e-12, "E {e0}");
        for r in &rows[1..] {
            let ratio = r.energy_per_image / e0;
            assert!(ratio > 0.8 && ratio < 1.25, "energy drift {ratio}");
        }
        // NM decreases with size but stays positive
        assert!(rows.windows(2).all(|w| w[1].nm <= w[0].nm + 1e-9));
        assert!(rows[4].nm > 0.0, "largest design still acceptable");
        // cell sizes match the paper column
        assert_eq!(
            rows.iter()
                .map(|r| format!("{:.0}x{:.0}", r.cell_w_nm, r.cell_l_nm))
                .collect::<Vec<_>>(),
            vec!["36x240", "36x320", "36x400", "36x480", "36x640"]
        );
    }
}

//! The paper's feasibility analysis: ideal voltage windows (§III, Eqs. 4–5),
//! the recursive Thevenin parasitic model (§V + Appendix A), noise margin
//! (Eq. 7), the acceptable design region (Fig. 11(b)) and maximum-subarray
//! search (§VI).
//!
//! The analytic ladder recursion here is validated against full MNA circuit
//! simulation (see [`corner_circuit`] and `rust/tests/prop_analysis.rs`).
//! [`montecarlo`] carries the point analyses to distributions: seeded
//! device-corner sweeps of noise margin and workload accuracy.

pub mod design;
pub mod voltage;
pub mod thevenin;
pub mod corner_circuit;
pub mod noise_margin;
pub mod montecarlo;

pub use design::{ArrayDesign, OutputLoading};
pub use montecarlo::{perturbed_design, variability_sweep, McConfig, McSizeResult};
pub use noise_margin::{max_rows_for_nm, noise_margin, region_boundary_alpha, NmAnalysis};
pub use thevenin::{ladder_thevenin, LadderThevenin};
pub use voltage::{ideal_window, IdealWindow};

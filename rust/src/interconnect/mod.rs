//! Interconnect models: the ASAP7 metal stack (supplementary Tables V–VI)
//! and the three metal-line configurations of Table I, producing the
//! per-cell-footprint segment conductances `G_x` (bit line) and `G_y`
//! (word lines) used by the parasitic analysis.

pub mod asap7;
pub mod config;
pub mod wire;

pub use asap7::{metal, via_chain_resistance, MetalLayer, Via, ASAP7_METALS, ASAP7_VIAS};
pub use config::{CellGeometry, LineConfig};

"""Pure-jnp oracle for the thresholded-crossbar TMVM kernel.

This is the CORE correctness reference: the Pallas kernel in tmvm.py must
agree with these functions exactly (same float32 arithmetic), and the rust
array simulator's ideal mode implements the same Eq.-3 physics in count
space.

Device constants mirror rust/src/device/params.rs (paper Table IV).
"""

from __future__ import annotations

import jax.numpy as jnp

# Paper Table IV / supplementary (SI units).
G_A = 660e-9
G_C = 160e-6
I_SET = 50e-6
I_RESET = 100e-6


def tmvm_currents_ref(x, w, alpha, r_th, v_dd):
    """Per-(image, neuron) output-cell current, Eq. 3 generalized with the
    per-row Thevenin attenuation.

    x:     (B, N) float32 in {0,1}  - stored images (one per physical row)
    w:     (N, P) float32 in {0,1}  - weight pulses (one step per neuron)
    alpha: (B, 1) float32           - per-row attenuation alpha_th
    r_th:  (B, 1) float32           - per-row Thevenin resistance [ohm]
    v_dd:  (1, 1) float32           - applied word-line voltage

    Returns (B, P) float32 currents. A zero conductance sum yields zero
    current.
    """
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    s1 = x @ w  # crystalline products
    xsum = jnp.sum(x, axis=1, keepdims=True)
    s0 = xsum - s1  # amorphous (leakage) products
    gsum = s1 * G_C + s0 * G_A
    safe = jnp.maximum(gsum, 1e-30)
    denom = r_th + 1.0 / safe + 1.0 / G_C
    i_t = alpha * v_dd / denom
    return jnp.where(gsum > 0.0, i_t, 0.0).astype(jnp.float32)


def tmvm_ref(x, w, alpha, r_th, v_dd):
    """Thresholded TMVM: (bits, currents).

    bits are 1.0 where I_T >= I_SET *and* the accidental-RESET bound
    I_T < I_RESET holds (a violating cell melts back to 0 - matching the
    rust simulator's TmvmOutcome::ResetViolation semantics).
    """
    i_t = tmvm_currents_ref(x, w, alpha, r_th, v_dd)
    bits = jnp.logical_and(i_t >= I_SET, i_t < I_RESET)
    return bits.astype(jnp.float32), i_t


def vdd_for_threshold(theta: int) -> float:
    """Operating voltage realizing integer firing threshold theta
    (twin of rust Subarray::vdd_for_threshold)."""
    assert theta >= 1
    return I_SET * (theta + 1) / (theta * G_C)

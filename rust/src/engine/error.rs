//! [`EngineError`] — the typed error surface of the engine layer.
//!
//! Spec validation, backend construction and ticket bookkeeping all fail
//! through this enum, so callers can match on *what* went wrong instead of
//! grepping strings. It implements [`std::error::Error`], which the
//! crate-wide `anyhow` blanket `From` lifts into [`crate::Result`] — `?`
//! works unchanged in `anyhow`-typed code.

use std::fmt;

/// Everything the engine layer can reject.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// The layer does not fit the single-subarray design.
    LayerTooLarge {
        n_in: usize,
        n_out: usize,
        n_col: usize,
    },
    /// A layer with a zero dimension cannot be placed or served.
    EmptyLayer {
        index: usize,
        n_out: usize,
        n_in: usize,
    },
    /// Fabric grid with a zero dimension.
    EmptyGrid { rows: usize, cols: usize },
    /// Subarray tile with a zero dimension.
    EmptyTile { rows: usize, cols: usize },
    /// Batch capacity (or fabric `max_batch`) of zero.
    ZeroBatch,
    /// Worker count of zero.
    ZeroWorkers,
    /// Shard count of zero (`--shards 0`).
    ZeroShards,
    /// Two options selecting incompatible backends were both given.
    Conflict {
        first: &'static str,
        second: &'static str,
    },
    /// An option that only applies together with another one.
    Requires {
        option: &'static str,
        requires: &'static str,
    },
    /// Unknown backend kind name.
    UnknownBackend(String),
    /// Unknown network source name.
    UnknownNetwork(String),
    /// Unknown placement strategy name.
    UnknownPlacement(String),
    /// Metal-line configuration id outside `1..=3`.
    UnknownLineConfig(String),
    /// Engaged column span outside `1..=n_col`.
    BadSpan { span: usize, n_col: usize },
    /// A spec field failed validation.
    Spec {
        field: &'static str,
        detail: String,
    },
    /// Malformed engine-spec JSON.
    Json(String),
    /// The backend needs AOT artifacts that are not available.
    Artifacts(String),
    /// Placing the network onto the fabric failed.
    Placement(String),
    /// Polling a ticket that was never issued or already collected.
    UnknownTicket(u64),
    /// Polling an engine that has never had a batch submitted.
    Empty,
    /// A submitted batch exceeds every shard's per-call batch limit.
    NoShardFits { batch: usize, max_batch: usize },
    /// Packed (popcount fast-path) dispatch requested on a
    /// parasitic-fidelity engine, whose tile steps must run the per-cell
    /// electrical walk. Refusing is deliberate: silently falling back to
    /// the ideal-mode kernel would serve un-attenuated results at the
    /// wrong fidelity.
    PackedFidelity { kind: &'static str },
    /// The backend cannot reprogram its weights in place.
    SwapUnsupported { kind: &'static str },
    /// The swap target does not match the resident network's shape.
    SwapShape { detail: String },
    /// `begin_swap` while a rolling swap is already active.
    SwapInProgress,
    /// `poll_swap` with no swap begun (or the report already collected).
    NoSwap,
    /// The engine cannot spawn or retire shards (no elastic template).
    ScaleUnsupported { kind: &'static str },
    /// `spawn_shard`/`retire_shard` while another lifecycle walk (rolling
    /// swap or scale operation) is still in progress.
    ScaleBusy,
    /// Retiring the last serving shard would stop serving entirely.
    LastServingShard,
    /// Programming the spawn target would exceed the per-shard
    /// pulse-endurance budget on every candidate shard.
    PulseBudget { needed: u64, budget: u64 },
    /// A remote shard host refused a request or its connection failed
    /// (connect/read/write timeouts, protocol violations, host-side
    /// engine errors).
    Remote { addr: String, detail: String },
    /// A `--remote`/`remote.addrs` address that is neither `host:port`
    /// nor `unix:/path`.
    BadRemoteAddr(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::LayerTooLarge { n_in, n_out, n_col } => write!(
                f,
                "layer does not fit the subarray: {n_in} inputs / {n_out} outputs \
                 need at most {n_col} columns"
            ),
            Self::EmptyLayer { index, n_out, n_in } => {
                write!(f, "layer {index} has an empty shape ({n_out}×{n_in})")
            }
            Self::EmptyGrid { rows, cols } => {
                write!(f, "fabric grid must be at least 1×1, got {rows}×{cols}")
            }
            Self::EmptyTile { rows, cols } => write!(
                f,
                "subarray tile must be at least 1×1 cells, got {rows}×{cols}"
            ),
            Self::ZeroBatch => write!(f, "batch capacity must be at least 1"),
            Self::ZeroWorkers => write!(f, "worker count must be at least 1"),
            Self::ZeroShards => write!(f, "shard count must be at least 1"),
            Self::Conflict { first, second } => write!(
                f,
                "{first} and {second} are mutually exclusive — pick one backend"
            ),
            Self::Requires { option, requires } => write!(f, "{option} requires {requires}"),
            Self::UnknownBackend(s) => write!(
                f,
                "unknown backend kind '{s}' (expected ideal|parasitic|fabric|xla|remote)"
            ),
            Self::UnknownNetwork(s) => write!(
                f,
                "unknown network source '{s}' (expected auto|template|artifact|\
                 multibit:BITS[:SCHEME]|conv:FxKHxKW[:tN])"
            ),
            Self::UnknownPlacement(s) => write!(
                f,
                "unknown placement strategy '{s}' (expected roundrobin|locality)"
            ),
            Self::UnknownLineConfig(s) => write!(
                f,
                "unknown metal-line configuration '{s}' (expected 1|2|3)"
            ),
            Self::BadSpan { span, n_col } => {
                write!(f, "column span {span} outside 1..={n_col}")
            }
            Self::Spec { field, detail } => {
                write!(f, "invalid engine spec field '{field}': {detail}")
            }
            Self::Json(detail) => write!(f, "engine spec JSON: {detail}"),
            Self::Artifacts(detail) => write!(f, "{detail}"),
            Self::Placement(detail) => write!(f, "fabric placement: {detail}"),
            Self::UnknownTicket(t) => {
                write!(f, "ticket {t} was never issued or already collected")
            }
            Self::Empty => write!(f, "nothing submitted — no batch is in flight"),
            Self::NoShardFits { batch, max_batch } => write!(
                f,
                "batch of {batch} exceeds every shard's max batch {max_batch}"
            ),
            Self::PackedFidelity { kind } => write!(
                f,
                "packed dispatch is ideal-only: the {kind} engine runs the per-cell \
                 parasitic walk — submit scalar images instead"
            ),
            Self::SwapUnsupported { kind } => write!(
                f,
                "the {kind} backend cannot reprogram weights in place — \
                 swap is supported by ideal|parasitic|fabric|sharded engines"
            ),
            Self::SwapShape { detail } => {
                write!(f, "swap target shape mismatch: {detail}")
            }
            Self::SwapInProgress => {
                write!(f, "a rolling swap is already in progress — poll it to completion first")
            }
            Self::NoSwap => write!(f, "no swap in progress — begin one before polling"),
            Self::ScaleUnsupported { kind } => write!(
                f,
                "the {kind} engine cannot spawn or retire shards — elastic scaling \
                 needs a sharded engine built from an autoscale spec"
            ),
            Self::ScaleBusy => write!(
                f,
                "a shard lifecycle walk (rolling swap or scale operation) is already \
                 in progress — let it finish first"
            ),
            Self::LastServingShard => write!(
                f,
                "cannot retire the last serving shard — serving must never stop"
            ),
            Self::PulseBudget { needed, budget } => write!(
                f,
                "spawn vetoed: programming needs {needed} pulses but the per-shard \
                 endurance budget is {budget}"
            ),
            Self::Remote { addr, detail } => {
                write!(f, "remote shard at {addr}: {detail}")
            }
            Self::BadRemoteAddr(s) => write!(
                f,
                "bad remote address '{s}' (expected host:port or unix:/path)"
            ),
        }
    }
}

impl EngineError {
    /// Reconstruct a [`EngineError::Remote`] from its rendered message.
    ///
    /// Shard worker threads report failures as strings over their event
    /// channel (the repo-wide pattern — cf. the coordinator recognizing
    /// `ScaleBusy` by its rendering), so the sharded engine uses this to
    /// lift a remote shard's failure back into the typed variant before
    /// handing it to callers.
    pub fn parse_remote(msg: &str) -> Option<Self> {
        let rest = msg.strip_prefix("remote shard at ")?;
        let (addr, detail) = rest.split_once(": ")?;
        Some(Self::Remote {
            addr: addr.to_string(),
            detail: detail.to_string(),
        })
    }
}

impl std::error::Error for EngineError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_actionable() {
        let e = EngineError::Conflict {
            first: "--xla",
            second: "--fabric",
        };
        assert_eq!(
            e.to_string(),
            "--xla and --fabric are mutually exclusive — pick one backend"
        );
        let e = EngineError::Requires {
            option: "--grid",
            requires: "--fabric",
        };
        assert_eq!(e.to_string(), "--grid requires --fabric");
        assert!(EngineError::EmptyGrid { rows: 0, cols: 2 }
            .to_string()
            .contains("at least 1×1"));
        assert_eq!(
            EngineError::ZeroShards.to_string(),
            "shard count must be at least 1"
        );
        assert_eq!(
            EngineError::Empty.to_string(),
            "nothing submitted — no batch is in flight"
        );
        assert!(EngineError::NoShardFits { batch: 9, max_batch: 4 }
            .to_string()
            .contains("batch of 9"));
        assert!(EngineError::UnknownPlacement("snake".into())
            .to_string()
            .contains("roundrobin|locality"));
        assert!(EngineError::SwapUnsupported { kind: "xla" }
            .to_string()
            .contains("xla backend cannot reprogram"));
        assert!(EngineError::PackedFidelity { kind: "parasitic" }
            .to_string()
            .contains("packed dispatch is ideal-only"));
        assert!(EngineError::SwapShape {
            detail: "layer 0 is 4×8 but the target is 4×9".into()
        }
        .to_string()
        .contains("shape mismatch"));
        assert_eq!(
            EngineError::NoSwap.to_string(),
            "no swap in progress — begin one before polling"
        );
        assert!(EngineError::SwapInProgress.to_string().contains("already in progress"));
        assert!(EngineError::ScaleUnsupported { kind: "ideal" }
            .to_string()
            .contains("cannot spawn or retire shards"));
        assert!(EngineError::ScaleBusy.to_string().contains("already"));
        assert!(EngineError::LastServingShard
            .to_string()
            .contains("last serving shard"));
        let e = EngineError::PulseBudget {
            needed: 120,
            budget: 100,
        };
        assert!(
            e.to_string().contains("120") && e.to_string().contains("100"),
            "{e}"
        );
        assert_eq!(
            EngineError::Remote {
                addr: "unix:/tmp/s0.sock".into(),
                detail: "connection closed mid-batch".into()
            }
            .to_string(),
            "remote shard at unix:/tmp/s0.sock: connection closed mid-batch"
        );
        assert!(EngineError::BadRemoteAddr("nonsense".into())
            .to_string()
            .contains("host:port or unix:/path"));
    }

    #[test]
    fn remote_errors_roundtrip_through_their_rendering() {
        let e = EngineError::Remote {
            addr: "10.0.0.7:9090".into(),
            detail: "socket i/o failed: timed out".into(),
        };
        assert_eq!(EngineError::parse_remote(&e.to_string()), Some(e));
        assert_eq!(EngineError::parse_remote("shard 3 worker thread died"), None);
        assert_eq!(EngineError::parse_remote("remote shard at nowhere"), None);
    }

    #[test]
    fn lifts_into_anyhow() {
        fn fails() -> crate::Result<()> {
            let r: Result<(), EngineError> = Err(EngineError::ZeroBatch);
            r?;
            Ok(())
        }
        let err = fails().unwrap_err();
        assert!(err.to_string().contains("batch capacity"), "{err}");
    }
}

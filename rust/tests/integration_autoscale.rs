//! Integration: shard-aware autoscaling. Pins the tentpole acceptance —
//! under a deterministic seeded burst trace the elastic engine scales up
//! at the high watermark and back down at the low watermark, serving
//! never stops, no ticket is ever dropped or duplicated, outputs stay
//! bit-exact with a fixed-`max`-shard engine fed the identical batches,
//! and a slot whose pulse-endurance budget is exhausted is never
//! selected for spawn.

use std::time::Duration;

use xpoint_imc::coordinator::{AutoscalePolicy, ScaleDecision};
use xpoint_imc::engine::{
    ArraySpec, AutoscaleSpec, BackendKind, Engine, EngineSpec, ScaleEvent, ScaleEventKind,
    ShardState, ShardedEngine,
};
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::util::Pcg32;

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.45)).collect())
            .collect(),
        theta,
    )
}

fn random_images(rng: &mut Pcg32, m: usize, n_in: usize) -> Vec<Vec<bool>> {
    (0..m)
        .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
        .collect()
}

fn base_spec(layer: BinaryLayer) -> EngineSpec {
    EngineSpec::new(BackendKind::Ideal)
        .with_array(ArraySpec {
            rows: 32,
            cols: 32,
            span: Some(16),
            ..ArraySpec::default()
        })
        .with_batching(32, 200)
        .with_layers(vec![layer])
}

fn redeem(engine: &mut ShardedEngine, ticket: u64) -> xpoint_imc::engine::InferenceResult {
    loop {
        match engine.poll(ticket).expect("poll") {
            Some(res) => return res,
            None => engine.wait_event(Duration::from_millis(1)),
        }
    }
}

/// The deterministic seeded burst soak: three phases (burst → mixed →
/// drain) driven by one PRNG, the policy ticked every op. The elastic
/// engine and a fixed-`max`-shard mirror receive identical batches.
fn soak(seed: u64) {
    let mut rng = Pcg32::seeded(seed);
    let layer = random_layer(&mut rng, 8, 16, 3);
    let auto = AutoscaleSpec {
        min_shards: 1,
        max_shards: 3,
        high_watermark: 12,
        low_watermark: 2,
        cooldown: 2,
        pulse_budget: 0,
    };
    let mut elastic = base_spec(layer.clone())
        .with_autoscale(auto)
        .build_sharded()
        .expect("elastic engine");
    let mut fixed = base_spec(layer.clone())
        .with_shards(3, BackendKind::Ideal)
        .build_sharded()
        .expect("fixed mirror");
    let mut policy = AutoscalePolicy::from_spec(&auto);

    // (elastic ticket, fixed ticket, batch)
    let mut outstanding: Vec<(u64, u64, Vec<Vec<bool>>)> = Vec::new();
    let mut redeemed: Vec<u64> = Vec::new();
    let mut events: Vec<ScaleEvent> = Vec::new();

    for op in 0..300u32 {
        // burst phase floods; mixed phase balances; drain phase only polls
        let submit_p = match op {
            0..=99 => 0.9,
            100..=199 => 0.4,
            _ => 0.0,
        };
        if rng.bernoulli(submit_p) {
            let m = rng.range(1, 6);
            let imgs = random_images(&mut rng, m, 16);
            let te = elastic.submit(imgs.clone()).expect("elastic submit");
            let tf = fixed.submit(imgs.clone()).expect("fixed submit");
            outstanding.push((te, tf, imgs));
        } else if !outstanding.is_empty() && rng.bernoulli(0.8) {
            let k = rng.range(0, outstanding.len());
            let te = outstanding[k].0;
            if let Some(res) = elastic.poll(te).expect("elastic poll") {
                let (te, tf, imgs) = outstanding.swap_remove(k);
                let want = redeem(&mut fixed, tf);
                assert_eq!(res.bits, want.bits, "bit-exact vs the fixed fleet");
                assert_eq!(res.classes, want.classes);
                for (img, bits) in imgs.iter().zip(&res.bits) {
                    assert_eq!(bits, &layer.forward(img), "functional identity");
                }
                redeemed.push(te);
            }
        }

        // the policy runs every op, exactly like the scheduler loop
        match policy.decide(&elastic.scale_load()) {
            ScaleDecision::Up => {
                let _ = elastic.spawn_shard(); // ScaleBusy mid-walk is fine
            }
            ScaleDecision::Down => {
                let _ = elastic.retire_shard();
            }
            ScaleDecision::Hold => {}
        }
        events.extend(elastic.take_scale_events());

        let serving = elastic.serving_shards();
        assert!(
            (1..=3).contains(&serving),
            "op {op} (seed {seed:#x}): serving {serving} left [min, max]"
        );
    }

    // drain every outstanding ticket — serving never stopped, nothing lost
    while let Some((te, tf, imgs)) = outstanding.pop() {
        let res = redeem(&mut elastic, te);
        let want = redeem(&mut fixed, tf);
        assert_eq!(res.bits, want.bits, "drained ticket bit-exact (seed {seed:#x})");
        for (img, bits) in imgs.iter().zip(&res.bits) {
            assert_eq!(bits, &layer.forward(img));
        }
        redeemed.push(te);
    }

    // idle: the policy must walk the fleet back to the floor (waiting out
    // any lifecycle walk still in flight from the mixed phase)
    let mut guard = 0u32;
    while elastic.serving_shards() != 1 || !elastic.scale_settled() {
        guard += 1;
        assert!(
            guard < 10_000,
            "seed {seed:#x}: the drained fleet never settled at min_shards"
        );
        if let ScaleDecision::Down = policy.decide(&elastic.scale_load()) {
            let _ = elastic.retire_shard();
        }
        elastic.wait_event(Duration::from_millis(1));
        events.extend(elastic.take_scale_events());
    }
    events.extend(elastic.take_scale_events());

    let spawns = events
        .iter()
        .filter(|e| matches!(e.kind, ScaleEventKind::Spawn { .. }))
        .count();
    let retires = events
        .iter()
        .filter(|e| e.kind == ScaleEventKind::Retire)
        .count();
    assert!(spawns >= 1, "seed {seed:#x}: the burst never scaled up");
    assert!(retires >= 1, "seed {seed:#x}: the drain never scaled down");
    assert_eq!(
        spawns, retires,
        "seed {seed:#x}: the fleet is back at the floor, so spawns balance retires"
    );

    // exactly-once: every ticket redeemed once, and re-polling is typed
    let mut unique = redeemed.clone();
    unique.sort_unstable();
    unique.dedup();
    assert_eq!(unique.len(), redeemed.len(), "a ticket completed twice");
    for &t in redeemed.iter().take(5) {
        let err = elastic.poll(t).expect_err("redeemed tickets are gone");
        assert!(
            err.to_string().contains("never issued or already collected"),
            "{err}"
        );
    }
}

#[test]
fn soak_seed_a_bursty_autoscale() {
    soak(0xa5c0);
}

#[test]
fn soak_seed_b_bursty_autoscale() {
    soak(0xa5c1);
}

#[test]
fn soak_seed_c_bursty_autoscale() {
    soak(0xa5c2);
}

/// 8×16 layer with exactly the flat indices in `on` set.
fn patterned(on: impl Fn(usize) -> bool) -> BinaryLayer {
    BinaryLayer::new(
        (0..8)
            .map(|r| (0..16).map(|c| on(r * 16 + c)).collect())
            .collect(),
        3,
    )
}

/// Acceptance: a shard whose pulse budget is exhausted is never selected
/// for spawn — the spawn is vetoed onto a fresh slot, and the worn slot
/// stays parked forever.
#[test]
fn exhausted_pulse_budget_vetoes_the_worn_slot() {
    // old: 20 ones. new: 30 SETs + 10 RESETs away → swap costs 40 pulses.
    let old = patterned(|i| i < 20);
    let new = patterned(|i| (10..20).contains(&i) || (20..50).contains(&i));
    // deployment charges 20; the swap takes each slot to 60 — over the
    // 55 budget, while a fresh slot's 40-pulse image still fits
    let auto = AutoscaleSpec {
        min_shards: 2,
        max_shards: 4,
        high_watermark: 12,
        low_watermark: 2,
        cooldown: 0,
        pulse_budget: 55,
    };
    let mut engine = base_spec(old.clone())
        .with_autoscale(auto)
        .build_sharded()
        .expect("elastic engine");
    engine.swap_network(vec![new.clone()]).expect("rolling swap");
    assert_eq!(engine.shard_wear(), vec![60, 60]);

    let parked = engine.retire_shard().expect("retire");
    while !engine.scale_settled() {
        engine.wait_event(Duration::from_millis(1));
    }
    engine.take_scale_events();
    assert_eq!(engine.shard_states()[parked], ShardState::Parked);

    let spawned = engine.spawn_shard().expect("spawn");
    while !engine.scale_settled() {
        engine.wait_event(Duration::from_millis(1));
    }
    assert_ne!(spawned, parked, "the worn slot must never be selected");
    assert_eq!(
        engine.shard_states()[parked],
        ShardState::Parked,
        "worn slot untouched"
    );
    let events = engine.take_scale_events();
    assert!(
        events.iter().any(|e| e.kind == ScaleEventKind::Veto && e.shard == parked),
        "the worn slot's veto is recorded: {events:?}"
    );
    let spawn = events
        .iter()
        .find(|e| e.kind == (ScaleEventKind::Spawn { fresh: true }))
        .expect("fresh spawn");
    assert_eq!(spawn.pulses, 40, "fresh slot pays the current network's image");

    // the spawned slot serves the post-swap network, bit-exact
    let mut rng = Pcg32::seeded(0xbeef);
    let imgs = random_images(&mut rng, 8, 16);
    let res = engine.infer_batch(&imgs).expect("serve after scale");
    for (img, bits) in imgs.iter().zip(&res.bits) {
        assert_eq!(bits, &new.forward(img));
    }

    // and when even a fresh image cannot fit the budget, the spawn is a
    // typed PulseBudget error and the fleet is unchanged
    let tiny = AutoscaleSpec {
        pulse_budget: 10,
        ..auto
    };
    let mut capped = base_spec(old.clone())
        .with_autoscale(tiny)
        .build_sharded()
        .expect("elastic engine");
    let err = capped.spawn_shard().expect_err("over budget");
    assert!(err.to_string().contains("endurance budget"), "{err}");
    assert_eq!(capped.serving_shards(), 2);
}

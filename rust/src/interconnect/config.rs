//! Metal-line configurations (paper Table I) and cell geometry, yielding
//! the per-segment conductances `G_x` / `G_y` consumed by the parasitic
//! analysis.

use super::asap7::{metal, via_chain_resistance};
use super::wire::segment_conductance;

/// Allocation of ASAP7 metal layers to the three 3D XPoint line groups
/// (paper Table I).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LineConfig {
    /// Human-readable id (1, 2, 3 for the paper's configurations).
    pub id: u8,
    /// Layers strapped together for top word lines.
    pub wlt: Vec<usize>,
    /// Layers for bottom word lines.
    pub wlb: Vec<usize>,
    /// Layers for bit lines.
    pub bl: Vec<usize>,
}

impl LineConfig {
    /// Configuration 1: M3 / M1 / M2 only.
    pub fn config1() -> Self {
        Self {
            id: 1,
            wlt: vec![3],
            wlb: vec![1],
            bl: vec![2],
        }
    }

    /// Configuration 2: WLT = M3+M6+M8, WLB = M1+M7+M9, BL = M2+M4+M5.
    pub fn config2() -> Self {
        Self {
            id: 2,
            wlt: vec![3, 6, 8],
            wlb: vec![1, 7, 9],
            bl: vec![2, 4, 5],
        }
    }

    /// Configuration 3: WLT = M3+M5+M6+M8, WLB = M1+M4+M7+M9, BL = M2.
    pub fn config3() -> Self {
        Self {
            id: 3,
            wlt: vec![3, 5, 6, 8],
            wlb: vec![1, 4, 7, 9],
            bl: vec![2],
        }
    }

    /// All three paper configurations.
    pub fn all() -> Vec<Self> {
        vec![Self::config1(), Self::config2(), Self::config3()]
    }

    /// Minimum cell footprint `(W_min, L_min)` \[m\]: the row pitch `W_cell`
    /// must fit the widest BL layer's minimum pitch, the column pitch
    /// `L_cell` the widest WL layer's (paper Table I last column).
    pub fn min_cell(&self) -> (f64, f64) {
        let w_min = self
            .bl
            .iter()
            .map(|&k| metal(k).pitch_min())
            .fold(0.0, f64::max);
        let l_min = self
            .wlt
            .iter()
            .chain(self.wlb.iter())
            .map(|&k| metal(k).pitch_min())
            .fold(0.0, f64::max);
        (w_min, l_min)
    }

    /// Lumped via-chain resistance from the base WL layers to the strap
    /// layers \[Ω\]. For long lines the strap current enters/leaves through
    /// via chains at the line ends, so this is charged once per line (added
    /// to the driver resistance), not per segment.
    pub fn wl_via_resistance(&self) -> f64 {
        let wlt_base = 3; // WLT base layer (top of the PCM stack)
        let wlb_base = 1;
        let chain = |base: usize, layers: &[usize]| -> f64 {
            layers
                .iter()
                .filter(|&&k| k != base)
                .map(|&k| via_chain_resistance(base, k))
                .fold(0.0, f64::max)
        };
        chain(wlt_base, &self.wlt) + chain(wlb_base, &self.wlb)
    }
}

/// Physical cell geometry: footprint pitches in meters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CellGeometry {
    /// Row pitch (distance between adjacent cells along a word line) \[m\].
    pub w_cell: f64,
    /// Column pitch (distance between adjacent cells along a bit line) \[m\].
    pub l_cell: f64,
}

impl CellGeometry {
    /// Geometry at scale multiples of the configuration's minimum cell:
    /// `W_cell = w_scale · W_min`, `L_cell = l_scale · L_min`.
    pub fn scaled(config: &LineConfig, w_scale: f64, l_scale: f64) -> Self {
        assert!(w_scale >= 1.0 && l_scale >= 1.0, "cannot go below min cell");
        let (w_min, l_min) = config.min_cell();
        Self {
            w_cell: w_scale * w_min,
            l_cell: l_scale * l_min,
        }
    }

    /// Cell footprint area \[m²\].
    pub fn area(&self) -> f64 {
        self.w_cell * self.l_cell
    }
}

/// Per-cell-footprint segment conductances for a (configuration, geometry)
/// pair — the `G_x` / `G_y` of the paper's Appendix A.
#[derive(Clone, Copy, Debug)]
pub struct SegmentConductances {
    /// Bit-line segment conductance `G_x` \[S\].
    pub g_x: f64,
    /// Top word-line segment conductance \[S\].
    pub g_wlt: f64,
    /// Bottom word-line segment conductance \[S\].
    pub g_wlb: f64,
    /// Lumped WL via-chain resistance, charged at the driver \[Ω\].
    pub r_via: f64,
}

impl SegmentConductances {
    /// Compute segment conductances: strapped layers add in parallel
    /// (conductances sum); each WL segment has length `W_cell` and width
    /// bounded by `L_cell`; each BL segment has length `L_cell` and width
    /// bounded by `W_cell`.
    pub fn of(config: &LineConfig, cell: &CellGeometry) -> Self {
        let wl = |layers: &[usize]| -> f64 {
            layers
                .iter()
                .map(|&k| segment_conductance(metal(k), cell.w_cell, cell.l_cell))
                .sum()
        };
        let g_x = config
            .bl
            .iter()
            .map(|&k| segment_conductance(metal(k), cell.l_cell, cell.w_cell))
            .sum();
        Self {
            g_x,
            g_wlt: wl(&config.wlt),
            g_wlb: wl(&config.wlb),
            r_via: config.wl_via_resistance(),
        }
    }

    /// The paper's single symmetric `G_y`, defined so that
    /// `2/G_y = 1/G_wlt + 1/G_wlb` (exact for symmetric allocations).
    pub fn g_y(&self) -> f64 {
        2.0 / (1.0 / self.g_wlt + 1.0 / self.g_wlb)
    }

    /// Series WL resistance of one row step (one WLT + one WLB segment) \[Ω\].
    pub fn r_wl_step(&self) -> f64 {
        1.0 / self.g_wlt + 1.0 / self.g_wlb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_i_min_cells() {
        // Paper Table I last column: 36×36, 48×80, 36×80 (nm).
        let cases = [
            (LineConfig::config1(), 36e-9, 36e-9),
            (LineConfig::config2(), 48e-9, 80e-9),
            (LineConfig::config3(), 36e-9, 80e-9),
        ];
        for (cfg, w, l) in cases {
            let (wm, lm) = cfg.min_cell();
            assert!((wm - w).abs() < 1e-15, "config {} W_min {wm}", cfg.id);
            assert!((lm - l).abs() < 1e-15, "config {} L_min {lm}", cfg.id);
        }
    }

    #[test]
    fn config3_has_best_wordlines() {
        // more WL metal ⇒ larger G_y at comparable geometry
        let geo = |c: &LineConfig| CellGeometry::scaled(c, 1.0, 4.0);
        let g1 = SegmentConductances::of(&LineConfig::config1(), &geo(&LineConfig::config1()));
        let g3 = SegmentConductances::of(&LineConfig::config3(), &geo(&LineConfig::config3()));
        assert!(
            g3.g_y() > g1.g_y(),
            "config3 {} vs config1 {}",
            g3.g_y(),
            g1.g_y()
        );
    }

    #[test]
    fn config1_segment_values_hand_checked() {
        // Config 1 at minimum cell (36×36): WLT = M3 segment, length 36 nm,
        // width = 36−18 = 18 nm ⇒ R = 43.2·36/(36·18) = 2.4 Ω.
        let cfg = LineConfig::config1();
        let cell = CellGeometry::scaled(&cfg, 1.0, 1.0);
        let s = SegmentConductances::of(&cfg, &cell);
        assert!((1.0 / s.g_wlt - 2.4).abs() < 1e-9);
        assert!((1.0 / s.g_wlb - 2.4).abs() < 1e-9);
        assert!((1.0 / s.g_x - 2.4).abs() < 1e-9);
        assert_eq!(s.r_via, 0.0, "single-layer lines need no straps");
    }

    #[test]
    fn l_cell_scaling_helps_wordlines() {
        let cfg = LineConfig::config1();
        let near = SegmentConductances::of(&cfg, &CellGeometry::scaled(&cfg, 1.0, 1.0));
        let far = SegmentConductances::of(&cfg, &CellGeometry::scaled(&cfg, 1.0, 4.0));
        assert!(far.g_y() > 3.0 * near.g_y(), "wider WL at larger L_cell");
        // while BL gets slightly worse (longer segments)
        assert!(far.g_x < near.g_x);
    }

    #[test]
    fn w_cell_scaling_hurts_wordlines() {
        let cfg = LineConfig::config3();
        let small = SegmentConductances::of(&cfg, &CellGeometry::scaled(&cfg, 1.0, 4.0));
        let big = SegmentConductances::of(&cfg, &CellGeometry::scaled(&cfg, 4.0, 4.0));
        assert!(big.g_y() < small.g_y());
    }

    #[test]
    fn via_chain_counted_for_strapped_configs() {
        assert!(LineConfig::config2().wl_via_resistance() > 0.0);
        assert!(LineConfig::config3().wl_via_resistance() > 0.0);
        assert_eq!(LineConfig::config1().wl_via_resistance(), 0.0);
    }

    #[test]
    fn cell_area() {
        let cell = CellGeometry {
            w_cell: 36e-9,
            l_cell: 240e-9,
        };
        assert!((cell.area() - 36e-9 * 240e-9).abs() < 1e-30);
    }
}

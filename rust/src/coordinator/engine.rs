//! The coordinator engine: a leader thread batches incoming requests and
//! dispatches them to scheduler threads, each driving one [`Engine`]
//! **purely through the non-blocking `submit`/`poll` pair**.
//!
//! The scheduler loop is backend-agnostic by construction: a synchronous
//! engine (one simulated subarray, a fabric, the XLA golden model)
//! completes its batch inside `submit` and the very next `poll` redeems
//! it — the `Completions`-backed submit/poll of those engines is the
//! trivial adapter. An asynchronous engine
//! ([`ShardedEngine`](crate::engine::ShardedEngine)) returns from
//! `submit` immediately while its shard threads work, so the scheduler
//! keeps several batches in flight (bounded by
//! [`Capabilities::shards`](crate::engine::Capabilities)) and drains
//! completions **out of order**, matching each ticket back to the jobs
//! that produced it — per-request identity is preserved by construction.
//!
//! std-thread based — the build is offline and the workload is CPU-bound
//! simulation, so threads + channels outperform an async reactor here.

use crate::engine::BackendFactory;
use super::batcher::Batcher;
use super::metrics::{Metrics, MetricsSnapshot};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max images per batch (≤ backend max batch).
    pub batch_capacity: usize,
    /// How long a partial batch may wait before shipping.
    pub linger: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            batch_capacity: 64,
            linger: Duration::from_micros(200),
        }
    }
}

/// A classification response.
#[derive(Clone, Debug)]
pub struct Prediction {
    pub id: u64,
    /// Hardware thresholded output bits.
    pub bits: Vec<bool>,
    /// Functional class prediction.
    pub class: usize,
}

struct Job {
    id: u64,
    image: Vec<bool>,
    label: Option<usize>,
    reply: mpsc::Sender<Prediction>,
}

enum Message {
    Job(Job),
    Shutdown,
}

/// How often an idle scheduler re-polls its in-flight tickets. Small
/// enough to keep completion latency negligible next to a simulated
/// batch, large enough not to spin a host core.
const POLL_INTERVAL: Duration = Duration::from_micros(50);

/// Deliver one completed batch: replies to every job, then one metrics
/// record for the batch.
fn deliver(
    metrics: &Metrics,
    jobs: Vec<Job>,
    res: crate::engine::InferenceResult,
    submitted: Instant,
) {
    let latency = submitted.elapsed().as_secs_f64() / jobs.len().max(1) as f64;
    let mut correct = 0u64;
    let mut labelled = 0u64;
    for (j, job) in jobs.iter().enumerate() {
        if let Some(label) = job.label {
            labelled += 1;
            if res.classes[j] == label {
                correct += 1;
            }
        }
        let _ = job.reply.send(Prediction {
            id: job.id,
            bits: res.bits[j].clone(),
            class: res.classes[j],
        });
    }
    metrics.record_batch(
        jobs.len() as u64,
        res.steps,
        latency,
        res.sim_time,
        res.energy,
        correct,
        labelled,
    );
}

/// The scheduler loop: one per engine. Accepts job batches from the
/// leader, submits them, and drains completions out of order — the only
/// engine surface it touches is `submit`/`poll` (+ introspection).
fn scheduler_main(
    wid: usize,
    factory: BackendFactory,
    wrx: mpsc::Receiver<Vec<Job>>,
    metrics: Arc<Metrics>,
) {
    let mut engine = match factory() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("worker {wid}: backend construction failed: {e:#}");
            return;
        }
    };
    // keep enough batches in flight to cover every shard plus one being
    // formed; synchronous engines complete at submit, so for them this
    // bound is never reached
    let max_in_flight = engine.capabilities().shards.max(1) + 1;
    let mut in_flight: Vec<(u64, Vec<Job>, Instant)> = Vec::new();
    let mut open = true;

    while open || !in_flight.is_empty() {
        // 1. intake — block only when nothing is in flight
        if open && in_flight.len() < max_in_flight {
            let next = if in_flight.is_empty() {
                match wrx.recv() {
                    Ok(jobs) => Some(jobs),
                    Err(_) => {
                        open = false;
                        None
                    }
                }
            } else {
                match wrx.recv_timeout(POLL_INTERVAL) {
                    Ok(jobs) => Some(jobs),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        None
                    }
                }
            };
            if let Some(jobs) = next {
                let images: Vec<Vec<bool>> = jobs.iter().map(|j| j.image.clone()).collect();
                // stamp before submit: synchronous engines do the whole
                // inference inside it, and that time is the latency
                let submitted = Instant::now();
                match engine.submit(images) {
                    Ok(ticket) => in_flight.push((ticket, jobs, submitted)),
                    Err(e) => {
                        eprintln!("worker {wid}: submit of {} jobs failed: {e:#}", jobs.len())
                    }
                }
            }
        } else if !in_flight.is_empty() {
            // intake closed or full: wait for completions without spinning
            std::thread::sleep(POLL_INTERVAL);
        }

        // 2. drain — redeem every ready ticket, in whatever order the
        // engine finished them
        let mut i = 0;
        while i < in_flight.len() {
            match engine.poll(in_flight[i].0) {
                Ok(Some(res)) => {
                    let (_, jobs, submitted) = in_flight.swap_remove(i);
                    deliver(&metrics, jobs, res, submitted);
                }
                Ok(None) => i += 1,
                Err(e) => {
                    let (ticket, jobs, _) = in_flight.swap_remove(i);
                    eprintln!(
                        "worker {wid}: batch (ticket {ticket}, {} jobs) failed: {e:#}",
                        jobs.len()
                    );
                }
            }
        }
    }
    // final per-shard telemetry into the shared metrics (one entry per
    // shard; plain engines contribute a single entry)
    metrics.record_shards(engine.shard_telemetry());
}

/// The running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Message>,
    leader: Option<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: u64,
}

impl Coordinator {
    /// Spawn the leader and one scheduler per backend factory. Each
    /// factory runs on its scheduler thread (PJRT handles are
    /// thread-affine; sharded engines spawn their own shard threads from
    /// there).
    pub fn spawn(backends: Vec<BackendFactory>, config: CoordinatorConfig) -> Self {
        assert!(!backends.is_empty());
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel::<Message>();

        // scheduler channels
        let mut worker_txs = Vec::new();
        let mut worker_handles = Vec::new();
        for (wid, factory) in backends.into_iter().enumerate() {
            let (wtx, wrx) = mpsc::channel::<Vec<Job>>();
            let m = Arc::clone(&metrics);
            worker_txs.push(wtx);
            worker_handles.push(std::thread::spawn(move || {
                scheduler_main(wid, factory, wrx, m)
            }));
        }

        // leader: batch + round-robin dispatch over the schedulers
        let cfg = config.clone();
        let leader = std::thread::spawn(move || {
            let mut batcher: Batcher<Job> = Batcher::new(cfg.batch_capacity, cfg.linger);
            let mut next_worker = 0usize;
            let dispatch = |batch: Vec<super::batcher::Request<Job>>,
                                next_worker: &mut usize| {
                let jobs: Vec<Job> = batch.into_iter().map(|r| r.payload).collect();
                let _ = worker_txs[*next_worker % worker_txs.len()].send(jobs);
                *next_worker += 1;
            };
            loop {
                // wait for work, but wake up to honour the linger deadline
                match rx.recv_timeout(cfg.linger.max(Duration::from_micros(50))) {
                    Ok(Message::Job(job)) => {
                        let id = job.id;
                        batcher.push(id, job);
                    }
                    Ok(Message::Shutdown) => break,
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
                while let Some(batch) = batcher.take_batch(Instant::now()) {
                    dispatch(batch, &mut next_worker);
                }
            }
            // drain on shutdown
            let rest = batcher.drain_all();
            if !rest.is_empty() {
                dispatch(rest, &mut next_worker);
            }
            drop(worker_txs);
            for h in worker_handles {
                let _ = h.join();
            }
        });

        Self {
            tx,
            leader: Some(leader),
            metrics,
            next_id: 0,
        }
    }

    /// Submit an image; returns a receiver for the prediction, or an
    /// error if the leader has already exited (instead of panicking —
    /// serving shells must be able to drain gracefully).
    pub fn submit(
        &mut self,
        image: Vec<bool>,
        label: Option<usize>,
    ) -> crate::Result<mpsc::Receiver<Prediction>> {
        let (reply, rx) = mpsc::channel();
        self.next_id += 1;
        let job = Job {
            id: self.next_id,
            image,
            label,
            reply,
        };
        self.tx
            .send(Message::Job(job))
            .map_err(|_| anyhow::anyhow!("coordinator is down: leader exited, not accepting jobs"))?;
        Ok(rx)
    }

    /// Graceful shutdown: flush queues, join workers, return final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let _ = self.tx.send(Message::Shutdown);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Message::Shutdown);
        if let Some(h) = self.leader.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArraySpec, BackendKind, EngineSpec};
    use crate::nn::BinaryLayer;
    use crate::util::Pcg32;

    fn make_backend(seed: u64) -> (BinaryLayer, BackendFactory) {
        let mut rng = Pcg32::seeded(seed);
        let layer = BinaryLayer::new(
            (0..10)
                .map(|_| (0..25).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            4,
        );
        let spec = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 32,
                span: Some(32),
                ..ArraySpec::default()
            })
            .with_batching(32, 200) // capacity may not exceed the 32 rows
            .with_layers(vec![layer.clone()]);
        (layer, spec.build().expect("valid spec"))
    }

    #[test]
    fn coordinator_roundtrip_matches_functional() {
        let (layer, be) = make_backend(5);
        let mut coord = Coordinator::spawn(
            vec![be],
            CoordinatorConfig {
                batch_capacity: 8,
                linger: Duration::from_micros(100),
            },
        );
        let mut rng = Pcg32::seeded(9);
        let images: Vec<Vec<bool>> = (0..40)
            .map(|_| (0..25).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let receivers: Vec<_> = images
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit"))
            .collect();
        for (img, rx) in images.iter().zip(receivers) {
            let pred = rx.recv_timeout(Duration::from_secs(10)).expect("reply");
            assert_eq!(pred.bits, layer.forward(img));
            assert_eq!(pred.class, layer.argmax(img));
        }
        let snap = coord.shutdown();
        assert_eq!(snap.images, 40);
        assert!(snap.energy > 0.0);
        assert!(snap.batches >= 5, "batched into ≥5 batches of ≤8");
        assert_eq!(snap.shards.len(), 1, "one plain engine = one shard entry");
        assert_eq!(snap.shards[0].images, 40);
    }

    #[test]
    fn multiple_workers_share_load() {
        let (_, b1) = make_backend(5);
        let (_, b2) = make_backend(5);
        let mut coord = Coordinator::spawn(
            vec![b1, b2],
            CoordinatorConfig {
                batch_capacity: 4,
                linger: Duration::from_micros(50),
            },
        );
        let mut rng = Pcg32::seeded(10);
        let rxs: Vec<_> = (0..32)
            .map(|_| {
                let img: Vec<bool> = (0..25).map(|_| rng.bernoulli(0.5)).collect();
                coord.submit(img, Some(3)).expect("submit")
            })
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).expect("reply");
        }
        let snap = coord.shutdown();
        assert_eq!(snap.images, 32);
        assert!(snap.accuracy.is_some());
        assert_eq!(snap.shards.len(), 2, "one shard entry per worker engine");
    }

    /// The scheduler loop drives a genuinely asynchronous engine: a
    /// sharded backend whose batches complete on shard threads, out of
    /// order — every prediction must still reach its own requester.
    #[test]
    fn scheduler_serves_a_sharded_engine() {
        let mut rng = Pcg32::seeded(21);
        let layer = BinaryLayer::new(
            (0..10)
                .map(|_| (0..25).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            4,
        );
        let spec = EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 32,
                span: Some(32),
                ..ArraySpec::default()
            })
            .with_batching(8, 100)
            .with_layers(vec![layer.clone()])
            .with_shards(3, BackendKind::Ideal)
            .with_workers(1);
        let mut coord = Coordinator::spawn(
            spec.build_factories().expect("sharded factories"),
            CoordinatorConfig {
                batch_capacity: 8,
                linger: Duration::from_micros(50),
            },
        );
        let images: Vec<Vec<bool>> = (0..64)
            .map(|_| (0..25).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let rxs: Vec<_> = images
            .iter()
            .map(|img| coord.submit(img.clone(), None).expect("submit"))
            .collect();
        for (img, rx) in images.iter().zip(rxs) {
            let pred = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
            assert_eq!(pred.bits, layer.forward(img), "identity preserved");
        }
        let snap = coord.shutdown();
        assert_eq!(snap.images, 64);
        assert_eq!(snap.shards.len(), 3, "per-shard telemetry reaches metrics");
        let spread: u64 = snap.shards.iter().map(|t| t.images).sum();
        assert_eq!(spread, 64, "every image accounted to some shard");
    }

    #[test]
    fn submit_after_leader_exit_errors_instead_of_panicking() {
        let (_, be) = make_backend(7);
        let mut coord = Coordinator::spawn(vec![be], CoordinatorConfig::default());
        let mut rng = Pcg32::seeded(12);
        let img: Vec<bool> = (0..25).map(|_| rng.bernoulli(0.5)).collect();
        assert!(coord.submit(img.clone(), None).is_ok());
        // force the leader down without consuming the coordinator (the
        // failure mode a serving shell sees when the leader dies under it)
        coord.tx.send(Message::Shutdown).unwrap();
        coord.leader.take().unwrap().join().unwrap();
        let err = coord.submit(img, None).unwrap_err();
        assert!(err.to_string().contains("coordinator is down"), "{err}");
    }

    #[test]
    fn shutdown_flushes_partial_batches() {
        let (_, be) = make_backend(6);
        let mut coord = Coordinator::spawn(
            vec![be],
            CoordinatorConfig {
                batch_capacity: 1000,
                linger: Duration::from_secs(60), // never ships on its own
            },
        );
        let mut rng = Pcg32::seeded(11);
        let img: Vec<bool> = (0..25).map(|_| rng.bernoulli(0.5)).collect();
        let rx = coord.submit(img, None).expect("submit");
        let snap = coord.shutdown();
        assert_eq!(snap.images, 1);
        assert!(rx.recv_timeout(Duration::from_secs(1)).is_ok());
    }
}

//! Numeric Thevenin extraction from a solved netlist — the ground truth the
//! paper's analytic recursion (Appendix A) is validated against.

use super::netlist::{Netlist, NodeId};

/// Thevenin equivalent seen between two terminals.
#[derive(Clone, Copy, Debug)]
pub struct TheveninEquivalent {
    /// Open-circuit voltage `v(a) − v(b)` \[V\].
    pub v_th: f64,
    /// Equivalent source resistance \[Ω\].
    pub r_th: f64,
}

impl TheveninEquivalent {
    /// Current delivered into an external load conductance `g_load`.
    pub fn load_current(&self, g_load: f64) -> f64 {
        self.v_th / (self.r_th + 1.0 / g_load)
    }

    /// Attenuation coefficient α = V_th / V_src (paper §V).
    pub fn alpha(&self, v_src: f64) -> f64 {
        self.v_th / v_src
    }
}

impl Netlist {
    /// Extract the Thevenin equivalent seen from terminals `(a, b)`.
    ///
    /// `v_th` is the open-circuit voltage of the live network; `r_th` is
    /// measured on the dead network (independent sources zeroed) by
    /// injecting a 1 A test current and reading the terminal voltage.
    pub fn thevenin(&self, a: NodeId, b: NodeId) -> crate::Result<TheveninEquivalent> {
        let open = self.solve()?;
        let v_th = open.vdiff(a, b);
        let mut dead = self.dead_network();
        dead.current_source(b, a, 1.0);
        let probe = dead.solve()?;
        let r_th = probe.vdiff(a, b); // V/1A
        Ok(TheveninEquivalent { v_th, r_th })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::GROUND;

    /// Textbook: 10 V source, 6 Ω series, 3 Ω shunt; Thevenin at the shunt
    /// node = (10·3/9 V, 2 Ω).
    #[test]
    fn textbook_divider() {
        let mut nl = Netlist::new();
        let top = nl.node();
        let out = nl.node();
        nl.voltage_source(top, GROUND, 10.0);
        nl.resistor(top, out, 6.0);
        nl.resistor(out, GROUND, 3.0);
        let th = nl.thevenin(out, GROUND).unwrap();
        assert!((th.v_th - 10.0 / 3.0).abs() < 1e-9, "v_th = {}", th.v_th);
        assert!((th.r_th - 2.0).abs() < 1e-9, "r_th = {}", th.r_th);
    }

    /// Loading the Thevenin equivalent must reproduce the full-circuit
    /// current for any load.
    #[test]
    fn load_current_matches_full_solve() {
        let mut nl = Netlist::new();
        let top = nl.node();
        let out = nl.node();
        nl.voltage_source(top, GROUND, 2.0);
        nl.resistor(top, out, 50.0);
        nl.resistor(out, GROUND, 200.0);
        let th = nl.thevenin(out, GROUND).unwrap();
        for r_load in [10.0, 100.0, 1e4] {
            let mut loaded = nl.clone();
            loaded.resistor(out, GROUND, r_load);
            let sol = loaded.solve().unwrap();
            let i_full = sol.v[out] / r_load;
            let i_th = th.load_current(1.0 / r_load);
            assert!(
                (i_full - i_th).abs() < 1e-12,
                "r_load={r_load}: {i_full} vs {i_th}"
            );
        }
    }

    /// A current source behind a resistor: Norton → Thevenin conversion.
    #[test]
    fn norton_to_thevenin() {
        let mut nl = Netlist::new();
        let a = nl.node();
        nl.current_source(GROUND, a, 1e-3);
        nl.resistor(a, GROUND, 1e3);
        let th = nl.thevenin(a, GROUND).unwrap();
        assert!((th.v_th - 1.0).abs() < 1e-12);
        assert!((th.r_th - 1e3).abs() < 1e-9);
    }

    #[test]
    fn alpha_is_vth_over_vsrc() {
        let th = TheveninEquivalent { v_th: 0.8, r_th: 10.0 };
        assert!((th.alpha(1.0) - 0.8).abs() < 1e-12);
    }
}

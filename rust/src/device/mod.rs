//! Compact device models for the 3D XPoint stack (paper §II, Fig. 2,
//! supplementary Table IV): phase-change memory (PCM) storage elements and
//! ovonic threshold switch (OTS) selectors.

pub mod params;
pub mod pcm;
pub mod ots;
pub mod pulse;
pub mod cell;
pub mod reprogram;

pub use cell::XPointCell;
pub use ots::Ots;
pub use params::{DeviceParams, PCM_LOGIC0, PCM_LOGIC1};
pub use pcm::{PcmCell, PcmState};
pub use pulse::{Pulse, PulseKind};
pub use reprogram::ReprogramPlan;

//! Integration: the multi-host serving path, end to end through a real
//! `xpoint shard-host` process. Pins the tentpole contracts — a sharded
//! fleet mixing local shards with a remote shard behind a socket is
//! **bit-exact** with an all-local fleet on identical seeded traffic
//! (bits/classes per batch; energy and simulated time sum across
//! shards), including through a rolling weight swap and a
//! retire → spawn autoscale cycle — and SIGKILLing the shard-host
//! mid-soak resolves every in-flight ticket exactly once, as a correct
//! result or a typed `remote shard at ..` error, while serving
//! continues on the surviving local shard.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use xpoint_imc::engine::{
    AutoscaleSpec, BackendKind, Engine, EngineSpec, InferenceResult, ScaleEventKind,
    ShardedEngine, Ticket,
};
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::util::Pcg32;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_xpoint")
}

/// A live `xpoint shard-host` child serving one shard's worth of fabric
/// on a loopback TCP port the OS picked (`--listen 127.0.0.1:0`).
struct Host {
    child: Child,
    addr: String,
}

impl Host {
    fn spawn() -> Host {
        let mut child = Command::new(bin())
            .args(["shard-host", "--listen", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn xpoint shard-host");
        let stdout = child.stdout.take().expect("stdout is piped");
        let mut lines = BufReader::new(stdout).lines();
        let addr = loop {
            match lines.next() {
                Some(Ok(line)) => {
                    if let Some(a) = line.strip_prefix("listening on ") {
                        break a.trim().to_string();
                    }
                }
                _ => panic!("shard-host exited before announcing its address"),
            }
        };
        // keep draining stdout so the child can never block on a full pipe
        std::thread::spawn(move || {
            for _ in lines {}
        });
        Host { child, addr }
    }
}

impl Drop for Host {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn random_images(rng: &mut Pcg32, m: usize, n_in: usize) -> Vec<Vec<bool>> {
    (0..m)
        .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
        .collect()
}

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.45)).collect())
            .collect(),
        theta,
    )
}

/// Redeem a ticket, panicking if it neither completes nor fails within
/// the deadline — a ticket that pends forever is a lost ticket.
fn redeem(e: &mut ShardedEngine, t: Ticket) -> xpoint_imc::Result<InferenceResult> {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        match e.poll(t) {
            Ok(Some(res)) => return Ok(res),
            Ok(None) => {
                assert!(
                    Instant::now() < deadline,
                    "ticket {t:?} still pending after 60 s — lost in the fleet"
                );
                e.wait_event(Duration::from_millis(1));
            }
            Err(err) => return Err(err),
        }
    }
}

fn settle(e: &mut ShardedEngine) {
    for _ in 0..10_000 {
        if e.scale_settled() {
            return;
        }
        e.wait_event(Duration::from_millis(1));
    }
    panic!("scale operation never settled");
}

/// Drive one wave of seeded batches through both fleets and demand
/// bit-exactness: identical bits, classes and per-batch physics (each
/// batch runs complete on one shard of identical substrate, so energy,
/// time and steps match exactly — not approximately).
fn compare_wave(
    rng: &mut Pcg32,
    mixed: &mut ShardedEngine,
    local: &mut ShardedEngine,
    n_batches: usize,
    n_in: usize,
    tag: &str,
) {
    let batches: Vec<Vec<Vec<bool>>> = (0..n_batches)
        .map(|i| random_images(rng, 3 + (i % 5), n_in))
        .collect();
    let mt: Vec<Ticket> = batches
        .iter()
        .map(|b| mixed.submit(b.clone()).expect("submit to mixed fleet"))
        .collect();
    let lt: Vec<Ticket> = batches
        .iter()
        .map(|b| local.submit(b.clone()).expect("submit to local fleet"))
        .collect();
    for (k, (m, l)) in mt.into_iter().zip(lt).enumerate() {
        let got = redeem(mixed, m)
            .unwrap_or_else(|e| panic!("{tag} batch {k} failed on the mixed fleet: {e:#}"));
        let want = redeem(local, l)
            .unwrap_or_else(|e| panic!("{tag} batch {k} failed on the local fleet: {e:#}"));
        assert_eq!(got.bits, want.bits, "{tag} batch {k} bits");
        assert_eq!(got.classes, want.classes, "{tag} batch {k} classes");
        assert_eq!(got.energy, want.energy, "{tag} batch {k} energy");
        assert_eq!(got.sim_time, want.sim_time, "{tag} batch {k} time");
        assert_eq!(got.steps, want.steps, "{tag} batch {k} steps");
    }
}

/// Tentpole: 1 local + 1 remote shard vs 2 local shards — identical
/// seeded traffic, bit-exact results and summed telemetry, and the
/// equivalence survives a rolling weight swap and a full
/// retire → spawn autoscale cycle with the remote host in the fleet.
#[test]
fn mixed_local_and_remote_fleet_is_bit_exact_with_all_local() {
    let host = Host::spawn();
    let mut rng = Pcg32::seeded(0xc1a5);

    // elastic fleet: one local shard from the builder + the remote host
    let mut mixed = EngineSpec::new(BackendKind::Ideal)
        .with_autoscale(AutoscaleSpec {
            min_shards: 1,
            max_shards: 3,
            ..Default::default()
        })
        .with_remote([host.addr.as_str()])
        .build_sharded()
        .expect("mixed local+remote fleet");
    let mut local = EngineSpec::new(BackendKind::Ideal)
        .with_shards(2, BackendKind::Ideal)
        .build_sharded()
        .expect("all-local fleet");

    let caps = mixed.capabilities();
    assert_eq!(caps.shards, 2, "1 local + 1 remote serving shard");
    let n_in = caps.n_in;
    assert_eq!(local.capabilities().n_in, n_in, "same resident network");

    // phase A — plain traffic, then the aggregate telemetry must agree:
    // energy and simulated time sum across shards whichever side of the
    // socket they live on
    compare_wave(&mut rng, &mut mixed, &mut local, 12, n_in, "pre-swap");
    let a = mixed.telemetry();
    let b = local.telemetry();
    assert_eq!(a.batches, b.batches, "batch totals");
    assert_eq!(a.images, b.images, "image totals");
    assert_eq!(a.steps, b.steps, "step totals");
    assert!(
        (a.energy - b.energy).abs() <= 1e-9 * b.energy.abs(),
        "energy sums across the socket: {} vs {}",
        a.energy,
        b.energy
    );
    assert!(
        (a.sim_time - b.sim_time).abs() <= 1e-9 * b.sim_time.abs(),
        "sim time sums across the socket: {} vs {}",
        a.sim_time,
        b.sim_time
    );
    let per = mixed.shard_telemetry();
    assert_eq!(per.len(), 2);
    assert!(
        per.iter().all(|t| t.batches > 0),
        "both the local and the remote shard served work: {:?}",
        per.iter().map(|t| t.batches).collect::<Vec<_>>()
    );

    // phase B — rolling reprogram to the same target on both fleets; the
    // remote slot takes its swap over the wire
    let target = vec![random_layer(&mut Pcg32::seeded(0x7e57), caps.n_out, n_in, 30)];
    let mr = mixed.swap_network(target.clone()).expect("mixed swap");
    let lr = local.swap_network(target).expect("local swap");
    assert_eq!(mr.shards, 2, "the rolling walk covered the remote slot");
    assert_eq!(lr.shards, 2);
    assert_eq!(mr.set_pulses, lr.set_pulses, "identical programming diff");
    assert_eq!(mr.reset_pulses, lr.reset_pulses);
    assert_eq!(mr.cells_changed, lr.cells_changed);
    compare_wave(&mut rng, &mut mixed, &mut local, 10, n_in, "post-swap");

    // phase C — autoscale cycle: retire parks a slot (the fleet keeps
    // serving through the remote host alone if the local slot rests),
    // spawn reprograms it back onto the post-swap resident network
    let parked = mixed.retire_shard().expect("retire");
    settle(&mut mixed);
    compare_wave(&mut rng, &mut mixed, &mut local, 8, n_in, "post-retire");
    let woken = mixed.spawn_shard().expect("spawn");
    settle(&mut mixed);
    assert_eq!(woken, parked, "the parked slot rejoins, not a fresh one");
    compare_wave(&mut rng, &mut mixed, &mut local, 8, n_in, "post-spawn");

    let events = mixed.take_scale_events();
    assert!(
        events.iter().any(|e| matches!(e.kind, ScaleEventKind::Retire)),
        "retire landed in the scale events"
    );
    assert!(
        events.iter().any(|e| matches!(e.kind, ScaleEventKind::Spawn { fresh: false })),
        "spawn reused the parked slot"
    );
}

/// SIGKILL the shard-host with a wave in flight: every ticket resolves
/// exactly once — drained with correct bits or failed with a typed
/// `remote shard at ..` error — nothing pends forever, and the fleet
/// keeps serving correct results on the surviving local shard.
#[test]
fn seeded_soak_shard_host_kill_resolves_every_ticket_with_typed_errors() {
    let mut host = Host::spawn();
    let mut rng = Pcg32::seeded(0x0dd5);

    let mut fleet = EngineSpec::new(BackendKind::Ideal)
        .with_shards(1, BackendKind::Ideal)
        .with_remote([host.addr.as_str()])
        .build_sharded()
        .expect("mixed fixed fleet");
    let mut truth = EngineSpec::new(BackendKind::Ideal)
        .build_engine()
        .expect("single-engine reference");
    let n_in = fleet.capabilities().n_in;

    // warm-up: both sides of the socket demonstrably serving
    let warm: Vec<Vec<Vec<bool>>> = (0..12).map(|_| random_images(&mut rng, 4, n_in)).collect();
    let wt: Vec<Ticket> = warm
        .iter()
        .map(|b| fleet.submit(b.clone()).expect("warm-up submit"))
        .collect();
    for (k, t) in wt.into_iter().enumerate() {
        let got = redeem(&mut fleet, t).unwrap_or_else(|e| panic!("warm-up batch {k}: {e:#}"));
        let want = truth.infer_batch(&warm[k]).expect("reference");
        assert_eq!(got.bits, want.bits, "warm-up batch {k} bits");
        assert_eq!(got.classes, want.classes, "warm-up batch {k} classes");
    }
    let per = fleet.shard_telemetry();
    assert!(
        per.iter().all(|t| t.batches > 0),
        "warm-up load reached both shards: {:?}",
        per.iter().map(|t| t.batches).collect::<Vec<_>>()
    );

    // soak: a full wave dispatched across both shards, then SIGKILL the
    // host while its half sits in flight
    let batches: Vec<Vec<Vec<bool>>> = (0..24).map(|_| random_images(&mut rng, 4, n_in)).collect();
    let tickets: Vec<Ticket> = batches
        .iter()
        .map(|b| fleet.submit(b.clone()).expect("soak submit"))
        .collect();
    host.child.kill().expect("SIGKILL the shard-host");
    host.child.wait().expect("reap the shard-host");

    let mut okays = 0usize;
    let mut typed_remote = 0usize;
    for (k, t) in tickets.into_iter().enumerate() {
        match redeem(&mut fleet, t) {
            Ok(got) => {
                let want = truth.infer_batch(&batches[k]).expect("reference");
                assert_eq!(got.bits, want.bits, "soak batch {k} bits");
                assert_eq!(got.classes, want.classes, "soak batch {k} classes");
                okays += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("remote shard at") || msg.contains("worker thread"),
                    "soak batch {k}: untyped failure leaked through: {msg}"
                );
                if msg.contains("remote shard at") {
                    typed_remote += 1;
                }
            }
        }
    }
    assert!(typed_remote > 0, "the dying host never surfaced a typed remote error");
    assert!(okays > 0, "the local survivor completed nothing mid-kill");

    // let the event channels drain so the dead shard leaves the rotation
    for _ in 0..20 {
        fleet.wait_event(Duration::from_millis(1));
    }

    // aftermath: the fleet still serves, bit-exact, on the survivor
    let after: Vec<Vec<Vec<bool>>> = (0..8).map(|_| random_images(&mut rng, 4, n_in)).collect();
    let at: Vec<Ticket> = after
        .iter()
        .map(|b| fleet.submit(b.clone()).expect("post-kill submit"))
        .collect();
    for (k, t) in at.into_iter().enumerate() {
        let got = redeem(&mut fleet, t).unwrap_or_else(|e| {
            panic!("aftermath batch {k} failed on the surviving shard: {e:#}")
        });
        let want = truth.infer_batch(&after[k]).expect("reference");
        assert_eq!(got.bits, want.bits, "aftermath batch {k} bits");
        assert_eq!(got.classes, want.classes, "aftermath batch {k} classes");
    }
}

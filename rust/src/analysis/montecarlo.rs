//! Monte Carlo variability engine: device corners + resistance variation
//! swept over the noise-margin and digit-accuracy models (§V–§VI carried
//! to distributions).
//!
//! The deterministic analyses answer "does the nominal design work?"; a
//! PCM process answers in distributions — cell conductances and driver
//! resistance spread lot to lot. Each trial perturbs the design with
//! seeded lognormal factors ([`perturbed_design`], fixed draw order) and
//! re-evaluates the Eq. 7 noise margin; a smaller set of trials replays
//! the digit workload through the parasitic circuit walk at the
//! *nominal* calibration voltage (the driver is trimmed at design time —
//! the perturbed silicon is what it actually drives). Everything is
//! seeded [`Pcg32`] with one stream per trial, *shared across sizes* —
//! every size sees the same process corners, so the sweep is paired and
//! the failure-rate-vs-size curve is monotone by construction — and the
//! whole thing (including its `--json` exhibit form) is
//! byte-deterministic across runs and machines (pinned by
//! `report::montecarlo` snapshot tests and the CI golden-file diff).

use crate::analysis::{noise_margin, ArrayDesign};
use crate::array::{Level, Subarray, TmvmMode, TmvmOutcome};
use crate::interconnect::LineConfig;
use crate::nn::dataset::DigitGen;
use crate::nn::BinaryLayer;
use crate::util::{Pcg32, Summary};

/// Configuration of one Monte Carlo sweep.
#[derive(Clone, Debug)]
pub struct McConfig {
    /// Base seed; every `(size, trial)` pair derives its own PCG stream.
    pub seed: u64,
    /// Noise-margin trials per array size.
    pub trials: usize,
    /// Workload-replay trials per array size (each runs `images` digits
    /// through the parasitic walk — far costlier than an NM evaluation).
    pub accuracy_trials: usize,
    /// Images per workload-replay trial (clamped to the row count).
    pub images: usize,
    /// Array sizes to sweep (`N_row`; columns are fixed).
    pub rows: Vec<usize>,
    /// Columns of every design point.
    pub cols: usize,
    /// Cell length scale (`L = l_scale · L_min`), fixed across sizes so
    /// the sweep isolates the row-count axis.
    pub l_scale: f64,
    /// Lognormal sigma of the device variation (0 = no variation).
    pub sigma: f64,
}

impl Default for McConfig {
    fn default() -> Self {
        Self {
            seed: 0x3d_c0ffee,
            trials: 48,
            accuracy_trials: 6,
            images: 64,
            rows: vec![64, 128, 256, 512, 1024],
            cols: 128,
            l_scale: 3.0,
            sigma: 0.2,
        }
    }
}

/// Distributions gathered for one array size.
#[derive(Clone, Debug, PartialEq)]
pub struct McSizeResult {
    pub n_row: usize,
    pub n_col: usize,
    /// Noise margin of the unperturbed design.
    pub nm_nominal: f64,
    /// Noise-margin distribution over the trials.
    pub nm: Summary,
    /// Trials whose perturbed noise margin closed (`nm ≤ 0`).
    pub nm_failures: usize,
    /// `nm_failures / trials`.
    pub failure_rate: f64,
    /// Digit-classification accuracy distribution over the replay trials.
    pub accuracy: Summary,
    /// RESET-violation fraction across all replay trials (violating
    /// row-steps over total row-steps).
    pub reset_rate: f64,
}

/// Standard normal via Box–Muller (two uniform draws per sample; the
/// `1 - u` flip keeps `ln` off exactly zero).
fn gaussian(rng: &mut Pcg32) -> f64 {
    let u1 = 1.0 - rng.next_f64();
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Multiplicative lognormal variation factor `exp(sigma · N(0,1))`.
fn lognormal(rng: &mut Pcg32, sigma: f64) -> f64 {
    (sigma * gaussian(rng)).exp()
}

/// One device-corner draw: the base design with cell conductances and
/// driver resistance scaled by independent lognormal factors.
///
/// Draw order is part of the determinism contract: `g_c`, then `g_a`,
/// then `r_driver` — three `gaussian` samples off `rng` in that order.
pub fn perturbed_design(base: &ArrayDesign, sigma: f64, rng: &mut Pcg32) -> ArrayDesign {
    let mut d = base.clone();
    d.device.g_c *= lognormal(rng, sigma);
    d.device.g_a *= lognormal(rng, sigma);
    d.r_driver *= lognormal(rng, sigma);
    d
}

/// First-max-wins argmax over per-class currents — the same tie-break as
/// [`crate::nn::argmax_counts`], carried into current space.
fn argmax_f64(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Replay `samples` through `layer` on one perturbed subarray at the
/// nominal calibration voltage; returns (correct, reset-violating
/// row-steps, total row-steps).
fn replay_trial(
    layer: &BinaryLayer,
    design: &ArrayDesign,
    v_dd: f64,
    samples: &[crate::nn::dataset::Sample],
) -> (usize, usize, usize) {
    let mut sa = Subarray::new(design.clone());
    let m = samples.len();
    let mut grid = vec![vec![false; sa.n_col()]; sa.n_row()];
    for (i, s) in samples.iter().enumerate() {
        grid[i][..layer.n_in()].copy_from_slice(&s.pixels);
    }
    sa.program_level(Level::Top, &grid);

    let mut steps = Vec::with_capacity(layer.n_out());
    for (p, w) in layer.weights.iter().enumerate() {
        let mut inputs = vec![false; sa.n_col()];
        inputs[..layer.n_in()].copy_from_slice(w);
        steps.push(sa.tmvm_rows(&inputs, p, v_dd, TmvmMode::Parasitic, m));
    }

    let mut correct = 0;
    let mut currents = vec![0.0; layer.n_out()];
    for (i, s) in samples.iter().enumerate() {
        for (p, step) in steps.iter().enumerate() {
            currents[p] = step.currents[i];
        }
        if argmax_f64(&currents) == s.label {
            correct += 1;
        }
    }
    let violations = steps
        .iter()
        .flat_map(|s| &s.outcomes[..m])
        .filter(|o| matches!(o, TmvmOutcome::ResetViolation))
        .count();
    (correct, violations, layer.n_out() * m)
}

/// Run the sweep: for every array size, `trials` noise-margin draws and
/// `accuracy_trials` full workload replays under device variation.
pub fn variability_sweep(
    cfg: &McConfig,
    layer: &BinaryLayer,
) -> crate::Result<Vec<McSizeResult>> {
    anyhow::ensure!(!cfg.rows.is_empty(), "montecarlo needs at least one array size");
    anyhow::ensure!(cfg.trials >= 1, "montecarlo needs at least one trial");
    anyhow::ensure!(cfg.sigma >= 0.0, "variation sigma must be non-negative");
    anyhow::ensure!(
        layer.n_in() <= cfg.cols && layer.n_out() <= cfg.cols,
        "layer {}×{} does not fit {} columns",
        layer.n_out(),
        layer.n_in(),
        cfg.cols
    );

    // one shared workload: accuracy variation comes from the device
    // perturbation alone, not from resampled digits
    let samples = DigitGen::new(cfg.seed ^ 0x5eed).dataset(cfg.images.max(1)).samples;

    let mut out = Vec::with_capacity(cfg.rows.len());
    for &n_row in &cfg.rows {
        anyhow::ensure!(n_row >= 1, "array size must be at least one row");
        let base = ArrayDesign::new(n_row, cfg.cols, LineConfig::config3(), cfg.l_scale, 1.0)
            .with_span(layer.n_in().clamp(1, cfg.cols));
        let nm_nominal = noise_margin(&base).noise_margin();
        // the driver is trimmed against the nominal design once; every
        // perturbed trial is driven at this same calibration voltage
        let v_dd = Subarray::new(base.clone()).vdd_for_threshold(layer.theta);

        let mut nms = Vec::with_capacity(cfg.trials);
        let mut nm_failures = 0usize;
        for trial in 0..cfg.trials {
            // stream = trial (not size × trial): every size re-draws the
            // same corner, pairing the sweep across the size axis
            let mut rng = Pcg32::new(cfg.seed, trial as u64);
            let d = perturbed_design(&base, cfg.sigma, &mut rng);
            let nm = noise_margin(&d).noise_margin();
            if nm <= 0.0 {
                nm_failures += 1;
            }
            nms.push(nm);
        }

        let m = samples.len().min(n_row);
        let mut accs = Vec::with_capacity(cfg.accuracy_trials);
        let mut violations = 0usize;
        let mut row_steps = 0usize;
        for trial in 0..cfg.accuracy_trials {
            // replay streams live far above the NM streams so growing
            // `trials` never re-seeds them; like the NM streams they are
            // shared across sizes (paired corners)
            let mut rng = Pcg32::new(cfg.seed, (1u64 << 32) + trial as u64);
            let d = perturbed_design(&base, cfg.sigma, &mut rng);
            let (correct, viol, total) = replay_trial(layer, &d, v_dd, &samples[..m]);
            accs.push(correct as f64 / m as f64);
            violations += viol;
            row_steps += total;
        }

        out.push(McSizeResult {
            n_row,
            n_col: cfg.cols,
            nm_nominal,
            nm: Summary::of(&nms).expect("trials >= 1"),
            nm_failures,
            failure_rate: nm_failures as f64 / cfg.trials as f64,
            accuracy: Summary::of(&accs).unwrap_or(Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
            }),
            reset_rate: if row_steps == 0 {
                0.0
            } else {
                violations as f64 / row_steps as f64
            },
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::table2::template_layer;

    fn small_cfg() -> McConfig {
        McConfig {
            trials: 16,
            accuracy_trials: 2,
            images: 16,
            rows: vec![64, 256],
            ..McConfig::default()
        }
    }

    #[test]
    fn sweep_is_seed_deterministic() {
        let cfg = small_cfg();
        let layer = template_layer();
        let a = variability_sweep(&cfg, &layer).unwrap();
        let b = variability_sweep(&cfg, &layer).unwrap();
        assert_eq!(a, b, "same seed, same distributions — bit for bit");
        let c = variability_sweep(&McConfig { seed: 1, ..cfg }, &layer).unwrap();
        assert_ne!(a, c, "a different seed draws different corners");
    }

    #[test]
    fn zero_sigma_collapses_to_the_nominal_design() {
        let cfg = McConfig {
            sigma: 0.0,
            ..small_cfg()
        };
        let layer = template_layer();
        for r in variability_sweep(&cfg, &layer).unwrap() {
            assert_eq!(r.nm.std, 0.0, "no variation, no spread");
            assert_eq!(r.nm.min, r.nm_nominal);
            assert_eq!(r.nm.max, r.nm_nominal);
            assert_eq!(r.nm_failures, 0);
            assert_eq!(r.accuracy.std, 0.0);
        }
    }

    #[test]
    fn margins_degrade_with_array_size() {
        let cfg = small_cfg();
        let rows = variability_sweep(&cfg, &template_layer()).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(
            rows[1].nm_nominal < rows[0].nm_nominal,
            "more rows, thinner margin: {} vs {}",
            rows[1].nm_nominal,
            rows[0].nm_nominal
        );
        assert!(
            rows[1].nm.p50 < rows[0].nm.p50,
            "the whole distribution shifts down with size"
        );
        assert!(rows[1].failure_rate >= rows[0].failure_rate);
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.failure_rate));
            assert!((0.0..=1.0).contains(&r.reset_rate));
            assert!(r.accuracy.min >= 0.0 && r.accuracy.max <= 1.0);
        }
        // the small nominal-NM design classifies digits well even under
        // 20% lognormal variation
        assert!(
            rows[0].accuracy.mean > 0.8,
            "accuracy collapsed: {}",
            rows[0].accuracy.mean
        );
    }

    #[test]
    fn perturbation_draw_order_is_pinned() {
        let base = ArrayDesign::new(64, 128, LineConfig::config3(), 3.0, 1.0);
        let mut rng = Pcg32::new(7, 7);
        let d = perturbed_design(&base, 0.2, &mut rng);
        // replicate by hand from a fresh copy of the stream
        let mut raw = Pcg32::new(7, 7);
        let f_gc = lognormal(&mut raw, 0.2);
        let f_ga = lognormal(&mut raw, 0.2);
        let f_rd = lognormal(&mut raw, 0.2);
        assert_eq!(d.device.g_c.to_bits(), (base.device.g_c * f_gc).to_bits());
        assert_eq!(d.device.g_a.to_bits(), (base.device.g_a * f_ga).to_bits());
        assert_eq!(d.r_driver.to_bits(), (base.r_driver * f_rd).to_bits());
    }
}

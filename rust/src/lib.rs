//! # xpoint-imc — 3D XPoint as an in-memory computing accelerator
//!
//! A device/circuit/architecture simulator stack reproducing
//! *"Exploring the Feasibility of Using 3D XPoint as an In-Memory Computing
//! Accelerator"* (Zabihi et al., 2021).
//!
//! The library is organized bottom-up:
//!
//! * [`util`] / [`testing`] — self-contained substrates (PRNG, stats, table
//!   rendering, CSV/JSON output, a mini property-testing framework). The
//!   build is fully offline, so these replace `rand`, `criterion` and
//!   `proptest`.
//! * [`device`] — PCM + OTS compact models (paper Fig. 2, Table IV): state,
//!   partial crystallization, SET/RESET pulse dynamics.
//! * [`circuit`] — a generic resistive-network substrate: netlist builder,
//!   modified-nodal-analysis solver (dense LU with a banded fast path), and
//!   numeric Thevenin extraction. Used to *validate* the paper's analytic
//!   parasitic model against full circuit simulation.
//! * [`interconnect`] — ASAP7 metal/via tables (Tables V–VI) and the three
//!   wire configurations of Table I.
//! * [`analysis`] — the paper's core contribution: the recursive
//!   `R_th`/`α_th` Thevenin model (Appendix A), the ideal voltage windows
//!   (Eqs. 4–5), the noise margin (Eq. 7), acceptable-region geometry and
//!   maximum-subarray-size search.
//! * [`array`] — the 3D XPoint subarray state machine and the TMVM
//!   (thresholded matrix–vector multiply) engine, in both ideal (Eq. 3) and
//!   parasitic-aware modes, with energy/latency/area accounting and the two
//!   multi-bit schemes of Table III.
//! * [`scaling`] — inter-subarray links (BL-to-BL and BL-to-WLT, Fig. 6) and
//!   matrix tiling across subarrays.
//! * [`fabric`] — the multi-subarray fabric simulator: a discrete-event
//!   model of a grid of interconnected subarrays executing multi-layer
//!   networks tiled across the grid, with image-level pipelining,
//!   per-subarray occupancy, interlink traffic/latency and energy — plus
//!   `FabricBackend`, which lets the coordinator serve a whole fabric.
//! * [`nn`] — the binary neural-network mapping (Figs. 4 and 8), the
//!   synthetic 11×11 digit workload, and a conv2d-as-TMVM lowering.
//! * [`runtime`] — PJRT client wrapper (via the `xla` crate) that loads the
//!   AOT-compiled JAX/Pallas artifacts (`artifacts/*.hlo.txt`) and serves as
//!   the functional golden model on the rust side.
//! * [`coordinator`] — the L3 serving shell: request batching, subarray
//!   scheduling (`⌊N_row/P⌋` images per computational step), worker threads
//!   and metrics.
//! * [`report`] — each paper exhibit (Fig. 10/11/13, Tables I–III) as a
//!   library function returning structured rows, shared by benches, examples
//!   and the CLI.
//!
//! See `examples/quickstart.rs` for a runnable end-to-end tour.

pub mod util;
pub mod testing;
pub mod device;
pub mod circuit;
pub mod interconnect;
pub mod analysis;
pub mod array;
pub mod scaling;
pub mod fabric;
pub mod nn;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod cli;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

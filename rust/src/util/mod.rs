//! Self-contained utility substrates (offline build: no `rand`, no `serde`).

pub mod prng;
pub mod stats;
pub mod table;
pub mod si;
pub mod io;
pub mod json;

pub use json::Json;
pub use prng::{Pcg32, SplitMix64};
pub use stats::Summary;
pub use table::Table;

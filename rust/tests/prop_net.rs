//! Property tests for the multi-host wire protocol: for **arbitrary**
//! messages — any variant, any payload shape — a frame roundtrips
//! bit-exactly through encode → decode, and no corruption of the bytes
//! (truncation, oversized lengths, version skew, flipped bits, pure
//! garbage) ever panics or over-allocates: every rejection is a typed
//! [`WireError`].

use xpoint_imc::engine::{
    BackendKind, Capabilities, InferenceResult, SwapReport, Telemetry,
};
use xpoint_imc::net::wire::TAG_INFER_PACKED;
use xpoint_imc::net::{
    read_frame, Msg, WireError, MAGIC, MAX_FRAME, MIN_PROTOCOL_VERSION, PROTOCOL_VERSION,
};
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

// ------------------------------------------------------- arbitrary data

fn arbitrary_kind(rng: &mut Pcg32) -> BackendKind {
    *rng.choose(&[
        BackendKind::Ideal,
        BackendKind::Parasitic,
        BackendKind::Fabric,
        BackendKind::Xla,
        BackendKind::Sharded,
        BackendKind::Remote,
    ])
}

fn arbitrary_caps(rng: &mut Pcg32) -> Capabilities {
    Capabilities {
        kind: arbitrary_kind(rng),
        n_in: rng.range(1, 200),
        n_out: rng.range(1, 40),
        max_batch: rng.range(1, 2000),
        nodes: rng.range(1, 64),
        tiles: rng.range(0, 64),
        shards: rng.range(1, 8),
        reports_energy: rng.bernoulli(0.5),
        pipelined: rng.bernoulli(0.5),
    }
}

fn arbitrary_telemetry(rng: &mut Pcg32) -> Telemetry {
    Telemetry {
        batches: rng.next_u64() >> 40,
        images: rng.next_u64() >> 40,
        steps: rng.next_u64() >> 40,
        sim_time: rng.range_f64(0.0, 1e3),
        energy: rng.range_f64(0.0, 1e3),
        compute_energy: rng.range_f64(0.0, 1e3),
        link_energy: rng.range_f64(0.0, 1e3),
        cycles: rng.next_u64() >> 40,
        link_transfers: rng.next_u64() >> 40,
        link_lines: rng.next_u64() >> 40,
        swaps: rng.range(0, 100) as u64,
        program_time: rng.range_f64(0.0, 1e3),
        program_energy: rng.range_f64(0.0, 1e3),
        wear_pulses: rng.next_u64() >> 40,
        multibit_energy: rng.range_f64(0.0, 1e3),
        utilization: (0..rng.range(0, 6)).map(|_| rng.range_f64(0.0, 1.0)).collect(),
        // wire v2 does not carry margin telemetry; the decoder always
        // reports the no-margin state, so the roundtrip pins +∞ here
        margin_min: f64::INFINITY,
    }
}

fn arbitrary_bits(rng: &mut Pcg32, n: usize) -> Vec<bool> {
    (0..n).map(|_| rng.bernoulli(0.5)).collect()
}

fn arbitrary_images(rng: &mut Pcg32) -> Vec<Vec<bool>> {
    // ragged on purpose: the wire carries rows independently and the
    // engine, not the protocol, owns shape policy
    (0..rng.range(0, 6))
        .map(|_| arbitrary_bits(rng, rng.range(0, 40)))
        .collect()
}

fn arbitrary_uniform_images(rng: &mut Pcg32) -> Vec<Vec<bool>> {
    // rectangular with width >= 1: the shape the v2 packed infer
    // encoding applies to (widths straddle the u64-lane and byte
    // boundaries the packers must mask correctly)
    let w = rng.range(1, 80);
    (0..rng.range(1, 8)).map(|_| arbitrary_bits(rng, w)).collect()
}

fn arbitrary_result(rng: &mut Pcg32) -> InferenceResult {
    let n = rng.range(0, 6);
    InferenceResult {
        bits: (0..n).map(|_| arbitrary_bits(rng, rng.range(0, 24))).collect(),
        classes: (0..n).map(|_| rng.range(0, 10)).collect(),
        sim_time: rng.range_f64(0.0, 1.0),
        energy: rng.range_f64(0.0, 1.0),
        steps: rng.range(0, 1000) as u64,
    }
}

fn arbitrary_report(rng: &mut Pcg32) -> SwapReport {
    SwapReport {
        set_pulses: rng.next_u64() >> 40,
        reset_pulses: rng.next_u64() >> 40,
        cells_changed: rng.next_u64() >> 40,
        cells_total: rng.next_u64() >> 40,
        time: rng.range_f64(0.0, 10.0),
        energy: rng.range_f64(0.0, 10.0),
        shards: rng.range(1, 8),
    }
}

fn arbitrary_layers(rng: &mut Pcg32) -> Vec<BinaryLayer> {
    (0..rng.range(1, 4))
        .map(|_| {
            let n_out = rng.range(1, 8);
            let n_in = rng.range(1, 24);
            let weights = (0..n_out).map(|_| arbitrary_bits(rng, n_in)).collect();
            BinaryLayer::new(weights, rng.range(1, n_in + 1))
        })
        .collect()
}

fn arbitrary_msg(rng: &mut Pcg32) -> Msg {
    match rng.range(0, 11) {
        0 => Msg::Hello { magic: MAGIC },
        1 => Msg::HelloOk {
            caps: arbitrary_caps(rng),
            telemetry: arbitrary_telemetry(rng),
        },
        2 => Msg::Infer {
            id: rng.next_u64(),
            images: arbitrary_images(rng),
        },
        3 => Msg::InferOk {
            id: rng.next_u64(),
            result: arbitrary_result(rng),
            telemetry: arbitrary_telemetry(rng),
        },
        4 => Msg::Swap {
            target: arbitrary_layers(rng),
        },
        5 => Msg::SwapOk {
            report: arbitrary_report(rng),
            telemetry: arbitrary_telemetry(rng),
        },
        6 => Msg::Telemetry,
        7 => Msg::TelemetryOk {
            telemetry: arbitrary_telemetry(rng),
        },
        8 => Msg::Err {
            detail: format!("remote shard exploded {}×", rng.range(0, 1_000_000)),
        },
        9 => Msg::Shutdown,
        _ => Msg::ShutdownOk,
    }
}

// ------------------------------------------------------------ properties

#[test]
fn every_message_roundtrips_bit_exactly() {
    forall(
        Config::default().cases(400),
        "wire roundtrip",
        |rng: &mut Pcg32| {
            let msg = arbitrary_msg(rng);
            let frame = msg.to_frame().map_err(|e| format!("encode: {e}"))?;
            let decoded = read_frame(&mut &frame[..])
                .map_err(|e| format!("decode {}: {e}", msg.name()))?
                .ok_or_else(|| "decode: clean EOF on a full frame".to_string())?;
            if decoded == msg {
                Ok(())
            } else {
                Err(format!("{} changed across the wire", msg.name()))
            }
        },
    );
}

#[test]
fn uniform_batches_roundtrip_through_the_packed_encoding() {
    forall(
        Config::default().cases(300),
        "wire packed roundtrip",
        |rng: &mut Pcg32| {
            let images = arbitrary_uniform_images(rng);
            let (n, w) = (images.len(), images[0].len());
            let msg = Msg::Infer {
                id: rng.next_u64(),
                images,
            };
            let frame = msg.to_frame().map_err(|e| format!("encode: {e}"))?;
            if frame[5] != TAG_INFER_PACKED {
                return Err(format!("uniform {n}x{w} batch took tag {}", frame[5]));
            }
            // header + id + n + width + the bits themselves, nothing more
            let want = 6 + 24 + (n * w).div_ceil(8);
            if frame.len() != want {
                return Err(format!(
                    "packed frame is {} bytes for {n}x{w} bits (want {want})",
                    frame.len()
                ));
            }
            let decoded = read_frame(&mut &frame[..])
                .map_err(|e| format!("decode: {e}"))?
                .ok_or_else(|| "clean EOF on a full frame".to_string())?;
            if decoded == msg {
                Ok(())
            } else {
                Err(format!("packed {n}x{w} infer changed across the wire"))
            }
        },
    );
}

#[test]
fn packed_frames_truncate_to_typed_errors() {
    forall(
        Config::default().cases(200),
        "wire packed truncation",
        |rng: &mut Pcg32| {
            let msg = Msg::Infer {
                id: rng.next_u64(),
                images: arbitrary_uniform_images(rng),
            };
            let frame = msg.to_frame().map_err(|e| format!("encode: {e}"))?;
            let cut = rng.range(1, frame.len()); // strictly inside the frame
            match read_frame(&mut &frame[..cut]) {
                Err(WireError::Truncated { .. }) => Ok(()),
                other => Err(format!("cut {cut}/{}: {other:?}", frame.len())),
            }
        },
    );
}

#[test]
fn truncation_at_any_byte_is_a_typed_error_never_a_panic() {
    forall(
        Config::default().cases(200),
        "wire truncation",
        |rng: &mut Pcg32| {
            let msg = arbitrary_msg(rng);
            let frame = msg.to_frame().map_err(|e| format!("encode: {e}"))?;
            let cut = rng.range(0, frame.len()); // strictly shorter
            match read_frame(&mut &frame[..cut]) {
                // no bytes at all is a clean end-of-stream
                Ok(None) if cut == 0 => Ok(()),
                Ok(None) => Err(format!("cut at {cut}/{} read as clean EOF", frame.len())),
                Ok(Some(m)) => Err(format!(
                    "cut at {cut}/{} still decoded a {}",
                    frame.len(),
                    m.name()
                )),
                // Truncated is the honest answer; a cut that lands inside
                // a length-prefixed payload may also surface as Malformed
                Err(WireError::Truncated { .. }) | Err(WireError::Malformed(_)) => Ok(()),
                Err(e) => Err(format!("cut at {cut}: unexpected error kind {e}")),
            }
        },
    );
}

#[test]
fn random_garbage_never_panics_the_decoder() {
    forall(
        Config::default().cases(400),
        "wire garbage",
        |rng: &mut Pcg32| {
            let n = rng.range(0, 96);
            let mut bytes: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            // keep announced lengths small so the property stays fast —
            // hostile *large* lengths get their own test below
            if bytes.len() >= 4 {
                bytes[2] = 0;
                bytes[3] = 0;
            }
            // must return *something* without panicking or allocating wild
            let _ = read_frame(&mut &bytes[..]);
            Ok(())
        },
    );
}

#[test]
fn corrupted_valid_frames_never_panic() {
    forall(
        Config::default().cases(400),
        "wire bit flips",
        |rng: &mut Pcg32| {
            let msg = arbitrary_msg(rng);
            let mut frame = msg.to_frame().map_err(|e| format!("encode: {e}"))?;
            // flip a handful of random bits anywhere in the frame; cap the
            // length prefix so a flipped length cannot demand a huge body
            for _ in 0..rng.range(1, 6) {
                let i = rng.range(0, frame.len());
                frame[i] ^= 1 << rng.range(0, 8);
            }
            frame[2] = 0;
            frame[3] = 0;
            let _ = read_frame(&mut &frame[..]);
            Ok(())
        },
    );
}

#[test]
fn oversized_lengths_are_rejected_up_front() {
    forall(
        Config::default().cases(100),
        "wire oversized",
        |rng: &mut Pcg32| {
            let over = MAX_FRAME + 1 + (rng.next_u64() % 1_000_000);
            let mut bytes = (over.min(u32::MAX as u64) as u32).to_le_bytes().to_vec();
            bytes.extend_from_slice(&[PROTOCOL_VERSION, 1]);
            match read_frame(&mut &bytes[..]) {
                Err(WireError::Oversized { len, max }) => {
                    if len > max && max == MAX_FRAME {
                        Ok(())
                    } else {
                        Err(format!("odd oversized report: len={len} max={max}"))
                    }
                }
                other => Err(format!("expected Oversized, got {other:?}")),
            }
        },
    );
}

#[test]
fn version_skew_is_reported_as_version_mismatch() {
    forall(
        Config::default().cases(100),
        "wire version skew",
        |rng: &mut Pcg32| {
            let msg = arbitrary_msg(rng);
            let mut frame = msg.to_frame().map_err(|e| format!("encode: {e}"))?;
            let bogus = loop {
                let v = rng.next_u32() as u8;
                // both accepted versions must be excluded: v1 frames
                // still decode, they are not version skew
                if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&v) {
                    break v;
                }
            };
            frame[4] = bogus; // version byte sits right after the length
            match read_frame(&mut &frame[..]) {
                Err(WireError::Version { got, want }) => {
                    if got == bogus && want == PROTOCOL_VERSION {
                        Ok(())
                    } else {
                        Err(format!("wrong versions in report: got={got} want={want}"))
                    }
                }
                other => Err(format!("expected Version error, got {other:?}")),
            }
        },
    );
}

#[test]
fn trailing_bytes_after_a_payload_are_malformed() {
    forall(
        Config::default().cases(100),
        "wire trailing bytes",
        |rng: &mut Pcg32| {
            let msg = arbitrary_msg(rng);
            let mut frame = msg.to_frame().map_err(|e| format!("encode: {e}"))?;
            // graft extra payload bytes on and fix the length prefix
            let extra = rng.range(1, 9);
            frame.extend((0..extra).map(|_| rng.next_u32() as u8));
            let body_len = (frame.len() - 4) as u32;
            frame[..4].copy_from_slice(&body_len.to_le_bytes());
            match read_frame(&mut &frame[..]) {
                Err(WireError::Malformed(_)) => Ok(()),
                // a grafted byte can also masquerade as a longer inner
                // count and then run out of bytes — still typed, still fine
                Err(WireError::Truncated { .. }) => Ok(()),
                Ok(Some(m)) => Err(format!(
                    "{extra} trailing bytes silently accepted on {}",
                    m.name()
                )),
                other => Err(format!("unexpected outcome: {other:?}")),
            }
        },
    );
}

//! Bonus exhibit (paper Fig. 2(a)): PCM SET/RESET transition dynamics from
//! the behavioural electro-thermal model, plus device-model microbenches.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, black_box, exhibit_header};
use xpoint_imc::device::{DeviceParams, PcmCell};
use xpoint_imc::util::Table;

fn main() {
    exhibit_header("Device dynamics — PCM SET/RESET transitions (paper Fig. 2(a))");
    let p = DeviceParams::default();

    let mut t = Table::new("SET pulse (50 µA, 80 ns) from amorphous — crystalline fraction")
        .header(&["t/t_SET", "cryst frac", "G (S)"]);
    let mut c = PcmCell::new();
    for step in 0..=8 {
        if step > 0 {
            c.apply_current_pulse(&p, p.i_set, p.t_set / 8.0, 8);
        }
        t.row(&[
            format!("{:.2}", step as f64 / 8.0),
            format!("{:.3}", c.cryst_frac()),
            format!("{:.2e}", c.conductance(&p)),
        ]);
    }
    print!("{}", t.render());

    let mut t = Table::new("RESET pulse (100 µA, 15 ns) from crystalline")
        .header(&["t/t_RESET", "cryst frac", "G (S)"]);
    let mut c = PcmCell::with_bit(true);
    for step in 0..=5 {
        if step > 0 {
            c.apply_current_pulse(&p, p.i_reset, p.t_reset / 5.0, 8);
        }
        t.row(&[
            format!("{:.2}", step as f64 / 5.0),
            format!("{:.3}", c.cryst_frac()),
            format!("{:.2e}", c.conductance(&p)),
        ]);
    }
    print!("{}", t.render());

    println!();
    bench("set_pulse (32 substeps)", || {
        let mut c = PcmCell::new();
        black_box(c.set_pulse(&p));
    });
    bench("conductance (log-interp)", || {
        let c = PcmCell::with_bit(true);
        black_box(c.conductance(&p));
    });
}

//! Paper Fig. 11: (a) first/last-row voltage windows, (b) the acceptable
//! region boundary in the (α_th, R_th) plane.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, black_box, exhibit_header};
use xpoint_imc::analysis::{noise_margin, ArrayDesign};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::report::fig11_regions;
use xpoint_imc::util::si::{format_pct, format_si};
use xpoint_imc::util::Table;

fn main() {
    exhibit_header("Paper Fig. 11 — voltage windows and acceptable region");

    let mut t = Table::new("Fig. 11(a) — windows per design (config 1, N_col = 128)")
        .header(&["N_row", "first row", "last row", "overlap", "NM"]);
    for n_row in [64usize, 256, 1024, 4096] {
        let d = ArrayDesign::new(n_row, 128, LineConfig::config1(), 4.0, 1.0);
        let data = fig11_regions(&d, &[]);
        let window = match data.window {
            Some((lo, hi)) => format!("[{}, {}]", format_si(lo, "V"), format_si(hi, "V")),
            None => "∅ (unacceptable)".to_string(),
        };
        t.row(&[
            n_row.to_string(),
            format!(
                "[{}, {}]",
                format_si(data.v_min_first, "V"),
                format_si(data.v_max_first, "V")
            ),
            format!(
                "[{}, {}]",
                format_si(data.v_min_last, "V"),
                format_si(data.v_max_last, "V")
            ),
            window,
            format_pct(data.nm),
        ]);
    }
    print!("{}", t.render());

    let d = ArrayDesign::new(64, 128, LineConfig::config1(), 4.0, 1.0);
    let samples: Vec<f64> = (0..=10).map(|i| i as f64 * 4e3).collect();
    let data = fig11_regions(&d, &samples);
    let mut t = Table::new("Fig. 11(b) — NM = 0 separating line (below = acceptable)")
        .header(&["R_th", "alpha boundary"]);
    for (r, a) in &data.boundary {
        t.row(&[format_si(*r, "Ω"), format!("{a:.3}")]);
    }
    print!("{}", t.render());

    println!();
    bench("noise_margin(1024x128)", || {
        let d = ArrayDesign::new(1024, 128, LineConfig::config1(), 4.0, 1.0);
        black_box(noise_margin(&d));
    });
}

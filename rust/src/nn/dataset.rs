//! Synthetic 11×11 digit dataset.
//!
//! The paper evaluates on MNIST scaled to 11×11 (after [27]); this
//! environment has no network access, so the workload is a *procedural*
//! digit set with the same dimensions: 10 stroke-rendered glyph templates,
//! augmented by ±1-pixel shifts and salt-and-pepper noise.
//!
//! **Cross-language determinism:** generation consumes a SplitMix64 stream
//! in a fixed draw order (label, dx, dy, then 121 noise draws), and the
//! exact same generator is implemented in `python/compile/dataset.py` — the
//! rust simulator and the JAX golden model see bit-identical data for a
//! given seed without shipping a dataset file.

use crate::util::SplitMix64;

/// Image side length (pixels).
pub const IMAGE_SIDE: usize = 11;
/// Pixels per image.
pub const IMAGE_PIXELS: usize = IMAGE_SIDE * IMAGE_SIDE;
/// Digit classes.
pub const N_CLASSES: usize = 10;

/// 11×11 glyph templates ('#' = 1). Mirrored verbatim in
/// `python/compile/dataset.py` — keep the two in sync.
pub const GLYPHS: [[&str; IMAGE_SIDE]; N_CLASSES] = [
    [
        "...#####...",
        "..##...##..",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        ".##.....##.",
        "..##...##..",
        "...#####...",
    ],
    [
        ".....##....",
        "....###....",
        "...####....",
        ".....##....",
        ".....##....",
        ".....##....",
        ".....##....",
        ".....##....",
        ".....##....",
        "...######..",
        "...######..",
    ],
    [
        "..######...",
        ".##....##..",
        ".......##..",
        ".......##..",
        "......##...",
        ".....##....",
        "....##.....",
        "...##......",
        "..##.......",
        ".#########.",
        ".#########.",
    ],
    [
        "..######...",
        ".##....##..",
        ".......##..",
        ".......##..",
        "...#####...",
        "...#####...",
        ".......##..",
        ".......##..",
        ".##....##..",
        "..######...",
        "...........",
    ],
    [
        ".....###...",
        "....####...",
        "...##.##...",
        "..##..##...",
        ".##...##...",
        ".#########.",
        ".#########.",
        "......##...",
        "......##...",
        "......##...",
        "...........",
    ],
    [
        ".########..",
        ".##........",
        ".##........",
        ".##........",
        ".#######...",
        ".......##..",
        ".......##..",
        ".......##..",
        ".##....##..",
        "..######...",
        "...........",
    ],
    [
        "...#####...",
        "..##.......",
        ".##........",
        ".##........",
        ".#######...",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        "..######...",
        "...........",
    ],
    [
        ".#########.",
        ".#########.",
        ".......##..",
        "......##...",
        ".....##....",
        ".....##....",
        "....##.....",
        "....##.....",
        "...##......",
        "...##......",
        "...........",
    ],
    [
        "..######...",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        "..######...",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        "..######...",
        "...........",
    ],
    [
        "..######...",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        ".##....##..",
        "..#######..",
        ".......##..",
        ".......##..",
        "......##...",
        "..#####....",
        "...........",
    ],
];

/// One labelled sample.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Sample {
    /// Row-major 11×11 binary pixels.
    pub pixels: Vec<bool>,
    pub label: usize,
}

/// A generated dataset.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub samples: Vec<Sample>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Deterministic digit generator.
#[derive(Clone, Debug)]
pub struct DigitGen {
    stream: SplitMix64,
    /// Per-pixel flip probability.
    pub noise: f64,
}

impl DigitGen {
    /// Standard generator (noise = 0.02), as used by the test corpus.
    pub fn new(seed: u64) -> Self {
        Self {
            stream: SplitMix64::new(seed),
            noise: 0.02,
        }
    }

    /// Template pixel (before augmentation).
    pub fn template_pixel(label: usize, y: usize, x: usize) -> bool {
        GLYPHS[label][y].as_bytes()[x] == b'#'
    }

    /// Generate the next sample. Draw order (must match python):
    /// label, dx∈{-1,0,1}, dy∈{-1,0,1}, then 121 uniform noise draws in
    /// row-major pixel order.
    pub fn next_sample(&mut self) -> Sample {
        let label = self.stream.next_below(N_CLASSES as u64) as usize;
        let dx = self.stream.next_below(3) as isize - 1;
        let dy = self.stream.next_below(3) as isize - 1;
        let mut pixels = Vec::with_capacity(IMAGE_PIXELS);
        for y in 0..IMAGE_SIDE as isize {
            for x in 0..IMAGE_SIDE as isize {
                let (sy, sx) = (y - dy, x - dx);
                let base = if (0..IMAGE_SIDE as isize).contains(&sy)
                    && (0..IMAGE_SIDE as isize).contains(&sx)
                {
                    Self::template_pixel(label, sy as usize, sx as usize)
                } else {
                    false
                };
                let flip = self.stream.next_f64() < self.noise;
                pixels.push(base ^ flip);
            }
        }
        Sample { pixels, label }
    }

    /// Generate `n` samples.
    pub fn dataset(&mut self, n: usize) -> Dataset {
        Dataset {
            samples: (0..n).map(|_| self.next_sample()).collect(),
        }
    }
}

/// The canonical test corpus seed shared with the python compile path.
pub const TEST_SEED: u64 = 0x3d_c0ffee;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glyphs_are_well_formed() {
        for (d, g) in GLYPHS.iter().enumerate() {
            for (y, row) in g.iter().enumerate() {
                assert_eq!(row.len(), IMAGE_SIDE, "digit {d} row {y}");
                assert!(
                    row.bytes().all(|b| b == b'#' || b == b'.'),
                    "digit {d} row {y}"
                );
            }
            // each glyph has a meaningful amount of ink
            let ink: usize = g
                .iter()
                .map(|r| r.bytes().filter(|&b| b == b'#').count())
                .sum();
            assert!(ink > 15 && ink < 80, "digit {d}: ink {ink}");
        }
    }

    #[test]
    fn glyphs_are_mutually_distinct() {
        // pairwise Hamming distance large enough to be separable
        for a in 0..N_CLASSES {
            for b in (a + 1)..N_CLASSES {
                let dist: usize = (0..IMAGE_SIDE)
                    .map(|y| {
                        (0..IMAGE_SIDE)
                            .filter(|&x| {
                                DigitGen::template_pixel(a, y, x)
                                    != DigitGen::template_pixel(b, y, x)
                            })
                            .count()
                    })
                    .sum();
                assert!(dist >= 8, "digits {a} vs {b}: distance {dist}");
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let d1 = DigitGen::new(42).dataset(16);
        let d2 = DigitGen::new(42).dataset(16);
        assert_eq!(d1.samples, d2.samples);
        let d3 = DigitGen::new(43).dataset(16);
        assert_ne!(d1.samples, d3.samples);
    }

    #[test]
    fn samples_resemble_their_template() {
        let mut g = DigitGen::new(7);
        for _ in 0..50 {
            let s = g.next_sample();
            // even after shift+noise, a sample is closer to its own label's
            // glyph family than to a blank image
            let ink = s.pixels.iter().filter(|&&p| p).count();
            assert!(ink > 5, "sample too empty");
            assert!(ink < 100, "sample too full");
        }
    }

    #[test]
    fn labels_cover_all_classes() {
        let ds = DigitGen::new(1).dataset(500);
        let mut seen = [0usize; N_CLASSES];
        for s in &ds.samples {
            seen[s.label] += 1;
        }
        for (d, &n) in seen.iter().enumerate() {
            assert!(n > 20, "digit {d} underrepresented: {n}");
        }
    }

    #[test]
    fn draw_order_is_documented_contract() {
        // Replicate next_sample by hand from the raw stream to pin the
        // cross-language draw order.
        let seed = 99;
        let mut raw = SplitMix64::new(seed);
        let label = raw.next_below(10) as usize;
        let dx = raw.next_below(3) as isize - 1;
        let dy = raw.next_below(3) as isize - 1;
        let mut flips = Vec::new();
        for _ in 0..IMAGE_PIXELS {
            flips.push(raw.next_f64() < 0.02);
        }
        let s = DigitGen::new(seed).next_sample();
        assert_eq!(s.label, label);
        let mut expect = Vec::new();
        for y in 0..11isize {
            for x in 0..11isize {
                let (sy, sx) = (y - dy, x - dx);
                let base = (0..11).contains(&sy)
                    && (0..11).contains(&sx)
                    && DigitGen::template_pixel(label, sy as usize, sx as usize);
                expect.push(base ^ flips[(y * 11 + x) as usize]);
            }
        }
        assert_eq!(s.pixels, expect);
    }
}

//! [`RemoteBackend`] — an [`Engine`] whose fabric lives in another
//! process, reached over TCP or a Unix socket via the [`wire`](super::wire)
//! protocol.
//!
//! The backend is deliberately a *thin proxy*: every [`Engine`] call maps
//! to one request/reply exchange with the `xpoint shard-host` on the
//! other end, so the sharded scheduler, rolling swaps and autoscaling see
//! exactly the per-shard semantics they see in process. Failure policy:
//!
//! * an **application** error (the host's engine refused the request,
//!   reported as [`Msg::Err`]) becomes a typed [`EngineError::Remote`]
//!   and the connection stays usable;
//! * a **transport** error (timeout, reset, EOF mid-frame, protocol
//!   violation) also becomes [`EngineError::Remote`] but additionally
//!   marks the backend unhealthy — [`Engine::healthy`] turns false and a
//!   [`ShardedEngine`](crate::engine::ShardedEngine) routes around the
//!   dead shard.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
#[cfg(unix)]
use std::os::unix::net::UnixStream;
#[cfg(unix)]
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::engine::{
    BackendFactory, BackendKind, Capabilities, Completions, Engine, EngineError,
    InferenceResult, SwapReport, Telemetry, Ticket,
};
use crate::nn::BinaryLayer;

use super::wire::{read_frame, write_frame, Msg, MAGIC};

/// Where a remote shard lives: `host:port` TCP or a `unix:/path` socket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RemoteAddr {
    /// A `host:port` endpoint (resolved at connect time).
    Tcp(String),
    /// A filesystem socket (`unix:` prefix on the CLI/JSON).
    #[cfg(unix)]
    Unix(PathBuf),
}

impl RemoteAddr {
    /// Parse a CLI/JSON address: `unix:<path>` or `<host>:<port>`.
    /// Anything else is the typed [`EngineError::BadRemoteAddr`].
    pub fn parse(s: &str) -> Result<Self, EngineError> {
        if let Some(path) = s.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                if path.is_empty() {
                    return Err(EngineError::BadRemoteAddr(s.to_string()));
                }
                return Ok(Self::Unix(PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(EngineError::BadRemoteAddr(s.to_string()));
            }
        }
        match s.rsplit_once(':') {
            Some((host, port)) if !host.is_empty() && port.parse::<u16>().is_ok() => {
                Ok(Self::Tcp(s.to_string()))
            }
            _ => Err(EngineError::BadRemoteAddr(s.to_string())),
        }
    }

    /// The typed failure for this endpoint.
    pub fn error(&self, detail: impl Into<String>) -> EngineError {
        EngineError::Remote {
            addr: self.to_string(),
            detail: detail.into(),
        }
    }

    /// Connect with retries until `timeout` elapses — a freshly launched
    /// `shard-host` may not be listening yet (its socket file not created,
    /// its port not bound), so refused/absent endpoints are retried on a
    /// short backoff instead of failing the whole fleet build.
    pub(crate) fn connect_stream(&self, timeout: Duration) -> Result<Stream, EngineError> {
        let deadline = Instant::now() + timeout;
        loop {
            let attempt = match self {
                Self::Tcp(hostport) => match hostport.to_socket_addrs() {
                    Ok(mut addrs) => match addrs.next() {
                        Some(sa) => {
                            let left = deadline
                                .saturating_duration_since(Instant::now())
                                .max(Duration::from_millis(1));
                            TcpStream::connect_timeout(&sa, left).map(Stream::Tcp)
                        }
                        None => Err(std::io::Error::new(
                            std::io::ErrorKind::NotFound,
                            "hostname resolved to no address",
                        )),
                    },
                    Err(e) => Err(e),
                },
                #[cfg(unix)]
                Self::Unix(path) => UnixStream::connect(path).map(Stream::Unix),
            };
            match attempt {
                Ok(stream) => return Ok(stream),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(self.error(format!(
                            "connect failed within {:.1}s: {e}",
                            timeout.as_secs_f64()
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }
}

impl std::fmt::Display for RemoteAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Tcp(s) => write!(f, "{s}"),
            #[cfg(unix)]
            Self::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// One connected socket, TCP or Unix, behind a common Read/Write face.
pub(crate) enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    pub(crate) fn set_io_timeout(&self, t: Duration) -> std::io::Result<()> {
        match self {
            Self::Tcp(s) => {
                s.set_read_timeout(Some(t))?;
                s.set_write_timeout(Some(t))
            }
            #[cfg(unix)]
            Self::Unix(s) => {
                s.set_read_timeout(Some(t))?;
                s.set_write_timeout(Some(t))
            }
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Self::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Self::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Self::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Self::Unix(s) => s.flush(),
        }
    }
}

/// An [`Engine`] proxying one remote shard host.
pub struct RemoteBackend {
    addr: RemoteAddr,
    stream: Stream,
    caps: Capabilities,
    /// Host telemetry at connect time — the host may have served other
    /// clients before us, so our counters are deltas against this.
    base: Telemetry,
    /// Latest host telemetry snapshot (piggybacked on every reply).
    latest: Telemetry,
    healthy: bool,
    next_id: u64,
    completions: Completions,
}

impl RemoteBackend {
    /// Connect and handshake. `connect_timeout` bounds the whole
    /// connect-with-retries walk; `io_timeout` bounds every subsequent
    /// socket read/write (a stalled host fails typed instead of hanging
    /// the shard worker forever).
    pub fn connect(
        addr: RemoteAddr,
        connect_timeout: Duration,
        io_timeout: Duration,
    ) -> crate::Result<Self> {
        let mut stream = addr.connect_stream(connect_timeout)?;
        stream
            .set_io_timeout(io_timeout)
            .map_err(|e| addr.error(format!("setting socket timeouts: {e}")))?;
        write_frame(&mut stream, &Msg::Hello { magic: MAGIC })
            .map_err(|e| addr.error(e.to_string()))?;
        let reply = match read_frame(&mut stream) {
            Ok(Some(m)) => m,
            Ok(None) => return Err(addr.error("host closed during handshake").into()),
            Err(e) => return Err(addr.error(e.to_string()).into()),
        };
        let (mut caps, telemetry) = match reply {
            Msg::HelloOk { caps, telemetry } => (caps, telemetry),
            Msg::Err { detail } => return Err(addr.error(detail).into()),
            other => {
                return Err(addr
                    .error(format!("unexpected {} reply to the handshake", other.name()))
                    .into())
            }
        };
        // what the host serves locally (ideal/fabric/...) is its own
        // business; from this side of the wire the shard *is* remote
        caps.kind = BackendKind::Remote;
        Ok(Self {
            addr,
            stream,
            caps,
            base: telemetry.clone(),
            latest: telemetry,
            healthy: true,
            next_id: 0,
            completions: Completions::default(),
        })
    }

    /// The endpoint this backend proxies.
    pub fn addr(&self) -> &RemoteAddr {
        &self.addr
    }

    fn transport_failed(&mut self, detail: String) -> anyhow::Error {
        self.healthy = false;
        self.addr.error(detail).into()
    }

    /// One request/reply exchange. Transport failures poison the
    /// connection (healthy → false).
    fn call(&mut self, msg: &Msg) -> crate::Result<Msg> {
        if !self.healthy {
            return Err(self
                .addr
                .error("connection already failed — shard is out of the pool")
                .into());
        }
        if let Err(e) = write_frame(&mut self.stream, msg) {
            return Err(self.transport_failed(e.to_string()));
        }
        match read_frame(&mut self.stream) {
            Ok(Some(reply)) => Ok(reply),
            Ok(None) => Err(self.transport_failed("connection closed by host".into())),
            Err(e) => Err(self.transport_failed(e.to_string())),
        }
    }

    /// Ask the host process to stop serving and exit (used by tests and
    /// orchestration scripts; a plain drop just closes the connection and
    /// leaves the host accepting).
    pub fn shutdown_host(&mut self) -> crate::Result<()> {
        match self.call(&Msg::Shutdown)? {
            Msg::ShutdownOk => {
                // the host is gone by design; don't route here again
                self.healthy = false;
                Ok(())
            }
            Msg::Err { detail } => Err(self.addr.error(detail).into()),
            other => Err(self.transport_failed(format!(
                "unexpected {} reply to a shutdown order",
                other.name()
            ))),
        }
    }
}

impl Engine for RemoteBackend {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        self.next_id += 1;
        let id = self.next_id;
        let reply = self.call(&Msg::Infer {
            id,
            images: images.to_vec(),
        })?;
        match reply {
            Msg::InferOk {
                id: rid,
                result,
                telemetry,
            } => {
                if rid != id {
                    return Err(self.transport_failed(format!(
                        "desynchronized stream: sent batch {id}, got a reply for {rid}"
                    )));
                }
                self.latest = telemetry;
                Ok(result)
            }
            // the host's engine refused the batch; the connection is fine
            Msg::Err { detail } => Err(self.addr.error(detail).into()),
            other => Err(self.transport_failed(format!(
                "unexpected {} reply to an infer order",
                other.name()
            ))),
        }
    }

    fn max_batch(&self) -> usize {
        self.caps.max_batch
    }

    fn capabilities(&self) -> Capabilities {
        self.caps
    }

    fn telemetry(&self) -> Telemetry {
        // counters are cumulative-since-construction by contract, so
        // subtract the connect-time baseline from the host's counters
        let (l, b) = (&self.latest, &self.base);
        Telemetry {
            batches: l.batches.saturating_sub(b.batches),
            images: l.images.saturating_sub(b.images),
            steps: l.steps.saturating_sub(b.steps),
            sim_time: l.sim_time - b.sim_time,
            energy: l.energy - b.energy,
            compute_energy: l.compute_energy - b.compute_energy,
            link_energy: l.link_energy - b.link_energy,
            cycles: l.cycles.saturating_sub(b.cycles),
            link_transfers: l.link_transfers.saturating_sub(b.link_transfers),
            link_lines: l.link_lines.saturating_sub(b.link_lines),
            swaps: l.swaps.saturating_sub(b.swaps),
            program_time: l.program_time - b.program_time,
            program_energy: l.program_energy - b.program_energy,
            wear_pulses: l.wear_pulses.saturating_sub(b.wear_pulses),
            // v1/v2 hosts never send the field; the decoder pins it to 0
            multibit_energy: l.multibit_energy - b.multibit_energy,
            utilization: l.utilization.clone(),
            // wire v2 does not carry margin telemetry — the decoder pins
            // the no-margin state (+∞, the min-merge identity)
            margin_min: l.margin_min,
        }
    }

    fn submit(&mut self, images: Vec<Vec<bool>>) -> crate::Result<Ticket> {
        let res = self.infer_batch(&images)?;
        Ok(self.completions.push(res))
    }

    fn poll(&mut self, ticket: Ticket) -> crate::Result<Option<InferenceResult>> {
        Ok(Some(self.completions.take(ticket)?))
    }

    fn swap_network(&mut self, target: Vec<BinaryLayer>) -> crate::Result<SwapReport> {
        let reply = self.call(&Msg::Swap { target })?;
        match reply {
            Msg::SwapOk { report, telemetry } => {
                self.latest = telemetry;
                Ok(report)
            }
            Msg::Err { detail } => Err(self.addr.error(detail).into()),
            other => Err(self.transport_failed(format!(
                "unexpected {} reply to a swap order",
                other.name()
            ))),
        }
    }

    fn healthy(&self) -> bool {
        self.healthy
    }
}

/// A [`BackendFactory`] that connects to `addr` on the worker thread that
/// will own the engine — the same late-construction contract the local
/// backends follow.
pub fn remote_factory(
    addr: RemoteAddr,
    connect_timeout: Duration,
    io_timeout: Duration,
) -> BackendFactory {
    Box::new(move || {
        let backend = RemoteBackend::connect(addr, connect_timeout, io_timeout)?;
        Ok(Box::new(backend) as Box<dyn Engine>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addresses_parse_and_render() {
        assert_eq!(
            RemoteAddr::parse("127.0.0.1:9090").unwrap(),
            RemoteAddr::Tcp("127.0.0.1:9090".into())
        );
        assert_eq!(
            RemoteAddr::parse("shard0.rack1:443").unwrap().to_string(),
            "shard0.rack1:443"
        );
        #[cfg(unix)]
        {
            let a = RemoteAddr::parse("unix:/tmp/xpoint-s0.sock").unwrap();
            assert_eq!(a, RemoteAddr::Unix(PathBuf::from("/tmp/xpoint-s0.sock")));
            assert_eq!(a.to_string(), "unix:/tmp/xpoint-s0.sock");
        }
    }

    #[test]
    fn bad_addresses_are_typed_errors() {
        for bad in ["", "nonsense", "host:", "host:notaport", ":9090", "host:70000", "unix:"] {
            assert_eq!(
                RemoteAddr::parse(bad).unwrap_err(),
                EngineError::BadRemoteAddr(bad.to_string()),
                "{bad}"
            );
        }
    }

    #[test]
    fn endpoint_errors_carry_the_address() {
        let e = RemoteAddr::parse("10.0.0.7:9090").unwrap().error("timed out");
        assert_eq!(
            e.to_string(),
            "remote shard at 10.0.0.7:9090: timed out"
        );
        // the rendering lifts back into the typed variant (the sharded
        // engine's worker channel carries errors as strings)
        assert_eq!(EngineError::parse_remote(&e.to_string()), Some(e));
    }

    #[test]
    fn connect_to_nowhere_times_out_typed() {
        // port 1 on localhost: refused (or filtered) — either way the
        // bounded retry loop must end in a typed Remote error
        let addr = RemoteAddr::Tcp("127.0.0.1:1".into());
        let err = addr
            .connect_stream(Duration::from_millis(120))
            .map(|_| ())
            .unwrap_err();
        match err {
            EngineError::Remote { addr, detail } => {
                assert_eq!(addr, "127.0.0.1:1");
                assert!(detail.contains("connect failed"), "{detail}");
            }
            other => panic!("expected Remote, got {other}"),
        }
    }
}

//! END-TO-END driver: the full three-layer stack on the digit-recognition
//! workload, proving all layers compose.
//!
//! 1. loads the AOT artifacts (`make artifacts`): trained binary weights +
//!    the jax/Pallas-lowered HLO modules;
//! 2. verifies the cross-language dataset contract (rust PRNG == python);
//! 3. executes the XLA golden model via PJRT and checks it against the
//!    circuit-level rust simulator bit-for-bit;
//! 4. serves the 10K-image corpus through the L3 coordinator on simulated
//!    subarrays, reporting accuracy, throughput, latency, energy/image and
//!    the Table II projections.
//!
//! ```bash
//! make artifacts && cargo run --release --example mnist_inference
//! ```

use std::time::{Duration, Instant};
use xpoint_imc::analysis::noise_margin;
use xpoint_imc::coordinator::{Coordinator, CoordinatorConfig};
use xpoint_imc::engine::{ArraySpec, BackendKind, EngineSpec, NetworkSource};
use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::runtime::ArtifactStore;
use xpoint_imc::util::si::{format_duration, format_pct, format_si};

fn main() -> xpoint_imc::Result<()> {
    println!("=== 3D XPoint end-to-end digit recognition ===\n");
    let store = ArtifactStore::open_default()?;
    let layer = store.single_layer()?;
    let v_dd = store.meta_f64("vdd_single")?;
    println!(
        "[1] artifacts: 121→10 trained binary layer, θ = {}, V_DD = {} (python-reported acc {:.1}%)",
        layer.theta,
        format_si(v_dd, "V"),
        100.0 * store.meta_f64("acc_single")?
    );

    // --- cross-language dataset contract ---
    let (labels, images) = store.dataset_check()?;
    let mut gen = DigitGen::new(TEST_SEED);
    for (i, (label, image)) in labels.iter().zip(&images).enumerate() {
        let s = gen.next_sample();
        anyhow::ensure!(s.label == *label && &s.pixels == image, "sample {i} mismatch");
    }
    println!("[2] dataset contract: 32/32 samples bit-identical rust vs python ✓");

    // --- XLA golden vs rust simulator, both through EngineSpec::build ---
    let array = ArraySpec {
        rows: 64,
        cols: 128,
        span: Some(121),
        ..ArraySpec::default()
    };
    let nm = noise_margin(&array.design()?);
    let sim_spec = EngineSpec::new(BackendKind::Ideal)
        .with_network(NetworkSource::Artifact)
        .with_array(array);
    let mut xla = EngineSpec::new(BackendKind::Xla).build_engine()?;
    let mut sim = sim_spec.build_engine()?;
    let mut gen = DigitGen::new(TEST_SEED);
    let batch: Vec<Vec<bool>> = (0..64).map(|_| gen.next_sample().pixels).collect();
    let t0 = Instant::now();
    let xla_out = xla.infer_batch(&batch)?;
    let xla_time = t0.elapsed();
    let t0 = Instant::now();
    let sim_out = sim.infer_batch(&batch)?;
    let sim_time = t0.elapsed();
    let mut agree = 0;
    for i in 0..64 {
        if xla_out.bits[i] == sim_out.bits[i] {
            agree += 1;
        }
    }
    anyhow::ensure!(agree == 64, "XLA vs simulator disagreement: {agree}/64");
    println!(
        "[3] golden check: XLA (jax/Pallas AOT, {}) == circuit simulator ({}) on 64/64 images ✓",
        format_duration(xla_time.as_secs_f64()),
        format_duration(sim_time.as_secs_f64())
    );
    println!(
        "    serving design: 64×128 config 3, NM = {} — electrically valid",
        format_pct(nm.noise_margin())
    );

    // --- full corpus through the coordinator ---
    let n_images = 10_000usize;
    let n_workers = 2usize;
    let factories = sim_spec
        .clone()
        .with_workers(n_workers)
        .build_factories()?;
    let mut coord = Coordinator::spawn(
        factories,
        CoordinatorConfig {
            batch_capacity: 64,
            linger: Duration::from_micros(200),
            autoscale: None,
        },
    );
    let mut gen = DigitGen::new(TEST_SEED);
    let started = Instant::now();
    let rxs: Vec<_> = (0..n_images)
        .map(|_| {
            let s = gen.next_sample();
            coord.submit(s.pixels, Some(s.label)).expect("submit")
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("prediction");
    }
    let wall = started.elapsed().as_secs_f64();
    let snap = coord.shutdown();

    println!("\n[4] coordinator run: {} images through {} simulated subarrays", snap.images, n_workers);
    println!("    accuracy:         {}", format_pct(snap.accuracy.unwrap_or(0.0)));
    println!(
        "    host throughput:  {:.0} img/s (wall {})",
        n_images as f64 / wall,
        format_duration(wall)
    );
    println!("    host latency:     {} mean/image", format_duration(snap.mean_latency));
    println!("    simulated time:   {} array-busy", format_duration(snap.sim_time));
    println!("    energy/image:     {} (paper Table II: ~21.5 pJ)", format_si(snap.energy_per_image, "J"));

    // --- Table II projection for this workload ---
    println!("\n[5] Table II projection (10K images, per design):");
    let rows = xpoint_imc::report::table2_rows(&layer);
    print!("{}", xpoint_imc::report::table2::table2_table(&rows).render());
    println!(
        "largest/smallest speedup: {:.1}× (paper: ~17×)",
        rows[0].exec_time / rows[4].exec_time
    );
    println!("\nend-to-end run complete ✓ (record in EXPERIMENTS.md)");
    Ok(())
}

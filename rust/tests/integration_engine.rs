//! Integration: the unified engine API. Pins the redesign's core
//! contract — everything `EngineSpec::build` constructs is **bit-exact**
//! (predictions *and* energy/time) with what the old direct-constructor
//! paths produced — plus the JSON spec round-trip and the CLI surface.

use std::time::Duration;
use xpoint_imc::analysis::ArrayDesign;
use xpoint_imc::array::TmvmMode;
use xpoint_imc::cli::Args;
use xpoint_imc::coordinator::{Coordinator, CoordinatorConfig};
use xpoint_imc::engine::{
    ArraySpec, AutoscaleSpec, BackendKind, Engine, EngineSpec, FabricBackend, NetworkSource,
    SimBackend, XLA_GRAPH_BATCH,
};
use xpoint_imc::fabric::FabricConfig;
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::report::table2::template_layer;
use xpoint_imc::runtime::artifact::artifacts_available;
use xpoint_imc::runtime::ArtifactStore;
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize) -> BinaryLayer {
    let theta = rng.range(1, 6);
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.45)).collect())
            .collect(),
        theta,
    )
}

fn random_images(rng: &mut Pcg32, m: usize, n_in: usize) -> Vec<Vec<bool>> {
    (0..m)
        .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
        .collect()
}

/// Property: for `Ideal` and `Parasitic`, an engine built from
/// `EngineSpec` equals the directly-constructed `SimBackend` — same bits,
/// classes, energy and simulated time, on random shapes.
#[test]
fn prop_sim_spec_engine_bit_exact_with_direct_constructor() {
    forall(Config::default().cases(40), "spec ≡ SimBackend", |rng| {
        let n_out = rng.range(1, 12);
        let n_in = rng.range(1, 30);
        let layer = random_layer(rng, n_out, n_in);
        let rows = rng.range(8, 48);
        let cols = n_in.max(n_out) + rng.range(0, 16);
        let mode = if rng.bernoulli(0.5) {
            TmvmMode::Ideal
        } else {
            TmvmMode::Parasitic
        };
        let kind = match mode {
            TmvmMode::Ideal => BackendKind::Ideal,
            TmvmMode::Parasitic => BackendKind::Parasitic,
        };

        // old path: direct constructor, serve's engaged-span default
        let design = ArrayDesign::new(rows, cols, LineConfig::config3(), 3.0, 1.0)
            .with_span(n_in);
        let mut old = SimBackend::new(layer.clone(), design, mode)
            .map_err(|e| format!("direct: {e}"))?;

        // new path: declarative spec (span None resolves to n_in)
        let spec = EngineSpec::new(kind)
            .with_array(ArraySpec {
                rows,
                cols,
                span: None,
                ..ArraySpec::default()
            })
            .with_batching(rows.min(64), 200)
            .with_layers(vec![layer.clone()]);
        let mut new = spec.build_engine().map_err(|e| format!("spec: {e:#}"))?;

        let m = rng.range(1, rows.min(8) + 1);
        let images = random_images(rng, m, n_in);
        let a = old.infer_batch(&images).map_err(|e| format!("old: {e:#}"))?;
        let b = new.infer_batch(&images).map_err(|e| format!("new: {e:#}"))?;
        if a.bits != b.bits {
            return Err("bits diverge".into());
        }
        if a.classes != b.classes {
            return Err("classes diverge".into());
        }
        if a.energy != b.energy {
            return Err(format!("energy diverges: {} vs {}", a.energy, b.energy));
        }
        if a.sim_time != b.sim_time {
            return Err(format!("time diverges: {} vs {}", a.sim_time, b.sim_time));
        }
        if a.steps != b.steps {
            return Err("steps diverge".into());
        }
        Ok(())
    });
}

/// Property: a fabric engine built from `EngineSpec` equals the
/// directly-constructed `FabricBackend` on random multi-layer stacks,
/// tile shapes and grids — bits, classes, energy, time and steps.
#[test]
fn prop_fabric_spec_engine_bit_exact_with_direct_constructor() {
    forall(Config::default().cases(25), "spec ≡ FabricBackend", |rng| {
        let depth = rng.range(1, 4);
        let mut widths = vec![rng.range(4, 30)];
        for _ in 0..depth {
            widths.push(rng.range(2, 20));
        }
        let mut layers = Vec::with_capacity(depth);
        for k in 0..depth {
            layers.push(random_layer(rng, widths[k + 1], widths[k]));
        }
        let (gr, gc) = (rng.range(1, 4), rng.range(1, 4));
        let (tr, tc) = (rng.range(2, 16), rng.range(2, 16));

        let mut old = FabricBackend::new(
            layers.clone(),
            FabricConfig::new(gr, gc, tr, tc),
            64,
        )
        .map_err(|e| format!("direct: {e}"))?;

        let spec = EngineSpec::new(BackendKind::Fabric)
            .with_layers(layers)
            .with_grid(gr, gc)
            .with_tile(tr, tc)
            .with_fabric_max_batch(64);
        let mut new = spec.build_engine().map_err(|e| format!("spec: {e:#}"))?;

        let images = random_images(rng, rng.range(1, 6), widths[0]);
        let a = old.infer_batch(&images).map_err(|e| format!("old: {e:#}"))?;
        let b = new.infer_batch(&images).map_err(|e| format!("new: {e:#}"))?;
        if a.bits != b.bits || a.classes != b.classes {
            return Err("predictions diverge".into());
        }
        if a.energy != b.energy || a.sim_time != b.sim_time || a.steps != b.steps {
            return Err(format!(
                "telemetry diverges: E {} vs {}, t {} vs {}",
                a.energy, b.energy, a.sim_time, b.sim_time
            ));
        }
        Ok(())
    });
}

/// The XLA golden model through the spec registry equals the direct
/// constructor path (skips when `make artifacts` hasn't run).
#[test]
fn xla_spec_engine_matches_direct_constructor() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    let store = ArtifactStore::open_default().unwrap();
    let layer = store.single_layer().unwrap();
    let v_dd = store.meta_f64("vdd_single").unwrap();
    let runtime = xpoint_imc::runtime::Runtime::cpu().unwrap();
    let mut old = xpoint_imc::engine::XlaBackend::new(
        &runtime,
        &store.nn_infer_hlo(),
        layer.clone(),
        64,
        v_dd,
    )
    .unwrap();

    let mut new = EngineSpec::new(BackendKind::Xla).build_engine().unwrap();

    let mut gen = xpoint_imc::nn::dataset::DigitGen::new(xpoint_imc::nn::dataset::TEST_SEED);
    let images: Vec<Vec<bool>> = (0..32).map(|_| gen.next_sample().pixels).collect();
    let a = old.infer_batch(&images).unwrap();
    let b = new.infer_batch(&images).unwrap();
    assert_eq!(a.bits, b.bits);
    assert_eq!(a.classes, b.classes);
}

/// The serve path (`EngineSpec::from_args`) builds the same engine the
/// old hand-rolled `main.rs::serve` constructed — checked end to end
/// through the coordinator on the digit workload.
#[test]
fn serve_flags_reproduce_the_old_serve_construction() {
    let args = Args::parse(
        "serve --fabric --grid 2 --batch 32 --workers 1"
            .split_whitespace()
            .map(String::from),
    );
    let spec = EngineSpec::from_args(&args).expect("serve flags");
    assert_eq!(spec.kind, BackendKind::Fabric);
    assert_eq!(spec.coordinator_config().batch_capacity, 32);

    // old path: what serve() used to assemble by hand — template layer
    // (or artifact layer when present: the Auto contract), 2×2 grid of
    // 64×32 subarrays, max_batch 1024
    let layer = match ArtifactStore::open_default() {
        Ok(s) => s.single_layer().expect("artifact layer"),
        Err(_) => template_layer(),
    };
    let mut old = FabricBackend::new(
        vec![layer.clone()],
        FabricConfig::new(2, 2, 64, 32),
        1024,
    )
    .unwrap();

    let mut gen = xpoint_imc::nn::dataset::DigitGen::new(xpoint_imc::nn::dataset::TEST_SEED);
    let samples: Vec<_> = (0..48).map(|_| gen.next_sample()).collect();
    let images: Vec<Vec<bool>> = samples.iter().map(|s| s.pixels.clone()).collect();
    let want = old.infer_batch(&images).unwrap();

    let mut coord = Coordinator::spawn(
        spec.build_factories().expect("factories"),
        CoordinatorConfig {
            // exactly one full batch (long linger: nothing ships early),
            // so energy/time compare exactly against one infer_batch call
            batch_capacity: 48,
            linger: Duration::from_secs(5),
            autoscale: None,
        },
    );
    let rxs: Vec<_> = samples
        .iter()
        .map(|s| coord.submit(s.pixels.clone(), Some(s.label)).expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let pred = rx.recv_timeout(Duration::from_secs(30)).expect("reply");
        assert_eq!(pred.bits, want.bits[i], "request {i} bits");
        assert_eq!(pred.class, want.classes[i], "request {i} class");
    }
    let snap = coord.shutdown();
    assert_eq!(snap.images, 48);
    assert_eq!(snap.energy, want.energy, "energy identical before/after");
    assert_eq!(snap.sim_time, want.sim_time, "time identical before/after");
}

/// JSON round-trip through a real file: write → `from_json_file` → build,
/// and the parsed spec serializes back to the identical document.
#[test]
fn engine_spec_json_file_roundtrip_and_build() {
    let spec = EngineSpec::new(BackendKind::Fabric)
        .with_workers(1)
        .with_network(NetworkSource::Template)
        .with_grid(2, 2)
        .with_tile(64, 32)
        .with_fabric_max_batch(128)
        .with_batching(16, 300);
    let text = spec.to_json();

    let path = std::env::temp_dir().join(format!(
        "xpoint-engine-spec-{}.json",
        std::process::id()
    ));
    std::fs::write(&path, &text).expect("write spec file");
    let loaded = EngineSpec::from_json_file(&path).expect("load spec file");
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, spec);
    assert_eq!(loaded.to_json(), text);

    // a loaded spec is directly buildable
    let mut engine = loaded.build_engine().expect("build from file spec");
    let caps = engine.capabilities();
    assert_eq!(caps.kind, BackendKind::Fabric);
    assert_eq!(caps.nodes, 4);
    let mut gen = xpoint_imc::nn::dataset::DigitGen::new(7);
    let images: Vec<Vec<bool>> = (0..4).map(|_| gen.next_sample().pixels).collect();
    let res = engine.infer_batch(&images).unwrap();
    let layer = template_layer();
    for (img, bits) in images.iter().zip(&res.bits) {
        assert_eq!(bits, &layer.forward(img));
    }

    // a missing file is a typed, path-labelled error
    let err = EngineSpec::from_json_file(std::path::Path::new(
        "/nonexistent/xpoint-spec.json",
    ))
    .unwrap_err();
    assert!(err.to_string().contains("engine spec JSON"), "{err}");
}

/// Property: random valid specs survive JSON serialization exactly.
#[test]
fn prop_spec_json_roundtrip_on_random_shapes() {
    forall(Config::default().cases(60), "spec JSON roundtrip", |rng| {
        let kind = *rng.choose(&[
            BackendKind::Ideal,
            BackendKind::Parasitic,
            BackendKind::Fabric,
            BackendKind::Xla,
        ]);
        let network = if kind == BackendKind::Xla {
            // xla + template is rejected by validation (no artifact-free run)
            *rng.choose(&[NetworkSource::Auto, NetworkSource::Artifact])
        } else {
            *rng.choose(&[
                NetworkSource::Auto,
                NetworkSource::Template,
                NetworkSource::Artifact,
            ])
        };
        let cols = rng.range(1, 200);
        let rows = rng.range(1, 300);
        let max_batch = rng.range(1, 2048);
        // the coordinator batch capacity must fit the backend's max batch
        let capacity_limit = match kind {
            BackendKind::Ideal | BackendKind::Parasitic => rows,
            BackendKind::Fabric => max_batch,
            BackendKind::Xla => XLA_GRAPH_BATCH,
        };
        let mut spec = EngineSpec::new(kind)
            .with_workers(rng.range(1, 8))
            .with_network(network)
            .with_array(ArraySpec {
                rows,
                cols,
                line_config: rng.range(1, 4),
                l_scale: (rng.range(1, 9) as f64) * 0.5,
                w_scale: (rng.range(1, 5) as f64) * 0.5,
                span: if rng.bernoulli(0.5) {
                    Some(rng.range(1, cols + 1))
                } else {
                    None
                },
            })
            .with_grid(rng.range(1, 6), rng.range(1, 6))
            .with_tile(rng.range(1, 64), rng.range(1, 64))
            .with_fabric_max_batch(max_batch)
            .with_batching(rng.range(1, capacity_limit + 1), rng.range(1, 10_000) as u64);
        // the reprogramming/swap section: any source (xla rejects swaps
        // outright, so only the other kinds draw one)
        if kind != BackendKind::Xla && rng.bernoulli(0.5) {
            spec = spec.with_swap_to(*rng.choose(&[
                NetworkSource::Auto,
                NetworkSource::Template,
                NetworkSource::Artifact,
            ]));
        }
        // the autoscale section (wraps the kind in an elastic sharded
        // fleet; xla shards are rejected by validation)
        if kind != BackendKind::Xla && rng.bernoulli(0.3) {
            let min = rng.range(1, 4);
            let low = rng.range(0, 50);
            spec = spec.with_autoscale(AutoscaleSpec {
                min_shards: min,
                max_shards: min + rng.range(0, 4),
                high_watermark: low + rng.range(1, 100),
                low_watermark: low,
                cooldown: rng.range(0, 9) as u64,
                pulse_budget: rng.range(0, 10_000) as u64,
            });
        }
        let text = spec.to_json();
        let parsed = EngineSpec::from_json(&text).map_err(|e| format!("parse: {e}"))?;
        if parsed != spec {
            return Err(format!("roundtrip drift:\n{text}"));
        }
        if parsed.to_json() != text {
            return Err("serialization not a fixed point".into());
        }
        Ok(())
    });
}

/// The unified surface: submit/poll and telemetry behave identically
/// across backend kinds built from specs.
#[test]
fn submit_poll_and_telemetry_across_kinds() {
    let specs = [
        EngineSpec::new(BackendKind::Ideal).with_network(NetworkSource::Template),
        EngineSpec::new(BackendKind::Parasitic).with_network(NetworkSource::Template),
        EngineSpec::new(BackendKind::Fabric).with_network(NetworkSource::Template),
    ];
    let layer = template_layer();
    let mut gen = xpoint_imc::nn::dataset::DigitGen::new(11);
    let images: Vec<Vec<bool>> = (0..6).map(|_| gen.next_sample().pixels).collect();
    for spec in specs {
        let mut engine = spec.build_engine().expect("build");
        let caps = engine.capabilities();
        assert_eq!(caps.n_in, 121);
        assert_eq!(caps.n_out, 10);
        assert!(caps.max_batch >= images.len());
        let ticket = engine.submit(images.clone()).expect("submit");
        let res = engine
            .poll(ticket)
            .expect("poll")
            .expect("sync engines complete at submit");
        if caps.kind != BackendKind::Parasitic {
            // ideal-fidelity kinds are bit-exact with the functional model
            // (parasitic wire drops may legitimately lose marginal bits)
            for (img, bits) in images.iter().zip(&res.bits) {
                assert_eq!(bits, &layer.forward(img), "kind {:?}", caps.kind);
            }
        }
        assert_eq!(res.bits.len(), images.len());
        assert!(engine.poll(ticket).is_err(), "tickets redeem once");
        let tel = engine.telemetry();
        assert_eq!(tel.images, 6);
        assert_eq!(tel.batches, 1);
        assert!(tel.energy > 0.0, "kind {:?} reports energy", caps.kind);
    }
}

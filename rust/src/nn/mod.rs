//! Binary neural-network mapping onto 3D XPoint subarrays (paper §III-B,
//! §IV-D) and the synthetic digit workload driving the Table II evaluation.

pub mod dataset;
pub mod layer;
pub mod mlp;
pub mod conv;
pub mod multibit;
pub mod packed;

pub use conv::{conv_bank, BinaryConv2d, ConvShapeError};
pub use dataset::{Dataset, DigitGen, IMAGE_PIXELS, IMAGE_SIDE, N_CLASSES};
pub use layer::{argmax_counts, BinaryLayer};
pub use multibit::{expand_unary, MultibitLayer};
pub use mlp::{BinaryMlp, MlpOnSubarrays};
pub use packed::{BitMatrix, BitVec, PackedBatch, PackedLayer, PackedMlp};

//! Quickstart: serve digit inference through the unified engine API, swap
//! backend fidelities with one enum, then drop down to the raw subarray
//! to see the in-memory TMVM the engines simulate.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use xpoint_imc::analysis::{ideal_window, noise_margin, ArrayDesign};
use xpoint_imc::array::{Level, Subarray, TmvmMode};
use xpoint_imc::engine::{BackendKind, EngineSpec, NetworkSource};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::util::si::{format_pct, format_si};

fn main() -> xpoint_imc::Result<()> {
    // ------------------------------------------------------------------
    // 1. the front door: a declarative EngineSpec → a running engine.
    //    The same spec is expressible as JSON (`xpoint serve --engine
    //    spec.json`) or CLI flags (`xpoint serve --parasitic`).
    let spec = EngineSpec::new(BackendKind::Ideal).with_network(NetworkSource::Template);
    println!("engine spec (JSON form):\n{}", spec.to_json());

    let mut engine = spec.build_engine()?;
    let caps = engine.capabilities();
    println!(
        "engine: {:?} backend, {}→{} network, batch ≤ {}, {} subarray(s)",
        caps.kind, caps.n_in, caps.n_out, caps.max_batch, caps.nodes
    );

    // 2. infer a batch of synthetic digits and read the typed telemetry
    let mut gen = DigitGen::new(TEST_SEED);
    let samples: Vec<_> = (0..8).map(|_| gen.next_sample()).collect();
    let images: Vec<Vec<bool>> = samples.iter().map(|s| s.pixels.clone()).collect();
    let res = engine.infer_batch(&images)?;
    let correct = samples
        .iter()
        .zip(&res.classes)
        .filter(|(s, &c)| s.label == c)
        .count();
    let tel = engine.telemetry();
    println!(
        "batch of {}: {}/{} correct, {} simulated, {} ({}/image)",
        images.len(),
        correct,
        images.len(),
        format_si(tel.sim_time, "s"),
        format_si(tel.energy, "J"),
        format_si(tel.energy_per_image(), "J"),
    );

    // 3. swap fidelity with one enum variant: the parasitic-aware model
    //    must agree bit-for-bit on a healthy design
    let mut parasitic = EngineSpec::new(BackendKind::Parasitic)
        .with_network(NetworkSource::Template)
        .build_engine()?;
    let res_p = parasitic.infer_batch(&images)?;
    let agree = res_p.bits.iter().zip(&res.bits).filter(|(p, i)| p == i).count();
    println!(
        "parasitic backend: {agree}/{} images decode identically (wire drops can \
         only lose bits), energy {}",
        images.len(),
        format_si(res_p.energy, "J")
    );

    // 4. the non-blocking surface every engine shares — and the sharded
    //    kind makes genuinely asynchronous: `BackendKind::Sharded` (CLI:
    //    `serve --shards N`) runs N copies of any backend on their own
    //    threads behind least-loaded submit/poll dispatch (see
    //    examples/sharded_serving.rs)
    let ticket = engine.submit(images.clone())?;
    let polled = engine.poll(ticket)?.expect("simulated engines complete at submit");
    assert_eq!(polled.bits, res.bits);
    println!("submit/poll: ticket {ticket} redeemed, same predictions");
    let sharded = EngineSpec::new(BackendKind::Ideal)
        .with_network(NetworkSource::Template)
        .with_shards(2, BackendKind::Ideal);
    let mut sharded = sharded.build_engine()?;
    let t = sharded.submit(images.clone())?;
    let res_s = loop {
        // Ok(None) = still in flight on a shard thread — poll never blocks
        match sharded.poll(t)? {
            Some(r) => break r,
            None => std::thread::yield_now(),
        }
    };
    assert_eq!(res_s.bits, res.bits, "sharded is bit-exact");
    println!("sharded:     2 ideal shards agree bit-for-bit\n");

    // ------------------------------------------------------------------
    // 5. under the hood: an 8×8 subarray design and its feasibility
    let design = ArrayDesign::new(8, 8, LineConfig::config3(), 3.0, 1.0);
    println!(
        "raw subarray: {}×{} cells, config {}, cell {:.0}×{:.0} nm, area {:.3} µm²",
        design.n_row,
        design.n_col,
        design.config.id,
        design.cell.w_cell * 1e9,
        design.cell.l_cell * 1e9,
        design.area() * 1e12
    );
    let nm = noise_margin(&design);
    println!(
        "noise margin: {} (window [{}, {}])",
        format_pct(nm.noise_margin()),
        format_si(nm.v_lo(), "V"),
        format_si(nm.v_hi(), "V"),
    );

    // 6. program a binary matrix G into the top PCM level
    let mut sa = Subarray::new(design);
    let g: Vec<Vec<bool>> = (0..8)
        .map(|r| (0..8).map(|c| (r + c) % 3 == 0).collect())
        .collect();
    sa.program_level(Level::Top, &g);
    println!("\nG (top PCM level):");
    for row in &g {
        let line: String = row.iter().map(|&b| if b { '#' } else { '.' }).collect();
        println!("  {line}");
    }

    // 7. choose an operating voltage realizing firing threshold θ = 2 and
    //    apply an input vector as word-line pulses; thresholded dot
    //    products land in bottom-level column 0
    let theta = 2;
    let v_dd = sa.vdd_for_threshold(theta);
    println!("\nθ = {theta} ⇒ V_DD = {}", format_si(v_dd, "V"));
    let x = vec![true, false, true, true, false, false, true, false];
    let report = sa.tmvm(&x, 0, v_dd, TmvmMode::Ideal);
    println!(
        "x = {:?}\nO = {:?}   (electrically clean: {})",
        x.iter().map(|&b| b as u8).collect::<Vec<_>>(),
        report.outputs.iter().map(|&b| b as u8).collect::<Vec<_>>(),
        report.is_clean()
    );

    // 8. verify against exact integer counts
    for (r, row) in g.iter().enumerate() {
        let count = row.iter().zip(&x).filter(|(&w, &xi)| w && xi).count();
        assert_eq!(report.outputs[r], count >= theta);
    }
    println!("verified: outputs equal exact count-thresholding ✓");

    // 9. energy/latency ledger + the ideal operating window (Eqs. 4–5)
    println!(
        "energy booked: {}, busy time: {}",
        format_si(sa.ledger.energy, "J"),
        format_si(sa.ledger.time, "s")
    );
    let w = ideal_window(121, &sa.design().device);
    println!(
        "ideal window for 121 inputs: [{}, {}] (NM {})",
        format_si(w.v_min(), "V"),
        format_si(w.v_max(), "V"),
        format_pct(w.noise_margin())
    );
    Ok(())
}

//! Integration: the parasitic canary shard. A mixed fleet — N ideal
//! primaries plus one parasitic-fidelity canary — serves a seeded trace
//! with exactly-once ticket semantics while the canary shadows a
//! deterministic sample of the traffic. The divergence counter must
//! match an offline ideal-vs-parasitic replay of the same sampled
//! batches, shadow tickets must never surface to the caller, and a
//! rolling swap must preserve the canary designation.

use std::time::Duration;
use xpoint_imc::engine::{
    ArraySpec, BackendKind, Engine, EngineSpec, ShardedEngine,
};
use xpoint_imc::nn::{BinaryLayer, PackedBatch};
use xpoint_imc::util::Pcg32;

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.45)).collect())
            .collect(),
        theta,
    )
}

fn random_images(rng: &mut Pcg32, m: usize, n_in: usize) -> Vec<Vec<bool>> {
    (0..m)
        .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
        .collect()
}

fn array() -> ArraySpec {
    ArraySpec {
        rows: 64,
        cols: 32,
        span: Some(20),
        ..ArraySpec::default()
    }
}

fn base_spec(kind: BackendKind, layers: &[BinaryLayer]) -> EngineSpec {
    EngineSpec::new(kind)
        .with_array(array())
        .with_batching(32, 200)
        .with_layers(layers.to_vec())
}

/// `primaries` ideal shards + one parasitic canary sampling `fraction`.
fn canary_fleet(layers: &[BinaryLayer], primaries: usize, fraction: f64) -> ShardedEngine {
    let mut factories = base_spec(BackendKind::Ideal, layers)
        .with_workers(primaries)
        .build_factories()
        .expect("ideal primaries");
    factories.push(
        base_spec(BackendKind::Parasitic, layers)
            .build()
            .expect("parasitic canary"),
    );
    ShardedEngine::with_canary(factories, fraction).expect("canary fleet")
}

/// Pump events until `compared` mirrored batches have settled (bounded).
fn settle_canary(e: &mut ShardedEngine, compared: u64) {
    for _ in 0..10_000 {
        if e.canary_report().expect("canary fleet").compared_batches >= compared {
            return;
        }
        e.wait_event(Duration::from_millis(1));
    }
    panic!("canary comparisons never settled");
}

/// The submission indices the deterministic stride sampler fires on —
/// the exact accumulator walk the engine performs at submit time, so an
/// offline replay sees the same batches the canary mirrored.
fn sampled_indices(n: usize, fraction: f64) -> Vec<usize> {
    let mut acc = 0.0;
    let mut out = Vec::new();
    for i in 0..n {
        acc += fraction;
        if acc >= 1.0 {
            acc -= 1.0;
            out.push(i);
        }
    }
    out
}

/// The tentpole contract: over a seeded trace on a 1-canary + 2-ideal
/// fleet, (a) every caller ticket redeems exactly once and shadow
/// tickets never surface, (b) the reported divergence equals an offline
/// ideal-vs-parasitic replay of exactly the sampled batches, and (c) the
/// canary's noise-margin telemetry reaches the engine aggregate.
#[test]
fn canary_divergence_matches_an_offline_replay() {
    let mut rng = Pcg32::seeded(0xca4a51);
    let layers = vec![random_layer(&mut rng, 10, 20, 3)];
    let fraction = 0.4;
    let mut fleet = canary_fleet(&layers, 2, fraction);
    assert_eq!(fleet.canary_shard(), Some(2), "last slot is the canary");
    assert_eq!(
        fleet.capabilities().shards,
        2,
        "caps describe the primary pool only"
    );

    // seeded trace: 12 batches of varied size, submitted in order
    let batches: Vec<Vec<Vec<bool>>> = (0..12)
        .map(|i| random_images(&mut rng, 1 + (i % 5), 20))
        .collect();
    let tickets: Vec<_> = batches
        .iter()
        .map(|b| fleet.submit(b.clone()).expect("submit"))
        .collect();

    // exactly-once: each ticket redeems once, then is a typed error
    for (k, &t) in tickets.iter().enumerate() {
        let res = loop {
            match fleet.poll(t).expect("poll") {
                Some(res) => break res,
                None => std::thread::yield_now(),
            }
        };
        for (img, bits) in batches[k].iter().zip(&res.bits) {
            assert_eq!(bits, &layers[0].forward(img), "batch {k} identity");
        }
        let err = fleet.poll(t).expect_err("redeemed tickets are gone");
        assert!(
            err.to_string().contains("never issued or already collected"),
            "{err}"
        );
    }
    // the canary settles its comparisons asynchronously
    let sampled = sampled_indices(batches.len(), fraction);
    settle_canary(&mut fleet, sampled.len() as u64);

    // shadow tickets share the counter but must never be redeemable:
    // once the mirrors settle, every id the caller was not handed is
    // unknown to `poll` (while in flight they are invisible `Ok(None)`s)
    let max_ticket = *tickets.iter().max().expect("tickets");
    for t in 1..=max_ticket + 2 {
        if tickets.contains(&t) {
            continue;
        }
        let err = fleet.poll(t).expect_err("shadow tickets never surface");
        assert!(
            err.to_string().contains("never issued or already collected"),
            "ticket {t}: {err}"
        );
    }
    let report = fleet.canary_report().expect("canary fleet");
    assert_eq!(report.compared_batches, sampled.len() as u64);
    assert_eq!(
        report.sampled_images,
        sampled.iter().map(|&i| batches[i].len() as u64).sum::<u64>()
    );

    // offline replay: run exactly the sampled batches through a single
    // ideal and a single parasitic engine and count differing images
    let mut ideal = base_spec(BackendKind::Ideal, &layers)
        .build_engine()
        .expect("ideal replay");
    let mut parasitic = base_spec(BackendKind::Parasitic, &layers)
        .build_engine()
        .expect("parasitic replay");
    let mut divergent = 0u64;
    for &i in &sampled {
        let a = ideal.infer_batch(&batches[i]).expect("ideal batch");
        let b = parasitic.infer_batch(&batches[i]).expect("parasitic batch");
        divergent += a.bits.iter().zip(&b.bits).filter(|(x, y)| x != y).count() as u64;
    }
    assert_eq!(
        report.divergent_images, divergent,
        "live divergence counter must equal the offline replay"
    );

    // the canary's electrical window reaches the aggregate telemetry
    assert!(report.margin_min.is_finite(), "canary served → margin known");
    assert_eq!(fleet.telemetry().margin_min, report.margin_min);
    // primaries took all 12 batches; the canary mirrored the sample
    let per_shard = fleet.shard_telemetry();
    assert_eq!(per_shard[0].batches + per_shard[1].batches, 12);
    assert_eq!(per_shard[2].batches, sampled.len() as u64);
}

/// A rolling swap walks the canary like any serving shard but never
/// steals its designation: after `swap_network` the same slot is still
/// the canary, mirrors keep flowing, and primaries serve the new weights.
#[test]
fn rolling_swap_preserves_the_canary_designation() {
    let mut rng = Pcg32::seeded(0x50ab);
    let layers = vec![random_layer(&mut rng, 8, 20, 3)];
    let mut fleet = canary_fleet(&layers, 2, 1.0);
    let canary = fleet.canary_shard().expect("designated");

    let warm = random_images(&mut rng, 4, 20);
    let res = fleet.infer_batch(&warm).expect("pre-swap batch");
    for (img, bits) in warm.iter().zip(&res.bits) {
        assert_eq!(bits, &layers[0].forward(img), "pre-swap identity");
    }
    settle_canary(&mut fleet, 1);

    // rolling swap to fresh weights of the same shape
    let target = vec![random_layer(&mut rng, 8, 20, 2)];
    let swap = fleet.swap_network(target.clone()).expect("rolling swap");
    assert!(swap.set_pulses + swap.reset_pulses > 0, "weights changed");
    assert_eq!(
        fleet.canary_shard(),
        Some(canary),
        "swap must not reassign the canary slot"
    );

    // post-swap traffic serves the new network and still gets mirrored
    let after = random_images(&mut rng, 3, 20);
    let res = fleet.infer_batch(&after).expect("post-swap batch");
    for (img, bits) in after.iter().zip(&res.bits) {
        assert_eq!(bits, &target[0].forward(img), "post-swap identity");
    }
    settle_canary(&mut fleet, 2);
    let report = fleet.canary_report().expect("canary fleet");
    assert_eq!(report.compared_batches, 2);
    assert_eq!(report.sampled_images, 4 + 3);
}

/// Packed submissions on a canary fleet: the primary rides the packed
/// fast path, while the canary's mirror is unpacked to the scalar path
/// (its parasitic fidelity refuses packed dispatch by typed error).
#[test]
fn packed_tickets_ride_the_scalar_mirror_path() {
    let mut rng = Pcg32::seeded(0xbac4ed);
    let layers = vec![random_layer(&mut rng, 6, 20, 2)];
    let mut fleet = canary_fleet(&layers, 1, 1.0);

    let images = random_images(&mut rng, 5, 20);
    let packed = PackedBatch::from_images(&images).expect("packable");
    let t = fleet.submit_packed(packed).expect("packed submit");
    let res = loop {
        match fleet.poll(t).expect("poll") {
            Some(res) => break res,
            None => std::thread::yield_now(),
        }
    };
    for (img, bits) in images.iter().zip(&res.bits) {
        assert_eq!(bits, &layers[0].forward(img), "packed identity");
    }
    settle_canary(&mut fleet, 1);
    let report = fleet.canary_report().expect("canary fleet");
    assert_eq!(report.sampled_images, 5);
    assert_eq!(report.compared_batches, 1);
}

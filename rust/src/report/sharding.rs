//! Sharded-serving exhibit (beyond the paper's single-array tables): host
//! throughput, per-image latency and shard load balance as the same
//! fabric workload is served by 1, 2 and 4 fabric shards behind the
//! asynchronous coordinator scheduler.
//!
//! Simulated time and energy *sum* across shards (they are independent
//! arrays doing the same physical work), so the exhibit's claim is about
//! the serving system: host wall-clock throughput scales with shards
//! while the per-image physics stays fixed — the §IV "system scalability"
//! story carried from one grid to a farm of grids. The same invariance
//! holds across machines: a shard served by a remote `xpoint shard-host`
//! (`serve --shards N --remote host:port`) does identical physical work,
//! so a mixed local+remote fleet is bit-exact with an all-local one
//! (pinned by the `integration_remote` suite).

use std::time::Instant;

use crate::coordinator::Coordinator;
use crate::engine::{BackendKind, EngineSpec};
use crate::nn::dataset::{DigitGen, TEST_SEED};
use crate::util::si::{format_duration, format_pct, format_si};
use crate::util::Table;

use super::fabric::{fabric_workload, FABRIC_TILE};

/// Default shard counts swept by the exhibit.
pub const SHARD_SWEEP: [usize; 3] = [1, 2, 4];

/// One evaluated shard count.
#[derive(Clone, Debug)]
pub struct ShardScalingRow {
    pub shards: usize,
    pub images: usize,
    /// Host wall-clock for the whole run \[s\].
    pub wall: f64,
    /// Host throughput \[images/s\].
    pub throughput: f64,
    /// Mean per-image host latency \[s\].
    pub mean_latency: f64,
    /// Simulated energy per image \[J\] (shard-count invariant).
    pub energy_per_image: f64,
    /// Images served by each shard — the load-balance view.
    pub shard_images: Vec<u64>,
    /// Mean subarray utilization across shards.
    pub mean_util: f64,
}

/// The spec this exhibit serves for `shards` shards: the fixed 3-layer
/// fabric workload on a 2×2 grid per shard, one coordinator worker.
pub fn shard_scaling_spec(shards: usize, batch: usize) -> EngineSpec {
    let mut spec = EngineSpec::new(BackendKind::Fabric)
        .with_layers(fabric_workload())
        .with_grid(2, 2)
        .with_tile(FABRIC_TILE.0, FABRIC_TILE.1)
        .with_fabric_max_batch(batch.max(1))
        .with_batching(batch.max(1), 200)
        .with_workers(1);
    if shards > 1 {
        spec = spec.with_shards(shards, BackendKind::Fabric);
    }
    spec
}

/// Run the exhibit: the same digit stream through the coordinator at each
/// shard count, reading throughput from the wall clock and balance from
/// the per-shard telemetry in
/// [`MetricsSnapshot::shards`](crate::coordinator::MetricsSnapshot).
pub fn shard_scaling_rows(
    shard_counts: &[usize],
    n_images: usize,
    batch: usize,
) -> crate::Result<Vec<ShardScalingRow>> {
    let mut rows = Vec::with_capacity(shard_counts.len());
    for &shards in shard_counts {
        let spec = shard_scaling_spec(shards, batch);
        let mut coord =
            Coordinator::spawn(spec.build_factories()?, spec.coordinator_config());
        let mut gen = DigitGen::new(TEST_SEED);
        let started = Instant::now();
        let mut rxs = Vec::with_capacity(n_images);
        for _ in 0..n_images {
            rxs.push(coord.submit(gen.next_sample().pixels, None)?);
        }
        for rx in rxs {
            let _ = rx.recv();
        }
        let wall = started.elapsed().as_secs_f64();
        let snap = coord.shutdown();
        let shard_images: Vec<u64> = snap.shards.iter().map(|t| t.images).collect();
        let utils: Vec<f64> = snap
            .shards
            .iter()
            .filter(|t| !t.utilization.is_empty())
            .map(|t| t.mean_utilization())
            .collect();
        rows.push(ShardScalingRow {
            shards,
            images: n_images,
            wall,
            throughput: if wall > 0.0 {
                n_images as f64 / wall
            } else {
                0.0
            },
            mean_latency: snap.mean_latency,
            energy_per_image: snap.energy_per_image,
            shard_images,
            mean_util: if utils.is_empty() {
                0.0
            } else {
                utils.iter().sum::<f64>() / utils.len() as f64
            },
        });
    }
    Ok(rows)
}

/// Render the exhibit table.
pub fn shard_scaling_table(rows: &[ShardScalingRow]) -> Table {
    let title = format!(
        "Sharded serving — 3-layer fabric workload, {} images per run",
        rows.first().map_or(0, |r| r.images)
    );
    let mut t = Table::new(&title).header(&[
        "Shards",
        "Host wall",
        "Throughput",
        "Mean latency",
        "E/image",
        "Util (mean)",
        "Images/shard",
    ]);
    for r in rows {
        let balance = r
            .shard_images
            .iter()
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join("/");
        t.row(&[
            r.shards.to_string(),
            format_duration(r.wall),
            format!("{} img/s", format_si(r.throughput, "")),
            format_duration(r.mean_latency),
            format_si(r.energy_per_image, "J"),
            format_pct(r.mean_util),
            balance,
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_cover_the_sweep_and_account_every_image() {
        let rows = shard_scaling_rows(&[1, 2], 96, 32).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert_eq!(r.images, 96);
            assert!(r.throughput > 0.0, "shards {}", r.shards);
            assert_eq!(
                r.shard_images.iter().sum::<u64>(),
                96,
                "every image lands on some shard (shards {})",
                r.shards
            );
            // physics is shard-invariant: per-image energy in the same
            // sub-nJ regime at every shard count
            assert!(r.energy_per_image > 1e-13 && r.energy_per_image < 2e-9);
        }
        assert_eq!(rows[0].shard_images.len(), 1);
        assert_eq!(rows[1].shard_images.len(), 2);
        // both shards of the 2-shard run actually served work
        assert!(rows[1].shard_images.iter().all(|&n| n > 0));
    }

    #[test]
    fn table_renders_all_rows() {
        let rows = shard_scaling_rows(&[1], 48, 16).unwrap();
        let t = shard_scaling_table(&rows);
        assert_eq!(t.n_rows(), 1);
        assert!(t.render().contains("img/s"));
    }
}

//! Noise-margin analysis (paper §V, Eq. 7; §VI Figs. 11/13) built on the
//! corner-case Thevenin model.
//!
//! The corner-case windows: the victim row's output must SET
//! (`I ≥ I_SET`) when the single driven input is crystalline, and must stay
//! clear of an accidental RESET (`I < I_RESET`). The first row (negligible
//! parasitics) gives the upper edge `V_max`; the last row (worst drop)
//! gives the lower edge `V'_min`:
//!
//! ```text
//! V'_min = I_SET   · (R_th(last)  + 2/G_C) / α_th(last)
//! V_max  = I_RESET · (R_th(first) + 2/G_C) / α_th(first)
//! NM     = (V_max − V'_min) / V_mid ,  V_mid = (V_max + V'_min)/2
//! ```
//!
//! With `I_RESET = 2·I_SET` the parasitic-free NM tends to 2/3 (≈66%),
//! matching the best entries of the paper's Table II.

use super::design::ArrayDesign;
use super::thevenin::{ladder_thevenin, LadderThevenin};

/// Complete NM analysis of a design point.
#[derive(Clone, Copy, Debug)]
pub struct NmAnalysis {
    /// Thevenin equivalent at the first row.
    pub first: LadderThevenin,
    /// Thevenin equivalent at the last row.
    pub last: LadderThevenin,
    /// First-row window \[V\].
    pub v_min_first: f64,
    pub v_max_first: f64,
    /// Last-row window \[V\].
    pub v_min_last: f64,
    pub v_max_last: f64,
}

impl NmAnalysis {
    /// Lower edge of the combined window `V'_min` (binding: last row).
    pub fn v_lo(&self) -> f64 {
        self.v_min_first.max(self.v_min_last)
    }

    /// Upper edge of the combined window `V_max` (binding: first row).
    pub fn v_hi(&self) -> f64 {
        self.v_max_first.min(self.v_max_last)
    }

    /// Midpoint operating voltage.
    pub fn v_mid(&self) -> f64 {
        0.5 * (self.v_lo() + self.v_hi())
    }

    /// Noise margin (Eq. 7); negative when the window is empty.
    pub fn noise_margin(&self) -> f64 {
        (self.v_hi() - self.v_lo()) / self.v_mid()
    }

    /// Is the design electrically valid?
    pub fn is_acceptable(&self) -> bool {
        self.noise_margin() >= 0.0
    }
}

/// Series resistance of the victim cells at the flip evaluation point
/// (input crystalline + output at its crystalline endpoint): `2/G_C`.
fn victim_load(design: &ArrayDesign) -> f64 {
    2.0 / design.device.g_c
}

/// Run the corner-case NM analysis for a design.
pub fn noise_margin(design: &ArrayDesign) -> NmAnalysis {
    let first = ladder_thevenin(design, 1);
    let last = ladder_thevenin(design, design.n_row);
    let load = victim_load(design);
    let p = &design.device;
    NmAnalysis {
        first,
        last,
        v_min_first: first.required_vdd(p.i_set, load),
        v_max_first: first.required_vdd(p.i_reset, load),
        v_min_last: last.required_vdd(p.i_set, load),
        v_max_last: last.required_vdd(p.i_reset, load),
    }
}

/// Fig. 11(b): the NM = 0 separating line in the `(α_th, R_th)` plane.
/// For a given `R_th`, returns the minimum α that keeps the design
/// acceptable (assuming a near-ideal first row with Thevenin `(1, r0)`).
pub fn region_boundary_alpha(design: &ArrayDesign, r_th: f64) -> f64 {
    let load = victim_load(design);
    let p = &design.device;
    let first = ladder_thevenin(design, 1);
    let v_max = first.required_vdd(p.i_reset, load);
    // NM = 0 ⇔ V'_min = V_max ⇔ α = I_SET (R_th + load) / V_max
    p.i_set * (r_th + load) / v_max
}

/// Largest `N_row` (power-of-two search then binary refinement) whose NM
/// stays ≥ `nm_target` with everything else in the design fixed.
pub fn max_rows_for_nm(template: &ArrayDesign, nm_target: f64) -> usize {
    let eval = |n_row: usize| -> f64 {
        let mut d = template.clone();
        d.n_row = n_row;
        noise_margin(&d).noise_margin()
    };
    if eval(1) < nm_target {
        return 0;
    }
    // exponential growth to bracket
    let mut lo = 1usize;
    let mut hi = 2usize;
    while eval(hi) >= nm_target {
        lo = hi;
        hi *= 2;
        if hi > (1 << 24) {
            return lo; // practically unbounded
        }
    }
    // binary search in (lo, hi)
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if eval(mid) >= nm_target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::LineConfig;

    #[test]
    fn small_array_nm_near_two_thirds() {
        // 64×128 config 3 at Table II geometry (L = 3·L_min): parasitics
        // are negligible, NM ≈ 2/3 (paper: 65.1%).
        let d = ArrayDesign::new(64, 128, LineConfig::config3(), 3.0, 1.0).with_span(121);
        let nm = noise_margin(&d).noise_margin();
        assert!(nm > 0.55 && nm < 0.6667, "nm = {nm}");
    }

    #[test]
    fn nm_decreases_with_rows() {
        let mut prev = f64::INFINITY;
        for n in [64, 128, 256, 512, 1024, 2048] {
            let d = ArrayDesign::new(n, 128, LineConfig::config1(), 4.0, 1.0);
            let nm = noise_margin(&d).noise_margin();
            assert!(nm < prev, "NM must fall with N_row (n={n}, nm={nm})");
            prev = nm;
        }
    }

    #[test]
    fn nm_becomes_negative_for_huge_arrays() {
        let d = ArrayDesign::new(1 << 14, 128, LineConfig::config1(), 4.0, 1.0);
        assert!(noise_margin(&d).noise_margin() < 0.0);
    }

    #[test]
    fn config3_gives_best_nm() {
        let nm = |cfg: LineConfig| {
            let d = ArrayDesign::new(1024, 128, cfg, 4.0, 1.0);
            noise_margin(&d).noise_margin()
        };
        let (n1, n2, n3) = (
            nm(LineConfig::config1()),
            nm(LineConfig::config2()),
            nm(LineConfig::config3()),
        );
        assert!(n3 > n1, "config3 {n3} vs config1 {n1}");
        assert!(n2 > n1, "config2 {n2} vs config1 {n1}");
    }

    #[test]
    fn nm_improves_with_l_cell() {
        let nm_at = |l_scale: f64| {
            let d = ArrayDesign::new(128, 128, LineConfig::config1(), l_scale, 1.0);
            noise_margin(&d).noise_margin()
        };
        assert!(nm_at(4.0) > nm_at(1.0));
        assert!(nm_at(8.0) > nm_at(4.0));
    }

    #[test]
    fn nm_degrades_with_w_cell() {
        let nm_at = |w_scale: f64| {
            let d = ArrayDesign::new(64, 128, LineConfig::config1(), 4.0, w_scale);
            noise_margin(&d).noise_margin()
        };
        assert!(nm_at(1.0) > nm_at(2.0));
        assert!(nm_at(2.0) > nm_at(4.0));
    }

    #[test]
    fn nm_flat_in_n_col_at_fixed_span() {
        // Fig. 13(d): with the engaged span fixed, total column count does
        // not matter.
        let nm_at = |n_col: usize| {
            let d =
                ArrayDesign::new(256, n_col, LineConfig::config1(), 4.0, 1.0).with_span(121);
            noise_margin(&d).noise_margin()
        };
        let base = nm_at(128);
        for n_col in [256, 512, 1024, 2048] {
            assert!((nm_at(n_col) - base).abs() < 1e-6, "flat in N_column");
        }
    }

    #[test]
    fn boundary_alpha_is_linear_in_r_th() {
        let d = ArrayDesign::new(64, 128, LineConfig::config1(), 4.0, 1.0);
        let a1 = region_boundary_alpha(&d, 0.0);
        let a2 = region_boundary_alpha(&d, 10e3);
        let a3 = region_boundary_alpha(&d, 20e3);
        assert!((a3 - a2 - (a2 - a1)).abs() < 1e-9, "linear boundary");
        assert!(a1 > 0.0 && a3 < 2.0);
    }

    #[test]
    fn max_rows_search_brackets_correctly() {
        let t = ArrayDesign::new(1, 128, LineConfig::config1(), 4.0, 1.0);
        let max_pos = max_rows_for_nm(&t, 0.0);
        assert!(max_pos > 64, "config1 should allow >64 rows, got {max_pos}");
        // NM at the boundary is ≥ 0, one past it is < 0
        let mut d = t.clone();
        d.n_row = max_pos;
        assert!(noise_margin(&d).is_acceptable());
        d.n_row = max_pos + 1;
        assert!(!noise_margin(&d).is_acceptable());
        // demanding a higher margin shrinks the allowed size
        assert!(max_rows_for_nm(&t, 0.3) < max_pos);
    }
}

//! [`ShardedEngine`] — genuinely asynchronous serving over N independent
//! engine shards.
//!
//! The paper's §"system scalability" connects multiple 3D XPoint arrays
//! into a larger engine; the fabric layer simulates one such grid, and
//! this module scales *past* one grid: a `ShardedEngine` owns N inner
//! engines (any non-sharded [`BackendKind`]), each constructed from its
//! [`BackendFactory`] **on its own worker thread** (engines are not
//! `Send`; PJRT handles are thread-affine — the factory travels, the
//! engine never does).
//!
//! The submit/poll pair is where the asynchrony becomes real instead of
//! the synchronous-completion adapter the plain engines use:
//!
//! * [`submit`](Engine::submit) is **capability-aware least-loaded
//!   dispatch**: the batch goes to the shard with the fewest in-flight
//!   images among those whose `max_batch` admits it, and returns a
//!   [`Ticket`] immediately — the shard thread does the work later.
//! * [`poll`](Engine::poll) drains shard completion channels without
//!   blocking and redeems tickets **out of submission order** while
//!   preserving per-ticket identity; `Ok(None)` means genuinely still in
//!   flight on a shard thread.
//! * [`infer_batch`](Engine::infer_batch) is submit + a blocking drain of
//!   the owning shard's completions — the synchronous view of the same
//!   machinery.
//!
//! ## Shard lifecycle and rolling weight swaps
//!
//! Every shard carries a [`ShardState`]. Normally it is `Serving`; a
//! rolling swap ([`Engine::begin_swap`]) walks the shards one at a time
//! through `Serving → Draining → Reprogramming → Rejoining → Serving`:
//! the dispatcher stops routing to the draining shard, its outstanding
//! completions drain (and stay redeemable — see the mid-drain poll
//! regression test), the shard thread reprograms its engine in place
//! ([`Engine::swap_network`] on the inner backend), and the shard rejoins
//! the pool. At most one shard is ever out of service, so with ≥2 shards
//! aggregate throughput never hits zero; per-shard atomicity (inner
//! engines validate-then-mutate) guarantees every completion reflects
//! wholly-old or wholly-new weights, never a torn mix.
//!
//! Telemetry sums across shards (energy and simulated time are additive;
//! per-subarray utilization concatenates in shard order), and
//! [`Engine::shard_telemetry`] exposes the per-shard breakdown so the
//! coordinator's metrics and the report exhibits can show load balance.
//!
//! ## Elastic lifecycle: spawn / retire with wear budgets
//!
//! An engine built from an autoscale spec ([`ShardedEngine::elastic`])
//! additionally owns a [`ShardBuilder`] — a reusable template that
//! constructs one more inner engine on demand — and tracks, per shard
//! slot, the weight image its cells physically hold and the cumulative
//! SET/RESET pulses programmed into them (endurance wear):
//!
//! * [`Engine::retire_shard`] — the most-worn serving shard walks
//!   `Serving → Draining → Parked`: it leaves the dispatch pool, its
//!   outstanding completions drain (and stay redeemable), and the slot
//!   parks with its cells and wear history intact.
//! * [`Engine::spawn_shard`] — the reverse walk. A parked slot whose
//!   pulse budget admits the *delta* back to the resident network
//!   reprograms in place (`Parked → Programming → Rejoining → Serving`;
//!   a slot that parked before a swap pays only the incremental diff);
//!   a worn slot is **vetoed** and never selected. With no eligible
//!   parked slot, a brand-new slot is constructed from the template and
//!   pulses the full weight image into fresh cells
//!   (`Spawning → Rejoining → Serving`) — the spawn cost the
//!   [`ReprogramPlan`] machinery prices.
//!
//! At most one lifecycle walk (rolling swap *or* scale operation) is in
//! flight at a time; every completed walk emits a
//! [`ScaleEvent`](super::api::ScaleEvent) the coordinator folds into its
//! metrics.
//!
//! ## Canary fidelity sampling
//!
//! A fleet built with [`ShardedEngine::with_canary`] designates its last
//! slot a **canary**: a higher-fidelity (parasitic) shard that never
//! serves primary traffic. A deterministic stride sampler mirrors a
//! configured fraction of submissions onto it as *shadow* tickets —
//! accounted in flight on the canary (drains and rolling swaps wait for
//! them) but never redeemable through [`poll`](Engine::poll). When both
//! halves of a mirrored batch complete, the scheduler compares the
//! electrical row outputs ([`InferenceResult::bits`]) and tallies
//! divergent images; [`Engine::canary_report`] surfaces the counts
//! together with the canary's worst reported noise margin. Sampling is
//! stride-based (`acc += fraction`, fire on wrap) in submission order,
//! so an offline replay of the same trace selects exactly the same
//! batches. Rolling swaps walk the canary like any serving shard, so its
//! designation (a slot index) survives a live reprogram.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use super::api::{
    BackendFactory, Batch, CanaryReport, Capabilities, Engine, InferenceResult, ScaleEvent,
    ScaleEventKind, ScaleLoad, SwapReport, Telemetry, Ticket,
};
use super::error::EngineError;
use super::spec::BackendKind;
use crate::device::{DeviceParams, ReprogramPlan};
use crate::nn::BinaryLayer;

/// Lifecycle of one shard under the rolling-swap / elastic scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardState {
    /// In the dispatch pool, accepting batches.
    Serving,
    /// Out of the pool; outstanding completions are draining (and remain
    /// redeemable through `poll`). Ends in `Reprogramming` for a rolling
    /// swap, `Parked` for a retire.
    Draining,
    /// The shard thread is rewriting its engine's weights in place
    /// (rolling swap).
    Reprogramming,
    /// Reprogrammed (or freshly constructed), about to re-enter the
    /// dispatch pool.
    Rejoining,
    /// Drained and retired from the pool; the slot keeps its cells and
    /// wear history and can be re-activated by a later spawn.
    Parked,
    /// A brand-new slot's worker thread is constructing its engine.
    Spawning,
    /// A parked slot is reprogramming its cells back to the resident
    /// network before rejoining (spawn of a parked slot).
    Programming,
}

impl ShardState {
    pub fn name(self) -> &'static str {
        match self {
            Self::Serving => "serving",
            Self::Draining => "draining",
            Self::Reprogramming => "reprogramming",
            Self::Rejoining => "rejoining",
            Self::Parked => "parked",
            Self::Spawning => "spawning",
            Self::Programming => "programming",
        }
    }
}

/// Reusable shard template: constructs one inner engine serving the given
/// layer stack, on whatever thread calls it. This is what makes an engine
/// *elastic* — [`BackendFactory`] is one-shot, a builder is for the
/// lifetime of the fleet.
pub type ShardBuilder =
    Arc<dyn Fn(Vec<BinaryLayer>) -> crate::Result<Box<dyn Engine>> + Send + Sync>;

/// Programming cost of rewriting a slot's cells to `to`: the per-layer
/// [`ReprogramPlan`] diffs, merged. `from: None` means fresh (all-RESET)
/// cells — the full weight image costs one SET pulse per stored 1.
fn image_plan(
    from: Option<&[BinaryLayer]>,
    to: &[BinaryLayer],
) -> crate::Result<ReprogramPlan> {
    let params = DeviceParams::default();
    if let Some(from) = from {
        anyhow::ensure!(
            from.len() == to.len(),
            "cell image has {} layers but the resident network has {}",
            from.len(),
            to.len()
        );
    }
    let mut total = ReprogramPlan::default();
    for (i, layer) in to.iter().enumerate() {
        let plan = match from {
            Some(f) => ReprogramPlan::diff(&f[i].weights, &layer.weights, &params)?,
            None => {
                let blank: Vec<Vec<bool>> = layer
                    .weights
                    .iter()
                    .map(|row| vec![false; row.len()])
                    .collect();
                ReprogramPlan::diff(&blank, &layer.weights, &params)?
            }
        };
        total.merge(&plan);
    }
    Ok(total)
}

/// Sentinel shard id for tickets parked behind a rolling swap (queued,
/// not yet dispatched to any shard).
const QUEUED: usize = usize::MAX;

/// Work order for a shard thread. Inference carries a [`Batch`], so a
/// packed submission crosses the channel as an `Arc`-shared buffer plus
/// an index range — cloning it for dispatch copies indices, not bits.
enum ShardRequest {
    Infer { ticket: Ticket, batch: Batch },
    Swap { target: Vec<BinaryLayer> },
}

/// Message from a shard thread back to the `ShardedEngine`.
enum ShardEvent {
    /// Engine construction finished (capabilities) or failed (message).
    Built(Result<Capabilities, String>),
    /// One batch completed (or failed), with the shard's telemetry
    /// snapshot taken right after the batch.
    Done {
        ticket: Ticket,
        result: Result<InferenceResult, String>,
        telemetry: Telemetry,
    },
    /// The shard finished (or failed) reprogramming its engine in place.
    Swapped {
        result: Result<SwapReport, String>,
        telemetry: Telemetry,
    },
}

/// One shard: the channel pair to its worker thread plus the scheduler's
/// view of it (capabilities, last telemetry snapshot, in-flight load,
/// lifecycle state).
struct Shard {
    tx: Option<mpsc::Sender<ShardRequest>>,
    rx: mpsc::Receiver<ShardEvent>,
    join: Option<JoinHandle<()>>,
    caps: Capabilities,
    telemetry: Telemetry,
    /// Batches currently submitted to this shard and not yet drained.
    in_flight_batches: usize,
    /// Images in those batches — the least-loaded dispatch key.
    in_flight_images: usize,
    state: ShardState,
    alive: bool,
    /// Cumulative SET+RESET pulses programmed into this slot's cells
    /// (initial image, swaps, spawn programming) — endurance wear.
    pulses: u64,
    /// The weight image the slot's cells physically hold (tracked on
    /// elastic engines so re-spawning a parked slot prices only the
    /// delta back to the resident network).
    cells: Option<Vec<BinaryLayer>>,
    /// A budget veto was already recorded for this parked slot (reset
    /// when it parks again or the resident network changes), so repeated
    /// spawn attempts don't inflate the veto counter.
    vetoed: bool,
    /// The last failure this shard reported. When the worker thread dies
    /// (a remote shard's connection was lost), tickets stranded on the
    /// shard fail with this message — so callers see the typed
    /// `EngineError::Remote` rendering, not a generic thread obituary.
    last_error: Option<String>,
}

/// Bookkeeping for one outstanding ticket.
struct InFlight {
    shard: usize,
    images: usize,
}

/// The in-progress rolling swap: remaining walk order, the shard
/// currently draining/reprogramming, and the accumulating report.
struct RollingSwap {
    target: Vec<BinaryLayer>,
    pending: VecDeque<usize>,
    current: Option<usize>,
    report: SwapReport,
    failed: Option<String>,
}

/// The canary slot and its divergence bookkeeping — see the module docs.
struct CanaryState {
    /// Slot index of the canary shard (the last factory handed to
    /// [`ShardedEngine::with_canary`]).
    shard: usize,
    /// Fraction of submissions mirrored. Stride-sampled, not random:
    /// the selection replays offline from the submission order alone.
    fraction: f64,
    /// Stride accumulator: `acc += fraction` per submission; a mirror
    /// fires on every wrap past 1.0.
    acc: f64,
    /// Shadow ticket → the primary ticket it mirrors.
    shadow_of: HashMap<Ticket, Ticket>,
    /// Primary ticket → the pending comparison, filled from both sides
    /// as completions drain and settled when the second half arrives.
    compare: HashMap<Ticket, CanaryCompare>,
    sampled_images: u64,
    compared_batches: u64,
    divergent_images: u64,
}

/// Both halves of one mirrored batch, captured as they complete.
#[derive(Default)]
struct CanaryCompare {
    primary: Option<Vec<Vec<bool>>>,
    canary: Option<Vec<Vec<bool>>>,
}

impl CanaryState {
    /// Settle `primary`'s comparison if both halves have arrived: count
    /// images whose electrical rows differ between the two fidelities.
    fn settle(&mut self, primary: Ticket) {
        let both = self
            .compare
            .get(&primary)
            .is_some_and(|s| s.primary.is_some() && s.canary.is_some());
        if !both {
            return;
        }
        let slot = self.compare.remove(&primary).expect("checked above");
        let (a, b) = (
            slot.primary.expect("checked above"),
            slot.canary.expect("checked above"),
        );
        self.compared_batches += 1;
        self.divergent_images +=
            a.iter().zip(b.iter()).filter(|(x, y)| x != y).count() as u64;
        // same submission on both sides, so a length mismatch cannot
        // happen — but if it ever did, count the tail as divergence
        // rather than silently truncating the comparison
        self.divergent_images += a.len().abs_diff(b.len()) as u64;
    }
}

/// The in-progress elastic lifecycle walk (at most one at a time, and
/// mutually exclusive with a rolling swap).
#[derive(Clone, Copy, Debug)]
enum ScaleOp {
    /// A slot is joining the pool; `pulses`/`energy`/`time` carry the
    /// programming cost priced for it (updated to the actual report for
    /// parked-slot reprogramming).
    Spawn {
        shard: usize,
        fresh: bool,
        pulses: u64,
        energy: f64,
        time: f64,
    },
    /// A serving slot is draining toward `Parked`.
    Retire { shard: usize },
}

/// N engine shards behind one [`Engine`] — see the module docs.
pub struct ShardedEngine {
    shards: Vec<Shard>,
    caps: Capabilities,
    next_ticket: Ticket,
    /// Rotation origin for the least-loaded tie-break: equal loads
    /// round-robin instead of always favouring shard 0.
    next_pref: usize,
    in_flight: HashMap<Ticket, InFlight>,
    /// Drained completions awaiting redemption, in completion order.
    ready: Vec<(Ticket, Result<InferenceResult, String>)>,
    /// Batches parked while every fitting shard is out of service
    /// (only reachable mid-swap on a 1-shard engine).
    queued: VecDeque<(Ticket, Batch)>,
    swap: Option<RollingSwap>,
    /// A finished rolling swap awaiting redemption via `poll_swap`.
    swap_done: Option<Result<SwapReport, String>>,
    /// Elastic template — `Some` only for autoscale-built engines.
    builder: Option<ShardBuilder>,
    /// The network the serving fleet holds (updated by successful rolling
    /// swaps; what a spawned slot must be programmed to).
    resident: Option<Vec<BinaryLayer>>,
    /// Per-shard pulse-endurance budget (0 = unlimited).
    pulse_budget: u64,
    /// The lifecycle walk currently in flight, if any.
    scale_op: Option<ScaleOp>,
    /// Completed lifecycle events awaiting [`Engine::take_scale_events`].
    events: Vec<ScaleEvent>,
    /// Canary fidelity sampling — `Some` only for fleets built with
    /// [`ShardedEngine::with_canary`].
    canary: Option<CanaryState>,
}

fn shard_main(
    factory: BackendFactory,
    rx: mpsc::Receiver<ShardRequest>,
    tx: mpsc::Sender<ShardEvent>,
) {
    let mut engine = match factory() {
        Ok(engine) => {
            let _ = tx.send(ShardEvent::Built(Ok(engine.capabilities())));
            engine
        }
        Err(e) => {
            let _ = tx.send(ShardEvent::Built(Err(format!("{e:#}"))));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        let evt = match req {
            ShardRequest::Infer { ticket, batch } => ShardEvent::Done {
                ticket,
                result: match &batch {
                    Batch::Bools(images) => engine.infer_batch(images),
                    Batch::Packed(packed) => engine.infer_packed(packed),
                }
                .map_err(|e| format!("{e:#}")),
                telemetry: engine.telemetry(),
            },
            ShardRequest::Swap { target } => ShardEvent::Swapped {
                result: engine.swap_network(target).map_err(|e| format!("{e:#}")),
                telemetry: engine.telemetry(),
            },
        };
        if tx.send(evt).is_err() {
            break; // owner gone — nothing left to report to
        }
        if !engine.healthy() {
            // the engine lost its substrate (a remote shard's connection
            // died) — end the thread so the scheduler sees the closed
            // channel and routes around the dead shard
            break;
        }
    }
}

impl ShardedEngine {
    /// Spawn one worker thread per factory and construct each shard's
    /// engine on its own thread (builds run concurrently). Fails with the
    /// first shard's construction error if any factory fails. The shard
    /// fleet is **fixed**: [`Engine::spawn_shard`]/[`Engine::retire_shard`]
    /// are typed errors — use [`ShardedEngine::elastic`] for that.
    pub fn new(factories: Vec<BackendFactory>) -> crate::Result<Self> {
        Self::assemble(factories)
    }

    /// Fleet with a **canary**: the last factory becomes a
    /// non-dispatching canary shard (normally a parasitic-fidelity twin
    /// of the ideal primaries) and `fraction` of submissions are mirrored
    /// onto it for divergence comparison — see the module docs. The
    /// engine-level capabilities describe the primary pool only; the
    /// canary observes, it never adds capacity.
    pub fn with_canary(factories: Vec<BackendFactory>, fraction: f64) -> crate::Result<Self> {
        anyhow::ensure!(
            factories.len() >= 2,
            "a canary fleet needs at least one primary shard plus the canary"
        );
        anyhow::ensure!(
            fraction > 0.0 && fraction <= 1.0,
            "canary sampling fraction must be in (0, 1], got {fraction}"
        );
        let mut engine = Self::assemble(factories)?;
        let shard = engine.shards.len() - 1;
        let primaries = &engine.shards[..shard];
        engine.caps.shards = shard;
        engine.caps.nodes = primaries.iter().map(|s| s.caps.nodes).sum();
        engine.caps.tiles = primaries.iter().map(|s| s.caps.tiles).sum();
        engine.caps.max_batch = primaries
            .iter()
            .map(|s| s.caps.max_batch)
            .max()
            .unwrap_or(0);
        engine.canary = Some(CanaryState {
            shard,
            fraction,
            acc: 0.0,
            shadow_of: HashMap::new(),
            compare: HashMap::new(),
            sampled_images: 0,
            compared_batches: 0,
            divergent_images: 0,
        });
        Ok(engine)
    }

    /// Slot index of the canary shard, if one is designated.
    pub fn canary_shard(&self) -> Option<usize> {
        self.canary.as_ref().map(|c| c.shard)
    }

    /// Elastic construction: `initial` shards built from `builder` on the
    /// `layers` network, with spawn/retire enabled. Every slot is charged
    /// the full-image programming cost of pulsing `layers` into fresh
    /// cells — endurance wear starts at deployment, not at the first
    /// swap. `pulse_budget` is the per-slot endurance budget further
    /// programming must fit in (0 = unlimited).
    pub fn elastic(
        builder: ShardBuilder,
        layers: Vec<BinaryLayer>,
        initial: usize,
        pulse_budget: u64,
    ) -> crate::Result<Self> {
        Self::elastic_with(builder, layers, initial, pulse_budget, Vec::new())
    }

    /// [`elastic`](ShardedEngine::elastic) plus `extras`: additional
    /// shard slots built from their own one-shot factories (remote shard
    /// hosts joining a local elastic fleet). Extras are full pool members
    /// — dispatch, rolling swaps and retire/spawn treat them exactly like
    /// builder-made slots, and they are charged the same deployment wear
    /// (their cells hold the same image) — but a *new* slot spawned later
    /// always comes from the local `builder`.
    pub fn elastic_with(
        builder: ShardBuilder,
        layers: Vec<BinaryLayer>,
        initial: usize,
        pulse_budget: u64,
        extras: Vec<BackendFactory>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            initial + extras.len() >= 1,
            "elastic engine needs at least one initial shard"
        );
        anyhow::ensure!(!layers.is_empty(), "elastic engine needs a network");
        let mut factories: Vec<BackendFactory> = (0..initial)
            .map(|_| {
                let b = builder.clone();
                let l = layers.clone();
                Box::new(move || (*b)(l)) as BackendFactory
            })
            .collect();
        factories.extend(extras);
        let mut engine = Self::assemble(factories)?;
        let image = image_plan(None, &layers)?;
        for s in &mut engine.shards {
            s.pulses = image.cells_changed();
            s.cells = Some(layers.clone());
        }
        engine.builder = Some(builder);
        engine.resident = Some(layers);
        engine.pulse_budget = pulse_budget;
        Ok(engine)
    }

    fn assemble(factories: Vec<BackendFactory>) -> crate::Result<Self> {
        anyhow::ensure!(
            !factories.is_empty(),
            "sharded engine needs at least one shard"
        );
        let mut pending = Vec::with_capacity(factories.len());
        for (i, factory) in factories.into_iter().enumerate() {
            let (req_tx, req_rx) = mpsc::channel::<ShardRequest>();
            let (evt_tx, evt_rx) = mpsc::channel::<ShardEvent>();
            let join = std::thread::Builder::new()
                .name(format!("xpoint-shard-{i}"))
                .spawn(move || shard_main(factory, req_rx, evt_tx))
                .map_err(|e| anyhow::anyhow!("spawning shard {i} thread: {e}"))?;
            pending.push((req_tx, evt_rx, join));
        }

        let mut shards = Vec::with_capacity(pending.len());
        for (i, (tx, rx, join)) in pending.into_iter().enumerate() {
            // the first event is always Built; dropping the remaining
            // `pending` senders on an early return unwinds the other
            // threads cleanly (their request channels close)
            let caps = match rx.recv() {
                Ok(ShardEvent::Built(Ok(caps))) => caps,
                Ok(ShardEvent::Built(Err(e))) => {
                    anyhow::bail!("shard {i}: backend construction failed: {e}")
                }
                Ok(_) => unreachable!("completion before Built"),
                Err(_) => anyhow::bail!("shard {i}: worker thread died during construction"),
            };
            shards.push(Shard {
                tx: Some(tx),
                rx,
                join: Some(join),
                caps,
                telemetry: Telemetry::default(),
                in_flight_batches: 0,
                in_flight_images: 0,
                state: ShardState::Serving,
                alive: true,
                pulses: 0,
                cells: None,
                vetoed: false,
                last_error: None,
            });
        }

        let first = shards[0].caps;
        let caps = Capabilities {
            kind: BackendKind::Sharded,
            n_in: first.n_in,
            n_out: first.n_out,
            // one batch lands on one shard, so the engine-level limit is
            // the largest single shard's (shards are normally identical)
            max_batch: shards.iter().map(|s| s.caps.max_batch).max().unwrap_or(0),
            nodes: shards.iter().map(|s| s.caps.nodes).sum(),
            tiles: shards.iter().map(|s| s.caps.tiles).sum(),
            shards: shards.len(),
            reports_energy: first.reports_energy,
            pipelined: first.pipelined,
        };
        Ok(Self {
            shards,
            caps,
            next_ticket: 0,
            next_pref: 0,
            in_flight: HashMap::new(),
            ready: Vec::new(),
            queued: VecDeque::new(),
            swap: None,
            swap_done: None,
            builder: None,
            resident: None,
            pulse_budget: 0,
            scale_op: None,
            events: Vec::new(),
            canary: None,
        })
    }

    /// Shards behind this engine.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// In-flight images per shard — the live load the least-loaded
    /// dispatch balances (test/introspection hook).
    pub fn shard_loads(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.in_flight_images).collect()
    }

    /// Lifecycle state per shard (the rolling-swap timeline view).
    pub fn shard_states(&self) -> Vec<ShardState> {
        self.shards.iter().map(|s| s.state).collect()
    }

    /// Whether a rolling swap is currently walking the shards.
    pub fn swap_in_progress(&self) -> bool {
        self.swap.is_some()
    }

    /// Shards currently in the dispatch pool.
    pub fn serving_shards(&self) -> usize {
        self.serving_count()
    }

    /// Cumulative programming pulses per shard slot — the endurance wear
    /// the autoscaler budgets against.
    pub fn shard_wear(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.pulses).collect()
    }

    /// Drain completion channels and advance any in-flight lifecycle walk
    /// without blocking (exhibit/test hook — `submit`/`poll` do this on
    /// every call).
    pub fn pump(&mut self) {
        self.drain_events();
    }

    fn serving_count(&self) -> usize {
        self.shards
            .iter()
            .filter(|s| s.alive && s.state == ShardState::Serving)
            .count()
    }

    /// Re-derive the engine-level capabilities from the serving pool
    /// (called whenever a scale operation changes the pool).
    fn recompute_caps(&mut self) {
        let serving: Vec<&Shard> = self
            .shards
            .iter()
            .filter(|s| s.alive && s.state == ShardState::Serving)
            .collect();
        self.caps.shards = serving.len().max(1);
        if !serving.is_empty() {
            self.caps.nodes = serving.iter().map(|s| s.caps.nodes).sum();
            self.caps.tiles = serving.iter().map(|s| s.caps.tiles).sum();
            self.caps.max_batch = serving
                .iter()
                .map(|s| s.caps.max_batch)
                .max()
                .unwrap_or(self.caps.max_batch);
        }
    }

    /// Fail every outstanding ticket on a shard whose thread is gone.
    fn mark_shard_dead(&mut self, shard: usize) {
        if !self.shards[shard].alive {
            return;
        }
        self.shards[shard].alive = false;
        let dead: Vec<Ticket> = self
            .in_flight
            .iter()
            .filter(|(_, f)| f.shard == shard)
            .map(|(&t, _)| t)
            .collect();
        // strand tickets with the shard's own failure when it reported
        // one (the typed `remote shard at ..` rendering a poll can lift)
        let cause = self.shards[shard]
            .last_error
            .clone()
            .unwrap_or_else(|| format!("shard {shard} worker thread died"));
        for t in dead {
            self.in_flight.remove(&t);
            if let Some(c) = self.canary.as_mut() {
                if let Some(primary) = c.shadow_of.remove(&t) {
                    // a dead canary abandons its comparisons; the
                    // primary's result stays redeemable on its own shard
                    c.compare.remove(&primary);
                    continue;
                }
                // a dead mirrored primary can never complete its half
                c.compare.remove(&t);
            }
            self.ready.push((t, Err(cause.clone())));
        }
        self.shards[shard].in_flight_batches = 0;
        self.shards[shard].in_flight_images = 0;
    }

    fn apply_event(&mut self, shard: usize, evt: ShardEvent) {
        match evt {
            // the initial fleet's Built events are consumed in assemble();
            // during operation one only arrives for a freshly spawned slot
            ShardEvent::Built(res) => {
                if self.shards[shard].state != ShardState::Spawning {
                    return;
                }
                match res {
                    Ok(caps) => {
                        // constructed directly on the resident network —
                        // the full-image cost was priced (and the wear
                        // charged) when the spawn was ordered
                        self.shards[shard].caps = caps;
                        self.shards[shard].state = ShardState::Rejoining;
                    }
                    Err(e) => {
                        // template validated eagerly at spec build; a
                        // runtime construction failure kills only the slot
                        // (and must not fail silently — the autoscaler
                        // thinks it added capacity)
                        eprintln!(
                            "shard {shard}: spawned slot failed to construct: {e}"
                        );
                        self.shards[shard].alive = false;
                    }
                }
            }
            ShardEvent::Done {
                ticket,
                result,
                telemetry,
            } => {
                self.shards[shard].telemetry = telemetry;
                if let Err(e) = &result {
                    self.shards[shard].last_error = Some(e.clone());
                }
                if let Some(info) = self.in_flight.remove(&ticket) {
                    let s = &mut self.shards[info.shard];
                    s.in_flight_batches = s.in_flight_batches.saturating_sub(1);
                    s.in_flight_images = s.in_flight_images.saturating_sub(info.images);
                }
                if let Some(c) = self.canary.as_mut() {
                    if let Some(primary) = c.shadow_of.remove(&ticket) {
                        // a shadow completion feeds the comparison and is
                        // never redeemable — a failed mirror abandons it
                        match result {
                            Ok(res) => {
                                if let Some(slot) = c.compare.get_mut(&primary) {
                                    slot.canary = Some(res.bits);
                                }
                                c.settle(primary);
                            }
                            Err(_) => {
                                c.compare.remove(&primary);
                            }
                        }
                        return;
                    }
                    if c.compare.contains_key(&ticket) {
                        // a mirrored primary: capture its rows for the
                        // comparison before the caller redeems (and
                        // consumes) the result through `poll`
                        match &result {
                            Ok(res) => {
                                if let Some(slot) = c.compare.get_mut(&ticket) {
                                    slot.primary = Some(res.bits.clone());
                                }
                                c.settle(ticket);
                            }
                            Err(_) => {
                                c.compare.remove(&ticket);
                            }
                        }
                    }
                }
                self.ready.push((ticket, result));
            }
            ShardEvent::Swapped { result, telemetry } => {
                self.shards[shard].telemetry = telemetry;
                let in_rolling_swap = self
                    .swap
                    .as_ref()
                    .is_some_and(|s| s.current == Some(shard));
                if in_rolling_swap {
                    match result {
                        Ok(report) => {
                            self.shards[shard].pulses +=
                                report.set_pulses + report.reset_pulses;
                            if self.builder.is_some() {
                                self.shards[shard].cells =
                                    self.swap.as_ref().map(|s| s.target.clone());
                            }
                            if let Some(swap) = self.swap.as_mut() {
                                swap.report.merge(&report);
                            }
                        }
                        Err(e) => {
                            // the inner engine validates before mutating, so a
                            // failed shard rejoins still serving the old weights
                            if let Some(swap) = self.swap.as_mut() {
                                swap.failed
                                    .get_or_insert_with(|| format!("shard {shard}: {e}"));
                            }
                        }
                    }
                    self.shards[shard].state = ShardState::Rejoining;
                } else if matches!(
                    self.scale_op,
                    Some(ScaleOp::Spawn { shard: s, .. }) if s == shard
                ) {
                    // a parked slot finished reprogramming back to the
                    // resident network
                    match result {
                        Ok(report) => {
                            self.shards[shard].pulses +=
                                report.set_pulses + report.reset_pulses;
                            self.shards[shard].cells = self.resident.clone();
                            self.shards[shard].state = ShardState::Rejoining;
                            if let Some(ScaleOp::Spawn {
                                pulses,
                                energy,
                                time,
                                ..
                            }) = self.scale_op.as_mut()
                            {
                                *pulses = report.set_pulses + report.reset_pulses;
                                *energy = report.energy;
                                *time = report.time;
                            }
                        }
                        Err(e) => {
                            // validate-then-mutate: the slot still holds its
                            // old cells — back to the parking lot (loudly:
                            // the autoscaler thinks it added capacity)
                            eprintln!(
                                "shard {shard}: spawn reprogramming failed ({e}); \
                                 slot re-parked"
                            );
                            self.shards[shard].state = ShardState::Parked;
                            self.scale_op = None;
                        }
                    }
                }
            }
        }
    }

    /// Pull every completion that has already arrived, without blocking,
    /// then advance the rolling swap (drain → reprogram → rejoin) and any
    /// elastic lifecycle walk.
    fn drain_events(&mut self) {
        for i in 0..self.shards.len() {
            loop {
                match self.shards[i].rx.try_recv() {
                    Ok(evt) => self.apply_event(i, evt),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        if self.shards[i].in_flight_batches > 0 {
                            self.mark_shard_dead(i);
                        } else {
                            self.shards[i].alive = false;
                        }
                        break;
                    }
                }
            }
        }
        self.advance();
    }

    /// Drive both lifecycle walks as far as they can go without blocking.
    fn advance(&mut self) {
        self.advance_swap();
        self.advance_scale();
    }

    /// Drive the elastic lifecycle walk forward: park a drained retiree,
    /// return a rejoined spawn to the pool, and publish the completed
    /// event.
    fn advance_scale(&mut self) {
        let Some(op) = self.scale_op else { return };
        match op {
            ScaleOp::Retire { shard } => {
                if !self.shards[shard].alive {
                    self.scale_op = None;
                    self.recompute_caps();
                    return;
                }
                if self.shards[shard].state == ShardState::Draining
                    && self.shards[shard].in_flight_batches == 0
                {
                    self.shards[shard].state = ShardState::Parked;
                    self.shards[shard].vetoed = false; // fresh park, fresh verdict
                    self.scale_op = None;
                    let serving_after = self.serving_count();
                    self.events.push(ScaleEvent {
                        kind: ScaleEventKind::Retire,
                        shard,
                        pulses: 0,
                        energy: 0.0,
                        time: 0.0,
                        serving_after,
                    });
                    self.recompute_caps();
                }
            }
            ScaleOp::Spawn {
                shard,
                fresh,
                pulses,
                energy,
                time,
            } => {
                if !self.shards[shard].alive {
                    self.scale_op = None;
                    self.recompute_caps();
                    return;
                }
                if self.shards[shard].state == ShardState::Rejoining {
                    self.shards[shard].state = ShardState::Serving;
                    self.scale_op = None;
                    let serving_after = self.serving_count();
                    self.events.push(ScaleEvent {
                        kind: ScaleEventKind::Spawn { fresh },
                        shard,
                        pulses,
                        energy,
                        time,
                        serving_after,
                    });
                    self.recompute_caps();
                    self.flush_queued();
                }
                // Spawning/Programming: still waiting on the shard thread
            }
        }
    }

    /// Drive the rolling swap forward as far as it can go without
    /// blocking: pick the next shard, drain it, hand it the reprogram
    /// order, and return it to the pool when it reports back.
    fn advance_swap(&mut self) {
        loop {
            let Some(swap) = self.swap.as_mut() else { return };
            match swap.current {
                None => {
                    let Some(i) = swap.pending.pop_front() else {
                        // walk complete: publish the aggregate report
                        let finished = self.swap.take().expect("active swap");
                        if finished.failed.is_none() && self.builder.is_some() {
                            // the serving fleet now holds the target — what
                            // future spawns must program slots to. Parked
                            // slots' spawn deltas changed with it, so their
                            // budget verdicts are re-evaluated (and
                            // re-reported) on the next spawn attempt.
                            self.resident = Some(finished.target.clone());
                            for s in &mut self.shards {
                                s.vetoed = false;
                            }
                        }
                        self.swap_done = Some(match finished.failed {
                            Some(msg) => Err(msg),
                            None => Ok(finished.report),
                        });
                        self.flush_queued();
                        return;
                    };
                    if !self.shards[i].alive {
                        swap.failed.get_or_insert_with(|| {
                            format!("shard {i} worker thread died before its swap")
                        });
                        continue;
                    }
                    self.shards[i].state = ShardState::Draining;
                    swap.current = Some(i);
                }
                Some(i) => {
                    if !self.shards[i].alive {
                        swap.failed.get_or_insert_with(|| {
                            format!("shard {i} worker thread died mid-swap")
                        });
                        swap.current = None;
                        continue;
                    }
                    match self.shards[i].state {
                        ShardState::Draining => {
                            if self.shards[i].in_flight_batches > 0 {
                                return; // completions still outstanding
                            }
                            let target = swap.target.clone();
                            let sent = self.shards[i]
                                .tx
                                .as_ref()
                                .expect("senders live until drop")
                                .send(ShardRequest::Swap { target });
                            if sent.is_err() {
                                swap.failed.get_or_insert_with(|| {
                                    format!("shard {i} worker thread is down")
                                });
                                swap.current = None;
                                self.mark_shard_dead(i);
                                continue;
                            }
                            self.shards[i].state = ShardState::Reprogramming;
                            return;
                        }
                        // waiting for the shard thread's Swapped event
                        ShardState::Reprogramming => return,
                        ShardState::Rejoining => {
                            self.shards[i].state = ShardState::Serving;
                            swap.current = None;
                            self.flush_queued();
                            continue;
                        }
                        // unreachable: the walk only visits Serving shards
                        _ => return,
                    }
                }
            }
        }
    }

    /// Least-loaded `Serving` shard admitting a batch of `n` images; ties
    /// resolve in rotation order from `next_pref`, so an all-idle engine
    /// round-robins instead of pinning shard 0.
    fn pick_shard(&self, n: usize) -> Option<usize> {
        let n_shards = self.shards.len();
        let mut best: Option<usize> = None;
        for k in 0..n_shards {
            let i = (self.next_pref + k) % n_shards;
            // the canary observes mirrored samples only — it is never a
            // primary dispatch target
            if self.canary.as_ref().is_some_and(|c| c.shard == i) {
                continue;
            }
            let s = &self.shards[i];
            if !s.alive || s.state != ShardState::Serving || n > s.caps.max_batch {
                continue;
            }
            best = match best {
                Some(b) if self.shards[b].in_flight_images <= s.in_flight_images => Some(b),
                _ => Some(i),
            };
        }
        best
    }

    /// Hand `ticket`'s batch to shard `i` and account it in flight.
    fn send_to(&mut self, i: usize, ticket: Ticket, batch: Batch) -> crate::Result<()> {
        let n = batch.len();
        self.next_pref = (i + 1) % self.shards.len();
        self.shards[i]
            .tx
            .as_ref()
            .expect("senders live until drop")
            .send(ShardRequest::Infer { ticket, batch })
            .map_err(|_| anyhow::anyhow!("shard {i} worker thread is down"))?;
        self.shards[i].in_flight_batches += 1;
        self.shards[i].in_flight_images += n;
        self.in_flight.insert(ticket, InFlight { shard: i, images: n });
        Ok(())
    }

    /// Stride-sample `primary`'s batch onto the canary shard, if one is
    /// designated and currently able to take it. The mirror travels as a
    /// *shadow* ticket: real in-flight accounting on the canary (so
    /// drains and swaps wait for it) but never redeemable through
    /// `poll` — its result feeds the divergence comparison instead.
    fn maybe_mirror(&mut self, primary: Ticket, batch: &Batch) {
        let shard = match self.canary.as_mut() {
            Some(c) => {
                c.acc += c.fraction;
                if c.acc < 1.0 {
                    return;
                }
                c.acc -= 1.0;
                c.shard
            }
            None => return,
        };
        let s = &self.shards[shard];
        if !s.alive || s.state != ShardState::Serving || batch.len() > s.caps.max_batch {
            // the canary is out of service (mid-swap, or dead): the
            // sample is skipped, not queued — canarying is best-effort
            // observation, never a serving dependency
            return;
        }
        // the canary runs the per-cell parasitic walk, so a packed
        // mirror is unpacked here: the sample rides the scalar path (a
        // packed dispatch on the canary would be the typed
        // `EngineError::PackedFidelity`)
        let mirror = match batch {
            Batch::Bools(images) => Batch::Bools(images.clone()),
            Batch::Packed(packed) => Batch::Bools(packed.to_images()),
        };
        let n = mirror.len();
        self.next_ticket += 1;
        let shadow = self.next_ticket;
        let sent = self.shards[shard]
            .tx
            .as_ref()
            .expect("senders live until drop")
            .send(ShardRequest::Infer {
                ticket: shadow,
                batch: mirror,
            });
        if sent.is_err() {
            self.mark_shard_dead(shard);
            return;
        }
        self.shards[shard].in_flight_batches += 1;
        self.shards[shard].in_flight_images += n;
        self.in_flight.insert(shadow, InFlight { shard, images: n });
        let c = self.canary.as_mut().expect("canary checked above");
        c.sampled_images += n as u64;
        c.shadow_of.insert(shadow, primary);
        c.compare.insert(primary, CanaryCompare::default());
    }

    /// Common dispatch behind [`Engine::submit`] and
    /// [`Engine::submit_packed`]: least-loaded shard choice, the mid-swap
    /// park path, and ticket issue — the batch representation only
    /// decides what crosses the worker channel.
    fn submit_any(&mut self, batch: Batch) -> crate::Result<Ticket> {
        self.drain_events();
        let n = batch.len();
        match self.pick_shard(n) {
            Some(i) => {
                self.next_ticket += 1;
                let ticket = self.next_ticket;
                self.maybe_mirror(ticket, &batch);
                self.send_to(i, ticket, batch)?;
                Ok(ticket)
            }
            None => {
                // a rolling swap can take every fitting shard out of
                // service at once only on a 1-shard engine; park the
                // batch and flush it when the shard rejoins
                let fits = self
                    .shards
                    .iter()
                    .any(|s| s.alive && n <= s.caps.max_batch);
                if self.swap.is_some() && fits {
                    self.next_ticket += 1;
                    let ticket = self.next_ticket;
                    // sampling follows submission order, so a parked
                    // primary still consumes its stride slot (the mirror
                    // runs now; the comparison waits for the flush)
                    self.maybe_mirror(ticket, &batch);
                    self.in_flight
                        .insert(ticket, InFlight { shard: QUEUED, images: n });
                    self.queued.push_back((ticket, batch));
                    return Ok(ticket);
                }
                Err(EngineError::NoShardFits {
                    batch: n,
                    max_batch: self.caps.max_batch,
                }
                .into())
            }
        }
    }

    /// Dispatch parked batches now that a shard may have rejoined the
    /// pool. Tickets whose batch no longer fits any living shard fail
    /// instead of waiting forever.
    fn flush_queued(&mut self) {
        while let Some((ticket, batch)) = self.queued.pop_front() {
            let n = batch.len();
            match self.pick_shard(n) {
                Some(i) => {
                    if let Err(e) = self.send_to(i, ticket, batch) {
                        self.in_flight.remove(&ticket);
                        self.ready.push((ticket, Err(format!("{e:#}"))));
                    }
                }
                None => {
                    if self
                        .shards
                        .iter()
                        .any(|s| s.alive && n <= s.caps.max_batch)
                    {
                        // a fitting shard is just out of service; keep waiting
                        self.queued.push_front((ticket, batch));
                        return;
                    }
                    self.in_flight.remove(&ticket);
                    self.ready.push((
                        ticket,
                        Err(format!("no living shard admits a batch of {n}")),
                    ));
                }
            }
        }
    }

    /// Block until the shard owning `ticket` reports *something* (its
    /// completions arrive in order, so this makes progress toward the
    /// ticket without busy-waiting). Tickets parked behind a rolling swap
    /// wait on the shard currently being walked.
    fn block_on_owner(&mut self, ticket: Ticket) {
        self.drain_events(); // also advances the rolling swap
        let shard = match self.in_flight.get(&ticket) {
            Some(f) if f.shard != QUEUED => f.shard,
            Some(_) => match self.swap.as_ref().and_then(|s| s.current) {
                Some(i) => i,
                None => return, // queue flushes on the next drain
            },
            None => return, // already drained (or failed)
        };
        match self.shards[shard].rx.recv() {
            Ok(evt) => self.apply_event(shard, evt),
            Err(_) => self.mark_shard_dead(shard),
        }
        self.advance();
    }

    /// Block until the rolling swap makes progress (an event from the
    /// shard currently draining or reprogramming).
    fn block_on_swap(&mut self) {
        let Some(i) = self.swap.as_ref().and_then(|s| s.current) else {
            return;
        };
        match self.shards[i].rx.recv() {
            Ok(evt) => self.apply_event(i, evt),
            Err(_) => self.mark_shard_dead(i),
        }
        self.advance();
    }
}

impl Engine for ShardedEngine {
    fn infer_batch(&mut self, images: &[Vec<bool>]) -> crate::Result<InferenceResult> {
        let ticket = self.submit(images.to_vec())?;
        loop {
            if let Some(res) = self.poll(ticket)? {
                return Ok(res);
            }
            self.block_on_owner(ticket);
        }
    }

    fn infer_packed(
        &mut self,
        batch: &crate::nn::packed::PackedBatch,
    ) -> crate::Result<InferenceResult> {
        let ticket = self.submit_packed(batch.clone())?;
        loop {
            if let Some(res) = self.poll(ticket)? {
                return Ok(res);
            }
            self.block_on_owner(ticket);
        }
    }

    fn max_batch(&self) -> usize {
        self.caps.max_batch
    }

    fn capabilities(&self) -> Capabilities {
        self.caps
    }

    /// Aggregate across shards: counters and energy/time sum (both are
    /// physically additive over independent arrays); `utilization`
    /// concatenates the per-shard vectors in shard order. Snapshots are
    /// as of the most recently drained completion.
    fn telemetry(&self) -> Telemetry {
        let mut total = Telemetry::default();
        for s in &self.shards {
            let t = &s.telemetry;
            total.batches += t.batches;
            total.images += t.images;
            total.steps += t.steps;
            total.sim_time += t.sim_time;
            total.energy += t.energy;
            total.compute_energy += t.compute_energy;
            total.link_energy += t.link_energy;
            total.cycles += t.cycles;
            total.link_transfers += t.link_transfers;
            total.link_lines += t.link_lines;
            total.swaps += t.swaps;
            total.program_time += t.program_time;
            total.program_energy += t.program_energy;
            // host-tracked: includes the spawn programming a fresh slot's
            // inner engine never saw (it was constructed on the image)
            total.wear_pulses += s.pulses;
            total.multibit_energy += t.multibit_energy;
            // min-merge: the fleet's margin is its worst shard's (the
            // no-report default is +∞, the identity of this fold)
            total.margin_min = total.margin_min.min(t.margin_min);
            total.utilization.extend(t.utilization.iter().copied());
        }
        total
    }

    fn canary_report(&self) -> Option<CanaryReport> {
        let c = self.canary.as_ref()?;
        Some(CanaryReport {
            sampled_images: c.sampled_images,
            compared_batches: c.compared_batches,
            divergent_images: c.divergent_images,
            margin_min: self.shards[c.shard].telemetry.margin_min,
        })
    }

    fn shard_telemetry(&self) -> Vec<Telemetry> {
        self.shards
            .iter()
            .map(|s| {
                let mut t = s.telemetry.clone();
                t.wear_pulses = s.pulses;
                t
            })
            .collect()
    }

    fn submit(&mut self, images: Vec<Vec<bool>>) -> crate::Result<Ticket> {
        self.submit_any(Batch::Bools(images))
    }

    fn submit_packed(&mut self, batch: crate::nn::packed::PackedBatch) -> crate::Result<Ticket> {
        self.submit_any(Batch::Packed(batch))
    }

    fn poll(&mut self, ticket: Ticket) -> crate::Result<Option<InferenceResult>> {
        self.drain_events();
        // ready first: a shard mid-`Draining` has left the dispatch pool,
        // but its already-completed tickets must stay redeemable (pinned
        // by the drain regression tests) — never a spurious `Empty`
        if let Some(pos) = self.ready.iter().position(|(t, _)| *t == ticket) {
            let (_, result) = self.ready.remove(pos);
            return result.map(Some).map_err(|e| {
                // a remote shard's failure travels the worker channel as
                // its rendering — lift it back into the typed variant so
                // callers can match on EngineError::Remote
                match EngineError::parse_remote(&e) {
                    Some(typed) => typed.into(),
                    None => anyhow::anyhow!("sharded batch failed: {e}"),
                }
            });
        }
        if self.in_flight.contains_key(&ticket) {
            return Ok(None);
        }
        if self.next_ticket == 0 {
            return Err(EngineError::Empty.into());
        }
        Err(EngineError::UnknownTicket(ticket).into())
    }

    /// Blocking rolling swap: `begin_swap` + drive the walk to completion.
    /// Prefer the non-blocking pair under live traffic — this blocks the
    /// caller (but the shard pool keeps serving already-submitted work).
    fn swap_network(&mut self, target: Vec<BinaryLayer>) -> crate::Result<SwapReport> {
        self.begin_swap(target)?;
        loop {
            match self.poll_swap()? {
                Some(report) => return Ok(report),
                None => self.block_on_swap(),
            }
        }
    }

    /// Start a rolling swap: shards will drain and reprogram one at a
    /// time while the rest keep serving. Always returns `Ok(None)` —
    /// redeem the aggregate [`SwapReport`] via
    /// [`poll_swap`](Engine::poll_swap).
    fn begin_swap(&mut self, target: Vec<BinaryLayer>) -> crate::Result<Option<SwapReport>> {
        self.drain_events();
        if self.swap.is_some() || self.swap_done.is_some() {
            return Err(EngineError::SwapInProgress.into());
        }
        if self.scale_op.is_some() {
            return Err(EngineError::ScaleBusy.into());
        }
        if target.is_empty() {
            return Err(EngineError::SwapShape {
                detail: "target stack is empty".into(),
            }
            .into());
        }
        // eager end-to-end shape gate; per-layer dims are checked by each
        // inner engine before it mutates anything
        let (n_in, n_out) = (target[0].n_in(), target[target.len() - 1].n_out());
        if n_in != self.caps.n_in || n_out != self.caps.n_out {
            return Err(EngineError::SwapShape {
                detail: format!(
                    "target serves {n_in}→{n_out} but the shards serve {}→{}",
                    self.caps.n_in, self.caps.n_out
                ),
            }
            .into());
        }
        // walk the serving pool only: parked slots keep their stale cells
        // (a later spawn prices the delta back to the resident network)
        let pending: VecDeque<usize> = (0..self.shards.len())
            .filter(|&i| self.shards[i].alive && self.shards[i].state == ShardState::Serving)
            .collect();
        self.swap = Some(RollingSwap {
            target,
            pending,
            current: None,
            report: SwapReport::default(),
            failed: None,
        });
        self.advance_swap();
        Ok(None)
    }

    fn poll_swap(&mut self) -> crate::Result<Option<SwapReport>> {
        self.drain_events();
        if let Some(done) = self.swap_done.take() {
            return done
                .map(Some)
                .map_err(|e| anyhow::anyhow!("rolling swap failed: {e}"));
        }
        if self.swap.is_some() {
            return Ok(None);
        }
        Err(EngineError::NoSwap.into())
    }

    fn scale_load(&self) -> ScaleLoad {
        ScaleLoad {
            serving: self.serving_count(),
            parked: self
                .shards
                .iter()
                .filter(|s| s.alive && s.state == ShardState::Parked)
                .count(),
            queued_images: self.queued.iter().map(|(_, b)| b.len()).sum(),
            in_flight_images: self.shards.iter().map(|s| s.in_flight_images).sum(),
        }
    }

    /// Bring one more shard into the pool — see the module docs. Prefers
    /// reprogramming the least-worn eligible parked slot (pricing only
    /// the delta its stale cells need); worn slots are vetoed, and with
    /// no eligible slot a fresh one is constructed and charged the full
    /// weight image.
    fn spawn_shard(&mut self) -> crate::Result<usize> {
        self.drain_events();
        let Some(builder) = self.builder.clone() else {
            return Err(EngineError::ScaleUnsupported { kind: "sharded" }.into());
        };
        if self.swap.is_some() || self.swap_done.is_some() || self.scale_op.is_some() {
            return Err(EngineError::ScaleBusy.into());
        }
        let resident = self
            .resident
            .clone()
            .expect("elastic engines track the resident network");

        // 1. least-worn parked slot whose endurance budget admits the
        //    delta back to the resident network
        let mut candidate: Option<(usize, ReprogramPlan)> = None;
        for i in 0..self.shards.len() {
            if !self.shards[i].alive || self.shards[i].state != ShardState::Parked {
                continue;
            }
            let plan = image_plan(self.shards[i].cells.as_deref(), &resident)?;
            if self.pulse_budget > 0
                && self.shards[i].pulses + plan.cells_changed() > self.pulse_budget
            {
                // worn out: never selected for spawn. Record the veto
                // once per park / resident change — repeated spawn
                // attempts against the same worn slot are not news.
                if !self.shards[i].vetoed {
                    self.shards[i].vetoed = true;
                    let serving_after = self.serving_count();
                    self.events.push(ScaleEvent {
                        kind: ScaleEventKind::Veto,
                        shard: i,
                        pulses: plan.cells_changed(),
                        energy: plan.energy,
                        time: plan.time,
                        serving_after,
                    });
                }
                continue;
            }
            let better = match &candidate {
                Some((b, _)) => self.shards[i].pulses < self.shards[*b].pulses,
                None => true,
            };
            if better {
                candidate = Some((i, plan));
            }
        }
        if let Some((i, plan)) = candidate {
            if plan.cells_changed() == 0 {
                // the cells already hold the resident image: rejoin free
                self.shards[i].state = ShardState::Serving;
                let serving_after = self.serving_count();
                self.events.push(ScaleEvent {
                    kind: ScaleEventKind::Spawn { fresh: false },
                    shard: i,
                    pulses: 0,
                    energy: 0.0,
                    time: 0.0,
                    serving_after,
                });
                self.recompute_caps();
                self.flush_queued();
                return Ok(i);
            }
            let sent = self.shards[i]
                .tx
                .as_ref()
                .expect("senders live until drop")
                .send(ShardRequest::Swap { target: resident });
            if sent.is_err() {
                self.mark_shard_dead(i);
                anyhow::bail!("shard {i} worker thread is down");
            }
            self.shards[i].state = ShardState::Programming;
            self.scale_op = Some(ScaleOp::Spawn {
                shard: i,
                fresh: false,
                pulses: plan.cells_changed(),
                energy: plan.energy,
                time: plan.time,
            });
            return Ok(i);
        }

        // 2. no parked slot is eligible: bring up a brand-new slot and
        //    pulse the full weight image into fresh (all-RESET) cells
        let plan = image_plan(None, &resident)?;
        if self.pulse_budget > 0 && plan.cells_changed() > self.pulse_budget {
            return Err(EngineError::PulseBudget {
                needed: plan.cells_changed(),
                budget: self.pulse_budget,
            }
            .into());
        }
        let i = self.shards.len();
        let (req_tx, req_rx) = mpsc::channel::<ShardRequest>();
        let (evt_tx, evt_rx) = mpsc::channel::<ShardEvent>();
        let cells = resident.clone();
        let factory: BackendFactory = Box::new(move || (*builder)(resident));
        let join = std::thread::Builder::new()
            .name(format!("xpoint-shard-{i}"))
            .spawn(move || shard_main(factory, req_rx, evt_tx))
            .map_err(|e| anyhow::anyhow!("spawning shard {i} thread: {e}"))?;
        self.shards.push(Shard {
            tx: Some(req_tx),
            rx: evt_rx,
            join: Some(join),
            // placeholder until the slot's Built event arrives; the slot
            // is not Serving, so dispatch never consults it before then
            caps: self.shards[0].caps,
            telemetry: Telemetry::default(),
            in_flight_batches: 0,
            in_flight_images: 0,
            state: ShardState::Spawning,
            alive: true,
            pulses: plan.cells_changed(),
            cells: Some(cells),
            vetoed: false,
            last_error: None,
        });
        self.scale_op = Some(ScaleOp::Spawn {
            shard: i,
            fresh: true,
            pulses: plan.cells_changed(),
            energy: plan.energy,
            time: plan.time,
        });
        Ok(i)
    }

    /// Park the most-worn serving shard — see the module docs. Its
    /// completed tickets stay redeemable while it drains.
    fn retire_shard(&mut self) -> crate::Result<usize> {
        self.drain_events();
        if self.builder.is_none() {
            return Err(EngineError::ScaleUnsupported { kind: "sharded" }.into());
        }
        if self.swap.is_some() || self.swap_done.is_some() || self.scale_op.is_some() {
            return Err(EngineError::ScaleBusy.into());
        }
        if self.serving_count() <= 1 {
            return Err(EngineError::LastServingShard.into());
        }
        // wear-aware: the most-worn slot rests (ties break low index)
        let mut pick: Option<usize> = None;
        for i in 0..self.shards.len() {
            if !self.shards[i].alive || self.shards[i].state != ShardState::Serving {
                continue;
            }
            pick = match pick {
                Some(b) if self.shards[b].pulses >= self.shards[i].pulses => Some(b),
                _ => Some(i),
            };
        }
        let i = pick.expect("serving_count > 1");
        self.shards[i].state = ShardState::Draining;
        self.scale_op = Some(ScaleOp::Retire { shard: i });
        self.recompute_caps(); // it left the dispatch pool immediately
        self.advance_scale(); // may already be drained
        Ok(i)
    }

    fn take_scale_events(&mut self) -> Vec<ScaleEvent> {
        std::mem::take(&mut self.events)
    }

    fn scale_settled(&self) -> bool {
        self.scale_op.is_none()
    }

    /// Park on the completion channel of the shard most likely to report
    /// next (the one a lifecycle walk waits on, else any shard with work
    /// in flight) — the scheduler's alternative to spinning on `poll`.
    fn wait_event(&mut self, timeout: std::time::Duration) {
        self.drain_events();
        if !self.ready.is_empty() || self.swap_done.is_some() {
            return; // progress is already redeemable
        }
        let target = self
            .swap
            .as_ref()
            .and_then(|s| s.current)
            .or(match self.scale_op {
                Some(ScaleOp::Spawn { shard, .. }) => Some(shard),
                _ => None,
            })
            .or_else(|| {
                (0..self.shards.len())
                    .find(|&i| self.shards[i].alive && self.shards[i].in_flight_batches > 0)
            });
        match target {
            Some(i) => {
                match self.shards[i].rx.recv_timeout(timeout) {
                    Ok(evt) => self.apply_event(i, evt),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => self.mark_shard_dead(i),
                }
                self.advance();
            }
            None => std::thread::sleep(timeout),
        }
    }
}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        for s in &mut self.shards {
            s.tx.take(); // closing the request channel ends the thread
        }
        for s in &mut self.shards {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArraySpec, AutoscaleSpec, EngineSpec};
    use crate::nn::BinaryLayer;
    use crate::util::Pcg32;

    fn layer(seed: u64) -> BinaryLayer {
        let mut rng = Pcg32::seeded(seed);
        BinaryLayer::new(
            (0..8)
                .map(|_| (0..16).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            3,
        )
    }

    fn sharded(shards: usize, rows: usize) -> ShardedEngine {
        let factories = EngineSpec::new(BackendKind::Ideal)
            .with_workers(shards)
            .with_array(ArraySpec {
                rows,
                cols: 32,
                span: Some(16),
                ..ArraySpec::default()
            })
            .with_batching(rows.min(64), 200)
            .with_layers(vec![layer(3)])
            .build_factories()
            .expect("valid spec");
        ShardedEngine::new(factories).expect("shards build")
    }

    fn images(seed: u64, m: usize) -> Vec<Vec<bool>> {
        let mut rng = Pcg32::seeded(seed);
        (0..m)
            .map(|_| (0..16).map(|_| rng.bernoulli(0.4)).collect())
            .collect()
    }

    #[test]
    fn sharded_infer_matches_functional_layer() {
        let l = layer(3);
        let mut e = sharded(3, 32);
        assert_eq!(e.n_shards(), 3);
        let caps = e.capabilities();
        assert_eq!(caps.kind, BackendKind::Sharded);
        assert_eq!(caps.shards, 3);
        assert_eq!(caps.nodes, 3, "one subarray per shard");
        let imgs = images(4, 6);
        let res = e.infer_batch(&imgs).unwrap();
        for (i, img) in imgs.iter().enumerate() {
            assert_eq!(res.bits[i], l.forward(img));
            assert_eq!(res.classes[i], l.argmax(img));
        }
        let tel = e.telemetry();
        assert_eq!((tel.batches, tel.images), (1, 6));
        assert!(tel.energy > 0.0);
        assert_eq!(e.shard_telemetry().len(), 3);
        assert!(e.shard_states().iter().all(|&s| s == ShardState::Serving));
    }

    #[test]
    fn packed_submission_matches_scalar_dispatch() {
        use crate::nn::packed::PackedBatch;
        let l = layer(3);
        let mut e = sharded(2, 32);
        let imgs = images(11, 5);
        let packed = PackedBatch::from_images(&imgs).expect("uniform widths");
        let t = e.submit_packed(packed.clone()).unwrap();
        let res = loop {
            match e.poll(t).unwrap() {
                Some(r) => break r,
                None => e.block_on_owner(t),
            }
        };
        let scalar = e.infer_batch(&imgs).unwrap();
        assert_eq!(res.bits, scalar.bits, "packed dispatch parity");
        assert_eq!(res.classes, scalar.classes);
        for (i, img) in imgs.iter().enumerate() {
            assert_eq!(res.bits[i], l.forward(img), "image {i}");
            assert_eq!(res.classes[i], l.argmax(img), "image {i}");
        }
    }

    #[test]
    fn tickets_redeem_out_of_order_with_identity() {
        let l = layer(3);
        let mut e = sharded(2, 32);
        let a = images(5, 5);
        let b = images(6, 2);
        let ta = e.submit(a.clone()).unwrap();
        let tb = e.submit(b.clone()).unwrap();
        assert_ne!(ta, tb);
        // redeem in reverse submission order; blocking helper drives both
        let rb = loop {
            match e.poll(tb).unwrap() {
                Some(r) => break r,
                None => e.block_on_owner(tb),
            }
        };
        let ra = loop {
            match e.poll(ta).unwrap() {
                Some(r) => break r,
                None => e.block_on_owner(ta),
            }
        };
        assert_eq!(rb.bits.len(), 2);
        assert_eq!(ra.bits.len(), 5);
        for (img, bits) in a.iter().zip(&ra.bits) {
            assert_eq!(bits, &l.forward(img), "batch a identity");
        }
        for (img, bits) in b.iter().zip(&rb.bits) {
            assert_eq!(bits, &l.forward(img), "batch b identity");
        }
        // dispatch rotation: two consecutive submits land on different
        // shards deterministically (ties round-robin from next_pref)
        let per_shard = e.shard_telemetry();
        assert_eq!(per_shard.iter().map(|t| t.batches).sum::<u64>(), 2);
        assert!(per_shard.iter().all(|t| t.batches == 1), "one batch each");
        // each ticket redeems exactly once
        assert!(e.poll(ta).is_err());
    }

    #[test]
    fn poll_contract_empty_then_unknown() {
        let mut e = sharded(2, 16);
        let err = e.poll(1).unwrap_err();
        assert!(
            err.to_string().contains("nothing submitted"),
            "fresh engine: {err}"
        );
        let t = e.submit(images(7, 3)).unwrap();
        loop {
            match e.poll(t).unwrap() {
                Some(_) => break,
                None => e.block_on_owner(t),
            }
        }
        let err = e.poll(t).unwrap_err();
        assert!(err.to_string().contains("never issued"), "{err}");
    }

    #[test]
    fn oversized_batch_is_a_typed_error() {
        let mut e = sharded(2, 8);
        let err = e.submit(images(8, 9)).unwrap_err();
        assert!(
            err.to_string().contains("exceeds every shard"),
            "{err}"
        );
    }

    #[test]
    fn blocking_rolling_swap_lands_the_new_weights_on_every_shard() {
        let old = layer(3);
        let new = layer(4);
        assert_ne!(old.weights, new.weights, "distinct checkpoints");
        let mut e = sharded(3, 32);
        let imgs = images(9, 6);
        let before = e.infer_batch(&imgs).unwrap();
        for (i, img) in imgs.iter().enumerate() {
            assert_eq!(before.bits[i], old.forward(img));
        }
        let report = e.swap_network(vec![new.clone()]).unwrap();
        assert_eq!(report.shards, 3, "the walk visited every shard");
        assert!(report.cells_changed > 0 && report.energy > 0.0);
        assert!(e.shard_states().iter().all(|&s| s == ShardState::Serving));
        // every shard now serves the new network: spread batches across
        // all three and check identity
        for seed in 10..16 {
            let batch = images(seed, 2);
            let res = e.infer_batch(&batch).unwrap();
            for (i, img) in batch.iter().enumerate() {
                assert_eq!(res.bits[i], new.forward(img), "post-swap identity");
            }
        }
        assert_eq!(e.telemetry().swaps, 3, "one in-place swap per shard");
    }

    /// Regression: tickets completed (or completing) on a shard that has
    /// entered `Draining` stay redeemable through `poll` — never a
    /// spurious `EngineError::Empty`, never a lost completion.
    #[test]
    fn poll_mid_draining_returns_completed_tickets() {
        let old = layer(3);
        let new = layer(5);
        let mut e = sharded(1, 32);
        // submit work, then immediately start the swap: the single shard
        // goes Serving → Draining with these batches still in flight
        let a = images(21, 4);
        let b = images(22, 3);
        let ta = e.submit(a.clone()).unwrap();
        let tb = e.submit(b.clone()).unwrap();
        assert!(e.begin_swap(vec![new.clone()]).unwrap().is_none());
        // the in-flight tickets must drain with old-weight results
        let ra = loop {
            match e.poll(ta).expect("poll mid-drain must not error") {
                Some(r) => break r,
                None => e.block_on_owner(ta),
            }
        };
        for (img, bits) in a.iter().zip(&ra.bits) {
            assert_eq!(bits, &old.forward(img), "drained ticket is wholly-old");
        }
        let rb = loop {
            match e.poll(tb).expect("poll mid-drain must not error") {
                Some(r) => break r,
                None => e.block_on_owner(tb),
            }
        };
        assert_eq!(rb.bits.len(), 3);
        // drive the swap home and confirm the flip
        let report = loop {
            match e.poll_swap().unwrap() {
                Some(r) => break r,
                None => e.block_on_swap(),
            }
        };
        assert_eq!(report.shards, 1);
        let res = e.infer_batch(&a).unwrap();
        for (img, bits) in a.iter().zip(&res.bits) {
            assert_eq!(bits, &new.forward(img), "post-swap is wholly-new");
        }
    }

    /// A 1-shard engine mid-swap parks new submits instead of failing
    /// them; the queue flushes when the shard rejoins, with new weights.
    #[test]
    fn submits_during_a_single_shard_swap_are_parked_and_flushed() {
        let new = layer(6);
        let mut e = sharded(1, 32);
        assert!(e.begin_swap(vec![new.clone()]).unwrap().is_none());
        let batch = images(23, 3);
        let t = e.submit(batch.clone()).unwrap();
        let res = loop {
            match e.poll(t).unwrap() {
                Some(r) => break r,
                None => e.block_on_owner(t),
            }
        };
        for (img, bits) in batch.iter().zip(&res.bits) {
            assert_eq!(bits, &new.forward(img), "flushed after rejoin → wholly-new");
        }
        // swap report still redeemable exactly once
        let report = loop {
            match e.poll_swap().unwrap() {
                Some(r) => break r,
                None => e.block_on_swap(),
            }
        };
        assert_eq!(report.shards, 1);
        assert!(e.poll_swap().is_err(), "report redeems once");
    }

    /// An elastic engine on an explicit 8×16 layer (`with_layers`), so
    /// the tests can account wear pulses exactly.
    fn elastic_on(layer: BinaryLayer, min: usize, budget: u64) -> ShardedEngine {
        EngineSpec::new(BackendKind::Ideal)
            .with_array(ArraySpec {
                rows: 32,
                cols: 32,
                span: Some(16),
                ..ArraySpec::default()
            })
            .with_batching(32, 200)
            .with_layers(vec![layer])
            .with_autoscale(AutoscaleSpec {
                min_shards: min,
                max_shards: 4,
                pulse_budget: budget,
                ..AutoscaleSpec::default()
            })
            .build_sharded()
            .expect("elastic engine")
    }

    /// Drive an in-flight scale operation to completion (parks on the
    /// walking shard's channel via `wait_event`, so this also exercises
    /// the no-spin path).
    fn settle(e: &mut ShardedEngine) {
        for _ in 0..10_000 {
            if e.scale_settled() {
                return;
            }
            e.wait_event(std::time::Duration::from_millis(1));
        }
        panic!("scale operation never settled");
    }

    fn ones(l: &BinaryLayer) -> u64 {
        l.weights
            .iter()
            .flat_map(|row| row.iter())
            .filter(|&&w| w)
            .count() as u64
    }

    /// Deterministic 8×16 layer: cell `r*16+c` is true iff its flat index
    /// is in `on`.
    fn patterned(on: impl Fn(usize) -> bool) -> BinaryLayer {
        BinaryLayer::new(
            (0..8)
                .map(|r| (0..16).map(|c| on(r * 16 + c)).collect())
                .collect(),
            3,
        )
    }

    #[test]
    fn spawn_and_retire_walk_the_elastic_lifecycle() {
        let l = layer(3);
        let image = ones(&l);
        let mut e = elastic_on(l.clone(), 1, 0);
        assert_eq!(e.serving_shards(), 1);
        assert_eq!(e.shard_wear(), vec![image], "deployment pulses the image");

        // scale up: a fresh slot pays the full image
        let i = e.spawn_shard().expect("spawn");
        assert_eq!(i, 1);
        settle(&mut e);
        assert_eq!(e.serving_shards(), 2);
        assert_eq!(e.shard_wear(), vec![image, image]);
        let events = e.take_scale_events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, ScaleEventKind::Spawn { fresh: true });
        assert_eq!(events[0].pulses, image);
        assert_eq!(events[0].serving_after, 2);
        assert!(events[0].energy > 0.0 && events[0].time > 0.0);

        // both shards serve, bit-exact
        let imgs = images(31, 6);
        let res = e.infer_batch(&imgs).unwrap();
        for (img, bits) in imgs.iter().zip(&res.bits) {
            assert_eq!(bits, &l.forward(img));
        }

        // scale down: drain → park, ticket redeemable, pool shrinks
        let t = e.submit(images(32, 3)).unwrap();
        let r = e.retire_shard().expect("retire");
        settle(&mut e);
        assert_eq!(e.serving_shards(), 1);
        assert_eq!(e.shard_states()[r], ShardState::Parked);
        let res = loop {
            match e.poll(t).expect("ticket survives the retire") {
                Some(res) => break res,
                None => e.block_on_owner(t),
            }
        };
        assert_eq!(res.bits.len(), 3);
        let events = e.take_scale_events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, ScaleEventKind::Retire);
        assert_eq!(events[0].serving_after, 1);

        // scale up again: the parked slot's cells already hold the
        // resident image — rejoin is pulse-free
        let j = e.spawn_shard().expect("respawn");
        assert_eq!(j, r, "parked slot re-activated, not a new one");
        settle(&mut e);
        assert_eq!(e.serving_shards(), 2);
        let events = e.take_scale_events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, ScaleEventKind::Spawn { fresh: false });
        assert_eq!(events[0].pulses, 0, "no delta: free rejoin");
        // telemetry carries the per-slot wear
        let wear: Vec<u64> = e.shard_telemetry().iter().map(|t| t.wear_pulses).collect();
        assert_eq!(wear, vec![image, image]);
        assert_eq!(e.telemetry().wear_pulses, 2 * image);
    }

    #[test]
    fn retiring_the_last_serving_shard_is_a_typed_error() {
        let mut e = elastic_on(layer(3), 1, 0);
        let err = e.retire_shard().unwrap_err();
        assert!(err.to_string().contains("last serving shard"), "{err}");
    }

    #[test]
    fn fixed_fleet_engines_cannot_scale() {
        let mut e = sharded(2, 32);
        let err = e.spawn_shard().unwrap_err();
        assert!(
            err.to_string().contains("cannot spawn or retire shards"),
            "{err}"
        );
        let err = e.retire_shard().unwrap_err();
        assert!(
            err.to_string().contains("cannot spawn or retire shards"),
            "{err}"
        );
        assert!(e.take_scale_events().is_empty());
    }

    #[test]
    fn scale_ops_and_rolling_swaps_are_mutually_exclusive() {
        let mut e = elastic_on(layer(3), 2, 0);
        assert!(e.begin_swap(vec![layer(4)]).unwrap().is_none());
        let err = e.spawn_shard().unwrap_err();
        assert!(err.to_string().contains("already in progress"), "{err}");
        let err = e.retire_shard().unwrap_err();
        assert!(err.to_string().contains("already in progress"), "{err}");
        // drive the swap home; scaling unblocks
        loop {
            match e.poll_swap().unwrap() {
                Some(_) => break,
                None => e.block_on_swap(),
            }
        }
        e.spawn_shard().expect("spawn after the swap settled");
        settle(&mut e);
        assert_eq!(e.serving_shards(), 3);
    }

    /// The wear-budget contract: a parked slot whose cumulative pulses
    /// would exceed the budget is vetoed (never selected), and the spawn
    /// falls through to a fresh slot.
    #[test]
    fn worn_parked_slot_is_vetoed_and_a_fresh_slot_spawns() {
        // old: 20 ones; new = old with 30 SETs (20..50) + 10 RESETs (0..10)
        let old = patterned(|i| i < 20);
        let new = patterned(|i| (10..20).contains(&i) || (20..50).contains(&i));
        assert_eq!(ones(&old), 20);
        assert_eq!(ones(&new), 40);
        // swap cost: 30 + 10 = 40 pulses → post-swap wear 20 + 40 = 60
        let budget = 55;
        let mut e = elastic_on(old.clone(), 2, budget);
        assert_eq!(e.shard_wear(), vec![20, 20]);

        let report = e.swap_network(vec![new.clone()]).expect("rolling swap");
        assert_eq!(report.set_pulses, 2 * 30);
        assert_eq!(report.reset_pulses, 2 * 10);
        assert_eq!(e.shard_wear(), vec![60, 60], "both slots over the 55 budget");

        let r = e.retire_shard().expect("retire");
        settle(&mut e);
        e.take_scale_events();
        assert_eq!(e.shard_states()[r], ShardState::Parked);

        // the parked slot is worn out (60 > 55): vetoed, fresh slot spawns
        // and pays the full 40-pulse image of the *current* network
        let i = e.spawn_shard().expect("spawn");
        assert_eq!(i, 2, "a new slot, not the worn one");
        settle(&mut e);
        assert_eq!(e.shard_states()[r], ShardState::Parked, "never selected");
        assert_eq!(e.serving_shards(), 2);
        let events = e.take_scale_events();
        let kinds: Vec<ScaleEventKind> = events.iter().map(|ev| ev.kind).collect();
        assert!(
            kinds.contains(&ScaleEventKind::Veto),
            "worn slot produced a veto: {kinds:?}"
        );
        let spawn = events
            .iter()
            .find(|ev| ev.kind == (ScaleEventKind::Spawn { fresh: true }))
            .expect("fresh spawn event");
        assert_eq!(spawn.pulses, 40);
        assert_eq!(e.shard_wear(), vec![60, 60, 40]);

        // the spawned slot serves the resident (post-swap) network
        let imgs = images(33, 6);
        let res = e.infer_batch(&imgs).unwrap();
        for (img, bits) in imgs.iter().zip(&res.bits) {
            assert_eq!(bits, &new.forward(img), "spawned slot is wholly-new");
        }
    }

    #[test]
    fn spawn_with_no_eligible_slot_at_all_is_a_typed_pulse_budget_error() {
        // budget below even the fresh image: nothing can ever spawn
        let l = patterned(|i| i < 20);
        let mut e = elastic_on(l, 1, 10);
        let err = e.spawn_shard().unwrap_err();
        assert!(
            err.to_string().contains("endurance budget"),
            "{err}"
        );
        assert_eq!(e.serving_shards(), 1, "fleet unchanged");
    }

    /// Satellite regression (busy-spin fix): `wait_event` parks on the
    /// completion channel — it returns as soon as the shard reports, not
    /// after the timeout — and times out quietly when idle.
    #[test]
    fn wait_event_wakes_on_completions_and_times_out_idle() {
        let mut e = sharded(1, 32);
        let t = e.submit(images(40, 4)).unwrap();
        let started = std::time::Instant::now();
        let res = loop {
            match e.poll(t).unwrap() {
                Some(res) => break res,
                // generous timeout: if wait_event slept it out instead of
                // waking on the completion, this test would take >10 s
                None => e.wait_event(std::time::Duration::from_secs(10)),
            }
        };
        assert_eq!(res.bits.len(), 4);
        assert!(
            started.elapsed() < std::time::Duration::from_secs(5),
            "wait_event failed to wake on the completion"
        );
        // idle: nothing to wait on — sleeps out the (short) timeout
        let started = std::time::Instant::now();
        e.wait_event(std::time::Duration::from_millis(5));
        assert!(started.elapsed() >= std::time::Duration::from_millis(4));
    }

    /// 2 ideal primaries + 1 parasitic canary on the same 8×16 layer and
    /// 32×32 design, sampling `fraction` of submissions.
    fn canary_fleet(primaries: usize, fraction: f64) -> ShardedEngine {
        let array = ArraySpec {
            rows: 32,
            cols: 32,
            span: Some(16),
            ..ArraySpec::default()
        };
        let mut factories = EngineSpec::new(BackendKind::Ideal)
            .with_workers(primaries)
            .with_array(array.clone())
            .with_batching(32, 200)
            .with_layers(vec![layer(3)])
            .build_factories()
            .expect("ideal primaries");
        factories.push(
            EngineSpec::new(BackendKind::Parasitic)
                .with_array(array)
                .with_batching(32, 200)
                .with_layers(vec![layer(3)])
                .build()
                .expect("parasitic canary"),
        );
        ShardedEngine::with_canary(factories, fraction).expect("canary fleet")
    }

    /// Pump until every mirrored batch has been compared (bounded).
    fn settle_canary(e: &mut ShardedEngine, compared: u64) {
        for _ in 0..10_000 {
            if e.canary_report().expect("canary fleet").compared_batches >= compared {
                return;
            }
            e.wait_event(std::time::Duration::from_millis(1));
        }
        panic!("canary comparisons never settled");
    }

    #[test]
    fn canary_mirrors_a_deterministic_sample_and_reports_divergence() {
        let l = layer(3);
        let mut e = canary_fleet(2, 0.5);
        let canary = e.canary_shard().expect("designated");
        assert_eq!(canary, 2, "last slot is the canary");
        // capabilities describe the primary pool only
        assert_eq!(e.capabilities().shards, 2);
        assert_eq!(e.capabilities().nodes, 2);

        // stride 0.5: submissions 2 and 4 fire mirrors (acc wraps at 1.0)
        let sizes = [3usize, 2, 4, 1];
        for (k, &n) in sizes.iter().enumerate() {
            let imgs = images(50 + k as u64, n);
            let res = e.infer_batch(&imgs).unwrap();
            for (img, bits) in imgs.iter().zip(&res.bits) {
                assert_eq!(bits, &l.forward(img), "primary serving is ideal");
            }
        }
        settle_canary(&mut e, 2);
        let report = e.canary_report().expect("canary fleet");
        assert_eq!(report.sampled_images, (sizes[1] + sizes[3]) as u64);
        assert_eq!(report.compared_batches, 2);
        assert!(report.divergent_images <= report.sampled_images);
        // the canary published telemetry with its (finite) design margin
        assert!(report.margin_min.is_finite());
        assert_eq!(e.telemetry().margin_min, report.margin_min, "min-merge");

        // the canary never took primary traffic: every submitted batch
        // landed on a primary, the canary saw exactly the two mirrors
        let per_shard = e.shard_telemetry();
        assert_eq!(per_shard[canary].batches, 2, "mirrors only");
        assert_eq!(
            per_shard[..canary].iter().map(|t| t.batches).sum::<u64>(),
            sizes.len() as u64
        );
    }

    #[test]
    fn packed_submits_on_a_canary_fleet_ride_the_scalar_mirror_path() {
        use crate::nn::packed::PackedBatch;
        let l = layer(3);
        let mut e = canary_fleet(1, 1.0);
        let imgs = images(60, 4);
        let packed = PackedBatch::from_images(&imgs).expect("uniform widths");
        // fraction 1.0: this packed submission is mirrored — the mirror
        // must be unpacked to scalars, or the parasitic canary would
        // reject it with the typed PackedFidelity error
        let res = e.infer_packed(&packed).unwrap();
        for (img, bits) in imgs.iter().zip(&res.bits) {
            assert_eq!(bits, &l.forward(img));
        }
        settle_canary(&mut e, 1);
        let report = e.canary_report().expect("canary fleet");
        assert_eq!(report.sampled_images, 4);
        assert_eq!(report.compared_batches, 1, "mirror completed scalar");
    }

    #[test]
    fn swap_contract_typed_errors() {
        let mut e = sharded(2, 16);
        // poll with no swap begun
        let err = e.poll_swap().unwrap_err();
        assert!(err.to_string().contains("no swap in progress"), "{err}");
        // end-to-end shape mismatch is rejected eagerly
        let mut rng = Pcg32::seeded(77);
        let wrong = BinaryLayer::new(
            (0..8)
                .map(|_| (0..12).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            2,
        );
        let err = e.begin_swap(vec![wrong]).unwrap_err();
        assert!(err.to_string().contains("swap target shape mismatch"), "{err}");
        let err = e.begin_swap(vec![]).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        // double-begin while one is rolling
        assert!(e.begin_swap(vec![layer(4)]).unwrap().is_none());
        let err = e.begin_swap(vec![layer(5)]).unwrap_err();
        assert!(err.to_string().contains("already in progress"), "{err}");
        // drive home so Drop joins cleanly with an empty queue
        loop {
            match e.poll_swap().unwrap() {
                Some(_) => break,
                None => e.block_on_swap(),
            }
        }
    }
}

"""AOT lowering tests: HLO text comes out well-formed."""

from compile.aot import lower_mlp, lower_single_layer


def test_single_layer_lowers_to_hlo_text():
    hlo = lower_single_layer(121, 10)
    assert hlo.startswith("HloModule")
    assert "f32[64,121]" in hlo, "batch input shape present"
    assert "f32[64,10]" in hlo, "output shape present"
    # lowered with return_tuple=True
    assert "ROOT" in hlo


def test_mlp_lowers_to_hlo_text():
    hlo = lower_mlp(121, 64, 10)
    assert hlo.startswith("HloModule")
    assert "f32[64,121]" in hlo
    assert "f32[121,64]" in hlo and "f32[64,10]" in hlo


def test_lowering_is_deterministic():
    assert lower_single_layer(121, 10) == lower_single_layer(121, 10)

//! Paper Fig. 13: NM sweeps over N_row, L_cell, W_cell, N_column for the
//! three wiring configurations.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, black_box, exhibit_header};
use xpoint_imc::report::exhibits::{fig13_sweeps, fig13_table};

fn main() {
    exhibit_header("Paper Fig. 13 — noise-margin sweeps (3 configurations)");
    print!("{}", fig13_table('a', "N_row").render());
    print!("{}", fig13_table('b', "L_cell/L_min").render());
    print!("{}", fig13_table('c', "W_cell/W_min").render());
    print!("{}", fig13_table('d', "N_column").render());

    println!("\nshape checks vs paper:");
    let a = fig13_sweeps('a');
    let c3_at_2048 = a[2].points.last().unwrap().1;
    println!(
        "  NM decreases with N_row; config 3 best; NM at N_row=2048: {:.1}% {}",
        c3_at_2048 * 100.0,
        if c3_at_2048 < 0.35 { "(degraded, as in paper)" } else { "" }
    );

    println!();
    bench("fig13 panel (a) full sweep", || {
        black_box(fig13_sweeps('a'));
    });
    bench("fig13 all four panels", || {
        for p in ['a', 'b', 'c', 'd'] {
            black_box(fig13_sweeps(p));
        }
    });
}

//! L3 coordinator: the serving shell around the simulated accelerator —
//! request batching, asynchronous scheduling over submit/poll, and
//! metrics.
//!
//! The paper's contribution is the in-memory compute substrate itself, so
//! the coordinator is deliberately thin: it owns process topology and the
//! batching policy (`⌊N_row/P⌋` images per computational step, Table II)
//! and treats the inference backend as pluggable behind the unified
//! [`Engine`](crate::engine::Engine) trait — scheduler threads are
//! spawned from the [`BackendFactory`] list produced by
//! [`EngineSpec::build_factories`](crate::engine::EngineSpec::build_factories),
//! and each scheduler drives its engine purely through the non-blocking
//! `submit`/`poll` pair (out-of-order completion, per-request identity
//! preserved; see [`engine`]). Per-shard
//! [`Telemetry`](crate::engine::Telemetry) flows into
//! [`MetricsSnapshot::shards`].
//!
//! With an [`AutoscalePolicy`] configured ([`CoordinatorConfig`]), each
//! scheduler also evaluates queue-driven elastic scaling every pass:
//! backlog above the high watermark spawns a shard (endurance budgets
//! veto worn slots), backlog below the low watermark retires one, and
//! every completed scale event lands in the metrics.
//!
//! Nothing here knows whether a shard is local or remote: a
//! [`RemoteBackend`](crate::net::RemoteBackend) (`--remote
//! host:port|unix:/path`) is just another factory in the list, so the
//! same batching, rolling swaps and autoscaling drive a mixed
//! local+remote fleet; a shard whose host dies fails its in-flight
//! tickets with typed [`EngineError::Remote`](crate::engine::EngineError)
//! errors and drops out of the rotation.
//!
//! `Backend` is a re-export of `engine::Engine` (the engine API subsumed
//! the old coordinator-local trait); the concrete backends live in
//! [`crate::engine::backends`] and [`crate::engine::sharded`].

pub mod autoscale;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod trace;

pub use crate::engine::{
    Engine as Backend, BackendFactory, InferenceResult, ShardedEngine, SimBackend, XlaBackend,
};
pub use autoscale::{AutoscalePolicy, ScaleDecision};
pub use batcher::Batcher;
pub use engine::{Coordinator, CoordinatorConfig, Prediction};
pub use metrics::{Metrics, MetricsSnapshot};
pub use trace::TrafficTrace;

//! Minimal CLI argument parser (offline build: no `clap`).

use std::collections::HashMap;

/// Parsed arguments: a subcommand, positional args, `--key value` options
/// and `--flag` booleans.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv\[0\]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                match iter.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = iter.next().unwrap();
                        out.options.insert(key.to_string(), v);
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// From the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> crate::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> crate::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v}")),
        }
    }

    /// A comma-separated option value split into its items, trimmed, with
    /// empties dropped (`--remote a:1,b:2` → `["a:1", "b:2"]`). `None`
    /// when the option was not given; an empty vec when its value held no
    /// items (`--remote ,`).
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect()
        })
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_options_flags() {
        // note: a bare flag followed by a non-dashed token would swallow it
        // as a value — flags go last or before another `--` option
        let a = parse("nm extra --rows 1024 --config 3 --verbose");
        assert_eq!(a.command.as_deref(), Some("nm"));
        assert_eq!(a.get("rows"), Some("1024"));
        assert_eq!(a.get_usize("rows", 0).unwrap(), 1024);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("nm");
        assert_eq!(a.get_usize("rows", 64).unwrap(), 64);
        assert_eq!(a.get_or("config", "1"), "1");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("nm --rows abc");
        assert!(a.get_usize("rows", 0).is_err());
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("serve --demo");
        assert!(a.has_flag("demo"));
    }

    #[test]
    fn comma_lists_split_trim_and_drop_empties() {
        let a = parse("serve --remote a:1,b:2");
        assert_eq!(
            a.get_list("remote"),
            Some(vec!["a:1".to_string(), "b:2".to_string()])
        );
        let a = parse("serve --remote host:9000");
        assert_eq!(a.get_list("remote"), Some(vec!["host:9000".to_string()]));
        // a dangling comma or pure separators yield an empty list, not
        // empty-string items
        let a = parse("serve --remote ,");
        assert_eq!(a.get_list("remote"), Some(vec![]));
        assert_eq!(parse("serve").get_list("remote"), None);
    }
}

//! Generic resistive-network substrate: netlist construction, modified
//! nodal analysis (MNA), and numeric Thevenin extraction.
//!
//! This is the validation backbone for the paper's analytic parasitic model
//! (Appendix A): the same crosspoint ladder is built as a full netlist and
//! solved exactly, and the analytic recursion must agree (see
//! `rust/tests/prop_analysis.rs`).

pub mod matrix;
pub mod netlist;
pub mod solve;
pub mod thevenin;

pub use matrix::Matrix;
pub use netlist::{Netlist, NodeId, GROUND};
pub use solve::Solution;
pub use thevenin::TheveninEquivalent;

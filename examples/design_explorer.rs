//! Design-space exploration: the §VI methodology as a tool — sweep wiring
//! configurations and cell geometries, find the largest electrically-valid
//! subarray for each, and print design guidance.
//!
//! ```bash
//! cargo run --release --example design_explorer
//! ```

use xpoint_imc::analysis::{max_rows_for_nm, noise_margin, ArrayDesign};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::util::si::format_pct;
use xpoint_imc::util::Table;

fn main() {
    println!("3D XPoint design explorer — maximum subarray sizes by configuration\n");

    let mut t = Table::new("max N_row meeting an NM target (N_col = 128, W = W_min)")
        .header(&["config", "L/L_min", "NM ≥ 0%", "NM ≥ 20%", "NM ≥ 40%"]);
    for cfg in LineConfig::all() {
        for l_scale in [1.0, 4.0, 8.0] {
            let template = ArrayDesign::new(1, 128, cfg.clone(), l_scale, 1.0);
            let m0 = max_rows_for_nm(&template, 0.0);
            let m20 = max_rows_for_nm(&template, 0.20);
            let m40 = max_rows_for_nm(&template, 0.40);
            t.row(&[
                cfg.id.to_string(),
                format!("{l_scale:.0}"),
                m0.to_string(),
                m20.to_string(),
                m40.to_string(),
            ]);
        }
    }
    print!("{}", t.render());

    // capacity view: bits per subarray at the NM ≥ 20% boundary
    let mut t = Table::new("capacity at NM ≥ 20% (2 levels × N_row × 128 cells)")
        .header(&["config", "L/L_min", "N_row", "capacity (kbit)", "NM at boundary"]);
    for cfg in LineConfig::all() {
        for l_scale in [4.0, 8.0] {
            let template = ArrayDesign::new(1, 128, cfg.clone(), l_scale, 1.0);
            let n = max_rows_for_nm(&template, 0.20);
            if n == 0 {
                continue;
            }
            let mut d = template.clone();
            d.n_row = n;
            t.row(&[
                cfg.id.to_string(),
                format!("{l_scale:.0}"),
                n.to_string(),
                format!("{}", d.cell_count() / 1024),
                format_pct(noise_margin(&d).noise_margin()),
            ]);
        }
    }
    print!("{}", t.render());

    // the paper's own 2 Mb design point
    let d = ArrayDesign::new(1024, 2048, LineConfig::config3(), 8.0, 1.0).with_span(121);
    println!(
        "\npaper's §VI design: 1024×2048 config 3, cell 36×640 nm ⇒ 2 Mb/level, NM = {} (paper: 34.5%)",
        format_pct(noise_margin(&d).noise_margin())
    );
}

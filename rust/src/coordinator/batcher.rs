//! Dynamic batching: group incoming requests into subarray-sized batches.
//!
//! A batch holds up to `M = N_row` images (the subarray processes the whole
//! batch in `P` steps — `⌊N_row/P⌋` images per step in the paper's
//! accounting). The batcher drains greedily: a full batch ships
//! immediately; a partial batch ships when `linger` expires, trading
//! latency for step efficiency exactly like a serving-system batcher.
//!
//! A shipped batch is packed **once** by the scheduler at intake into an
//! `Arc`-shared [`PackedBatch`](crate::nn::packed::PackedBatch); every
//! hop after that — dispatch to a shard thread, reroute off a dead shard
//! — moves indices over the one shared bit buffer, never image clones.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A queued inference request.
#[derive(Clone, Debug)]
pub struct Request<T> {
    pub id: u64,
    pub payload: T,
    pub enqueued: Instant,
}

/// Greedy size+deadline batcher.
#[derive(Debug)]
pub struct Batcher<T> {
    queue: VecDeque<Request<T>>,
    capacity: usize,
    linger: Duration,
}

impl<T> Batcher<T> {
    pub fn new(capacity: usize, linger: Duration) -> Self {
        assert!(capacity >= 1);
        Self {
            queue: VecDeque::new(),
            capacity,
            linger,
        }
    }

    pub fn push(&mut self, id: u64, payload: T) {
        self.queue.push_back(Request {
            id,
            payload,
            enqueued: Instant::now(),
        });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Take the next batch if ready: either a full batch, or whatever is
    /// queued once the oldest request has lingered past the deadline.
    pub fn take_batch(&mut self, now: Instant) -> Option<Vec<Request<T>>> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now.duration_since(self.queue[0].enqueued);
        if self.queue.len() >= self.capacity || oldest_wait >= self.linger {
            let n = self.queue.len().min(self.capacity);
            return Some(self.queue.drain(..n).collect());
        }
        None
    }

    /// Drain everything (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Request<T>> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_batch_ships_immediately() {
        let mut b = Batcher::new(3, Duration::from_secs(60));
        for i in 0..5 {
            b.push(i, i);
        }
        let batch = b.take_batch(Instant::now()).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn partial_batch_waits_for_linger() {
        let mut b = Batcher::new(10, Duration::from_millis(5));
        b.push(1, ());
        assert!(b.take_batch(Instant::now()).is_none(), "must linger");
        let later = Instant::now() + Duration::from_millis(6);
        let batch = b.take_batch(later).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn empty_queue_yields_none() {
        let mut b: Batcher<()> = Batcher::new(4, Duration::from_millis(1));
        assert!(b.take_batch(Instant::now()).is_none());
    }

    #[test]
    fn drain_all_empties() {
        let mut b = Batcher::new(4, Duration::from_secs(1));
        b.push(1, 'a');
        b.push(2, 'b');
        assert_eq!(b.drain_all().len(), 2);
        assert!(b.is_empty());
    }
}

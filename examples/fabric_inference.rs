//! Pipelined inference on a simulated multi-subarray fabric: tile a
//! three-layer binary network over a grid of 3D XPoint subarrays, stream a
//! batch of digit images through it, and inspect timing, per-subarray
//! utilization, interlink traffic and energy.
//!
//! ```bash
//! cargo run --release --example fabric_inference
//! ```

use xpoint_imc::fabric::{FabricConfig, FabricExecutor};
use xpoint_imc::nn::dataset::{DigitGen, TEST_SEED};
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::report::table2::template_layer;
use xpoint_imc::util::si::{format_duration, format_pct, format_si};

fn main() -> xpoint_imc::Result<()> {
    // 1. a three-layer network: the 10 digit templates as feature
    //    detectors, then two small random binary layers stacked on top
    let l1 = template_layer(); // 121 → 10, θ = 20
    let mut rng = xpoint_imc::util::Pcg32::seeded(2024);
    let mk = |n_out: usize, n_in: usize, theta: usize, rng: &mut xpoint_imc::util::Pcg32| {
        BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            theta,
        )
    };
    let l2 = mk(16, 10, 2, &mut rng);
    let l3 = mk(10, 16, 3, &mut rng);
    println!("network: 121 → 10 → 16 → 10 (binary weights, shared θ per layer)");

    // 2. place it on a 2×2 fabric of 32×32-cell subarrays
    let cfg = FabricConfig::new(2, 2, 32, 32);
    let exec = FabricExecutor::new(vec![l1, l2, l3], cfg)?;
    let p = exec.placement();
    println!(
        "fabric:  2×2 subarrays (32×32 cells), {} weight tiles placed round-robin",
        p.n_tiles()
    );
    for t in &p.tiles {
        println!(
            "         layer {} tile ({},{}) rows {:?} cols {:?} → subarray {}",
            t.layer, t.tile_row, t.tile_col, t.row_range, t.col_range, t.node
        );
    }

    // 3. stream a batch of synthetic digits through the pipeline
    let mut gen = DigitGen::new(TEST_SEED);
    let batch = 48;
    let images: Vec<Vec<bool>> = (0..batch).map(|_| gen.next_sample().pixels).collect();
    let run = exec.run_batch(&images)?;

    println!("\nbatch of {batch} images:");
    println!("  makespan:       {} ({} cycles)", format_duration(run.makespan), run.cycles);
    println!(
        "  throughput:     {} img/s (simulated)",
        format_si(run.throughput(), "")
    );
    println!("  TMVM steps:     {}", run.steps);
    println!(
        "  energy:         {} compute + {} interlink = {} total ({}/image)",
        format_si(run.compute_energy, "J"),
        format_si(run.link_energy, "J"),
        format_si(run.energy, "J"),
        format_si(run.energy / batch as f64, "J"),
    );
    println!(
        "  interlink:      {} hop-transfers, {} line-hops of traffic",
        run.traffic.transfers, run.traffic.lines
    );
    for (n, u) in run.utilization.iter().enumerate() {
        println!("  subarray {n}:     {} busy", format_pct(*u));
    }

    // 4. pipelining: compare with one image alone
    let one = exec.run_batch(&images[..1])?;
    println!(
        "\nper-image latency alone: {} — {} images pipelined in {} ({:.1}× over back-to-back)",
        format_duration(one.makespan),
        batch,
        format_duration(run.makespan),
        batch as f64 * one.makespan / run.makespan
    );

    // 5. the executor is bit-exact with the functional forward chain
    let mismatches = images
        .iter()
        .zip(&run.outputs)
        .filter(|(img, out)| {
            let mut x = (*img).clone();
            for l in exec.layers() {
                x = l.forward(&x);
            }
            &x != *out
        })
        .count();
    println!("functional cross-check: {mismatches} mismatches (must be 0)");
    assert_eq!(mismatches, 0);
    Ok(())
}

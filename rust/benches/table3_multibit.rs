//! Paper Table III: multi-bit TMVM energy/area for the area-efficient and
//! low-power schemes, 1–6 bits.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, black_box, exhibit_header};
use xpoint_imc::report::table3_rows;

fn main() {
    exhibit_header("Paper Table III — multi-bit TMVM energy and area");
    let (ae, lp, table) = table3_rows(0.9);
    print!("{}", table.render());

    println!("\nshape checks vs paper:");
    println!(
        "  AE energy growth 1→3 bits: {:.1}× (paper: 2.0→13.1 pJ ≈ 6.6×)",
        ae[2].energy / ae[0].energy
    );
    println!(
        "  LP energy growth 1→6 bits: {:.2}× (paper: 2.0→2.6 pJ ≈ 1.3×)",
        lp[5].energy / lp[0].energy
    );
    println!(
        "  AE area linear: {:.1}× at 6 bits; LP area exponential: {:.1}× at 6 bits (paper: 3×, 58×)",
        ae[5].area / ae[0].area,
        lp[5].area / lp[0].area
    );
    println!(
        "  AE infeasible beyond 3 bits: {} (max drive voltage at 4 bits: {:.1} V)",
        !ae[3].feasible,
        ae[3].max_voltage
    );

    println!();
    bench("table3 both schemes, 6 widths", || {
        black_box(table3_rows(0.9));
    });
}

//! The serving side of the wire protocol: bind a socket, build one
//! shard's engine, answer [`Msg`] requests — the library behind the
//! `xpoint shard-host` subcommand.
//!
//! One engine, one connection at a time: engines are deliberately not
//! `Send` (PJRT thread-affinity), so the host builds its engine on the
//! serving thread and multiplexing is left to the *fleet* layer — a
//! cluster runs one `shard-host` process per shard, exactly like the
//! in-process fleet runs one worker thread per shard.

use std::io::Write as _;
use std::net::TcpListener;
#[cfg(unix)]
use std::os::unix::net::UnixListener;
#[cfg(unix)]
use std::path::PathBuf;

use crate::engine::{BackendFactory, Engine};

use super::remote::{RemoteAddr, Stream};
use super::wire::{read_frame, write_frame, Msg, WireError, MAGIC};

/// A bound serving socket (TCP or Unix).
pub enum Listener {
    Tcp(TcpListener),
    /// Keeps the socket path so `Drop` can unlink it.
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    /// Bind `addr`. A stale Unix socket file (a previous host that died
    /// without cleanup) is removed first — the common crash-restart case.
    pub fn bind(addr: &RemoteAddr) -> crate::Result<Self> {
        match addr {
            RemoteAddr::Tcp(hostport) => {
                let l = TcpListener::bind(hostport.as_str())
                    .map_err(|e| addr.error(format!("bind failed: {e}")))?;
                Ok(Self::Tcp(l))
            }
            #[cfg(unix)]
            RemoteAddr::Unix(path) => {
                if path.exists() {
                    std::fs::remove_file(path)
                        .map_err(|e| addr.error(format!("removing stale socket: {e}")))?;
                }
                let l = UnixListener::bind(path)
                    .map_err(|e| addr.error(format!("bind failed: {e}")))?;
                Ok(Self::Unix(l, path.clone()))
            }
        }
    }

    /// The bound address as a connectable string (resolves `:0` TCP binds
    /// to the actual port).
    pub fn local_addr_string(&self) -> String {
        match self {
            Self::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "tcp:?".into()),
            #[cfg(unix)]
            Self::Unix(_, path) => format!("unix:{}", path.display()),
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Self::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
            #[cfg(unix)]
            Self::Unix(l, _) => l.accept().map(|(s, _)| Stream::Unix(s)),
        }
    }
}

#[cfg(unix)]
impl Drop for Listener {
    fn drop(&mut self) {
        if let Self::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

enum ConnOutcome {
    /// The client went away (clean EOF or a poisoned stream).
    Closed,
    /// The client ordered the host to exit.
    Shutdown,
}

/// Build the engine from `factory` and serve connections until a
/// [`Msg::Shutdown`] arrives or `max_conns` connections have come and
/// gone (`None` = serve forever). Connections are served one at a time;
/// a decode failure on untrusted bytes answers with [`Msg::Err`] and
/// drops that connection, never the host.
pub fn serve_factory(
    factory: BackendFactory,
    listener: Listener,
    max_conns: Option<usize>,
) -> crate::Result<()> {
    let mut engine = factory()?;
    let mut served = 0usize;
    loop {
        if let Some(max) = max_conns {
            if served >= max {
                return Ok(());
            }
        }
        let stream = match listener.accept() {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow::anyhow!("accept failed: {e}")),
        };
        served += 1;
        if let ConnOutcome::Shutdown = serve_conn(engine.as_mut(), stream) {
            return Ok(());
        }
    }
}

fn serve_conn(engine: &mut dyn Engine, mut stream: Stream) -> ConnOutcome {
    loop {
        let msg = match read_frame(&mut stream) {
            Ok(Some(m)) => m,
            Ok(None) => return ConnOutcome::Closed,
            Err(e) => {
                // tell the peer why before hanging up; if even that write
                // fails the connection was already gone
                let _ = reply(&mut stream, &Msg::Err { detail: e.to_string() });
                return ConnOutcome::Closed;
            }
        };
        let (response, outcome) = handle(engine, msg);
        if reply(&mut stream, &response).is_err() {
            return ConnOutcome::Closed;
        }
        match outcome {
            Some(o) => return o,
            None => continue,
        }
    }
}

/// Map one request to its reply; `Some(outcome)` ends the connection
/// after the reply is written.
fn handle(engine: &mut dyn Engine, msg: Msg) -> (Msg, Option<ConnOutcome>) {
    match msg {
        Msg::Hello { magic } => {
            if magic != MAGIC {
                let detail = WireError::BadMagic(magic).to_string();
                return (Msg::Err { detail }, Some(ConnOutcome::Closed));
            }
            (
                Msg::HelloOk {
                    caps: engine.capabilities(),
                    telemetry: engine.telemetry(),
                },
                None,
            )
        }
        Msg::Infer { id, images } => match engine.infer_batch(&images) {
            Ok(result) => (
                Msg::InferOk {
                    id,
                    result,
                    telemetry: engine.telemetry(),
                },
                None,
            ),
            Err(e) => (Msg::Err { detail: e.to_string() }, None),
        },
        Msg::Swap { target } => match engine.swap_network(target) {
            Ok(report) => (
                Msg::SwapOk {
                    report,
                    telemetry: engine.telemetry(),
                },
                None,
            ),
            Err(e) => (Msg::Err { detail: e.to_string() }, None),
        },
        Msg::Telemetry => (
            Msg::TelemetryOk {
                telemetry: engine.telemetry(),
            },
            None,
        ),
        Msg::Shutdown => (Msg::ShutdownOk, Some(ConnOutcome::Shutdown)),
        // replies arriving as requests mean the peer is desynchronized —
        // answer typed and hang up so it can reconnect cleanly
        other => (
            Msg::Err {
                detail: format!("unexpected {} — this end serves requests", other.name()),
            },
            Some(ConnOutcome::Closed),
        ),
    }
}

fn reply(stream: &mut Stream, msg: &Msg) -> Result<(), WireError> {
    write_frame(stream, msg)?;
    stream.flush().map_err(|e| WireError::Io(e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{BackendKind, EngineSpec, EngineError, ShardedEngine};
    use crate::net::RemoteBackend;
    use std::time::Duration;

    const CONNECT: Duration = Duration::from_secs(5);
    const IO: Duration = Duration::from_secs(10);

    /// One factory for a small deterministic ideal-backend shard.
    fn shard_spec() -> EngineSpec {
        EngineSpec::new(BackendKind::Ideal)
            .with_workers(1)
            .with_array(crate::engine::ArraySpec {
                rows: 64,
                cols: 32,
                span: Some(16),
                ..Default::default()
            })
            .with_batching(16, 200)
            .with_layers(vec![test_layer()])
    }

    fn test_layer() -> crate::nn::BinaryLayer {
        let mut rng = crate::util::Pcg32::seeded(3);
        crate::nn::BinaryLayer::new(
            (0..8)
                .map(|_| (0..16).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            3,
        )
    }

    fn images(seed: u64, n: usize) -> Vec<Vec<bool>> {
        let mut rng = crate::util::Pcg32::seeded(seed);
        (0..n)
            .map(|_| (0..16).map(|_| rng.bernoulli(0.4)).collect())
            .collect()
    }

    /// Bind on an ephemeral TCP port and serve `conns` connections on a
    /// background thread; returns the connectable address.
    fn spawn_host(conns: usize) -> (RemoteAddr, std::thread::JoinHandle<crate::Result<()>>) {
        let listener = Listener::bind(&RemoteAddr::Tcp("127.0.0.1:0".into())).unwrap();
        let addr = RemoteAddr::Tcp(listener.local_addr_string());
        let factory = shard_spec().build_factories().unwrap().pop().unwrap();
        let join = std::thread::spawn(move || serve_factory(factory, listener, Some(conns)));
        (addr, join)
    }

    #[test]
    fn remote_backend_matches_the_local_engine_bit_for_bit() {
        let (addr, join) = spawn_host(1);
        let mut remote = RemoteBackend::connect(addr, CONNECT, IO).unwrap();
        let mut local = shard_spec().build_factories().unwrap().pop().unwrap()().unwrap();
        assert_eq!(remote.capabilities().kind, BackendKind::Remote);
        assert_eq!(remote.capabilities().n_out, local.capabilities().n_out);
        for round in 0..3 {
            let batch = images(round + 10, 12);
            let r = remote.infer_batch(&batch).unwrap();
            let l = local.infer_batch(&batch).unwrap();
            assert_eq!(r, l, "round {round}");
        }
        let t = remote.telemetry();
        assert_eq!(t.batches, 3);
        assert_eq!(t.images, 36);
        assert_eq!(t, local.telemetry());
        drop(remote);
        join.join().unwrap().unwrap();
    }

    #[test]
    fn swaps_propagate_and_application_errors_keep_the_connection() {
        let (addr, join) = spawn_host(1);
        let mut remote = RemoteBackend::connect(addr, CONNECT, IO).unwrap();
        let mut local = shard_spec().build_factories().unwrap().pop().unwrap()().unwrap();

        // an oversized batch is refused by the host's engine (application
        // error): typed, and the connection survives
        let err = remote.infer_batch(&images(1, 1000)).unwrap_err();
        let typed = EngineError::parse_remote(&err.to_string()).expect("typed remote error");
        assert!(matches!(typed, EngineError::Remote { .. }));
        assert!(remote.healthy(), "application errors must not poison the link");

        // rolling-swap order: flip the resident network on both sides
        let mut target = vec![test_layer()];
        for row in &mut target[0].weights {
            for b in row.iter_mut().take(4) {
                *b = !*b;
            }
        }
        let rr = remote.swap_network(target.clone()).unwrap();
        let lr = local.swap_network(target).unwrap();
        assert_eq!(rr, lr);
        let batch = images(77, 8);
        assert_eq!(
            remote.infer_batch(&batch).unwrap(),
            local.infer_batch(&batch).unwrap()
        );
        assert_eq!(remote.telemetry().swaps, 1);
        drop(remote);
        join.join().unwrap().unwrap();
    }

    #[test]
    fn shutdown_order_stops_the_host() {
        let (addr, join) = spawn_host(99);
        let mut remote = RemoteBackend::connect(addr, CONNECT, IO).unwrap();
        remote.shutdown_host().unwrap();
        assert!(!remote.healthy(), "a shut-down host must leave the pool");
        join.join().unwrap().unwrap();
    }

    #[test]
    fn sharded_engine_drives_a_mixed_local_and_remote_fleet() {
        let (addr, join) = spawn_host(1);
        let spec = shard_spec();
        let mut factories = spec.build_factories().unwrap();
        factories.push(crate::net::remote_factory(addr, CONNECT, IO));
        let mut mixed = ShardedEngine::new(factories).unwrap();
        assert_eq!(mixed.capabilities().shards, 2);

        let mut reference = shard_spec().build_factories().unwrap().pop().unwrap()().unwrap();
        let mut tickets = Vec::new();
        for round in 0..6 {
            tickets.push((mixed.submit(images(round + 40, 8)).unwrap(), round + 40));
        }
        for (ticket, seed) in tickets {
            let got = loop {
                if let Some(r) = mixed.poll(ticket).unwrap() {
                    break r;
                }
                mixed.wait_event(Duration::from_millis(5));
            };
            let want = reference.infer_batch(&images(seed, 8)).unwrap();
            assert_eq!((got.bits, got.classes), (want.bits, want.classes));
        }
        // both shards actually served work
        let per_shard = mixed.shard_telemetry();
        assert_eq!(per_shard.len(), 2);
        assert!(per_shard.iter().all(|t| t.images > 0), "{per_shard:?}");
        drop(mixed);
        join.join().unwrap().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_hosts_serve_and_clean_up_their_socket_file() {
        let path = std::env::temp_dir().join(format!(
            "xpoint-host-test-{}.sock",
            std::process::id()
        ));
        let addr = RemoteAddr::Unix(path.clone());
        let listener = Listener::bind(&addr).unwrap();
        let factory = shard_spec().build_factories().unwrap().pop().unwrap();
        let join = std::thread::spawn(move || serve_factory(factory, listener, Some(1)));
        let mut remote = RemoteBackend::connect(addr, CONNECT, IO).unwrap();
        let batch = images(5, 4);
        let r = remote.infer_batch(&batch).unwrap();
        assert_eq!(r.bits.len(), 4);
        drop(remote);
        join.join().unwrap().unwrap();
        assert!(!path.exists(), "socket file must be unlinked on shutdown");
    }
}

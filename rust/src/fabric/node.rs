//! Per-subarray state of the fabric simulator: step occupancy and the
//! count-space TMVM model with the same electrical/energy semantics as the
//! cell-level engine in [`crate::array`] (Eq. 3 at the crystalline
//! endpoint), booked through the shared [`EnergyLedger`].
//!
//! The fabric deliberately does **not** instantiate `2·N_row·N_col` PCM
//! cells per node: computation is count-exact by construction (the
//! partial-count dataflow is what the executor simulates), while currents
//! and energy use exactly the ideal-mode formulas of
//! [`Subarray::tmvm`](crate::array::Subarray::tmvm), which keeps the two
//! engines' ledgers comparable. `node::tests` pins that equivalence
//! against the cell-level engine.

use super::event::Time;
use crate::analysis::LadderThevenin;
use crate::array::EnergyLedger;
use crate::device::DeviceParams;
use crate::nn::packed::{BitMatrix, BitVec};

/// The operating voltage realizing integer firing threshold `theta` —
/// delegates to the shared [`DeviceParams::vdd_for_threshold`], the same
/// expression the cell-level engine uses.
pub fn vdd_for_theta(theta: usize, p: &DeviceParams) -> f64 {
    p.vdd_for_threshold(theta)
}

/// Ideal-mode output current for a row with `count` crystalline products
/// among `active` driven inputs (Eq. 3 with `G_O = G_C`):
/// `I = G_C·V·Σg / (Σg + G_C)` with
/// `Σg = count·G_C + (active − count)·G_A` — amorphous cells on driven
/// word lines still leak `G_A`, exactly as in the cell-level engine.
pub fn row_current(count: u32, active: u32, v_dd: f64, p: &DeviceParams) -> f64 {
    debug_assert!(count <= active);
    // the shared count-space formula — one definition keeps the fabric
    // and the cell-level packed path bit-identical in f64
    crate::array::ideal_row_current(count, active, v_dd, p)
}

/// Result of one tile step: partial dot-product counts for the tile's
/// rows, plus the summed output current (energy/link intensity).
#[derive(Clone, Debug)]
pub struct TileStep {
    pub counts: Vec<u32>,
    /// Driven word lines in this tile's input slice.
    pub active: u32,
    pub current_sum: f64,
}

/// Compute a tile's partial counts for input slice `x` (already sliced to
/// the tile's column range): `counts[r] = Σ_c x[c]·w[r][c]`, with per-row
/// currents drawn through Eq. 3 including amorphous leakage.
pub fn tile_step(weights: &[Vec<bool>], x: &[bool], v_dd: f64, p: &DeviceParams) -> TileStep {
    let active = x.iter().filter(|&&b| b).count() as u32;
    let mut counts = Vec::with_capacity(weights.len());
    let mut current_sum = 0.0;
    for row in weights {
        debug_assert_eq!(row.len(), x.len(), "input slice width");
        let c = row.iter().zip(x).filter(|(&w, &xi)| w && xi).count() as u32;
        current_sum += row_current(c, active, v_dd, p);
        counts.push(c);
    }
    TileStep {
        counts,
        active,
        current_sum,
    }
}

/// [`tile_step`] over pre-packed tile weights: counts come from
/// `popcount(row & x)` per lane, currents accumulate in the same row
/// order through the same [`row_current`], so the result — `counts`,
/// `active` and the f64 `current_sum` — is bit-identical to the scalar
/// form (the executor's determinism test depends on that).
pub fn tile_step_packed(weights: &BitMatrix, x: &BitVec, v_dd: f64, p: &DeviceParams) -> TileStep {
    debug_assert_eq!(weights.n_cols(), x.len(), "input slice width");
    let active = x.count_ones();
    let mut counts = Vec::with_capacity(weights.n_rows());
    let mut current_sum = 0.0;
    for row in 0..weights.n_rows() {
        let c = weights.row_and_count(row, x);
        current_sum += row_current(c, active, v_dd, p);
        counts.push(c);
    }
    TileStep {
        counts,
        active,
        current_sum,
    }
}

/// Result of one parasitic-fidelity tile step: the functional partial
/// counts (identical to the ideal step — thresholding stays count-exact
/// at the row-group heads), plus the attenuated per-row electrical
/// currents the Thevenin ladder actually delivers.
#[derive(Clone, Debug)]
pub struct ParasiticStep {
    /// Partial dot-product counts, bit-identical to [`tile_step`].
    pub counts: Vec<u32>,
    /// Driven word lines in this tile's input slice.
    pub active: u32,
    /// Per-row attenuated output currents \[A\] — bit-exact with the
    /// scalar parasitic oracle
    /// ([`Subarray::tmvm_rows_scalar`](crate::array::Subarray::tmvm_rows_scalar)).
    pub currents: Vec<f64>,
    /// Summed output current (energy/link intensity).
    pub current_sum: f64,
    /// Rows whose attenuated current still reached `I_RESET` — an
    /// operating-window violation the run report surfaces.
    pub reset_violations: u32,
}

impl ParasiticStep {
    /// The count/current view the executor's dataflow consumes.
    pub fn into_tile_step(self) -> TileStep {
        TileStep {
            counts: self.counts,
            active: self.active,
            current_sum: self.current_sum,
        }
    }
}

/// [`tile_step`] at parasitic fidelity: counts stay exact, but every
/// row's current flows through its own Appendix-A Thevenin equivalent
/// (`thevenin[r]` = the ladder seen by local row `r+1` of the tile's
/// subarray). The arithmetic — conductance sum in column order at the
/// programmed endpoints, then `α·V / (R_th + 1/Σg + 1/G_C)`, accumulated
/// in row order — replicates the scalar oracle exactly, so the result is
/// bit-identical in f64 (pinned by `tests/prop_parasitic.rs`).
pub fn tile_step_parasitic(
    weights: &[Vec<bool>],
    x: &[bool],
    v_dd: f64,
    p: &DeviceParams,
    thevenin: &[LadderThevenin],
) -> ParasiticStep {
    debug_assert!(weights.len() <= thevenin.len(), "one ladder per tile row");
    let active = x.iter().filter(|&&b| b).count() as u32;
    let mut counts = Vec::with_capacity(weights.len());
    let mut currents = Vec::with_capacity(weights.len());
    let mut current_sum = 0.0;
    let mut reset_violations = 0u32;
    for (r, row) in weights.iter().enumerate() {
        debug_assert_eq!(row.len(), x.len(), "input slice width");
        let mut count = 0u32;
        // driven-column conductance sum, in column order at the
        // programmed endpoints — the same walk (and f64 accumulation
        // order) as the oracle's `top_conductance` loop
        let mut g_sum = 0.0;
        for (&w, &xi) in row.iter().zip(x) {
            if xi {
                g_sum += if w { p.g_c } else { p.g_a };
                if w {
                    count += 1;
                }
            }
        }
        let i_t = if g_sum == 0.0 {
            0.0
        } else {
            let th = thevenin[r];
            // wire Thevenin drives input network + output cell
            let r_path = th.r_th + 1.0 / g_sum + 1.0 / p.g_c;
            th.alpha * v_dd / r_path
        };
        if i_t >= p.i_reset {
            reset_violations += 1;
        }
        counts.push(count);
        currents.push(i_t);
        current_sum += i_t;
    }
    ParasiticStep {
        counts,
        active,
        currents,
        current_sum,
        reset_violations,
    }
}

/// One physical subarray of the fabric: occupancy for the event scheduler
/// plus the per-node energy/step ledger.
#[derive(Clone, Debug)]
pub struct SubarrayNode {
    pub id: usize,
    pub grid_row: usize,
    pub grid_col: usize,
    /// The node is reserved up to this simulated time.
    pub busy_until: Time,
    /// Energy/busy-time/step accounting (shared ledger type with the
    /// cell-level engine).
    pub ledger: EnergyLedger,
}

impl SubarrayNode {
    pub fn new(id: usize, grid_row: usize, grid_col: usize) -> Self {
        Self {
            id,
            grid_row,
            grid_col,
            busy_until: 0,
            ledger: EnergyLedger::new(),
        }
    }

    /// Reserve the node for one computational step of `dur` ticks,
    /// starting no earlier than `ready`. Returns `(start, end)`; the node
    /// serializes overlapping requests FIFO in reservation order.
    pub fn reserve_step(&mut self, ready: Time, dur: Time) -> (Time, Time) {
        let start = ready.max(self.busy_until);
        let end = start + dur;
        self.busy_until = end;
        (start, end)
    }

    /// Fraction of the run this node spent computing.
    pub fn utilization(&self, makespan_s: f64) -> f64 {
        if makespan_s <= 0.0 {
            0.0
        } else {
            (self.ledger.time / makespan_s).min(1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::ArrayDesign;
    use crate::array::{Level, Subarray, TmvmMode};
    use crate::interconnect::LineConfig;
    use crate::util::Pcg32;

    /// The fabric's count-space current/energy model must agree with the
    /// cell-level TMVM engine row for row.
    #[test]
    fn currents_and_energy_match_cell_level_engine() {
        let mut rng = Pcg32::seeded(71);
        let (n_row, n_col) = (12, 24);
        let weights: Vec<Vec<bool>> = (0..n_row)
            .map(|_| (0..n_col).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let x: Vec<bool> = (0..n_col).map(|_| rng.bernoulli(0.5)).collect();
        let theta = 3;

        let mut sa = Subarray::new(ArrayDesign::new(
            n_row,
            n_col,
            LineConfig::config3(),
            3.0,
            1.0,
        ));
        sa.program_level(Level::Top, &weights);
        let v_cell = sa.vdd_for_threshold(theta);
        let rep = sa.tmvm(&x, 0, v_cell, TmvmMode::Ideal);

        let p = sa.design().device;
        let v_fab = vdd_for_theta(theta, &p);
        assert!((v_fab - v_cell).abs() / v_cell < 1e-12, "same V_DD");

        let step = tile_step(&weights, &x, v_fab, &p);
        for (r, &i_cell) in rep.currents.iter().enumerate() {
            let i_fab = row_current(step.counts[r], step.active, v_fab, &p);
            assert!(
                (i_fab - i_cell).abs() <= 1e-18 + 1e-12 * i_cell.abs(),
                "row {r}: fabric {i_fab} vs cell {i_cell}"
            );
            // thresholding agrees too
            assert_eq!(step.counts[r] as usize >= theta, rep.outputs[r], "row {r}");
        }
        // energy: book the same step through the shared ledger
        let mut ledger = EnergyLedger::new();
        ledger.book_step(v_fab, step.current_sum, p.t_set);
        assert!(
            (ledger.energy - rep.energy).abs() <= 1e-24 + 1e-9 * rep.energy,
            "fabric {} vs cell {}",
            ledger.energy,
            rep.energy
        );
    }

    #[test]
    fn tile_step_counts_are_exact() {
        let w = vec![
            vec![true, true, false, true],
            vec![false, false, false, false],
            vec![true, true, true, true],
        ];
        let x = vec![true, false, true, true];
        let p = DeviceParams::default();
        let step = tile_step(&w, &x, vdd_for_theta(2, &p), &p);
        assert_eq!(step.counts, vec![2, 0, 3]);
        assert_eq!(step.active, 3);
        assert!(step.current_sum > 0.0);
        // the all-zero row still leaks through its amorphous cells
        let leak = row_current(0, 3, vdd_for_theta(2, &p), &p);
        assert!(leak > 0.0 && leak < p.i_set);
    }

    #[test]
    fn packed_tile_step_is_bit_identical_to_scalar() {
        let mut rng = Pcg32::seeded(97);
        let p = DeviceParams::default();
        for &(rows, cols) in &[(3usize, 4usize), (12, 64), (7, 65), (5, 130)] {
            let w: Vec<Vec<bool>> = (0..rows)
                .map(|_| (0..cols).map(|_| rng.bernoulli(0.5)).collect())
                .collect();
            let x: Vec<bool> = (0..cols).map(|_| rng.bernoulli(0.4)).collect();
            let v = vdd_for_theta(2, &p);
            let a = tile_step(&w, &x, v, &p);
            let b = tile_step_packed(&BitMatrix::from_rows(&w), &BitVec::from_bools(&x), v, &p);
            assert_eq!(a.counts, b.counts, "{rows}x{cols}");
            assert_eq!(a.active, b.active);
            // same formula, same accumulation order — exact, not approximate
            assert_eq!(a.current_sum.to_bits(), b.current_sum.to_bits());
        }
    }

    #[test]
    fn reserve_step_serializes_fifo() {
        let mut n = SubarrayNode::new(0, 0, 0);
        let (s1, e1) = n.reserve_step(100, 80);
        assert_eq!((s1, e1), (100, 180));
        // a request arriving earlier still queues behind the reservation
        let (s2, e2) = n.reserve_step(50, 80);
        assert_eq!((s2, e2), (180, 260));
        // idle gap: starts at the ready time
        let (s3, _) = n.reserve_step(1000, 80);
        assert_eq!(s3, 1000);
    }

    #[test]
    fn utilization_is_bounded() {
        let mut n = SubarrayNode::new(0, 0, 0);
        n.ledger.book_step(1.0, 1e-3, 80e-9);
        n.ledger.book_step(1.0, 1e-3, 80e-9);
        assert!((n.utilization(320e-9) - 0.5).abs() < 1e-12);
        assert_eq!(n.utilization(0.0), 0.0);
        assert!(n.utilization(1e-9) <= 1.0);
    }
}

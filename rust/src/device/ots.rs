//! Ovonic threshold switch (OTS) selector model.
//!
//! The OTS sits in series with every PCM element (paper Fig. 1); its sharp
//! voltage threshold is what suppresses sneak paths in the crosspoint array
//! (§II: the OFF conductance is up to 1e8× smaller than ON).

use super::params::DeviceParams;

/// OTS selector: a voltage-controlled switch with hysteresis-free threshold
/// behaviour (the S1 switch of Fig. 2(b) / Table IV).
#[derive(Clone, Copy, Debug, Default)]
pub struct Ots;

impl Ots {
    /// Conductance at a given voltage across the selector.
    pub fn conductance(&self, p: &DeviceParams, v_across: f64) -> f64 {
        if v_across.abs() >= p.ots_v_th {
            p.ots_g_on
        } else {
            p.ots_g_off
        }
    }

    /// Is the selector conducting at this bias?
    pub fn is_on(&self, p: &DeviceParams, v_across: f64) -> bool {
        v_across.abs() >= p.ots_v_th
    }

    /// Worst-case sneak current through an unselected (half-biased OFF)
    /// cell: `G_off · v`.
    pub fn sneak_current(&self, p: &DeviceParams, v_half: f64) -> f64 {
        p.ots_g_off * v_half
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_behaviour() {
        let p = DeviceParams::default();
        let ots = Ots;
        assert_eq!(ots.conductance(&p, 0.1), p.ots_g_off);
        assert_eq!(ots.conductance(&p, 0.5), p.ots_g_on);
        assert_eq!(ots.conductance(&p, -0.5), p.ots_g_on, "bipolar");
        assert!(!ots.is_on(&p, 0.0));
        assert!(ots.is_on(&p, p.ots_v_th));
    }

    #[test]
    fn on_off_ratio_is_large() {
        let p = DeviceParams::default();
        let ots = Ots;
        let ratio = ots.conductance(&p, 1.0) / ots.conductance(&p, 0.0);
        assert!(ratio >= 1e6);
    }

    #[test]
    fn sneak_current_is_negligible_vs_signal() {
        // A floated line sits near half-bias; the sneak current through an
        // OFF selector must be orders of magnitude below I_SET for the
        // thresholded computation to be trustworthy.
        let p = DeviceParams::default();
        let sneak = Ots.sneak_current(&p, 0.15);
        assert!(sneak < 1e-3 * p.i_set, "sneak {sneak} vs I_SET {}", p.i_set);
    }
}

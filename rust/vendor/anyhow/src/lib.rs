//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so instead of the real
//! dependency this vendored crate implements exactly the surface the
//! workspace uses:
//!
//! * [`Error`] — a boxed-free error with a context chain; `{}` prints the
//!   outermost message, `{:#}` the full `outer: inner: …` chain (matching
//!   anyhow's alternate formatting).
//! * [`Result<T>`] with the `E = Error` default.
//! * A blanket `From<E: std::error::Error>` so `?` lifts std errors.
//! * The [`Context`] extension trait for `Result` and `Option`.
//! * The [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what keeps the blanket `From` coherent.

use std::error::Error as StdError;
use std::fmt;

/// Error with a chain of context messages (outermost first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the chain messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, anyhow-style
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // flatten the std source chain into our message chain
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(Error {
                msg,
                source: err.map(Box::new),
            });
        }
        err.expect("at least one message")
    }
}

/// `anyhow::Result<T>` — like `std::result::Result` with a default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

// One impl covers both std errors (via the blanket `From`) and
// `anyhow::Error` itself (via the identity `From`) with no overlap.
impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ctx(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("parsing the flag")?;
        Ok(n)
    }

    #[test]
    fn from_std_error_and_context_chain() {
        let err = parse_ctx("abc").unwrap_err();
        assert_eq!(err.to_string(), "parsing the flag");
        let full = format!("{err:#}");
        assert!(full.starts_with("parsing the flag: "), "{full}");
        assert!(parse_ctx("42").is_ok());
    }

    #[test]
    fn option_context_and_macros() {
        fn f(x: Option<u8>) -> Result<u8> {
            let v = x.context("missing value")?;
            ensure!(v < 10, "value {v} too large");
            if v == 9 {
                bail!("nine is right out");
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(f(None).unwrap_err().to_string(), "missing value");
        assert_eq!(f(Some(20)).unwrap_err().to_string(), "value 20 too large");
        let e = anyhow!("x = {}", 5);
        assert_eq!(e.to_string(), "x = 5");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let err = Error::msg("root").context("mid").context("top");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("top") && dbg.contains("Caused by:") && dbg.contains("root"));
        assert_eq!(err.root_cause(), "root");
    }
}

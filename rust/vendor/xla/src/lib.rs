//! Offline stub of the `xla` crate surface used by `xpoint-imc`.
//!
//! The real crate wraps `xla_extension` (PJRT); that shared library is not
//! available in this offline build environment, so every entry point that
//! would touch PJRT returns a descriptive [`Error`] instead. The runtime
//! integration tests skip themselves when the AOT artifacts are absent, so
//! this stub only needs to typecheck the call sites — and to fail with a
//! useful message if someone runs `xpoint serve --xla` without the real
//! runtime.

use std::fmt;

/// Stub error type (implements `std::error::Error`, so `?` lifts it into
/// `anyhow::Error` at the call sites).
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: the XLA/PJRT runtime is unavailable in this offline build \
         (vendored stub `xla` crate; install xla_extension and swap the real \
         dependency in rust/Cargo.toml to enable the golden-model backend)"
    )))
}

/// Host literal (the stub only stores the host buffer + shape).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1(data: &[f32]) -> Self {
        let dims = vec![data.len() as i64];
        Self {
            data: data.to_vec(),
            dims,
        }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.data.len() as i64 {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Split a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Array shape of this literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub).
#[derive(Clone, Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
#[derive(Clone, Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_checks_count() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn pjrt_entry_points_error_descriptively() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}

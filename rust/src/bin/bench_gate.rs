//! `bench_gate` — the CI perf-regression gate over the machine-readable
//! bench output.
//!
//! The benches write `BENCH_<name>.json` files (throughput, cycles,
//! energy per exhibit case) when `BENCH_JSON_DIR` is set; this gate
//! compares each case's **throughput** against the checked-in baseline
//! (`rust/benches/baseline.json`) and exits non-zero on a regression
//! beyond the configured tolerance (default 20%). Gated throughputs are
//! *simulated* images/s — deterministic and machine-independent, so one
//! baseline serves every runner.
//!
//! Baseline schema:
//!
//! ```json
//! {
//!   "tolerance": 0.2,
//!   "mode": "enforce",            // or "bootstrap"
//!   "benches": {
//!     "fabric_pipeline": { "grid 1x1 batch 32": { "throughput": 1.2e6 } }
//!   }
//! }
//! ```
//!
//! In `bootstrap` mode (or for cases whose baseline throughput is
//! `null`) the gate only sanity-checks the measurements and writes
//! `baseline.calibrated.json` next to the measured JSON — check its
//! values into `benches/baseline.json` and flip the mode to `enforce`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use xpoint_imc::cli::Args;
use xpoint_imc::util::io::read_text;
use xpoint_imc::util::json::Json;

/// One measured case.
struct Measured {
    case: String,
    throughput: f64,
}

/// The verdict for one baseline entry.
enum Verdict {
    Pass { ratio: f64 },
    Regression { ratio: f64 },
    Missing,
    Unbaselined,
}

/// Core comparison (unit-tested below): measured vs baseline throughput
/// under a relative tolerance. `None` baseline means "record only".
fn compare(measured: Option<f64>, baseline: Option<f64>, tolerance: f64) -> Verdict {
    match (measured, baseline) {
        (None, _) => Verdict::Missing,
        (Some(_), None) => Verdict::Unbaselined,
        (Some(m), Some(b)) => {
            let ratio = if b > 0.0 { m / b } else { f64::INFINITY };
            if ratio < 1.0 - tolerance {
                Verdict::Regression { ratio }
            } else {
                Verdict::Pass { ratio }
            }
        }
    }
}

fn load_measured(dir: &Path, bench: &str) -> xpoint_imc::Result<Vec<Measured>> {
    let path = dir.join(format!("BENCH_{bench}.json"));
    let text = read_text(&path)?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let cases = match doc.get("cases") {
        Some(Json::Arr(cases)) => cases,
        _ => anyhow::bail!("{}: missing 'cases' array", path.display()),
    };
    let mut out = Vec::with_capacity(cases.len());
    for c in cases {
        let name = c
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("{}: case without a name", path.display()))?;
        let throughput = c
            .get("throughput")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("{}: case '{name}' has no throughput", path.display()))?;
        anyhow::ensure!(
            throughput.is_finite() && throughput > 0.0,
            "{}: case '{name}' has degenerate throughput {throughput}",
            path.display()
        );
        out.push(Measured {
            case: name.to_string(),
            throughput,
        });
    }
    Ok(out)
}

fn run(args: &Args) -> xpoint_imc::Result<bool> {
    let baseline_path = PathBuf::from(args.get_or("baseline", "benches/baseline.json"));
    let dir = PathBuf::from(args.get_or("dir", "target/bench-json"));

    let text = read_text(&baseline_path)?;
    let baseline = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", baseline_path.display()))?;
    let tolerance = baseline
        .get("tolerance")
        .and_then(Json::as_f64)
        .unwrap_or(0.2);
    let bootstrap = baseline
        .get("mode")
        .and_then(Json::as_str)
        .is_some_and(|m| m == "bootstrap");
    let benches = match baseline.get("benches") {
        Some(Json::Obj(entries)) => entries,
        _ => anyhow::bail!("{}: missing 'benches' object", baseline_path.display()),
    };

    let mut ok = true;
    let mut calibrated: Vec<(String, Json)> = Vec::new();
    for (bench, expected) in benches {
        let measured = load_measured(&dir, bench)?;
        let expected = match expected {
            Json::Obj(entries) => entries.as_slice(),
            _ => anyhow::bail!("baseline bench '{bench}' must be an object"),
        };
        // every baselined case must be measured and fast enough
        for (case, want) in expected {
            let want_tp = want.get("throughput").and_then(Json::as_f64);
            let got = measured
                .iter()
                .find(|m| &m.case == case)
                .map(|m| m.throughput);
            let verdict = compare(got, if bootstrap { None } else { want_tp }, tolerance);
            match verdict {
                Verdict::Pass { ratio } => {
                    println!("PASS  {bench} :: {case}  ({:.0}% of baseline)", ratio * 100.0);
                }
                Verdict::Regression { ratio } => {
                    ok = false;
                    println!(
                        "FAIL  {bench} :: {case}  throughput fell to {:.0}% of baseline \
                         (tolerance {:.0}%)",
                        ratio * 100.0,
                        tolerance * 100.0
                    );
                }
                Verdict::Missing => {
                    ok = false;
                    println!("FAIL  {bench} :: {case}  not measured (bench case renamed?)");
                }
                Verdict::Unbaselined => {
                    println!(
                        "REC   {bench} :: {case}  measured {:.6e} img/s (no baseline yet)",
                        got.unwrap_or(0.0)
                    );
                }
            }
        }
        // surface measured cases the baseline does not know about
        for m in &measured {
            if !expected.iter().any(|(case, _)| case == &m.case) {
                println!(
                    "NEW   {bench} :: {}  measured {:.6e} img/s (add it to the baseline)",
                    m.case, m.throughput
                );
            }
        }
        calibrated.push((
            bench.clone(),
            Json::Obj(
                measured
                    .iter()
                    .map(|m| {
                        (
                            m.case.clone(),
                            Json::Obj(vec![(
                                "throughput".to_string(),
                                Json::Num(m.throughput),
                            )]),
                        )
                    })
                    .collect(),
            ),
        ));
    }

    // always leave a calibrated baseline next to the measurements — in
    // bootstrap mode this is the file to check in (then flip to enforce)
    let calibrated = Json::Obj(vec![
        ("tolerance".to_string(), Json::Num(tolerance)),
        ("mode".to_string(), Json::Str("enforce".into())),
        ("benches".to_string(), Json::Obj(calibrated)),
    ]);
    let out = dir.join("baseline.calibrated.json");
    let mut text = calibrated.pretty();
    text.push('\n');
    std::fs::write(&out, text)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", out.display()))?;
    println!("calibrated baseline written to {}", out.display());
    if bootstrap {
        println!(
            "bootstrap mode: measurements sanity-checked only — check the calibrated \
             baseline into benches/baseline.json and set \"mode\": \"enforce\""
        );
    }
    Ok(ok)
}

fn main() -> ExitCode {
    let args = Args::from_env();
    match run(&args) {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => {
            eprintln!("bench_gate: throughput regression detected");
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("bench_gate: {e:#}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_applies_the_tolerance_band() {
        assert!(matches!(
            compare(Some(100.0), Some(100.0), 0.2),
            Verdict::Pass { .. }
        ));
        // 81% of baseline: inside the 20% band
        assert!(matches!(
            compare(Some(81.0), Some(100.0), 0.2),
            Verdict::Pass { .. }
        ));
        // 79%: regression
        assert!(matches!(
            compare(Some(79.0), Some(100.0), 0.2),
            Verdict::Regression { .. }
        ));
        // faster than baseline always passes
        assert!(matches!(
            compare(Some(250.0), Some(100.0), 0.2),
            Verdict::Pass { .. }
        ));
        assert!(matches!(compare(None, Some(100.0), 0.2), Verdict::Missing));
        assert!(matches!(
            compare(Some(50.0), None, 0.2),
            Verdict::Unbaselined
        ));
    }
}

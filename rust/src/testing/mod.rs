//! Mini property-based testing framework (offline replacement for
//! `proptest`).
//!
//! Usage:
//!
//! ```
//! use xpoint_imc::testing::{forall, Config};
//! use xpoint_imc::util::Pcg32;
//!
//! forall(Config::default().cases(200), "addition commutes", |rng: &mut Pcg32| {
//!     let a = rng.range_f64(-1e3, 1e3);
//!     let b = rng.range_f64(-1e3, 1e3);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```
//!
//! On failure the harness panics with the failing seed and case index so the
//! exact case can be replayed with `Config::default().seed(...)`.

use crate::util::Pcg32;

/// Property-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 100,
            // Allow external seed override for replay:
            // XPOINT_PROP_SEED=1234 cargo test
            seed: match std::env::var("XPOINT_PROP_SEED") {
                Ok(s) => s.parse().unwrap_or(0x5eed_0001),
                Err(_) => 0x5eed_0001,
            },
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` for `config.cases` generated cases. `prop` receives a PRNG
/// seeded per-case and returns `Err(description)` on violation.
pub fn forall<F>(config: Config, name: &str, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    for case in 0..config.cases {
        let case_seed = config.seed.wrapping_add(case as u64);
        let mut rng = Pcg32::new(case_seed, 0x70_70);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' falsified at case {case}/{} \
                 (replay: Config::default().seed({case_seed}).cases(1)): {msg}",
                config.cases
            );
        }
    }
}

/// Assert two floats agree to relative tolerance, with a labelled message.
pub fn check_close(label: &str, got: f64, want: f64, tol: f64) -> Result<(), String> {
    if crate::util::stats::approx_eq(got, want, tol) {
        Ok(())
    } else {
        Err(format!(
            "{label}: got {got:.9e}, want {want:.9e} (rel err {:.3e} > tol {tol:.1e})",
            crate::util::stats::rel_err(got, want)
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(Config::default().cases(37), "count", |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 37);
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn failing_property_panics_with_seed() {
        forall(Config::default().cases(10), "always-fails", |_| {
            Err("nope".into())
        });
    }

    #[test]
    fn check_close_reports_error() {
        assert!(check_close("x", 1.0, 1.0 + 1e-12, 1e-9).is_ok());
        let e = check_close("x", 1.0, 2.0, 1e-9).unwrap_err();
        assert!(e.contains("rel err"));
    }

    #[test]
    fn cases_are_distinct() {
        let mut firsts = Vec::new();
        forall(Config::default().cases(20), "distinct", |rng| {
            firsts.push(rng.next_u32());
            Ok(())
        });
        firsts.sort_unstable();
        firsts.dedup();
        assert!(firsts.len() > 15, "case seeds should differ");
    }
}

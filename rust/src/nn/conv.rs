//! 2-D convolution lowered to TMVM (the paper's conclusion lists 2D
//! convolution among the implemented kernels): an im2col unroll turns each
//! output position's receptive field into a TMVM input vector, and each
//! binary filter into a stored weight row.

use super::layer::BinaryLayer;

/// A binary 2-D convolution layer (single input channel, valid padding,
/// stride 1).
#[derive(Clone, Debug)]
pub struct BinaryConv2d {
    /// `filters[f][ky*kw + kx]` ∈ {0,1}.
    pub filters: Vec<Vec<bool>>,
    pub kh: usize,
    pub kw: usize,
    /// Shared firing threshold.
    pub theta: usize,
}

impl BinaryConv2d {
    pub fn new(filters: Vec<Vec<bool>>, kh: usize, kw: usize, theta: usize) -> Self {
        assert!(!filters.is_empty());
        assert!(filters.iter().all(|f| f.len() == kh * kw));
        Self {
            filters,
            kh,
            kw,
            theta,
        }
    }

    /// Output spatial dimensions for an `h×w` input.
    pub fn out_shape(&self, h: usize, w: usize) -> (usize, usize) {
        assert!(h >= self.kh && w >= self.kw);
        (h - self.kh + 1, w - self.kw + 1)
    }

    /// im2col: unroll each output position's receptive field into a row of
    /// the patch matrix (`patches[pos][kidx]`).
    pub fn im2col(&self, image: &[bool], h: usize, w: usize) -> Vec<Vec<bool>> {
        assert_eq!(image.len(), h * w);
        let (oh, ow) = self.out_shape(h, w);
        let mut patches = Vec::with_capacity(oh * ow);
        for oy in 0..oh {
            for ox in 0..ow {
                let mut patch = Vec::with_capacity(self.kh * self.kw);
                for ky in 0..self.kh {
                    for kx in 0..self.kw {
                        patch.push(image[(oy + ky) * w + (ox + kx)]);
                    }
                }
                patches.push(patch);
            }
        }
        patches
    }

    /// As a [`BinaryLayer`] over patch vectors — this is exactly what gets
    /// mapped onto the subarray (patches stored as rows, filters applied as
    /// word-line pulses).
    pub fn as_layer(&self) -> BinaryLayer {
        BinaryLayer::new(self.filters.clone(), self.theta)
    }

    /// Direct (reference) convolution: thresholded popcount per filter and
    /// output position. `out[f][pos]`.
    pub fn forward_direct(&self, image: &[bool], h: usize, w: usize) -> Vec<Vec<bool>> {
        let (oh, ow) = self.out_shape(h, w);
        let mut out = vec![vec![false; oh * ow]; self.filters.len()];
        for (f, filt) in self.filters.iter().enumerate() {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0usize;
                    for ky in 0..self.kh {
                        for kx in 0..self.kw {
                            if filt[ky * self.kw + kx] && image[(oy + ky) * w + (ox + kx)] {
                                acc += 1;
                            }
                        }
                    }
                    out[f][oy * ow + ox] = acc >= self.theta;
                }
            }
        }
        out
    }

    /// Convolution through the im2col + TMVM path (functional).
    pub fn forward_im2col(&self, image: &[bool], h: usize, w: usize) -> Vec<Vec<bool>> {
        let patches = self.im2col(image, h, w);
        let layer = self.as_layer();
        let mut out = vec![vec![false; patches.len()]; self.filters.len()];
        for (pos, patch) in patches.iter().enumerate() {
            for (f, &bit) in layer.forward(patch).iter().enumerate() {
                out[f][pos] = bit;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn im2col_matches_direct_convolution() {
        let mut rng = Pcg32::seeded(31);
        for _ in 0..25 {
            let h = rng.range(3, 12);
            let w = rng.range(3, 12);
            let kh = rng.range(1, h.min(4) + 1);
            let kw = rng.range(1, w.min(4) + 1);
            let n_f = rng.range(1, 5);
            let theta = rng.range(1, kh * kw + 1);
            let filters: Vec<Vec<bool>> = (0..n_f)
                .map(|_| (0..kh * kw).map(|_| rng.bernoulli(0.5)).collect())
                .collect();
            let conv = BinaryConv2d::new(filters, kh, kw, theta);
            let image: Vec<bool> = (0..h * w).map(|_| rng.bernoulli(0.5)).collect();
            assert_eq!(
                conv.forward_direct(&image, h, w),
                conv.forward_im2col(&image, h, w),
                "h={h} w={w} kh={kh} kw={kw} theta={theta}"
            );
        }
    }

    #[test]
    fn edge_detector_fires_on_edges() {
        // 3×1 vertical edge filter on an image with a vertical stripe
        let conv = BinaryConv2d::new(vec![vec![true, true, true]], 3, 1, 3);
        let (h, w) = (5usize, 4usize);
        let mut image = vec![false; h * w];
        for y in 0..h {
            image[y * w + 2] = true; // stripe at x = 2
        }
        let out = conv.forward_direct(&image, h, w);
        let (oh, ow) = conv.out_shape(h, w);
        assert_eq!((oh, ow), (3, 4));
        for oy in 0..oh {
            for ox in 0..ow {
                assert_eq!(out[0][oy * ow + ox], ox == 2, "({oy},{ox})");
            }
        }
    }

    #[test]
    fn patch_count_matches_output_shape() {
        let conv = BinaryConv2d::new(vec![vec![true; 9]], 3, 3, 1);
        let image = vec![true; 11 * 11];
        let patches = conv.im2col(&image, 11, 11);
        assert_eq!(patches.len(), 9 * 9);
        assert!(patches.iter().all(|p| p.len() == 9));
    }
}

//! Integration: live weight reprogramming. Pins the tentpole contracts —
//! a rolling swap over ≥2 shards keeps serving (measured throughput never
//! drops to zero), post-swap outputs are bit-exact with a fresh engine
//! built on the new weights, and a deterministic seeded soak harness
//! (PRNG interleavings of submit/poll/swap across shards ∈ {1, 2, 4})
//! verifies that **every completion reflects wholly-old or wholly-new
//! weights, never a torn mix**, and that every ticket completes exactly
//! once.

use xpoint_imc::engine::{ArraySpec, BackendKind, Engine, EngineSpec, SwapReport};
use xpoint_imc::nn::BinaryLayer;
use xpoint_imc::util::Pcg32;

fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
    BinaryLayer::new(
        (0..n_out)
            .map(|_| (0..n_in).map(|_| rng.bernoulli(0.45)).collect())
            .collect(),
        theta,
    )
}

fn random_images(rng: &mut Pcg32, m: usize, n_in: usize) -> Vec<Vec<bool>> {
    (0..m)
        .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
        .collect()
}

fn chain_forward(layers: &[BinaryLayer], x: &[bool]) -> Vec<bool> {
    let mut v = x.to_vec();
    for l in layers {
        v = l.forward(&v);
    }
    v
}

/// A 3-layer stack with fixed dimensions (24←40, 16←24, 10←16).
fn stack(rng: &mut Pcg32) -> Vec<BinaryLayer> {
    vec![
        random_layer(rng, 24, 40, 6),
        random_layer(rng, 16, 24, 4),
        random_layer(rng, 10, 16, 3),
    ]
}

fn fabric_spec(layers: Vec<BinaryLayer>) -> EngineSpec {
    EngineSpec::new(BackendKind::Fabric)
        .with_layers(layers)
        .with_grid(2, 2)
        .with_tile(16, 16)
        .with_fabric_max_batch(64)
        .with_batching(32, 200)
}

/// Redeem a ticket by spinning on `poll` (shard threads make progress on
/// their own).
fn redeem(
    engine: &mut Box<dyn Engine>,
    ticket: u64,
) -> xpoint_imc::engine::InferenceResult {
    loop {
        match engine.poll(ticket).expect("poll") {
            Some(res) => return res,
            None => std::thread::yield_now(),
        }
    }
}

/// Tentpole acceptance: during a rolling swap over 2 shards, traffic
/// keeps completing (never zero), every mid-swap completion is wholly-old
/// or wholly-new, and the post-swap engine is bit-exact with a fresh
/// engine built on the new weights.
#[test]
fn rolling_swap_over_two_shards_keeps_serving_and_lands_bit_exact() {
    let mut rng = Pcg32::seeded(0x4e11);
    let old = stack(&mut rng);
    let new = stack(&mut rng);
    assert_ne!(old[0].weights, new[0].weights);

    let spec = fabric_spec(old.clone()).with_shards(2, BackendKind::Fabric);
    let mut engine = spec.build_engine().expect("sharded engine");

    // pre-swap: wholly-old
    let probe = random_images(&mut rng, 6, 40);
    let res = engine.infer_batch(&probe).expect("pre-swap batch");
    for (img, bits) in probe.iter().zip(&res.bits) {
        assert_eq!(bits, &chain_forward(&old, img), "pre-swap identity");
    }

    // the rolling swap starts; with 2 shards the first poll always finds
    // it still walking, so at least one batch is served mid-swap
    assert!(engine.begin_swap(new.clone()).expect("begin").is_none());
    let mut served_during_swap = 0usize;
    let mut report: Option<SwapReport> = None;
    for round in 0.. {
        assert!(round < 10_000, "rolling swap never completed");
        match engine.poll_swap().expect("poll_swap") {
            Some(r) => {
                report = Some(r);
                break;
            }
            None => {
                // measured throughput during the swap: this batch completes
                // on the still-serving shard(s)
                let batch = random_images(&mut rng, 3, 40);
                let t = engine.submit(batch.clone()).expect("submit during swap");
                let res = redeem(&mut engine, t);
                let old_bits: Vec<Vec<bool>> =
                    batch.iter().map(|x| chain_forward(&old, x)).collect();
                let new_bits: Vec<Vec<bool>> =
                    batch.iter().map(|x| chain_forward(&new, x)).collect();
                assert!(
                    res.bits == old_bits || res.bits == new_bits,
                    "mid-swap completion is a torn mix (round {round})"
                );
                served_during_swap += res.bits.len();
            }
        }
    }
    assert!(
        served_during_swap > 0,
        "throughput dropped to zero during the rolling swap"
    );
    let report = report.expect("swap report");
    assert_eq!(report.shards, 2, "the walk visited both shards");
    assert!(report.set_pulses > 0 && report.reset_pulses > 0);
    assert!(report.time > 0.0 && report.energy > 0.0);
    assert_eq!(report.cells_total, 2 * (24 * 40 + 16 * 24 + 10 * 16));

    // post-swap: bit-exact with a fresh engine on the new weights, across
    // enough batches to touch both shards
    let mut fresh = fabric_spec(new.clone()).build_engine().expect("fresh engine");
    for _ in 0..4 {
        let batch = random_images(&mut rng, 5, 40);
        let got = engine.infer_batch(&batch).expect("post-swap batch");
        let want = fresh.infer_batch(&batch).expect("fresh batch");
        assert_eq!(got.bits, want.bits, "post-swap bit-exactness");
        assert_eq!(got.classes, want.classes);
    }
    let tel = engine.telemetry();
    assert_eq!(tel.swaps, 2, "one in-place swap per shard");
    assert!(tel.program_energy > 0.0);
}

/// The deterministic soak harness: seeded PRNG interleavings of
/// submit / poll / begin_swap / poll_swap. Invariants checked on every
/// path: each completed batch is wholly-old or wholly-new; every ticket
/// completes exactly once (and re-polling it is a typed error); after the
/// swap report lands, the engine serves only new weights.
fn soak(seed: u64, shards: usize) {
    let mut rng = Pcg32::seeded(seed);
    let old = random_layer(&mut rng, 8, 16, 3);
    let new = random_layer(&mut rng, 8, 16, 4);
    let spec = EngineSpec::new(BackendKind::Ideal)
        .with_array(ArraySpec {
            rows: 16,
            cols: 32,
            span: Some(16),
            ..ArraySpec::default()
        })
        .with_batching(16, 200)
        .with_layers(vec![old.clone()])
        .with_shards(shards, BackendKind::Ideal)
        .with_workers(1);
    let mut engine = spec.build_engine().expect("sharded engine");

    // Vec (not HashMap) so the interleaving is fully seed-deterministic
    let mut outstanding: Vec<(u64, Vec<Vec<bool>>)> = Vec::new();
    let mut redeemed: Vec<u64> = Vec::new();
    let swap_at = rng.range(10, 60);
    let mut swap_started = false;
    let mut report: Option<SwapReport> = None;

    let check = |imgs: &[Vec<bool>], bits: &[Vec<bool>], old: &BinaryLayer, new: &BinaryLayer| {
        let old_bits: Vec<Vec<bool>> = imgs.iter().map(|x| old.forward(x)).collect();
        let new_bits: Vec<Vec<bool>> = imgs.iter().map(|x| new.forward(x)).collect();
        assert!(
            bits == old_bits || bits == new_bits,
            "completion is a torn mix of old and new weights"
        );
    };

    for op in 0..160 {
        if op == swap_at {
            assert!(engine.begin_swap(vec![new.clone()]).expect("begin").is_none());
            swap_started = true;
            continue;
        }
        match rng.range(0, 10) {
            // submit a small batch
            0..=3 => {
                let m = rng.range(1, 6);
                let imgs = random_images(&mut rng, m, 16);
                let t = engine.submit(imgs.clone()).expect("submit");
                outstanding.push((t, imgs));
            }
            // poll a random outstanding ticket (non-blocking)
            4..=7 => {
                if outstanding.is_empty() {
                    continue;
                }
                let k = rng.range(0, outstanding.len());
                let t = outstanding[k].0;
                if let Some(res) = engine.poll(t).expect("poll") {
                    let (t, imgs) = outstanding.swap_remove(k);
                    check(&imgs, &res.bits, &old, &new);
                    redeemed.push(t);
                }
            }
            // drive / redeem the rolling swap
            _ => {
                if swap_started && report.is_none() {
                    report = engine.poll_swap().expect("poll_swap");
                }
            }
        }
    }

    // drain everything still in flight
    while let Some((t, imgs)) = outstanding.pop() {
        let res = redeem(&mut engine, t);
        check(&imgs, &res.bits, &old, &new);
        redeemed.push(t);
    }
    if swap_started && report.is_none() {
        loop {
            match engine.poll_swap().expect("poll_swap") {
                Some(r) => {
                    report = Some(r);
                    break;
                }
                None => std::thread::yield_now(),
            }
        }
    }

    // exactly-once: every redeemed ticket is unique and now unknown
    let mut unique = redeemed.clone();
    unique.sort_unstable();
    unique.dedup();
    assert_eq!(unique.len(), redeemed.len(), "a ticket completed twice");
    for &t in redeemed.iter().take(5) {
        let err = engine.poll(t).expect_err("redeemed tickets are gone");
        assert!(
            err.to_string().contains("never issued or already collected"),
            "{err}"
        );
    }

    // the swap landed on every shard: the engine is wholly-new now
    if swap_started {
        let report = report.expect("report collected");
        assert_eq!(report.shards, shards, "seed {seed:#x} shards {shards}");
        let imgs = random_images(&mut rng, 8, 16);
        let res = engine.infer_batch(&imgs).expect("post-swap batch");
        for (img, bits) in imgs.iter().zip(&res.bits) {
            assert_eq!(
                bits,
                &new.forward(img),
                "post-swap inference must be wholly-new (seed {seed:#x})"
            );
        }
        assert_eq!(engine.telemetry().swaps, shards as u64);
    }
}

/// Acceptance: the soak harness passes for ≥3 distinct seeds, at every
/// shard count the scheduler distinguishes (1 exercises the parked-submit
/// queue, 2 and 4 the rolling walk around serving shards).
#[test]
fn soak_seed_a_across_shard_counts() {
    for shards in [1usize, 2, 4] {
        soak(0x50a1, shards);
    }
}

#[test]
fn soak_seed_b_across_shard_counts() {
    for shards in [1usize, 2, 4] {
        soak(0x50a2, shards);
    }
}

#[test]
fn soak_seed_c_across_shard_counts() {
    for shards in [1usize, 2, 4] {
        soak(0x50a3, shards);
    }
}

/// Satellite regression: a shard mid-`Draining` must hand back its
/// already-completed tickets through `poll` — never a spurious
/// `EngineError::Empty`, never a lost completion — and the drained
/// results are wholly-old.
#[test]
fn draining_shard_returns_completed_tickets_not_empty() {
    let mut rng = Pcg32::seeded(0xd4a1);
    let old = stack(&mut rng);
    let new = stack(&mut rng);
    let spec = fabric_spec(old.clone()).with_shards(2, BackendKind::Fabric);
    let mut engine = spec.build_engine().expect("sharded engine");

    // load both shards, then immediately begin the swap: the first shard
    // enters Draining with work still in flight
    let batches: Vec<Vec<Vec<bool>>> =
        (0..4).map(|_| random_images(&mut rng, 4, 40)).collect();
    let tickets: Vec<u64> = batches
        .iter()
        .map(|b| engine.submit(b.clone()).expect("submit"))
        .collect();
    assert!(engine.begin_swap(new).expect("begin").is_none());

    for (k, t) in tickets.into_iter().enumerate() {
        let res = loop {
            match engine.poll(t) {
                Ok(Some(res)) => break res,
                Ok(None) => std::thread::yield_now(),
                Err(e) => panic!("poll mid-drain errored (batch {k}): {e:#}"),
            }
        };
        for (img, bits) in batches[k].iter().zip(&res.bits) {
            assert_eq!(
                bits,
                &chain_forward(&old, img),
                "batch {k} drained with old weights"
            );
        }
    }
    // drive the swap home so the engine drops cleanly
    loop {
        match engine.poll_swap().expect("poll_swap") {
            Some(r) => {
                assert_eq!(r.shards, 2);
                break;
            }
            None => std::thread::yield_now(),
        }
    }
}

//! Bit-packed hot-path representation for binary networks.
//!
//! The paper's TMVM is binary end-to-end — weights, inputs and thresholded
//! outputs are single bits — so the hot-path currency is `u64` lanes, not
//! `Vec<bool>`: a dot-product count is `count_ones(weights & inputs)`
//! summed per lane (word-parallel popcount, 64 products per instruction),
//! the same layout XNOR/binary inference engines use.
//!
//! Two invariants every container here maintains:
//!
//! * **row-major lanes** — a row of `n` bits occupies `⌈n/64⌉` words, bit
//!   `i` lives in word `i / 64` at position `i % 64` (LSB-first);
//! * **tail masking** — bits past the logical width of the last word are
//!   always zero, so popcount over whole words never over-counts and two
//!   equal bit patterns are equal as word slices.
//!
//! The scalar `Vec<bool>` kernels ([`BinaryLayer::counts`],
//! [`BinaryLayer::forward`], `Subarray::tmvm_rows_scalar`,
//! `fabric::node::tile_step`) remain the reference oracle —
//! `tests/prop_packed.rs` pins bit-exactness between the two forms,
//! including widths that are not multiples of 64.
//!
//! [`BinaryLayer::counts`]: super::BinaryLayer::counts
//! [`BinaryLayer::forward`]: super::BinaryLayer::forward

use super::layer::{argmax_counts, BinaryLayer};
use std::ops::Range;
use std::sync::Arc;

/// Words needed to hold `n_bits` bits.
#[inline]
pub fn words_for(n_bits: usize) -> usize {
    n_bits.div_ceil(64)
}

/// Mask selecting the valid bits of the *last* word of an `n_bits`-wide
/// row (`!0` when the width is lane-aligned).
#[inline]
pub fn tail_mask(n_bits: usize) -> u64 {
    match n_bits % 64 {
        0 => !0u64,
        r => (1u64 << r) - 1,
    }
}

/// Popcount of the lane-wise AND of two equally-wide bit rows — the
/// packed dot-product count. Both slices must respect the tail-mask
/// invariant for the count to be exact.
#[inline]
pub fn and_count(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len(), "lane count mismatch");
    a.iter().zip(b).map(|(&x, &y)| (x & y).count_ones()).sum()
}

/// A packed bit vector: `n_bits` logical bits in `⌈n_bits/64⌉` words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    n_bits: usize,
    words: Vec<u64>,
}

impl BitVec {
    /// All-zero vector of `n_bits` bits.
    pub fn zeros(n_bits: usize) -> Self {
        Self {
            n_bits,
            words: vec![0; words_for(n_bits)],
        }
    }

    /// Pack a `&[bool]` row.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut v = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.words[i / 64] |= 1u64 << (i % 64);
            }
        }
        v
    }

    pub fn len(&self) -> usize {
        self.n_bits
    }

    pub fn is_empty(&self) -> bool {
        self.n_bits == 0
    }

    /// The backing lanes (tail bits always zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.n_bits);
        self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    pub fn set(&mut self, i: usize, bit: bool) {
        assert!(i < self.n_bits);
        let (w, m) = (i / 64, 1u64 << (i % 64));
        if bit {
            self.words[w] |= m;
        } else {
            self.words[w] &= !m;
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Unpack to the scalar form.
    pub fn to_bools(&self) -> Vec<bool> {
        (0..self.n_bits).map(|i| self.get(i)).collect()
    }
}

/// A packed row-major bit matrix: `n_rows` rows of `n_cols` bits, each
/// row padded to whole words with a masked tail.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitMatrix {
    n_rows: usize,
    n_cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl BitMatrix {
    /// All-zero matrix.
    pub fn zeros(n_rows: usize, n_cols: usize) -> Self {
        let words_per_row = words_for(n_cols);
        Self {
            n_rows,
            n_cols,
            words_per_row,
            words: vec![0; n_rows * words_per_row],
        }
    }

    /// Pack a rectangular `rows[r][c]` matrix (all rows equally wide).
    pub fn from_rows(rows: &[Vec<bool>]) -> Self {
        let n_cols = rows.first().map_or(0, Vec::len);
        let mut m = Self::zeros(rows.len(), n_cols);
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), n_cols, "row {r} width");
            for (c, &b) in row.iter().enumerate() {
                if b {
                    m.words[r * m.words_per_row + c / 64] |= 1u64 << (c % 64);
                }
            }
        }
        m
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// One row's lanes (tail bits always zero).
    #[inline]
    pub fn row(&self, r: usize) -> &[u64] {
        debug_assert!(r < self.n_rows);
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    pub fn get(&self, r: usize, c: usize) -> bool {
        assert!(r < self.n_rows && c < self.n_cols);
        self.words[r * self.words_per_row + c / 64] & (1u64 << (c % 64)) != 0
    }

    pub fn set(&mut self, r: usize, c: usize, bit: bool) {
        assert!(r < self.n_rows && c < self.n_cols);
        let (w, m) = (r * self.words_per_row + c / 64, 1u64 << (c % 64));
        if bit {
            self.words[w] |= m;
        } else {
            self.words[w] &= !m;
        }
    }

    /// Packed dot-product count of row `r` against `x`
    /// (`popcount(row & x)` per lane).
    #[inline]
    pub fn row_and_count(&self, r: usize, x: &BitVec) -> u32 {
        debug_assert_eq!(x.len(), self.n_cols, "input width");
        and_count(self.row(r), x.words())
    }

    /// Set bits in row `r`.
    pub fn row_count_ones(&self, r: usize) -> u32 {
        self.row(r).iter().map(|w| w.count_ones()).sum()
    }

    /// Unpack one row.
    pub fn row_bools(&self, r: usize) -> Vec<bool> {
        (0..self.n_cols).map(|c| self.get(r, c)).collect()
    }

    /// Unpack to the scalar form.
    pub fn to_rows(&self) -> Vec<Vec<bool>> {
        (0..self.n_rows).map(|r| self.row_bools(r)).collect()
    }
}

/// Packed form of a [`BinaryLayer`]: weights as a [`BitMatrix`], counts
/// as per-lane popcounts. Bit-exact with the scalar layer by
/// construction (`tests/prop_packed.rs` pins it).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedLayer {
    /// `weights[out][in]` packed row-major.
    pub weights: BitMatrix,
    /// Shared firing threshold θ (see [`BinaryLayer::theta`]).
    pub theta: usize,
}

impl PackedLayer {
    pub fn new(weights: BitMatrix, theta: usize) -> Self {
        assert!(weights.n_rows() >= 1 && theta >= 1);
        Self { weights, theta }
    }

    pub fn n_out(&self) -> usize {
        self.weights.n_rows()
    }

    pub fn n_in(&self) -> usize {
        self.weights.n_cols()
    }

    /// Packed dot-product counts — the popcount kernel.
    pub fn counts(&self, x: &BitVec) -> Vec<u32> {
        assert_eq!(x.len(), self.n_in(), "input width");
        self.counts_words(x.words())
    }

    /// [`PackedLayer::counts`] straight over borrowed lanes (e.g. one
    /// [`PackedBatch`] row) — no `BitVec` materialization.
    pub fn counts_words(&self, words: &[u64]) -> Vec<u32> {
        debug_assert_eq!(words.len(), self.weights.words_per_row(), "lane count");
        (0..self.n_out())
            .map(|r| and_count(self.weights.row(r), words))
            .collect()
    }

    /// [`PackedLayer::argmax`] over borrowed lanes.
    pub fn argmax_words(&self, words: &[u64]) -> usize {
        argmax_counts(&self.counts_words(words))
    }

    /// Thresholded forward pass, staying packed for layer chaining.
    pub fn forward(&self, x: &BitVec) -> BitVec {
        let mut out = BitVec::zeros(self.n_out());
        for (r, c) in self.counts(x).into_iter().enumerate() {
            if c as usize >= self.theta {
                out.set(r, true);
            }
        }
        out
    }

    /// Packed classification — same first-max-wins tie-break as the
    /// scalar stack ([`argmax_counts`]).
    pub fn argmax(&self, x: &BitVec) -> usize {
        argmax_counts(&self.counts(x))
    }
}

impl From<&BinaryLayer> for PackedLayer {
    fn from(l: &BinaryLayer) -> Self {
        Self::new(BitMatrix::from_rows(&l.weights), l.theta)
    }
}

/// Packed form of a layer stack (the MLP runner's hot path).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedMlp {
    pub layers: Vec<PackedLayer>,
}

impl PackedMlp {
    pub fn from_layers(layers: &[BinaryLayer]) -> Self {
        assert!(!layers.is_empty());
        Self {
            layers: layers.iter().map(PackedLayer::from).collect(),
        }
    }

    pub fn n_in(&self) -> usize {
        self.layers[0].n_in()
    }

    pub fn n_out(&self) -> usize {
        self.layers[self.layers.len() - 1].n_out()
    }

    /// Chained packed forward pass.
    pub fn forward(&self, x: &BitVec) -> BitVec {
        let mut v = self.layers[0].forward(x);
        for l in &self.layers[1..] {
            v = l.forward(&v);
        }
        v
    }

    /// Final-layer counts after chaining the hidden layers.
    pub fn final_counts(&self, x: &BitVec) -> Vec<u32> {
        let mut v = x.clone();
        for l in &self.layers[..self.layers.len() - 1] {
            v = l.forward(&v);
        }
        self.layers[self.layers.len() - 1].counts(&v)
    }
}

/// An `Arc`-shared packed batch of equally-wide images, with a per-ticket
/// index range — the zero-copy dispatch currency: submit → dispatch →
/// complete moves `(Arc, Range)` pairs, never cloned `Vec<Vec<bool>>`.
#[derive(Clone, Debug)]
pub struct PackedBatch {
    data: Arc<BitMatrix>,
    range: Range<usize>,
}

impl PackedBatch {
    /// Pack a uniform-width batch; `None` when the rows are ragged (the
    /// scalar path keeps owning shape policy for those).
    pub fn from_images(images: &[Vec<bool>]) -> Option<Self> {
        let refs: Vec<&[bool]> = images.iter().map(Vec::as_slice).collect();
        Self::from_rows(&refs)
    }

    /// Pack a uniform-width batch of borrowed rows.
    pub fn from_rows(rows: &[&[bool]]) -> Option<Self> {
        let width = rows.first().map_or(0, |r| r.len());
        if rows.iter().any(|r| r.len() != width) {
            return None;
        }
        let mut m = BitMatrix::zeros(rows.len(), width);
        for (r, row) in rows.iter().enumerate() {
            for (c, &b) in row.iter().enumerate() {
                if b {
                    m.set(r, c, true);
                }
            }
        }
        Some(Self::from_matrix(m))
    }

    /// Wrap an already-packed matrix (one image per row).
    pub fn from_matrix(m: BitMatrix) -> Self {
        let n = m.n_rows();
        Self {
            data: Arc::new(m),
            range: 0..n,
        }
    }

    /// Images in this view.
    pub fn len(&self) -> usize {
        self.range.len()
    }

    pub fn is_empty(&self) -> bool {
        self.range.is_empty()
    }

    /// Bits per image.
    pub fn width(&self) -> usize {
        self.data.n_cols()
    }

    /// A sub-range view sharing the same buffer (`Arc` clone — no bit is
    /// copied). `range` is relative to this view.
    pub fn slice(&self, range: Range<usize>) -> Self {
        assert!(range.end <= self.len(), "slice out of range");
        Self {
            data: Arc::clone(&self.data),
            range: self.range.start + range.start..self.range.start + range.end,
        }
    }

    /// Lanes of image `i` (relative to this view).
    pub fn row_words(&self, i: usize) -> &[u64] {
        assert!(i < self.len());
        self.data.row(self.range.start + i)
    }

    /// Unpack image `i`.
    pub fn image_bools(&self, i: usize) -> Vec<bool> {
        assert!(i < self.len());
        self.data.row_bools(self.range.start + i)
    }

    /// Unpack the whole view to the scalar form (the compatibility
    /// fallback for engines without a packed kernel).
    pub fn to_images(&self) -> Vec<Vec<bool>> {
        (0..self.len()).map(|i| self.image_bools(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_bools(rng: &mut Pcg32, n: usize, p: f64) -> Vec<bool> {
        (0..n).map(|_| rng.bernoulli(p)).collect()
    }

    #[test]
    fn bitvec_roundtrips_across_lane_boundaries() {
        let mut rng = Pcg32::seeded(11);
        for n in [0usize, 1, 63, 64, 65, 127, 128, 130, 121] {
            let bits = random_bools(&mut rng, n, 0.5);
            let v = BitVec::from_bools(&bits);
            assert_eq!(v.len(), n);
            assert_eq!(v.to_bools(), bits, "width {n}");
            assert_eq!(v.count_ones() as usize, bits.iter().filter(|&&b| b).count());
        }
    }

    #[test]
    fn tail_bits_stay_masked() {
        let mut v = BitVec::from_bools(&[true; 70]);
        v.set(69, false);
        v.set(69, true);
        let tail = v.words()[1];
        assert_eq!(tail & !tail_mask(70), 0, "tail bits must stay zero");
        let m = BitMatrix::from_rows(&vec![vec![true; 70]; 3]);
        for r in 0..3 {
            assert_eq!(m.row(r)[1] & !tail_mask(70), 0);
        }
    }

    #[test]
    fn matrix_get_set_roundtrip() {
        let mut m = BitMatrix::zeros(4, 67);
        m.set(2, 66, true);
        m.set(0, 0, true);
        assert!(m.get(2, 66) && m.get(0, 0) && !m.get(1, 33));
        m.set(2, 66, false);
        assert!(!m.get(2, 66));
        assert_eq!(m.row_count_ones(0), 1);
    }

    #[test]
    fn packed_layer_matches_scalar_layer() {
        let mut rng = Pcg32::seeded(12);
        let rows: Vec<Vec<bool>> = (0..7).map(|_| random_bools(&mut rng, 100, 0.5)).collect();
        let layer = BinaryLayer::new(rows, 3);
        let packed = PackedLayer::from(&layer);
        for _ in 0..20 {
            let x = random_bools(&mut rng, 100, 0.4);
            let px = BitVec::from_bools(&x);
            assert_eq!(packed.counts(&px), layer.counts(&x));
            assert_eq!(packed.forward(&px).to_bools(), layer.forward(&x));
            assert_eq!(packed.argmax(&px), layer.argmax(&x));
        }
    }

    #[test]
    fn packed_mlp_chains_like_scalar_layers() {
        let mut rng = Pcg32::seeded(13);
        let hidden: Vec<Vec<bool>> = (0..9).map(|_| random_bools(&mut rng, 20, 0.5)).collect();
        let out: Vec<Vec<bool>> = (0..5).map(|_| random_bools(&mut rng, 9, 0.5)).collect();
        let layers = vec![BinaryLayer::new(hidden, 2), BinaryLayer::new(out, 1)];
        let mlp = PackedMlp::from_layers(&layers);
        for _ in 0..10 {
            let x = random_bools(&mut rng, 20, 0.5);
            let mut want = x.clone();
            for l in &layers {
                want = l.forward(&want);
            }
            assert_eq!(mlp.forward(&BitVec::from_bools(&x)).to_bools(), want);
            let want_counts = layers[1].counts(&layers[0].forward(&x));
            assert_eq!(mlp.final_counts(&BitVec::from_bools(&x)), want_counts);
        }
    }

    #[test]
    fn packed_batch_is_a_shared_view() {
        let images: Vec<Vec<bool>> = (0..6).map(|i| vec![i % 2 == 0; 10]).collect();
        let batch = PackedBatch::from_images(&images).expect("uniform");
        assert_eq!((batch.len(), batch.width()), (6, 10));
        assert_eq!(batch.to_images(), images);
        let half = batch.slice(2..5);
        assert_eq!(half.len(), 3);
        assert_eq!(half.image_bools(0), images[2]);
        // the slice shares the buffer — no bits were copied
        assert!(Arc::ptr_eq(&batch.data, &half.data));
    }

    #[test]
    fn ragged_batches_stay_scalar() {
        let ragged = vec![vec![true; 4], vec![false; 5]];
        assert!(PackedBatch::from_images(&ragged).is_none());
    }
}

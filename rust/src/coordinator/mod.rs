//! L3 coordinator: the serving shell around the simulated accelerator —
//! request batching, subarray scheduling, worker threads and metrics.
//!
//! The paper's contribution is the in-memory compute substrate itself, so
//! the coordinator is deliberately thin: it owns process topology and the
//! batching policy (`⌊N_row/P⌋` images per computational step, Table II)
//! and treats the inference backend as pluggable — either the circuit-level
//! rust simulator or the AOT-compiled XLA golden model.

pub mod backend;
pub mod batcher;
pub mod engine;
pub mod metrics;

pub use backend::{Backend, BackendFactory, InferenceResult, SimBackend, XlaBackend};
pub use batcher::Batcher;
pub use engine::{Coordinator, CoordinatorConfig, Prediction};
pub use metrics::{Metrics, MetricsSnapshot};

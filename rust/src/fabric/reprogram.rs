//! Fabric-level weight reprogramming: streaming a new network's bits over
//! the interlink fabric and pulsing them into the resident tiles.
//!
//! Inference assumed the weights were programmed before serving; swapping
//! a model in place is a different traffic class entirely — SET/RESET
//! pulses are orders of magnitude longer than a computational step, and
//! the new bits have to reach every tile over the same host spine and
//! interlinks the activations use. The simulation here reuses exactly the
//! inference machinery so program traffic *contends* for the same
//! resources:
//!
//! * each tile's changed bits travel from the host spine to the tile's
//!   node ([`LinkFabric::transfer_input`]) — injection ports serialize, so
//!   a fabric-wide rewrite queues on the spine like a big batch would;
//! * each node then pulses its tiles' diffs through its single write
//!   driver ([`SubarrayNode::reserve_step`] occupancy) — tiles sharing a
//!   subarray serialize, exactly as their inference steps do.
//!
//! Only the *diff* is programmed ([`ReprogramPlan`]): unchanged cells are
//! non-volatile and cost nothing, so swapping between similar checkpoints
//! is much cheaper than a cold program — the incremental-update story that
//! makes live swaps viable at all.
//!
//! The executor method that drives this ([`FabricExecutor::reprogram`])
//! swaps the weights only after the whole plan is simulated and validated,
//! so a fabric is always wholly-old or wholly-new — never a torn mix.

use super::event::{secs_to_ticks, ticks_to_secs, Time};
use super::link::{LinkFabric, LinkTraffic};
use super::node::SubarrayNode;
use super::placement::{FabricConfig, Placement};
use crate::device::ReprogramPlan;
use crate::nn::BinaryLayer;

/// Result of reprogramming a placed network to new weights.
#[derive(Clone, Debug)]
pub struct ReprogramRun {
    /// Aggregate pulse plan across every tile.
    pub plan: ReprogramPlan,
    /// Per-node pulse plans (index = flat node id).
    pub per_node: Vec<ReprogramPlan>,
    /// End-to-end simulated time of the rewrite \[s\] (spine streaming +
    /// write-driver occupancy, with per-node parallelism).
    pub makespan: f64,
    /// Interlink/spine switch losses of the weight distribution \[J\].
    pub link_energy: f64,
    /// Total rewrite energy: pulses + distribution \[J\].
    pub energy: f64,
    /// Traffic counters of the weight distribution.
    pub traffic: LinkTraffic,
    /// Per-node busy fraction of the rewrite makespan.
    pub utilization: Vec<f64>,
}

/// The target weight slice a tile must hold after the swap.
pub(super) fn target_slice(
    tile: &super::placement::TileSlice,
    target: &[BinaryLayer],
) -> Vec<Vec<bool>> {
    tile.row_range
        .clone()
        .map(|r| target[tile.layer].weights[r][tile.col_range.clone()].to_vec())
        .collect()
}

/// Simulate rewriting every placed tile from its current weights to the
/// `target` stack (which must be shape-identical — validated by the
/// caller). Pure simulation: nothing is mutated.
pub fn simulate_reprogram(
    placement: &Placement,
    cfg: &FabricConfig,
    target: &[BinaryLayer],
) -> crate::Result<ReprogramRun> {
    let p = cfg.device;
    let mut nodes: Vec<SubarrayNode> = (0..cfg.n_nodes())
        .map(|n| {
            let (r, c) = cfg.node_coords(n);
            SubarrayNode::new(n, r, c)
        })
        .collect();
    let mut links = LinkFabric::new(cfg);
    let mut per_node = vec![ReprogramPlan::default(); cfg.n_nodes()];
    let mut total = ReprogramPlan::default();
    let mut makespan: Time = 0;

    for tile in &placement.tiles {
        let slice = target_slice(tile, target);
        let tile_plan = ReprogramPlan::diff(&tile.weights, &slice, &p)?;
        per_node[tile.node].merge(&tile_plan);
        total.merge(&tile_plan);
        if tile_plan.cells_changed() == 0 {
            continue; // non-volatile cells: no traffic, no pulses
        }
        // stream the changed bits to the tile's node: one line per changed
        // cell, carrying the write current of the bits being set (the
        // plan's SET pulses are exactly the 0→1 flips)
        let arrival = links.transfer_input(
            0,
            tile.node,
            tile_plan.cells_changed(),
            tile_plan.set_pulses as f64 * p.i_set,
        );
        // then the node's write driver pulses the diff, serialized behind
        // whatever this node is already programming
        let dur = secs_to_ticks(tile_plan.time).max(1);
        let node = &mut nodes[tile.node];
        let (_start, end) = node.reserve_step(arrival, dur);
        node.ledger.energy += tile_plan.energy;
        node.ledger.time += tile_plan.time;
        node.ledger.writes += tile_plan.cells_changed();
        makespan = makespan.max(end);
    }

    let traffic = links.totals();
    let link_energy = traffic.energy + traffic.input_energy;
    let makespan_s = ticks_to_secs(makespan);
    Ok(ReprogramRun {
        energy: total.energy + link_energy,
        plan: total,
        per_node,
        makespan: makespan_s,
        link_energy,
        traffic,
        utilization: nodes.iter().map(|n| n.utilization(makespan_s)).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{place_layers, FabricExecutor};
    use crate::util::Pcg32;

    fn random_layer(rng: &mut Pcg32, n_out: usize, n_in: usize, theta: usize) -> BinaryLayer {
        BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            theta,
        )
    }

    #[test]
    fn plan_covers_every_cell_and_books_physical_energy() {
        let mut rng = Pcg32::seeded(0x8e01);
        let old = vec![random_layer(&mut rng, 20, 20, 4)];
        let new = vec![random_layer(&mut rng, 20, 20, 4)];
        let cfg = FabricConfig::new(2, 2, 8, 8);
        let placement = place_layers(&old, &cfg).unwrap();
        let run = simulate_reprogram(&placement, &cfg, &new).unwrap();
        assert_eq!(run.plan.cells_total(), 400, "every weight cell planned");
        assert!(run.plan.set_pulses > 0 && run.plan.reset_pulses > 0);
        assert!(run.makespan > 0.0 && run.energy > run.plan.energy);
        assert!(run.traffic.input_transfers > 0, "bits crossed the spine");
        assert_eq!(run.utilization.len(), 4);
        assert!(run.utilization.iter().any(|&u| u > 0.0));
        // per-node plans partition the aggregate
        let set: u64 = run.per_node.iter().map(|p| p.set_pulses).sum();
        assert_eq!(set, run.plan.set_pulses);
    }

    #[test]
    fn identical_target_is_free() {
        let mut rng = Pcg32::seeded(0x8e02);
        let layers = vec![random_layer(&mut rng, 12, 16, 3)];
        let cfg = FabricConfig::new(1, 2, 8, 8);
        let placement = place_layers(&layers, &cfg).unwrap();
        let run = simulate_reprogram(&placement, &cfg, &layers).unwrap();
        assert_eq!(run.plan.cells_changed(), 0);
        assert_eq!(run.makespan, 0.0);
        assert_eq!(run.energy, 0.0);
        assert_eq!(run.traffic.input_transfers, 0);
    }

    #[test]
    fn tiles_sharing_a_node_serialize_on_its_write_driver() {
        let mut rng = Pcg32::seeded(0x8e03);
        let old = vec![random_layer(&mut rng, 16, 16, 3)];
        let new = vec![random_layer(&mut rng, 16, 16, 3)];
        // 4 tiles on 1 node vs the same 4 tiles on 4 nodes
        let cfg1 = FabricConfig::new(1, 1, 8, 8);
        let cfg4 = FabricConfig::new(2, 2, 8, 8);
        let run1 =
            simulate_reprogram(&place_layers(&old, &cfg1).unwrap(), &cfg1, &new).unwrap();
        let run4 =
            simulate_reprogram(&place_layers(&old, &cfg4).unwrap(), &cfg4, &new).unwrap();
        assert_eq!(run1.plan, run4.plan, "same diff either way");
        assert!(
            run1.makespan > run4.makespan,
            "one shared write driver must be slower: {} vs {}",
            run1.makespan,
            run4.makespan
        );
    }

    #[test]
    fn executor_reprogram_swaps_weights_atomically() {
        let mut rng = Pcg32::seeded(0x8e04);
        let old = vec![
            random_layer(&mut rng, 12, 18, 3),
            random_layer(&mut rng, 6, 12, 2),
        ];
        let new = vec![
            random_layer(&mut rng, 12, 18, 4),
            random_layer(&mut rng, 6, 12, 2),
        ];
        let images: Vec<Vec<bool>> = (0..5)
            .map(|_| (0..18).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let mut exec = FabricExecutor::new(old.clone(), FabricConfig::new(2, 2, 8, 8)).unwrap();
        let before = exec.run_batch(&images).unwrap();
        let run = exec.reprogram(new.clone()).unwrap();
        assert!(run.plan.cells_changed() > 0);
        let after = exec.run_batch(&images).unwrap();
        // post-swap the fabric is wholly-new: bit-exact with a fresh
        // executor built on the new stack (θ change included)
        let fresh = FabricExecutor::new(new, FabricConfig::new(2, 2, 8, 8)).unwrap();
        let want = fresh.run_batch(&images).unwrap();
        assert_eq!(after.outputs, want.outputs);
        assert_eq!(after.final_counts, want.final_counts);
        assert_ne!(after.outputs, before.outputs, "weights visibly changed");
    }

    #[test]
    fn executor_rejects_mismatched_target_shapes_untouched() {
        let mut rng = Pcg32::seeded(0x8e05);
        let old = vec![random_layer(&mut rng, 8, 12, 2)];
        let images: Vec<Vec<bool>> = (0..3)
            .map(|_| (0..12).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let mut exec = FabricExecutor::new(old.clone(), FabricConfig::new(1, 2, 8, 8)).unwrap();
        let before = exec.run_batch(&images).unwrap();
        // wrong layer count
        let err = exec
            .reprogram(vec![
                random_layer(&mut rng, 8, 12, 2),
                random_layer(&mut rng, 4, 8, 1),
            ])
            .unwrap_err();
        assert!(err.to_string().contains("swap"), "{err}");
        // wrong dims
        let err = exec.reprogram(vec![random_layer(&mut rng, 8, 10, 2)]).unwrap_err();
        assert!(err.to_string().contains("swap"), "{err}");
        // failed swaps leave the old network fully intact
        let after = exec.run_batch(&images).unwrap();
        assert_eq!(after.outputs, before.outputs);
    }
}

"""Dataset generator tests, including the cross-language PRNG contract."""

import numpy as np

from compile.dataset import (
    GLYPHS,
    IMAGE_PIXELS,
    IMAGE_SIDE,
    N_CLASSES,
    DigitGen,
    SplitMix64,
)


def test_splitmix_known_values():
    # identical reference vector as rust/src/util/prng.rs tests
    g = SplitMix64(0)
    assert g.next_u64() == 0xE220A8397B1DCDAF
    assert g.next_u64() == 0x6E789E6AA1B965F4
    assert g.next_u64() == 0x06C45D188009454F


def test_splitmix_f64_unit_interval():
    g = SplitMix64(42)
    for _ in range(1000):
        assert 0.0 <= g.next_f64() < 1.0


def test_glyphs_well_formed():
    assert len(GLYPHS) == N_CLASSES
    for g in GLYPHS:
        assert len(g) == IMAGE_SIDE
        for row in g:
            assert len(row) == IMAGE_SIDE
            assert set(row) <= {"#", "."}


def test_generation_deterministic():
    a = DigitGen(42).dataset(16)
    b = DigitGen(42).dataset(16)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    c = DigitGen(43).dataset(16)
    assert not np.array_equal(a[0], c[0])


def test_shapes_and_values():
    xs, ys = DigitGen(7).dataset(64)
    assert xs.shape == (64, IMAGE_PIXELS)
    assert set(np.unique(xs)) <= {0.0, 1.0}
    assert ys.min() >= 0 and ys.max() < N_CLASSES


def test_class_coverage():
    _, ys = DigitGen(1).dataset(500)
    counts = np.bincount(ys, minlength=N_CLASSES)
    assert counts.min() > 20

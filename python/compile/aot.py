"""AOT compile path: lower the L2/L1 jax graphs to HLO TEXT and export the
trained weights + workload metadata for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(behind the rust `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Run once via `make artifacts`; python never appears on the request path.

Artifacts produced in --out (default ../artifacts):
  nn_infer.hlo.txt     batched single-layer inference (B=64)
  mlp_infer.hlo.txt    batched 3-layer inference (B=64)
  w_single.txt         121x10 binary weights, rust layout [out][in] = 10x121
  w_mlp1.txt           64x121, w_mlp2.txt 10x64 (rust layout)
  meta.txt             thetas, vdds, accuracies (key value lines)
  dataset_check.txt    first 32 TEST_SEED samples: label + 121 bits per row
"""

from __future__ import annotations

import argparse
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .dataset import TEST_SEED, DigitGen
from .kernels import ref

BATCH = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_single_layer(n_in: int, n_out: int) -> str:
    spec = jax.ShapeDtypeStruct
    f32 = jnp.float32
    fn = lambda x, w, a, r, v: model.single_layer_infer(x, w, a, r, v)
    lowered = jax.jit(fn).lower(
        spec((BATCH, n_in), f32),
        spec((n_in, n_out), f32),
        spec((BATCH, 1), f32),
        spec((BATCH, 1), f32),
        spec((1, 1), f32),
    )
    return to_hlo_text(lowered)


def lower_mlp(n_in: int, n_hidden: int, n_out: int) -> str:
    spec = jax.ShapeDtypeStruct
    f32 = jnp.float32
    fn = lambda x, w1, w2, v1, v2: model.mlp_infer(x, w1, w2, v1, v2)
    lowered = jax.jit(fn).lower(
        spec((BATCH, n_in), f32),
        spec((n_in, n_hidden), f32),
        spec((n_hidden, n_out), f32),
        spec((1, 1), f32),
        spec((1, 1), f32),
    )
    return to_hlo_text(lowered)


def save_matrix(path: pathlib.Path, m: np.ndarray) -> None:
    with open(path, "w") as f:
        for row in np.atleast_2d(m):
            f.write(" ".join(f"{v:g}" for v in row))
            f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--train-size", type=int, default=3000)
    ap.add_argument("--test-size", type=int, default=1000)
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    # ---- data ----
    train_x, train_y = DigitGen(seed=0x7121).dataset(args.train_size)
    test_x, test_y = DigitGen(seed=TEST_SEED).dataset(args.test_size)

    # ---- single layer ----
    w = model.train_single_layer(train_x, train_y)
    theta = model.pick_theta(train_x, train_y, w)
    acc = model.accuracy_argmax(test_x, test_y, w)
    print(f"single layer: theta={theta} test argmax acc={acc:.3f}")

    # ---- mlp ----
    theta1 = 14
    w1, w2 = model.train_mlp(train_x, train_y, theta1=theta1)
    theta2 = model.pick_theta(
        ((train_x @ w1) >= theta1).astype(np.float32), train_y, w2
    )
    mlp_acc = model.mlp_accuracy(test_x, test_y, w1, theta1, w2)
    print(f"mlp: theta1={theta1} theta2={theta2} test argmax acc={mlp_acc:.3f}")

    # ---- HLO artifacts ----
    hlo_single = lower_single_layer(121, 10)
    (out / "nn_infer.hlo.txt").write_text(hlo_single)
    hlo_mlp = lower_mlp(121, w1.shape[1], 10)
    (out / "mlp_infer.hlo.txt").write_text(hlo_mlp)
    print(f"wrote HLO: nn_infer ({len(hlo_single)} chars), mlp_infer ({len(hlo_mlp)} chars)")

    # ---- weights (rust layout [out][in]) ----
    save_matrix(out / "w_single.txt", w.T)
    save_matrix(out / "w_mlp1.txt", w1.T)
    save_matrix(out / "w_mlp2.txt", w2.T)

    # ---- metadata ----
    vdd = ref.vdd_for_threshold(theta)
    meta = {
        "theta_single": theta,
        "vdd_single": vdd,
        "theta_mlp1": theta1,
        "theta_mlp2": theta2,
        "vdd_mlp1": ref.vdd_for_threshold(theta1),
        "vdd_mlp2": ref.vdd_for_threshold(theta2),
        "acc_single": acc,
        "acc_mlp": mlp_acc,
        "batch": BATCH,
        "n_in": 121,
        "n_hidden": w1.shape[1],
        "n_out": 10,
        "test_seed": TEST_SEED,
    }
    with open(out / "meta.txt", "w") as f:
        for k, v in meta.items():
            f.write(f"{k} {v}\n")

    # ---- cross-language dataset check ----
    check_x, check_y = DigitGen(seed=TEST_SEED).dataset(32)
    rows = np.concatenate([check_y[:, None].astype(np.float32), check_x], axis=1)
    save_matrix(out / "dataset_check.txt", rows)
    print(f"artifacts written to {out.resolve()}")


if __name__ == "__main__":
    main()

//! Minimal JSON value tree (offline build: no `serde`) — just enough for
//! declarative configuration files such as the engine spec
//! (`EngineSpec::{to_json, from_json}`): a recursive-descent parser and a
//! deterministic renderer (compact and pretty).
//!
//! Objects preserve insertion order so `parse(render(v)) == v` holds
//! structurally; numbers are `f64` (the JSON number model).

use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in source (or insertion) order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing content at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object member lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer (rejects fractional and out-of-range numbers).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u32::MAX as f64 => {
                Some(*n as usize)
            }
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_usize().map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Compact single-line rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Multi-line rendering with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                let _ = write!(out, "{n}");
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if indent.is_some() {
                            out.push(' ');
                        }
                    }
                    item.write(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                if entries.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        for _ in 0..w * (depth + 1) {
                            out.push(' ');
                        }
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if let Some(w) = indent {
                    out.push('\n');
                    for _ in 0..w * depth {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.literal("false").map(|_| Json::Bool(false)),
            Some(b'n') => self.literal("null").map(|_| Json::Null),
            Some(_) => self.number(),
        }
    }

    fn literal(&mut self, lit: &str) -> Result<(), String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        match s.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            _ => Err(format!("invalid number '{s}' at byte {start}")),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' {
                    break;
                }
                self.pos += 1;
            }
            // the run stops only at ASCII bytes, so it is whole codepoints
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| "invalid utf-8 in string".to_string())?,
            );
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(_) => {
                    self.pos += 1; // the backslash
                    let esc = self
                        .peek()
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("unknown escape '\\{}'", other as char)),
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            entries.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(entries));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").and_then(Json::as_str), Some("x"));
        match v.get("a").unwrap() {
            Json::Arr(items) => {
                assert_eq!(items.len(), 3);
                assert_eq!(items[0].as_usize(), Some(1));
                assert!(items[2].get("b").unwrap().is_null());
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn roundtrips_compact_and_pretty() {
        let text = r#"{"backend":"fabric","dims":[2,2],"scale":3.5,"span":null,"on":true}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
        assert_eq!(v.render(), text);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\tüé".into());
        assert_eq!(Json::parse(&v.render()).unwrap(), v);
        let u = Json::parse(r#""Aü""#).unwrap();
        assert_eq!(u, Json::Str("Aü".into()));
    }

    #[test]
    fn integer_accessor_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(Json::parse("7.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-7").unwrap().as_usize(), None);
        assert_eq!(Json::parse("\"7\"").unwrap().as_usize(), None);
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nulls").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::Obj(vec![]).pretty(), "{}");
    }
}

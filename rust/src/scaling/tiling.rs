//! Tiling large operands across multiple subarrays (paper §IV-B: "we can
//! connect multiple 3D XPoint subarrays to create a larger array to handle
//! computations with higher matrix dimensions").
//!
//! A logical `rows × cols` binary matrix is partitioned into a grid of
//! `n_row × n_col` subarray tiles; partial dot products from column-tiles
//! are combined through the switch fabric (current summing on linked bit
//! lines), which the simulator realizes by accumulating per-tile
//! conductance sums before thresholding.

/// Assignment of a logical matrix element to a tile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileAssignment {
    /// Tile grid coordinates.
    pub tile_row: usize,
    pub tile_col: usize,
    /// Position within the tile.
    pub local_row: usize,
    pub local_col: usize,
}

/// A tiling of a logical matrix over fixed-size subarrays.
#[derive(Clone, Copy, Debug)]
pub struct Tiling {
    pub logical_rows: usize,
    pub logical_cols: usize,
    pub tile_rows: usize,
    pub tile_cols: usize,
}

impl Tiling {
    pub fn new(logical_rows: usize, logical_cols: usize, tile_rows: usize, tile_cols: usize) -> Self {
        assert!(tile_rows > 0 && tile_cols > 0, "empty tile dimensions");
        assert!(
            logical_rows > 0 && logical_cols > 0,
            "empty logical matrix: {logical_rows}×{logical_cols}"
        );
        Self {
            logical_rows,
            logical_cols,
            tile_rows,
            tile_cols,
        }
    }

    /// Number of tiles along the row dimension.
    pub fn grid_rows(&self) -> usize {
        self.logical_rows.div_ceil(self.tile_rows)
    }

    /// Number of tiles along the column dimension.
    pub fn grid_cols(&self) -> usize {
        self.logical_cols.div_ceil(self.tile_cols)
    }

    /// Total subarrays needed.
    pub fn n_tiles(&self) -> usize {
        self.grid_rows() * self.grid_cols()
    }

    /// Where does logical element `(r, c)` live?
    pub fn assign(&self, r: usize, c: usize) -> TileAssignment {
        assert!(r < self.logical_rows && c < self.logical_cols);
        TileAssignment {
            tile_row: r / self.tile_rows,
            tile_col: c / self.tile_cols,
            local_row: r % self.tile_rows,
            local_col: c % self.tile_cols,
        }
    }

    /// Rows covered by tile row `tr` (for slicing operands).
    pub fn row_range(&self, tr: usize) -> std::ops::Range<usize> {
        let start = tr * self.tile_rows;
        start..(start + self.tile_rows).min(self.logical_rows)
    }

    /// Columns covered by tile column `tc`.
    pub fn col_range(&self, tc: usize) -> std::ops::Range<usize> {
        let start = tc * self.tile_cols;
        start..(start + self.tile_cols).min(self.logical_cols)
    }
}

/// Tiled thresholded matrix–vector product in count space: partial sums of
/// `x·G` accumulate across column tiles (current summing through the
/// fabric), thresholded once at the end. Used as the functional model for
/// multi-subarray TMVM; the electrical model runs per tile.
pub fn tiled_tmvm_counts(
    tiling: &Tiling,
    g: &[Vec<bool>], // logical [row][col]
    x: &[bool],      // logical [col]
) -> Vec<u32> {
    assert_eq!(g.len(), tiling.logical_rows);
    assert_eq!(x.len(), tiling.logical_cols);
    let mut counts = vec![0u32; tiling.logical_rows];
    for tr in 0..tiling.grid_rows() {
        for tc in 0..tiling.grid_cols() {
            for r in tiling.row_range(tr) {
                let mut acc = 0u32;
                for c in tiling.col_range(tc) {
                    acc += (x[c] && g[r][c]) as u32;
                }
                counts[r] += acc;
            }
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn grid_dimensions_round_up() {
        let t = Tiling::new(100, 300, 64, 128);
        assert_eq!(t.grid_rows(), 2);
        assert_eq!(t.grid_cols(), 3);
        assert_eq!(t.n_tiles(), 6);
    }

    #[test]
    fn assignment_roundtrips() {
        let t = Tiling::new(100, 300, 64, 128);
        let a = t.assign(70, 250);
        assert_eq!((a.tile_row, a.tile_col), (1, 1));
        assert_eq!((a.local_row, a.local_col), (6, 122));
        // ranges cover without overlap
        let mut seen = vec![false; 100];
        for tr in 0..t.grid_rows() {
            for r in t.row_range(tr) {
                assert!(!seen[r]);
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    #[should_panic(expected = "empty logical matrix")]
    fn zero_logical_rows_rejected() {
        let _ = Tiling::new(0, 10, 4, 4);
    }

    #[test]
    #[should_panic(expected = "empty logical matrix")]
    fn zero_logical_cols_rejected() {
        let _ = Tiling::new(10, 0, 4, 4);
    }

    #[test]
    #[should_panic(expected = "empty tile dimensions")]
    fn zero_tile_dims_rejected() {
        let _ = Tiling::new(10, 10, 0, 4);
    }

    #[test]
    fn tiled_counts_equal_flat_counts() {
        let mut rng = Pcg32::seeded(17);
        for _ in 0..20 {
            let rows = rng.range(1, 50);
            let cols = rng.range(1, 50);
            let g: Vec<Vec<bool>> = (0..rows)
                .map(|_| (0..cols).map(|_| rng.bernoulli(0.4)).collect())
                .collect();
            let x: Vec<bool> = (0..cols).map(|_| rng.bernoulli(0.5)).collect();
            let flat: Vec<u32> = (0..rows)
                .map(|r| (0..cols).filter(|&c| x[c] && g[r][c]).count() as u32)
                .collect();
            let t = Tiling::new(rows, cols, rng.range(1, 8), rng.range(1, 8));
            assert_eq!(tiled_tmvm_counts(&t, &g, &x), flat);
        }
    }
}

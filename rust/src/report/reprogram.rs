//! Live-reprogramming exhibit (beyond the paper's static-weight tables):
//! the drain → reprogram → rejoin timeline of a rolling weight swap over
//! a sharded fabric engine, wave by wave.
//!
//! Each wave submits one batch per shard and drains it fully, recording
//! which shards served (the throughput-dip view — a shard mid-swap serves
//! nothing, the rest carry the wave, completed work never drops to zero)
//! and the lifecycle state of every shard. The swap kicks in a third of
//! the way through; the final [`SwapReport`] summarizes the pulse counts,
//! programming time and energy the rewrite cost — the write-traffic class
//! 3D-aCortex-style accelerators budget separately from inference.

use crate::engine::{BackendKind, Engine, EngineSpec, ShardState, ShardedEngine, SwapReport};
use crate::nn::dataset::{DigitGen, TEST_SEED};
use crate::nn::BinaryLayer;
use crate::util::si::{format_duration, format_si};
use crate::util::{Pcg32, Table};

use super::fabric::{fabric_workload, FABRIC_TILE};

/// Default shard count of the exhibit.
pub const REPROGRAM_SHARDS: usize = 2;

/// Default wave count of the exhibit.
pub const REPROGRAM_WAVES: usize = 6;

/// The swap target: the exhibit workload with a deterministic fraction of
/// the weights flipped (same dims, same thetas — a re-trained checkpoint).
pub fn perturbed_workload() -> Vec<BinaryLayer> {
    let mut rng = Pcg32::seeded(0x5aff);
    fabric_workload()
        .into_iter()
        .map(|layer| {
            let weights = layer
                .weights
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|&w| if rng.bernoulli(0.25) { !w } else { w })
                        .collect()
                })
                .collect();
            BinaryLayer::new(weights, layer.theta)
        })
        .collect()
}

/// One wave of the rolling-swap timeline.
#[derive(Clone, Debug)]
pub struct ReprogramWaveRow {
    pub wave: usize,
    /// Shard lifecycle states at the start of the wave.
    pub states: Vec<ShardState>,
    /// Whether the rolling swap was active during the wave.
    pub swapping: bool,
    /// Images completed this wave (fully drained, so the serving shards
    /// always carry the wave — never zero).
    pub images_done: usize,
    /// Images served per shard this wave (telemetry delta).
    pub per_shard: Vec<u64>,
}

/// Run the exhibit: `waves` waves of one batch per shard over a sharded
/// fabric engine, with a rolling swap to [`perturbed_workload`] starting
/// a third of the way in. Returns the timeline and the final aggregate
/// [`SwapReport`].
pub fn reprogram_timeline(
    shards: usize,
    waves: usize,
    batch: usize,
) -> crate::Result<(Vec<ReprogramWaveRow>, SwapReport)> {
    anyhow::ensure!(shards >= 1 && waves >= 2, "need ≥1 shard and ≥2 waves");
    let batch = batch.max(1);
    let spec = EngineSpec::new(BackendKind::Fabric)
        .with_layers(fabric_workload())
        .with_grid(2, 2)
        .with_tile(FABRIC_TILE.0, FABRIC_TILE.1)
        .with_fabric_max_batch(batch)
        .with_batching(batch, 200)
        .with_workers(shards);
    let mut engine = ShardedEngine::new(spec.build_factories()?)?;
    let target = perturbed_workload();
    let swap_at = waves / 3;

    let mut gen = DigitGen::new(TEST_SEED);
    let mut rows = Vec::with_capacity(waves);
    let mut report: Option<SwapReport> = None;
    let mut prev_images: Vec<u64> = vec![0; shards];
    for wave in 0..waves {
        if wave == swap_at {
            engine.begin_swap(target.clone())?;
        }
        let states = engine.shard_states();
        let swapping = engine.swap_in_progress();
        let mut tickets = Vec::with_capacity(shards);
        for _ in 0..shards {
            let images: Vec<Vec<bool>> =
                (0..batch).map(|_| gen.next_sample().pixels).collect();
            tickets.push(engine.submit(images)?);
        }
        let mut images_done = 0usize;
        for t in tickets {
            let res = loop {
                match engine.poll(t)? {
                    Some(res) => break res,
                    None => std::thread::yield_now(),
                }
            };
            images_done += res.bits.len();
        }
        // advance/redeem the rolling swap between waves, without blocking
        if report.is_none() && wave >= swap_at {
            report = engine.poll_swap()?;
        }
        let per_shard: Vec<u64> = engine
            .shard_telemetry()
            .iter()
            .zip(&prev_images)
            .map(|(t, &prev)| t.images - prev)
            .collect();
        prev_images = engine.shard_telemetry().iter().map(|t| t.images).collect();
        rows.push(ReprogramWaveRow {
            wave,
            states,
            swapping,
            images_done,
            per_shard,
        });
    }
    // drive the walk home if it is still rolling
    let report = match report {
        Some(r) => r,
        None => loop {
            match engine.poll_swap()? {
                Some(r) => break r,
                None => std::thread::yield_now(),
            }
        },
    };
    Ok((rows, report))
}

/// Render the drain/reprogram timeline.
pub fn reprogram_table(rows: &[ReprogramWaveRow]) -> Table {
    let title = format!(
        "Live reprogramming — rolling swap over {} shard(s), one batch per shard per wave",
        rows.first().map_or(0, |r| r.states.len())
    );
    let mut t = Table::new(&title).header(&["Wave", "Shard states", "Swap", "Done", "Per shard"]);
    for r in rows {
        let states = r
            .states
            .iter()
            .map(|s| s.name())
            .collect::<Vec<_>>()
            .join("/");
        let per_shard = r
            .per_shard
            .iter()
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join("/");
        t.row(&[
            r.wave.to_string(),
            states,
            if r.swapping { "rolling" } else { "—" }.to_string(),
            r.images_done.to_string(),
            per_shard,
        ]);
    }
    t
}

/// One-line summary of what the swap cost.
pub fn reprogram_summary(report: &SwapReport) -> String {
    format!(
        "swap walked {} shard(s): {} SET + {} RESET pulses over {} of {} cells, \
         {} programming, {}",
        report.shards,
        report.set_pulses,
        report.reset_pulses,
        report.cells_changed,
        report.cells_total,
        format_duration(report.time),
        format_si(report.energy, "J"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perturbed_workload_matches_shapes_but_not_weights() {
        let old = fabric_workload();
        let new = perturbed_workload();
        assert_eq!(old.len(), new.len());
        for (a, b) in old.iter().zip(&new) {
            assert_eq!((a.n_out(), a.n_in(), a.theta), (b.n_out(), b.n_in(), b.theta));
            assert_ne!(a.weights, b.weights, "the checkpoint actually differs");
        }
    }

    #[test]
    fn timeline_never_drops_to_zero_and_reports_the_pulses() {
        let (rows, report) = reprogram_timeline(2, 6, 16).unwrap();
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(
                r.images_done > 0,
                "wave {} completed nothing — throughput hit zero",
                r.wave
            );
            assert_eq!(r.per_shard.iter().sum::<u64>() as usize, r.images_done);
        }
        // the swap actually rolled: some wave saw a non-serving shard
        assert!(
            rows.iter()
                .any(|r| r.states.iter().any(|&s| s != ShardState::Serving)),
            "no wave observed the drain/reprogram window"
        );
        assert_eq!(report.shards, 2);
        assert!(report.set_pulses > 0 && report.reset_pulses > 0);
        assert!(report.energy > 0.0 && report.time > 0.0);
        // a 1-shard timeline parks mid-swap submits in the queue and
        // still completes every wave (bit-exactness is pinned by the
        // integration_reprogram tests)
        let (rows1, report1) = reprogram_timeline(1, 3, 8).unwrap();
        assert!(rows1.iter().all(|r| r.images_done > 0));
        assert_eq!(report1.shards, 1);
    }

    #[test]
    fn table_renders_the_timeline() {
        let (rows, report) = reprogram_timeline(2, 3, 8).unwrap();
        let t = reprogram_table(&rows);
        assert_eq!(t.n_rows(), 3);
        let s = t.render();
        assert!(s.contains("serving"), "{s}");
        let summary = reprogram_summary(&report);
        assert!(summary.contains("SET") && summary.contains("RESET"), "{summary}");
    }
}

//! Integration: trace-driven serving determinism at the process level.
//! Runs the real `xpoint` binary (the same artifact CI ships) and pins
//! that identical seed + trace spec produce identical output across
//! runs — the property that makes policy comparisons on replayed
//! traffic meaningful — plus the `--trace-out` record → `--trace`
//! replay loop.

use std::process::Command;

use xpoint_imc::coordinator::TrafficTrace;

/// Run `xpoint` with a whitespace-separated argument string (no
/// argument in these tests contains spaces).
fn xpoint(cmdline: &str) -> (bool, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_xpoint"))
        .args(cmdline.split_whitespace())
        .output()
        .expect("xpoint binary runs");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    let stderr = String::from_utf8_lossy(&out.stderr).into_owned();
    (out.status.success(), stdout, stderr)
}

/// The serve report mixes deterministic lines (trace shape, per-tenant
/// tallies, image counts, accuracy — pure functions of seed + spec)
/// with host-timing lines (wall clock, latency, batch boundaries whose
/// energy association follows linger timing). Keep only the former for
/// cross-run comparison.
fn deterministic_lines(stdout: &str) -> Vec<String> {
    let prefixes = ["backend:", "trace:", "tenant ", "images:", "accuracy:"];
    stdout
        .lines()
        .filter(|l| prefixes.iter().any(|p| l.starts_with(p)))
        .map(str::to_string)
        .collect()
}

#[test]
fn autoscale_json_replay_is_byte_identical_across_processes() {
    let cmd = "autoscale --min 1 --max 2 --batch 4 --trace multitenant --json";
    let (ok1, out1, err1) = xpoint(cmd);
    assert!(ok1, "first run failed: {err1}");
    let (ok2, out2, _) = xpoint(cmd);
    assert!(ok2);
    assert_eq!(out1, out2, "autoscale --json must replay byte-identically");
    assert!(
        out1.contains("\"trace\": \"multitenant\""),
        "the exhibit records which trace it replayed:\n{out1}"
    );
}

#[test]
fn serve_trace_report_is_deterministic_across_runs() {
    let cmd = "serve --trace bursty --batch 8 --workers 1";
    let (ok1, out1, err1) = xpoint(cmd);
    assert!(ok1, "first run failed: {err1}");
    let (ok2, out2, _) = xpoint(cmd);
    assert!(ok2);
    let lines1 = deterministic_lines(&out1);
    assert_eq!(lines1, deterministic_lines(&out2));
    // the bursty trace at batch 8 offers a known image count
    let total = TrafficTrace::bursty(0, 8).total_images();
    let has_count = |l: &String| l.starts_with("images:") && l.ends_with(&total.to_string());
    assert!(lines1.iter().any(has_count), "expected {total} images in:\n{out1}");
    assert!(lines1.iter().any(|l| l.starts_with("trace:")), "{out1}");
    assert!(lines1.iter().any(|l| l.starts_with("tenant ")), "{out1}");
}

#[test]
fn multitenant_serve_reports_every_tenant() {
    let (ok, out, err) = xpoint("serve --trace multitenant --batch 4 --workers 1");
    assert!(ok, "{err}");
    for tenant in ["tenant-a", "tenant-b", "tenant-c"] {
        assert!(
            out.lines().any(|l| l.starts_with(&format!("tenant {tenant}:"))),
            "missing per-tenant line for {tenant}:\n{out}"
        );
    }
}

#[test]
fn trace_out_records_a_replayable_trace() {
    let path = std::env::temp_dir().join(format!(
        "xpoint-trace-{}-{:?}.json",
        std::process::id(),
        std::thread::current().id()
    ));
    let path_str = path.to_str().unwrap();
    let generate = "serve --trace diurnal --trace-seed 7 --batch 4 --workers 1";
    let (ok, _, err) = xpoint(&format!("{generate} --trace-out {path_str}"));
    assert!(ok, "{err}");

    // the recorded file is the canonical JSON form of the generator
    let text = std::fs::read_to_string(&path).expect("trace recorded");
    let parsed = TrafficTrace::from_json(&text).expect("recorded trace parses");
    assert_eq!(parsed, TrafficTrace::diurnal(7, 12, 16));
    assert_eq!(parsed.to_json_string(), text, "record is the canonical form");

    // and replaying the file reproduces the generator's deterministic report
    let replay = format!("serve --trace {path_str} --batch 4 --workers 1");
    let (ok_file, out_file, err_file) = xpoint(&replay);
    assert!(ok_file, "{err_file}");
    let (ok_gen, out_gen, _) = xpoint(generate);
    assert!(ok_gen);
    assert_eq!(
        deterministic_lines(&out_file),
        deterministic_lines(&out_gen),
        "a recorded trace replays exactly like its generator"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn bad_trace_arguments_fail_with_typed_errors() {
    let (ok, _, err) = xpoint("serve --trace sawtooth");
    assert!(!ok);
    assert!(err.contains("unknown trace"), "{err}");

    let (ok, _, err) = xpoint("serve --trace bursty --images 10");
    assert!(!ok);
    assert!(err.contains("--images conflicts with --trace"), "{err}");

    let (ok, _, err) = xpoint("serve --trace-out /tmp/nope.json");
    assert!(!ok);
    assert!(err.contains("--trace-out needs --trace"), "{err}");
}

//! Modified nodal analysis: stamp the netlist into an MNA system and solve.
//!
//! Unknowns: node voltages 1..n−1 (ground eliminated) followed by the branch
//! currents of voltage sources. The conductance part is symmetric positive
//! (semi-)definite; voltage sources add the usual ±1 border rows.

use super::matrix::{BandedMatrix, Matrix};
use super::netlist::{Netlist, NodeId, GROUND};

/// Solved operating point of a netlist.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Node voltages (index = NodeId; `v[GROUND] == 0`).
    pub v: Vec<f64>,
    /// Branch current through each voltage source (positive = flowing out
    /// of the `pos` terminal through the external circuit).
    pub vsource_i: Vec<f64>,
}

impl Solution {
    /// Voltage difference `v(a) − v(b)`.
    pub fn vdiff(&self, a: NodeId, b: NodeId) -> f64 {
        self.v[a] - self.v[b]
    }

    /// Current through a conductance `g` placed between `a` and `b`,
    /// flowing a → b.
    pub fn branch_current(&self, a: NodeId, b: NodeId, g: f64) -> f64 {
        self.vdiff(a, b) * g
    }
}

impl Netlist {
    /// Solve the network with a dense LU factorization.
    pub fn solve(&self) -> crate::Result<Solution> {
        let n = self.n_nodes() - 1; // ground eliminated
        let m = self.n_vsources();
        let dim = n + m;
        anyhow::ensure!(dim > 0, "nothing to solve");
        let mut a = Matrix::zeros(dim);
        let mut b = vec![0.0; dim];
        self.stamp(
            |r, c, v| a.add(r, c, v),
            |r, v| b[r] += v,
        );
        let x = a.solve(&b)?;
        Ok(self.unpack(&x))
    }

    /// Solve using the banded fast path. Correct whenever the MNA matrix's
    /// bandwidth under natural ordering is ≤ `half_bandwidth`; the crosspoint
    /// ladder builders guarantee this by allocating nodes row-major.
    pub fn solve_banded(&self, half_bandwidth: usize) -> crate::Result<Solution> {
        let n = self.n_nodes() - 1;
        let m = self.n_vsources();
        let dim = n + m;
        anyhow::ensure!(dim > 0, "nothing to solve");
        let mut a = BandedMatrix::zeros(dim, half_bandwidth);
        let mut b = vec![0.0; dim];
        self.stamp(
            |r, c, v| a.add(r, c, v),
            |r, v| b[r] += v,
        );
        let x = a.solve(&b)?;
        Ok(self.unpack(&x))
    }

    /// Stamp MNA entries through callbacks (shared by dense/banded paths).
    fn stamp(&self, mut mat: impl FnMut(usize, usize, f64), mut rhs: impl FnMut(usize, f64)) {
        let n = self.n_nodes() - 1;
        let idx = |node: NodeId| -> Option<usize> {
            if node == GROUND {
                None
            } else {
                Some(node - 1)
            }
        };
        for c in &self.conductances {
            let (ia, ib) = (idx(c.a), idx(c.b));
            if let Some(i) = ia {
                mat(i, i, c.g);
            }
            if let Some(j) = ib {
                mat(j, j, c.g);
            }
            if let (Some(i), Some(j)) = (ia, ib) {
                mat(i, j, -c.g);
                mat(j, i, -c.g);
            }
        }
        for s in &self.isources {
            if let Some(i) = idx(s.from) {
                rhs(i, -s.i);
            }
            if let Some(j) = idx(s.to) {
                rhs(j, s.i);
            }
        }
        for (k, vs) in self.vsources.iter().enumerate() {
            let row = n + k;
            if let Some(i) = idx(vs.pos) {
                mat(i, row, 1.0);
                mat(row, i, 1.0);
            }
            if let Some(j) = idx(vs.neg) {
                mat(j, row, -1.0);
                mat(row, j, -1.0);
            }
            rhs(row, vs.v);
        }
    }

    fn unpack(&self, x: &[f64]) -> Solution {
        let n = self.n_nodes() - 1;
        let mut v = vec![0.0; self.n_nodes()];
        v[1..].copy_from_slice(&x[..n]);
        // MNA convention: the extra unknown is the current flowing INTO the
        // pos terminal from the source; negate so positive = source driving
        // current out of pos into the external circuit.
        let vsource_i = x[n..].iter().map(|&i| -i).collect();
        Solution { v, vsource_i }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Voltage divider: 1 V across two equal 1 kΩ resistors.
    #[test]
    fn voltage_divider() {
        let mut nl = Netlist::new();
        let top = nl.node();
        let mid = nl.node();
        nl.voltage_source(top, GROUND, 1.0);
        nl.resistor(top, mid, 1e3);
        nl.resistor(mid, GROUND, 1e3);
        let sol = nl.solve().unwrap();
        assert!((sol.v[mid] - 0.5).abs() < 1e-12);
        // source current = 1 V / 2 kΩ = 0.5 mA
        assert!((sol.vsource_i[0] - 0.5e-3).abs() < 1e-12);
    }

    /// Current source into parallel resistors.
    #[test]
    fn current_into_parallel() {
        let mut nl = Netlist::new();
        let a = nl.node();
        nl.current_source(GROUND, a, 2e-3);
        nl.resistor(a, GROUND, 1e3);
        nl.resistor(a, GROUND, 1e3);
        let sol = nl.solve().unwrap();
        assert!((sol.v[a] - 1.0).abs() < 1e-12); // 2mA * 500Ω
    }

    /// Wheatstone bridge balance: zero volts across the detector.
    #[test]
    fn wheatstone_balanced() {
        let mut nl = Netlist::new();
        let top = nl.node();
        let l = nl.node();
        let r = nl.node();
        nl.voltage_source(top, GROUND, 1.0);
        nl.resistor(top, l, 1e3);
        nl.resistor(l, GROUND, 2e3);
        nl.resistor(top, r, 2e3);
        nl.resistor(r, GROUND, 4e3);
        nl.resistor(l, r, 5e3); // detector
        let sol = nl.solve().unwrap();
        assert!(sol.vdiff(l, r).abs() < 1e-12, "balanced bridge");
    }

    /// KCL at every internal node of a random ladder.
    #[test]
    fn kcl_holds() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(4);
        let mut nl = Netlist::new();
        let mut prev = GROUND;
        let mut nodes = vec![];
        for _ in 0..20 {
            let n = nl.node();
            nl.resistor(prev, n, rng.range_f64(10.0, 1e4));
            nl.resistor(n, GROUND, rng.range_f64(1e3, 1e6));
            nodes.push(n);
            prev = n;
        }
        let drive = nodes[0];
        nl.voltage_source(drive, GROUND, 1.0);
        let sol = nl.solve().unwrap();
        for &n in &nodes[1..] {
            let mut sum = 0.0;
            for c in &nl.conductances {
                if c.a == n {
                    sum -= sol.branch_current(c.a, c.b, c.g);
                } else if c.b == n {
                    sum += sol.branch_current(c.a, c.b, c.g);
                }
            }
            assert!(sum.abs() < 1e-12, "KCL violated at node {n}: {sum}");
        }
    }

    #[test]
    fn banded_agrees_with_dense_on_ladder() {
        let mut nl = Netlist::new();
        let mut prev = GROUND;
        for i in 0..50 {
            let n = nl.node();
            nl.resistor(prev, n, 100.0 + i as f64);
            nl.resistor(n, GROUND, 1e4);
            prev = n;
        }
        nl.current_source(GROUND, 1, 1e-3);
        let dense = nl.solve().unwrap();
        let banded = nl.solve_banded(2).unwrap();
        for (a, b) in dense.v.iter().zip(banded.v.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn floating_node_is_singular() {
        let mut nl = Netlist::new();
        let a = nl.node();
        let _floating = nl.node();
        nl.resistor(a, GROUND, 1e3);
        nl.current_source(GROUND, a, 1e-3);
        assert!(nl.solve().is_err());
    }
}

//! Multi-layer binary NN on two linked subarrays (paper §IV-D, Fig. 8).
//!
//! Layer 1 runs weights-stored / image-applied: `W1` (H×N) lives in the top
//! level of subarray 1; each image is applied as word-line pulses, its H
//! hidden bits are computed in one step and deposited — through the
//! BL-to-WLT link, which transposes — into one **row** of subarray 2's top
//! level. After `M` steps, subarray 2 holds the M×H hidden matrix, and
//! layer 2 runs in the weights-applied scheme (`P` steps for all M images).

use super::layer::BinaryLayer;
use crate::analysis::ArrayDesign;
use crate::array::{Level, Subarray, TmvmMode};
use crate::scaling::interlink::{LinkConfig, LinkedPair};

/// A functional binary MLP (one hidden layer).
#[derive(Clone, Debug)]
pub struct BinaryMlp {
    pub l1: BinaryLayer,
    pub l2: BinaryLayer,
}

impl BinaryMlp {
    pub fn new(l1: BinaryLayer, l2: BinaryLayer) -> Self {
        assert_eq!(l2.n_in(), l1.n_out(), "layer shape mismatch");
        Self { l1, l2 }
    }

    /// Functional forward pass (golden model).
    pub fn forward(&self, x: &[bool]) -> Vec<bool> {
        self.l2.forward(&self.l1.forward(x))
    }

    /// Functional classification through the hidden layer.
    pub fn argmax(&self, x: &[bool]) -> usize {
        self.l2.argmax(&self.l1.forward(x))
    }
}

/// The Fig. 8 two-subarray pipeline execution.
pub struct MlpOnSubarrays {
    pub pair: LinkedPair,
    pub mlp: BinaryMlp,
}

/// Result of a pipelined MLP batch.
#[derive(Clone, Debug)]
pub struct MlpBatchRun {
    /// `outputs[image][class]` hardware bits.
    pub outputs: Vec<Vec<bool>>,
    /// Total steps executed (M hidden steps + P output steps).
    pub steps: usize,
    /// Batch energy \[J\] across both subarrays.
    pub energy: f64,
    /// Batch wall-clock \[s\].
    pub time: f64,
    /// Any electrical violations?
    pub clean: bool,
}

impl MlpOnSubarrays {
    /// Build the pipeline: `W1` is programmed into subarray 1's top level.
    pub fn new(mlp: BinaryMlp, d1: ArrayDesign, d2: ArrayDesign) -> Self {
        assert!(mlp.l1.n_out() <= d1.n_row, "hidden units exceed sub1 rows");
        assert!(mlp.l1.n_in() <= d1.n_col, "inputs exceed sub1 columns");
        assert!(mlp.l1.n_out() <= d2.n_col, "hidden units exceed sub2 columns");
        assert!(mlp.l2.n_out() <= d2.n_col, "outputs exceed sub2 columns");
        let mut src = Subarray::new(d1);
        let dst = Subarray::new(d2);
        // program W1 (zero-padded) into subarray 1
        let mut grid = vec![vec![false; src.n_col()]; src.n_row()];
        for (h, w) in mlp.l1.weights.iter().enumerate() {
            grid[h][..w.len()].copy_from_slice(w);
        }
        src.program_level(Level::Top, &grid);
        Self {
            pair: LinkedPair::new(src, dst, LinkConfig::BlToWlt),
            mlp,
        }
    }

    /// Run a batch of `M ≤ sub2.n_row` images through the pipeline.
    pub fn run_batch(&mut self, images: &[Vec<bool>], mode: TmvmMode) -> MlpBatchRun {
        let m = images.len();
        assert!(m <= self.pair.dst.n_row(), "batch exceeds sub2 rows");
        let e0 = self.pair.src.ledger.energy + self.pair.dst.ledger.energy;
        let t0 = self.pair.src.ledger.time + self.pair.dst.ledger.time;
        let mut clean = true;

        // --- stage 1: M steps, one per image ---
        let v1 = self.pair.src.vdd_for_threshold(self.mlp.l1.theta);
        for (i, img) in images.iter().enumerate() {
            let mut inputs = vec![false; self.pair.src.n_col()];
            inputs[..img.len()].copy_from_slice(img);
            let rep = self.pair.tmvm_into(&inputs, i, v1, mode);
            clean &= rep.is_clean();
        }

        // --- stage 2: P steps, weights-applied over the hidden matrix ---
        let v2 = self.pair.dst.vdd_for_threshold(self.mlp.l2.theta);
        let p_out = self.mlp.l2.n_out();
        let mut step_reports = Vec::with_capacity(p_out);
        for (p, w) in self.mlp.l2.weights.iter().enumerate() {
            let mut inputs = vec![false; self.pair.dst.n_col()];
            inputs[..w.len()].copy_from_slice(w);
            let rep = self.pair.dst.tmvm(&inputs, p, v2, mode);
            clean &= rep.is_clean();
            step_reports.push(rep);
        }

        let outputs: Vec<Vec<bool>> = (0..m)
            .map(|i| (0..p_out).map(|p| step_reports[p].outputs[i]).collect())
            .collect();
        let e1 = self.pair.src.ledger.energy + self.pair.dst.ledger.energy;
        let t1 = self.pair.src.ledger.time + self.pair.dst.ledger.time;
        MlpBatchRun {
            outputs,
            steps: m + p_out,
            energy: e1 - e0,
            time: t1 - t0,
            clean,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::LineConfig;
    use crate::util::Pcg32;

    fn random_mlp(rng: &mut Pcg32, n_in: usize, n_hidden: usize, n_out: usize) -> BinaryMlp {
        let l1 = BinaryLayer::new(
            (0..n_hidden)
                .map(|_| (0..n_in).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            3,
        );
        let l2 = BinaryLayer::new(
            (0..n_out)
                .map(|_| (0..n_hidden).map(|_| rng.bernoulli(0.5)).collect())
                .collect(),
            2,
        );
        BinaryMlp::new(l1, l2)
    }

    #[test]
    fn pipeline_matches_functional_forward() {
        let mut rng = Pcg32::seeded(15);
        let mlp = random_mlp(&mut rng, 20, 12, 5);
        let images: Vec<Vec<bool>> = (0..8)
            .map(|_| (0..20).map(|_| rng.bernoulli(0.4)).collect())
            .collect();
        let d1 = ArrayDesign::new(16, 32, LineConfig::config3(), 3.0, 1.0);
        let d2 = ArrayDesign::new(8, 16, LineConfig::config3(), 3.0, 1.0);
        let mut pipe = MlpOnSubarrays::new(mlp.clone(), d1, d2);
        let run = pipe.run_batch(&images, TmvmMode::Ideal);
        assert!(run.clean);
        assert_eq!(run.steps, 8 + 5);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(run.outputs[i], mlp.forward(img), "image {i}");
        }
        assert!(run.energy > 0.0 && run.time > 0.0);
    }

    #[test]
    fn hidden_matrix_lands_transposed_in_sub2() {
        let mut rng = Pcg32::seeded(25);
        let mlp = random_mlp(&mut rng, 10, 6, 3);
        let images: Vec<Vec<bool>> = (0..4)
            .map(|_| (0..10).map(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let d1 = ArrayDesign::new(8, 16, LineConfig::config3(), 3.0, 1.0);
        let d2 = ArrayDesign::new(4, 8, LineConfig::config3(), 3.0, 1.0);
        let mlp2 = mlp.clone();
        let mut pipe = MlpOnSubarrays::new(mlp, d1, d2);
        pipe.run_batch(&images, TmvmMode::Ideal);
        for (i, img) in images.iter().enumerate() {
            let hidden = mlp2.l1.forward(img);
            for (h, &bit) in hidden.iter().enumerate() {
                assert_eq!(
                    pipe.pair.dst.peek(Level::Top, i, h),
                    bit,
                    "hidden[{i}][{h}]"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_layers_rejected() {
        let l1 = BinaryLayer::new(vec![vec![true; 4]; 3], 1);
        let l2 = BinaryLayer::new(vec![vec![true; 5]; 2], 1);
        let _ = BinaryMlp::new(l1, l2);
    }
}

//! Analytic Thevenin model of the worst-case corner circuit — paper §V and
//! Appendix A (Eqs. 8–13), generalized to an arbitrary victim row.
//!
//! Topology (single-rail fold of the symmetric WLT/WLB pair, Fig. 14): the
//! driver (source `V_DD`, series `2R_D` plus the lumped strap-via
//! resistance) feeds a ladder of `N_row` nodes separated by one word-line
//! step `r_step = 1/G_wlt + 1/G_wlb` (the paper's `2/G_y`). Every row hangs
//! a branch to ground: `span_cols` bit-line segments + input cell (`G_C`) +
//! output cell (`G_O`). The Thevenin equivalent is observed by the victim
//! row's own branch (which is removed from the network while observing).

use super::design::ArrayDesign;

/// Thevenin equivalent seen by the victim row's cells.
#[derive(Clone, Copy, Debug)]
pub struct LadderThevenin {
    /// Source resistance, *including* the victim row's bit-line path \[Ω\].
    pub r_th: f64,
    /// `α_th = V_th / V_DD` ∈ (0, 1].
    pub alpha: f64,
}

impl LadderThevenin {
    /// Current driven through the victim cells (load `r_load`, Ω) at a given
    /// applied `v_dd`.
    pub fn cell_current(&self, v_dd: f64, r_load: f64) -> f64 {
        self.alpha * v_dd / (self.r_th + r_load)
    }

    /// Voltage that must be applied at the driver for the victim cell
    /// current to reach `i_target` through `r_load`.
    pub fn required_vdd(&self, i_target: f64, r_load: f64) -> f64 {
        i_target * (self.r_th + r_load) / self.alpha
    }
}

/// Compute the analytic Thevenin equivalent at `victim_row`
/// (1-based; `victim_row == n_row` reproduces Appendix A exactly).
pub fn ladder_thevenin(design: &ArrayDesign, victim_row: usize) -> LadderThevenin {
    assert!(
        (1..=design.n_row).contains(&victim_row),
        "victim row {victim_row} out of 1..={}",
        design.n_row
    );
    let seg = design.segments();
    let r_step = seg.r_wl_step(); // 2/G_y
    let r_branch = design.branch_resistance(); // Eq. 8
    let r_bl = design.span_cols as f64 / seg.g_x;
    let r_drv = 2.0 * design.r_driver + seg.r_via; // R_0 = 2R_D (+ straps)
    let n = design.n_row;
    let v = victim_row;

    // --- upstream resistance: R_i = branch ‖ (R_{i-1} + r_step), R_0 = 2R_D
    // (Appendix A, Eqs. 9–10) ---
    let mut r_up = r_drv;
    for _ in 1..v {
        r_up = parallel(r_branch, r_up + r_step);
    }
    // Looking back from the victim node: one more WL step.
    let r_up_at_victim = r_up + r_step;

    // --- downstream resistance: rows v+1..n load the victim node too
    // (vanishes for the paper's victim = last row) ---
    let r_down_at_victim = if v == n {
        f64::INFINITY
    } else {
        let mut d = r_branch; // row n
        for _ in (v + 1..n).rev() {
            d = parallel(r_branch, d + r_step);
        }
        d + r_step
    };

    let r_node = parallel_maybe_inf(r_up_at_victim, r_down_at_victim);

    // --- open-circuit attenuation: per-step divider product from the
    // driver to the victim (Eqs. 11–13). Z_j = impedance to ground looking
    // into node j away from the driver, with the victim branch removed:
    //   Z_v = r_down_at_victim            (∞ when victim = last row)
    //   Z_j = branch ‖ (r_step + Z_{j+1}) for j < v
    //   α   = Z_1/(Z_1 + r_drv + r_step) · Π_{j=2..v} Z_j/(Z_j + r_step)
    let mut alpha = 1.0;
    let mut z = r_down_at_victim; // Z_v before branch fold
    for j in (1..=v).rev() {
        if j < v {
            // node j's own branch loads the line (the victim's is removed)
            z = parallel_maybe_inf(r_branch, z);
        }
        let series = if j == 1 { r_drv + r_step } else { r_step };
        let stage = if z.is_infinite() {
            1.0 // no current flows past this node: no drop across the step
        } else {
            z / (z + series)
        };
        alpha *= stage;
        if j > 1 {
            z += r_step; // step toward node j-1
        }
    }

    LadderThevenin {
        r_th: r_node + r_bl,
        alpha: alpha.clamp(0.0, 1.0),
    }
}

fn parallel(a: f64, b: f64) -> f64 {
    a * b / (a + b)
}

fn parallel_maybe_inf(a: f64, b: f64) -> f64 {
    if a.is_infinite() {
        b
    } else if b.is_infinite() {
        a
    } else {
        parallel(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::LineConfig;

    fn design(n_row: usize) -> ArrayDesign {
        ArrayDesign::new(n_row, 128, LineConfig::config1(), 4.0, 1.0)
    }

    #[test]
    fn single_row_ladder_is_driver_plus_step_plus_bl() {
        let d = design(1);
        let seg = d.segments();
        let th = ladder_thevenin(&d, 1);
        let expect = 2.0 * d.r_driver + seg.r_via + seg.r_wl_step() + d.span_cols as f64 / seg.g_x;
        assert!((th.r_th - expect).abs() / expect < 1e-12);
        assert!((th.alpha - 1.0).abs() < 1e-12, "open ladder: no drop");
    }

    #[test]
    fn alpha_decreases_with_rows() {
        let mut prev = 1.0;
        for n in [16, 64, 256, 1024] {
            let th = ladder_thevenin(&design(n), n);
            assert!(th.alpha < prev, "alpha must fall with N_row");
            assert!(th.alpha > 0.0);
            prev = th.alpha;
        }
    }

    #[test]
    fn first_row_beats_last_row() {
        // Under the worst-case loading (all rows conducting) even the first
        // row sees a driver-resistance drop, but it is always better off
        // than the last row — the NM window edges are ordered.
        let d = design(512);
        let first = ladder_thevenin(&d, 1);
        let last = ladder_thevenin(&d, 512);
        assert!(first.alpha > last.alpha);
        assert!(first.r_th < last.r_th);
        // with a stiff driver the first row approaches the ideal α = 1
        let stiff = design(512).with_driver(0.01);
        let first_stiff = ladder_thevenin(&stiff, 1);
        assert!(first_stiff.alpha > 0.95, "alpha = {}", first_stiff.alpha);
    }

    #[test]
    fn config3_beats_config1() {
        let d1 = ArrayDesign::new(512, 128, LineConfig::config1(), 4.0, 1.0);
        let d3 = ArrayDesign::new(512, 128, LineConfig::config3(), 4.0, 1.0);
        let t1 = ladder_thevenin(&d1, 512);
        let t3 = ladder_thevenin(&d3, 512);
        assert!(t3.alpha > t1.alpha, "{} vs {}", t3.alpha, t1.alpha);
    }

    #[test]
    fn required_vdd_roundtrips_cell_current() {
        let d = design(128);
        let th = ladder_thevenin(&d, 128);
        let r_load = 2.0 / d.device.g_c;
        let v = th.required_vdd(d.device.i_set, r_load);
        let i = th.cell_current(v, r_load);
        assert!((i - d.device.i_set).abs() / d.device.i_set < 1e-12);
    }

    #[test]
    fn downstream_loading_lowers_first_row_alpha() {
        // With many rows downstream, even the first row sees some drop
        // across the driver resistance.
        let th_short = ladder_thevenin(&design(1), 1);
        let th_long = ladder_thevenin(&design(2048), 1);
        assert!(th_long.alpha < th_short.alpha);
    }
}

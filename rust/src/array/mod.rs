//! The 3D XPoint subarray simulator: two stacked PCM levels, memory
//! operations, and the in-memory TMVM engine (paper §III), with
//! energy/latency accounting and the multi-bit schemes of §IV-C.

pub mod subarray;
pub mod tmvm;
pub mod energy;
pub mod multibit;

pub use energy::EnergyLedger;
pub use multibit::{multibit_tmvm_cost, MultibitCost, MultibitScheme};
pub use subarray::{Level, Subarray};
pub use tmvm::{ideal_row_current, TmvmMode, TmvmOutcome, TmvmReport};

//! Paper Fig. 10(b)/(c): R_th and α_th at the last row vs N_row, plus the
//! driver-resistance and output-loading ablations.
#[path = "harness/mod.rs"]
mod harness;

use harness::{bench, black_box, exhibit_header};
use xpoint_imc::analysis::{ladder_thevenin, ArrayDesign, OutputLoading};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::report::exhibits::fig10_series_loaded;
use xpoint_imc::util::si::format_si;
use xpoint_imc::util::Table;

const N_ROWS: [usize; 8] = [16, 32, 64, 128, 256, 512, 1024, 2048];

fn main() {
    exhibit_header("Paper Fig. 10 — Thevenin equivalents vs N_row (config 1, N_col=128)");

    for (loading, label) in [
        (OutputLoading::Preset, "outputs preset (G_O = G_A) — paper's start-of-SET state"),
        (OutputLoading::Set, "outputs crystalline (G_O = G_C) — worst-case loading"),
    ] {
        let mut t = Table::new(label).header(&["N_row", "R_th", "alpha_th"]);
        for row in fig10_series_loaded(&N_ROWS, 100.0, loading) {
            t.row(&[
                row.n_row.to_string(),
                format_si(row.r_th, "Ω"),
                format!("{:.4}", row.alpha),
            ]);
        }
        print!("{}", t.render());
    }

    // driver-resistance ablation (R_D is unpublished; show insensitivity)
    let mut t = Table::new("ablation: driver resistance R_D (N_row = 1024, preset)")
        .header(&["R_D", "R_th", "alpha_th"]);
    for r_d in [10.0, 100.0, 1000.0] {
        let row = &fig10_series_loaded(&[1024], r_d, OutputLoading::Preset)[0];
        t.row(&[
            format_si(r_d, "Ω"),
            format_si(row.r_th, "Ω"),
            format!("{:.4}", row.alpha),
        ]);
    }
    print!("{}", t.render());

    println!();
    let d = ArrayDesign::new(2048, 128, LineConfig::config1(), 4.0, 1.0);
    bench("ladder_thevenin(last row, N=2048)", || {
        black_box(ladder_thevenin(&d, 2048));
    });
    bench("full fig10 series (8 points)", || {
        black_box(fig10_series_loaded(&N_ROWS, 100.0, OutputLoading::Preset));
    });
}

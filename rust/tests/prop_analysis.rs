//! The paper's analytic parasitic model vs exact circuit simulation, over
//! randomized designs — the strongest correctness evidence in the repo:
//! the Appendix-A recursion must agree with full MNA nodal analysis to
//! ~1e-9 relative error on every randomized design.

use xpoint_imc::analysis::corner_circuit::build_corner_circuit;
use xpoint_imc::analysis::{
    ladder_thevenin, max_rows_for_nm, noise_margin, ArrayDesign, OutputLoading,
};
use xpoint_imc::interconnect::LineConfig;
use xpoint_imc::testing::{forall, Config};
use xpoint_imc::util::Pcg32;

fn random_design(rng: &mut Pcg32) -> ArrayDesign {
    let config = match rng.range(0, 3) {
        0 => LineConfig::config1(),
        1 => LineConfig::config2(),
        _ => LineConfig::config3(),
    };
    let n_row = rng.range(1, 48);
    let n_col = rng.range(1, 256);
    let span = rng.range(1, n_col + 1);
    let d = ArrayDesign::new(
        n_row,
        n_col,
        config,
        rng.range_f64(1.0, 8.0),
        rng.range_f64(1.0, 4.0),
    )
    .with_driver(rng.range_f64(1.0, 2e3))
    .with_span(span);
    if rng.bernoulli(0.5) {
        d.with_loading(OutputLoading::Preset)
    } else {
        d
    }
}

#[test]
fn analytic_thevenin_equals_mna() {
    forall(Config::default().cases(80), "recursion == MNA", |rng| {
        let d = random_design(rng);
        let victim = rng.range(1, d.n_row + 1);
        let ana = ladder_thevenin(&d, victim);
        let cc = build_corner_circuit(&d, victim, 1.0, false);
        let num = cc.thevenin().map_err(|e| e.to_string())?;
        let seg = d.segments();
        let num_r = num.r_th + d.span_cols as f64 / seg.g_x;
        let r_err = (ana.r_th - num_r).abs() / num_r.abs().max(1e-9);
        if r_err > 1e-8 {
            return Err(format!(
                "R_th mismatch {:.6e} vs {:.6e} (err {r_err:e}, victim {victim}/{})",
                ana.r_th, num_r, d.n_row
            ));
        }
        let a_err = (ana.alpha - num.v_th).abs();
        if a_err > 1e-8 {
            return Err(format!(
                "alpha mismatch {:.9} vs {:.9} (victim {victim}/{})",
                ana.alpha, num.v_th, d.n_row
            ));
        }
        Ok(())
    });
}

#[test]
fn loaded_victim_current_matches_prediction() {
    forall(Config::default().cases(40), "loaded current", |rng| {
        let d = random_design(rng);
        let victim = rng.range(1, d.n_row + 1);
        let v_dd = rng.range_f64(0.2, 1.5);
        let ana = ladder_thevenin(&d, victim);
        let r_cells = 1.0 / d.device.g_c + 1.0 / d.output_conductance();
        let i_pred = ana.cell_current(v_dd, r_cells);
        let cc = build_corner_circuit(&d, victim, v_dd, true);
        let sol = cc.netlist.solve().map_err(|e| e.to_string())?;
        let mid = cc.victim_mid.expect("victim branch included");
        let i_num = sol.vdiff(mid, cc.victim_wlb) * d.output_conductance();
        let err = (i_pred - i_num).abs() / i_num.abs().max(1e-15);
        if err > 1e-8 {
            return Err(format!("current mismatch: {i_pred:e} vs {i_num:e}"));
        }
        Ok(())
    });
}

#[test]
fn alpha_is_monotone_in_victim_depth() {
    forall(Config::default().cases(30), "alpha monotone", |rng| {
        let mut d = random_design(rng);
        d.n_row = rng.range(4, 40);
        let mut prev = f64::INFINITY;
        for v in 1..=d.n_row {
            let th = ladder_thevenin(&d, v);
            if th.alpha > prev + 1e-12 {
                return Err(format!("alpha increased at victim {v}"));
            }
            prev = th.alpha;
        }
        Ok(())
    });
}

#[test]
fn nm_is_monotone_decreasing_in_rows() {
    forall(Config::default().cases(20), "NM monotone", |rng| {
        let template = random_design(rng);
        let mut prev = f64::INFINITY;
        for n in [4usize, 16, 64, 256, 1024] {
            let mut d = template.clone();
            d.n_row = n;
            let nm = noise_margin(&d).noise_margin();
            if nm > prev + 1e-9 {
                return Err(format!("NM increased at N_row={n}"));
            }
            prev = nm;
        }
        Ok(())
    });
}

#[test]
fn max_rows_search_is_tight() {
    forall(Config::default().cases(15), "maxsize tight", |rng| {
        let mut template = random_design(rng);
        template.n_row = 1;
        let target = rng.range_f64(0.0, 0.5);
        let max = max_rows_for_nm(&template, target);
        if max == 0 {
            return Ok(()); // even one row misses the target
        }
        let mut d = template.clone();
        d.n_row = max;
        if noise_margin(&d).noise_margin() < target {
            return Err(format!("NM below target at reported max {max}"));
        }
        if max < (1 << 24) {
            d.n_row = max + 1;
            if noise_margin(&d).noise_margin() >= target {
                return Err(format!("max {max} not tight"));
            }
        }
        Ok(())
    });
}

//! Energy and latency accounting for subarray operations.
//!
//! Model (documented in DESIGN.md §7): every computational step applies
//! `V_DD` across the engaged rows for `t_SET`; the energy booked per output
//! is `V_DD · I_row · t_SET` (the full current path: input cells, bit line,
//! output cell). Presets book a RESET pulse per output cell; reads book the
//! small read pulse. Wall-clock advances by the pulse durations, with
//! presets pipelined against the previous step when requested.

/// Running energy/latency ledger for a subarray (or a whole system).
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    /// Total energy \[J\].
    pub energy: f64,
    /// Total busy time \[s\].
    pub time: f64,
    /// Number of computational (TMVM) steps executed.
    pub steps: u64,
    /// Number of write pulses (SET + RESET).
    pub writes: u64,
    /// Number of read pulses.
    pub reads: u64,
}

impl EnergyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Book one TMVM step: per-row path energies at the applied voltage.
    pub fn book_step(&mut self, v_dd: f64, row_currents_sum: f64, t_set: f64) {
        self.energy += v_dd * row_currents_sum * t_set;
        self.time += t_set;
        self.steps += 1;
    }

    /// Book `n` preset (RESET) pulses; `pipelined` presets overlap the
    /// previous step and cost no extra wall-clock.
    pub fn book_preset(&mut self, n: u64, v: f64, i_reset: f64, t_reset: f64, pipelined: bool) {
        self.energy += n as f64 * v * i_reset * t_reset;
        if !pipelined {
            self.time += t_reset;
        }
        self.writes += n;
    }

    /// Book a single write pulse (program a weight).
    pub fn book_write(&mut self, v: f64, i: f64, t: f64) {
        self.energy += v * i * t;
        self.time += t;
        self.writes += 1;
    }

    /// Book `n` parallel read pulses (one wall-clock read slot).
    pub fn book_read(&mut self, n: u64, v: f64, i_read: f64, t_read: f64) {
        self.energy += n as f64 * v * i_read * t_read;
        self.time += t_read;
        self.reads += n;
    }

    /// Merge another ledger (e.g. per-worker ledgers into a system total).
    pub fn merge(&mut self, other: &EnergyLedger) {
        self.energy += other.energy;
        self.time = self.time.max(other.time); // parallel workers
        self.steps += other.steps;
        self.writes += other.writes;
        self.reads += other.reads;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_energy_is_vit() {
        let mut l = EnergyLedger::new();
        l.book_step(1.0, 500e-6, 80e-9);
        assert!((l.energy - 4e-11).abs() < 1e-20); // 40 pJ
        assert!((l.time - 80e-9).abs() < 1e-18);
        assert_eq!(l.steps, 1);
    }

    #[test]
    fn pipelined_preset_is_free_in_time() {
        let mut l = EnergyLedger::new();
        l.book_preset(10, 1.0, 100e-6, 15e-9, true);
        assert_eq!(l.time, 0.0);
        assert!(l.energy > 0.0);
        let mut l2 = EnergyLedger::new();
        l2.book_preset(10, 1.0, 100e-6, 15e-9, false);
        assert!(l2.time > 0.0);
        assert!((l2.energy - l.energy).abs() < 1e-20);
    }

    #[test]
    fn merge_takes_parallel_max_time() {
        let mut a = EnergyLedger::new();
        a.book_step(1.0, 1e-3, 80e-9);
        let mut b = EnergyLedger::new();
        b.book_step(1.0, 1e-3, 80e-9);
        b.book_step(1.0, 1e-3, 80e-9);
        a.merge(&b);
        assert_eq!(a.steps, 3);
        assert!((a.time - 160e-9).abs() < 1e-18, "max, not sum");
        assert!((a.energy - 3.0 * 8e-11).abs() < 1e-20);
    }
}

//! Autoscaling exhibit (beyond the paper's fixed-size tables): replay a
//! bursty offered-load trace against an elastic sharded engine and show
//! the queue-driven scale-up/scale-down decisions wave by wave — serving
//! shard count, backlog, the policy's decision, and every completed
//! lifecycle event (spawn / retire / budget veto) with its programming
//! cost.
//!
//! The replay is fully deterministic: offered load follows a
//! [`TrafficTrace`] (the default is the canonical burst; `--trace`
//! swaps in uniform / diurnal / multi-tenant generators or a recorded
//! JSON trace), every wave drains completely, and in-flight lifecycle
//! walks are settled before the wave is recorded — so the timeline (and
//! its `--json` form, which round-trips through [`crate::util::json`])
//! can be diffed across runs and machines in CI. Replaying *identical*
//! traces against different watermark policies is how policies are
//! judged.

use crate::coordinator::autoscale::{AutoscalePolicy, ScaleDecision};
use crate::coordinator::trace::TrafficTrace;
use crate::engine::{
    AutoscaleSpec, BackendKind, Engine, EngineSpec, ScaleEvent, ScaleEventKind, ShardState,
    ShardedEngine,
};
use crate::nn::dataset::{DigitGen, TEST_SEED};
use crate::util::json::Json;
use crate::util::si::{format_duration, format_si};
use crate::util::Table;

/// Default serving-shard floor of the exhibit.
pub const AUTOSCALE_MIN: usize = 1;

/// Default serving-shard ceiling of the exhibit.
pub const AUTOSCALE_MAX: usize = 4;

/// Offered load per wave, in batches — a burst that ramps, plateaus and
/// decays to silence, so the timeline crosses both watermarks (the
/// trailing idle waves are what lets the low watermark retire shards).
/// The canonical shape now lives in
/// [`trace::BURST_SHAPE`](crate::coordinator::trace::BURST_SHAPE); this
/// alias keeps the exhibit's historical name.
pub const AUTOSCALE_TRACE: [usize; 14] = crate::coordinator::trace::BURST_SHAPE;

/// One wave of the autoscale timeline.
#[derive(Clone, Debug)]
pub struct AutoscaleWaveRow {
    pub wave: usize,
    /// Images submitted this wave.
    pub offered: usize,
    /// Backlog (queued + in-flight images) at decision time.
    pub backlog: usize,
    /// Serving shards when the policy decided.
    pub serving_before: usize,
    /// The policy's decision ("up" | "down" | "hold").
    pub decision: &'static str,
    /// Lifecycle events completed during the wave.
    pub events: Vec<ScaleEvent>,
    /// Serving shards after the wave settled.
    pub serving_after: usize,
    /// Lifecycle state of every slot after the wave.
    pub states: Vec<ShardState>,
    /// Images drained this wave (every wave drains fully).
    pub images_done: usize,
}

/// Aggregate of the whole replay.
#[derive(Clone, Debug, Default)]
pub struct AutoscaleSummary {
    pub spawns: u64,
    pub retires: u64,
    pub vetoes: u64,
    /// Programming pulses spent on spawns.
    pub spawn_pulses: u64,
    /// Spawn-programming energy \[J\].
    pub spawn_energy: f64,
    /// Spawn-programming time \[s\].
    pub spawn_time: f64,
    /// Final cumulative wear per shard slot.
    pub wear: Vec<u64>,
}

fn decision_name(d: ScaleDecision) -> &'static str {
    match d {
        ScaleDecision::Hold => "hold",
        ScaleDecision::Up => "up",
        ScaleDecision::Down => "down",
    }
}

/// Drive any in-flight lifecycle walk to completion (deterministic
/// settling — live serving would keep going instead).
fn settle(engine: &mut ShardedEngine) -> crate::Result<()> {
    for _ in 0..100_000 {
        if engine.scale_settled() {
            return Ok(());
        }
        engine.wait_event(std::time::Duration::from_millis(1));
    }
    anyhow::bail!("autoscale exhibit: lifecycle walk never settled")
}

/// Run the exhibit against the canonical burst: replay
/// [`AUTOSCALE_TRACE`] (scaled by `batch` images per offered batch)
/// against an elastic engine bounded to `[min, max]` serving shards.
/// `pulse_budget` is the per-slot endurance budget (0 = unlimited).
/// Thin wrapper over [`autoscale_timeline_trace`] with
/// [`TrafficTrace::bursty`] — offered counts and digit streams are
/// byte-identical to what this exhibit has always replayed.
pub fn autoscale_timeline(
    min: usize,
    max: usize,
    batch: usize,
    pulse_budget: u64,
) -> crate::Result<(Vec<AutoscaleWaveRow>, AutoscaleSummary)> {
    // the exhibit's Ideal shards store one batch per subarray row set
    // (64 rows) — clamp like `serve --batch` does
    let batch = batch.clamp(1, 64);
    autoscale_timeline_trace(
        &TrafficTrace::bursty(TEST_SEED, batch),
        min,
        max,
        batch,
        pulse_budget,
    )
}

/// Run the exhibit on an arbitrary [`TrafficTrace`]: replay the trace's
/// offered load (each tenant's images drawn from its own seeded digit
/// stream, submitted in `batch`-sized chunks) against an elastic engine
/// bounded to `[min, max]` serving shards, evaluating the policy once
/// per wave. `pulse_budget` is the per-slot endurance budget (0 =
/// unlimited).
pub fn autoscale_timeline_trace(
    trace: &TrafficTrace,
    min: usize,
    max: usize,
    batch: usize,
    pulse_budget: u64,
) -> crate::Result<(Vec<AutoscaleWaveRow>, AutoscaleSummary)> {
    anyhow::ensure!(min >= 1 && min <= max, "need 1 <= min <= max shards");
    trace.validate().map_err(|e| anyhow::anyhow!("trace: {e}"))?;
    let batch = batch.clamp(1, 64);
    // the same watermark policy `serve --autoscale` derives, with a
    // 1-wave cooldown so the short trace shows both directions
    let auto = AutoscaleSpec {
        cooldown: 1,
        pulse_budget,
        ..AutoscaleSpec::for_batch(min, max, batch)
    };
    let spec = EngineSpec::new(BackendKind::Ideal)
        .with_layers(vec![super::table2::template_layer()])
        .with_batching(batch, 200)
        .with_autoscale(auto);
    let mut engine = spec.build_sharded()?;
    let mut policy = AutoscalePolicy::from_spec(&auto);

    // one seeded digit stream per tenant — replays regenerate identical
    // per-tenant request streams from the trace alone
    let mut gens: Vec<DigitGen> = (0..trace.n_tenants())
        .map(|t| DigitGen::new(trace.tenant_seed(t)))
        .collect();
    let mut rows = Vec::with_capacity(trace.n_waves());
    let mut summary = AutoscaleSummary::default();
    for wave in 0..trace.n_waves() {
        // offer the wave's load, tenant by tenant in batch-sized chunks
        let mut tickets = Vec::new();
        for (t, gen) in gens.iter_mut().enumerate() {
            let mut remaining = trace.waves[wave][t];
            while remaining > 0 {
                let n = remaining.min(batch);
                let images: Vec<Vec<bool>> = (0..n).map(|_| gen.next_sample().pixels).collect();
                tickets.push(engine.submit(images)?);
                remaining -= n;
            }
        }
        // evaluate the policy against the live backlog
        let load = engine.scale_load();
        let backlog = load.queued_images + load.in_flight_images;
        let serving_before = load.serving;
        let decision = policy.decide(&load);
        match decision {
            ScaleDecision::Up => {
                // a budget-exhausted fleet keeps serving at its size
                let _ = engine.spawn_shard();
            }
            ScaleDecision::Down => {
                let _ = engine.retire_shard();
            }
            ScaleDecision::Hold => {}
        }
        settle(&mut engine)?;
        // drain the wave fully (the replay is deterministic; live serving
        // overlaps waves instead)
        let mut images_done = 0usize;
        for t in tickets {
            let res = loop {
                match engine.poll(t)? {
                    Some(res) => break res,
                    None => engine.wait_event(std::time::Duration::from_millis(1)),
                }
            };
            images_done += res.bits.len();
        }
        let events = engine.take_scale_events();
        for ev in &events {
            match ev.kind {
                ScaleEventKind::Spawn { .. } => {
                    summary.spawns += 1;
                    summary.spawn_pulses += ev.pulses;
                    summary.spawn_energy += ev.energy;
                    summary.spawn_time += ev.time;
                }
                ScaleEventKind::Retire => summary.retires += 1,
                ScaleEventKind::Veto => summary.vetoes += 1,
            }
        }
        rows.push(AutoscaleWaveRow {
            wave,
            offered: trace.offered(wave),
            backlog,
            serving_before,
            decision: decision_name(decision),
            events,
            serving_after: engine.serving_shards(),
            states: engine.shard_states(),
            images_done,
        });
    }
    summary.wear = engine.shard_wear();
    Ok((rows, summary))
}

/// Render the timeline table.
pub fn autoscale_table(rows: &[AutoscaleWaveRow]) -> Table {
    let mut t = Table::new("Shard autoscaling — bursty trace, queue-driven spawn/retire")
        .header(&[
            "Wave", "Offered", "Backlog", "Serving", "Decision", "Events", "Done", "States",
        ]);
    for r in rows {
        let events = if r.events.is_empty() {
            "—".to_string()
        } else {
            r.events
                .iter()
                .map(|e| format!("{}#{}", e.kind.name(), e.shard))
                .collect::<Vec<_>>()
                .join(" ")
        };
        let states = r
            .states
            .iter()
            .map(|s| s.name())
            .collect::<Vec<_>>()
            .join("/");
        t.row(&[
            r.wave.to_string(),
            r.offered.to_string(),
            r.backlog.to_string(),
            format!("{}→{}", r.serving_before, r.serving_after),
            r.decision.to_string(),
            events,
            r.images_done.to_string(),
            states,
        ]);
    }
    t
}

/// One-line summary of what the elasticity cost.
pub fn autoscale_summary_line(s: &AutoscaleSummary) -> String {
    let wear = s
        .wear
        .iter()
        .map(|w| w.to_string())
        .collect::<Vec<_>>()
        .join("/");
    format!(
        "{} spawn(s) ({} pulses, {}, {}), {} retire(s), {} veto(es); wear per slot: {}",
        s.spawns,
        s.spawn_pulses,
        format_duration(s.spawn_time),
        format_si(s.spawn_energy, "J"),
        s.retires,
        s.vetoes,
        wear,
    )
}

/// The `--json` form: the whole timeline as a [`Json`] tree (stable key
/// order, so CI can diff scale-event timelines across runs). `trace` is
/// the name of the replayed [`TrafficTrace`], recorded so diffs across
/// policies are anchored to the workload they replayed.
pub fn autoscale_json(trace: &str, rows: &[AutoscaleWaveRow], summary: &AutoscaleSummary) -> Json {
    let waves = rows
        .iter()
        .map(|r| {
            Json::Obj(vec![
                ("wave".into(), Json::Num(r.wave as f64)),
                ("offered".into(), Json::Num(r.offered as f64)),
                ("backlog".into(), Json::Num(r.backlog as f64)),
                ("serving_before".into(), Json::Num(r.serving_before as f64)),
                ("decision".into(), Json::Str(r.decision.into())),
                (
                    "events".into(),
                    Json::Arr(
                        r.events
                            .iter()
                            .map(|e| {
                                Json::Obj(vec![
                                    ("kind".into(), Json::Str(e.kind.name().into())),
                                    ("shard".into(), Json::Num(e.shard as f64)),
                                    ("pulses".into(), Json::Num(e.pulses as f64)),
                                    (
                                        "serving_after".into(),
                                        Json::Num(e.serving_after as f64),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("serving_after".into(), Json::Num(r.serving_after as f64)),
                (
                    "states".into(),
                    Json::Arr(
                        r.states
                            .iter()
                            .map(|s| Json::Str(s.name().into()))
                            .collect(),
                    ),
                ),
                ("images_done".into(), Json::Num(r.images_done as f64)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("exhibit".into(), Json::Str("autoscale".into())),
        ("trace".into(), Json::Str(trace.into())),
        ("waves".into(), Json::Arr(waves)),
        (
            "summary".into(),
            Json::Obj(vec![
                ("spawns".into(), Json::Num(summary.spawns as f64)),
                ("retires".into(), Json::Num(summary.retires as f64)),
                ("vetoes".into(), Json::Num(summary.vetoes as f64)),
                ("spawn_pulses".into(), Json::Num(summary.spawn_pulses as f64)),
                ("spawn_energy_j".into(), Json::Num(summary.spawn_energy)),
                ("spawn_time_s".into(), Json::Num(summary.spawn_time)),
                (
                    "wear".into(),
                    Json::Arr(summary.wear.iter().map(|&w| Json::Num(w as f64)).collect()),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_scales_up_on_the_burst_and_back_down() {
        let (rows, summary) = autoscale_timeline(1, 3, 16, 0).unwrap();
        assert_eq!(rows.len(), AUTOSCALE_TRACE.len());
        for r in &rows {
            assert_eq!(r.images_done, r.offered, "every wave drains fully");
            assert!(
                (1..=3).contains(&r.serving_after),
                "wave {}: serving {} out of bounds",
                r.wave,
                r.serving_after
            );
        }
        let peak = rows.iter().map(|r| r.serving_after).max().unwrap();
        assert!(peak > 1, "the burst never scaled the fleet up");
        assert!(summary.spawns >= 1);
        assert!(summary.retires >= 1, "the decay never scaled back down");
        assert!(summary.spawn_pulses > 0 && summary.spawn_energy > 0.0);
        assert!(!summary.wear.is_empty());
        assert_eq!(
            rows.last().unwrap().serving_after,
            rows.last().unwrap().states.iter().filter(|&&s| s == ShardState::Serving).count()
        );
    }

    #[test]
    fn table_renders_every_wave() {
        let (rows, summary) = autoscale_timeline(1, 2, 8, 0).unwrap();
        let t = autoscale_table(&rows);
        assert_eq!(t.n_rows(), rows.len());
        let s = t.render();
        assert!(s.contains("Decision"), "{s}");
        let line = autoscale_summary_line(&summary);
        assert!(line.contains("spawn") && line.contains("wear"), "{line}");
    }

    /// Satellite pin: the `--json` exhibit output round-trips through
    /// `util::json` bit-for-bit (parse ∘ render is the identity, and
    /// rendering is a fixed point), and its schema is stable — this is
    /// what lets the CI bench job diff scale-event timelines across runs.
    #[test]
    fn json_snapshot_roundtrips_and_pins_the_schema() {
        let (rows, summary) = autoscale_timeline(1, 3, 16, 0).unwrap();
        let v = autoscale_json("bursty", &rows, &summary);
        let text = v.pretty();
        let parsed = Json::parse(&text).expect("exhibit JSON parses");
        assert_eq!(parsed, v, "parse ∘ pretty is the identity");
        assert_eq!(
            Json::parse(&parsed.render()).unwrap(),
            v,
            "compact form round-trips too"
        );
        // schema snapshot: exact top-level and per-wave key order
        match &v {
            Json::Obj(entries) => {
                let keys: Vec<&str> = entries.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(keys, vec!["exhibit", "trace", "waves", "summary"]);
            }
            other => panic!("expected object, got {other:?}"),
        }
        let wave0 = match v.get("waves") {
            Some(Json::Arr(waves)) => &waves[0],
            other => panic!("expected waves array, got {other:?}"),
        };
        match wave0 {
            Json::Obj(entries) => {
                let keys: Vec<&str> = entries.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(
                    keys,
                    vec![
                        "wave",
                        "offered",
                        "backlog",
                        "serving_before",
                        "decision",
                        "events",
                        "serving_after",
                        "states",
                        "images_done"
                    ]
                );
            }
            other => panic!("expected wave object, got {other:?}"),
        }
        // deterministic replay: a second run produces the identical JSON
        let (rows2, summary2) = autoscale_timeline(1, 3, 16, 0).unwrap();
        assert_eq!(
            autoscale_json("bursty", &rows2, &summary2).pretty(),
            text,
            "the replay is bit-deterministic"
        );
    }

    /// The legacy entry point is now a wrapper over the trace replay —
    /// pin that the bursty trace reproduces it exactly, offered counts
    /// and all.
    #[test]
    fn bursty_trace_reproduces_the_legacy_exhibit() {
        let (legacy_rows, legacy_summary) = autoscale_timeline(1, 3, 16, 0).unwrap();
        let trace = TrafficTrace::bursty(TEST_SEED, 16);
        let (rows, summary) = autoscale_timeline_trace(&trace, 1, 3, 16, 0).unwrap();
        assert_eq!(
            autoscale_json("bursty", &rows, &summary).pretty(),
            autoscale_json("bursty", &legacy_rows, &legacy_summary).pretty(),
        );
        for (r, &batches) in rows.iter().zip(AUTOSCALE_TRACE.iter()) {
            assert_eq!(r.offered, batches * 16);
        }
    }

    /// A multi-tenant trace replays deterministically too: every wave
    /// drains its full offered load and two runs agree byte-for-byte.
    #[test]
    fn multi_tenant_trace_replays_deterministically() {
        let trace = TrafficTrace::multi_tenant(TEST_SEED, 6, 24);
        let (rows, summary) = autoscale_timeline_trace(&trace, 1, 3, 8, 0).unwrap();
        assert_eq!(rows.len(), trace.n_waves());
        for r in &rows {
            assert_eq!(r.images_done, r.offered, "every wave drains fully");
            assert_eq!(r.offered, trace.offered(r.wave));
        }
        let (rows2, summary2) = autoscale_timeline_trace(&trace, 1, 3, 8, 0).unwrap();
        assert_eq!(
            autoscale_json(&trace.name, &rows2, &summary2).pretty(),
            autoscale_json(&trace.name, &rows, &summary).pretty(),
        );
    }

    #[test]
    fn invalid_traces_are_rejected() {
        let mut ragged = TrafficTrace::multi_tenant(TEST_SEED, 4, 8);
        ragged.waves[1].pop();
        let err = autoscale_timeline_trace(&ragged, 1, 2, 8, 0).unwrap_err();
        assert!(err.to_string().contains("trace"), "{err}");
    }
}
